"""Fig 4 reproduction: steady-state bus utilization vs transfer size for the
three memory systems (ideal / DDR3 / ultra-deep) x four DMAC configurations.
"""
from __future__ import annotations

import time

from repro.core.simulator import (
    MEMORY_CONFIGS,
    SimConfig,
    ideal_utilization,
    simulate,
)

SIZES = [32, 64, 128, 256, 512, 1024, 2048, 4096]
CONFIGS = [SimConfig.base(), SimConfig.speculation(), SimConfig.scaled(),
           SimConfig.logicore_ip()]


def run(csv_rows: list) -> dict:
    derived = {}
    for mem_name, latency in MEMORY_CONFIGS.items():
        for cfg in CONFIGS:
            t0 = time.perf_counter()
            utils = [simulate(cfg, latency, s).utilization for s in SIZES]
            us = (time.perf_counter() - t0) * 1e6 / len(SIZES)
            for s, u in zip(SIZES, utils):
                csv_rows.append((f"fig4_{mem_name}_{cfg.name}_{s}B", us,
                                 f"util={u:.4f};ideal={ideal_utilization(s):.4f}"))
            derived[(mem_name, cfg.name)] = utils
    # Headline ratios at 64 B (paper: 2.5x ideal, 1.7x/3.9x DDR3, >=3.6x deep)
    for mem_name, ours_cfg, paper in [
            ("ideal", "base", 2.5), ("ddr3", "base", 1.7),
            ("ddr3", "speculation", 3.9), ("ultra_deep", "scaled", 3.6)]:
        i = SIZES.index(64)
        ratio = derived[(mem_name, ours_cfg)][i] / \
            derived[(mem_name, "LogiCORE")][i]
        csv_rows.append((f"fig4_ratio64B_{mem_name}_{ours_cfg}", 0.0,
                         f"measured={ratio:.2f};paper={paper}"))
    return derived
