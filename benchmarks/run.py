"""Benchmark orchestrator. One module per paper table/figure; prints
``name,us_per_call,derived`` CSV (deliverable d) and regenerates BOTH
baseline artifacts from one entrypoint:

* ``BENCH_runtime.json`` — the runtime perf trajectory (launch latency,
  per-channel utilization, coalescer effectiveness);
* ``BENCH_perf.json``    — the gated scenario-sweep contract consumed by
  ``python -m repro.perf.gate`` (DESIGN.md §4).

``--seed`` threads one seed through every seeded generator, so the
deterministic sections of both documents regenerate bit-for-bit:
``python benchmarks/run.py --seed 0`` twice yields byte-identical
BENCH_perf.json (wall-clock fields in BENCH_runtime.json are excluded
from that claim and marked as such in the document).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

# Runnable as `python benchmarks/run.py` from anywhere: the script's
# parent (the repo root) must be importable for the benchmarks package.
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks import (  # noqa: E402
    bench_engine,
    bench_runtime,
    fig4_utilization,
    fig5_hitrate,
    roofline,
    table2_area,
    table4_latency,
)

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Regenerate every benchmark table/figure and both "
                    "BENCH_*.json baselines.")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for every deterministic generator "
                         "(baselines regenerate bit-for-bit)")
    ap.add_argument("--perf-mode", choices=("quick", "full", "skip"),
                    default="quick",
                    help="scenario-sweep size for BENCH_perf.json; "
                         "'skip' leaves the committed baseline untouched")
    ap.add_argument("--out-dir", type=pathlib.Path, default=REPO_ROOT,
                    help="where to write BENCH_*.json")
    args = ap.parse_args(argv)

    csv_rows: list = []
    fig4_utilization.run(csv_rows)
    fig5_hitrate.run(csv_rows)
    table2_area.run(csv_rows)
    table4_latency.run(csv_rows)
    bench_engine.run(csv_rows)
    runtime_metrics = bench_runtime.run(csv_rows, seed=args.seed)
    roofline.run(csv_rows)
    print("name,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.2f},{derived}")

    out = args.out_dir / "BENCH_runtime.json"
    runtime_metrics["seed"] = args.seed
    out.write_text(json.dumps(runtime_metrics, indent=2, sort_keys=True)
                   + "\n")
    print(f"wrote {out}")

    if args.perf_mode != "skip":
        from repro.perf.sweep import default_spec, run_sweep, write_doc
        perf_out = args.out_dir / "BENCH_perf.json"
        doc = run_sweep(default_spec(args.perf_mode, args.seed))
        write_doc(doc, str(perf_out))
        print(f"wrote {perf_out}: {len(doc['cells'])} cells "
              f"(mode={args.perf_mode}, seed={args.seed})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
