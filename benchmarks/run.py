"""Benchmark orchestrator. One module per paper table/figure; prints
``name,us_per_call,derived`` CSV (deliverable d) and writes the runtime
perf trajectory to BENCH_runtime.json for cross-PR comparison."""
from __future__ import annotations

import json
import pathlib
import sys

from benchmarks import (
    bench_engine,
    bench_runtime,
    fig4_utilization,
    fig5_hitrate,
    roofline,
    table2_area,
    table4_latency,
)


def main() -> None:
    csv_rows: list = []
    fig4_utilization.run(csv_rows)
    fig5_hitrate.run(csv_rows)
    table2_area.run(csv_rows)
    table4_latency.run(csv_rows)
    bench_engine.run(csv_rows)
    runtime_metrics = bench_runtime.run(csv_rows)
    roofline.run(csv_rows)
    print("name,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.2f},{derived}")

    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_runtime.json"
    out.write_text(json.dumps(runtime_metrics, indent=2, sort_keys=True))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
