"""Benchmark orchestrator. One module per paper table/figure; prints
``name,us_per_call,derived`` CSV (deliverable d) and regenerates BOTH
baseline artifacts from one entrypoint:

* ``BENCH_runtime.json`` — the runtime perf trajectory (launch latency,
  per-channel utilization, coalescer effectiveness);
* ``BENCH_perf.json``    — the gated scenario-sweep contract consumed by
  ``python -m repro.perf.gate`` (DESIGN.md §4).

``--seed`` threads one seed through every seeded generator, so the
deterministic sections of both documents regenerate bit-for-bit:
``python benchmarks/run.py --seed 0`` twice yields byte-identical
BENCH_perf.json (wall-clock fields in BENCH_runtime.json are excluded
from that claim and marked as such in the document).
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

# Runnable as `python benchmarks/run.py` from anywhere: the script's
# parent (the repo root) must be importable for the benchmarks package.
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))


def _apply_mesh_flag() -> None:
    """Honor ``--mesh N`` before anything imports jax.

    ``--xla_force_host_platform_device_count`` only takes effect if set
    before the XLA backend initializes, so the flag is peeked off argv at
    module import time (argparse validates it again later). The sharded
    cells regenerate bit-for-bit with or without real devices — the flag
    only controls whether shards get placed on a real CPU mesh, matching
    what CI's sharded lane exercises.
    """
    argv = sys.argv[1:]
    n = None
    for i, tok in enumerate(argv):
        try:
            if tok == "--mesh" and i + 1 < len(argv):
                n = int(argv[i + 1])
            elif tok.startswith("--mesh="):
                n = int(tok.split("=", 1)[1])
        except ValueError:
            return   # argparse will produce the real error message
    if n is None:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] = \
        f"{flags} --xla_force_host_platform_device_count={n}".strip()


_apply_mesh_flag()

from benchmarks import (  # noqa: E402
    bench_engine,
    bench_runtime,
    bench_sharded,
    bench_transforms,
    fig4_utilization,
    fig5_hitrate,
    roofline,
    table2_area,
    table4_latency,
)

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Regenerate every benchmark table/figure and both "
                    "BENCH_*.json baselines.")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for every deterministic generator "
                         "(baselines regenerate bit-for-bit)")
    ap.add_argument("--perf-mode", choices=("quick", "full", "skip"),
                    default="quick",
                    help="scenario-sweep size for BENCH_perf.json; "
                         "'skip' leaves the committed baseline untouched")
    ap.add_argument("--mesh", type=int, default=None, metavar="N",
                    help="emulate N host CPU devices "
                         "(--xla_force_host_platform_device_count) so the "
                         "sharded cells place shards on a real mesh, as "
                         "CI's sharded lane does; cells regenerate "
                         "bit-for-bit with or without it")
    ap.add_argument("--trace", metavar="OUT.trace.json",
                    help="also record a seeded serve+simulator lifecycle "
                         "trace (Perfetto/chrome://tracing JSON, DESIGN.md "
                         "§8); includes sharded migration-hop flow arrows "
                         "when --mesh >= 2")
    ap.add_argument("--transforms", action="store_true",
                    help="run only the in-flight transform A/B "
                         "(int8-quantized vs fp32 datapath, real both "
                         "legs) and exit nonzero unless int8 beats fp32 "
                         "on effective bandwidth at equal fidelity "
                         "tolerance with every transform plan fused; "
                         "this is the CI perf-gate job's transform lane")
    ap.add_argument("--sync-fabric", action="store_true",
                    help="escape hatch: run the sharded migration benches "
                         "through the synchronous blocking hop path "
                         "(fabric='sync', bit-identical to the pre-fabric "
                         "planner) instead of the async fabric "
                         "(DESIGN.md §10)")
    ap.add_argument("--no-translation-cache", action="store_true",
                    help="escape hatch: run the legacy uncached dispatch "
                         "path everywhere (runtime benches and the perf "
                         "sweep); the resulting BENCH_perf.json records "
                         "translation_cache_enabled=false")
    ap.add_argument("--no-iotlb", action="store_true",
                    help="escape hatch: drop the MMU/IOTLB cells from the "
                         "perf sweep (physical addressing only, as before "
                         "schema v8); the resulting BENCH_perf.json "
                         "records iotlb_enabled=false")
    ap.add_argument("--out-dir", type=pathlib.Path, default=REPO_ROOT,
                    help="where to write BENCH_*.json")
    args = ap.parse_args(argv)
    translation = not args.no_translation_cache

    if args.transforms:
        csv_rows: list = []
        metrics = bench_transforms.run(csv_rows, seed=args.seed)
        print("name,us_per_call,derived")
        for name, us, derived in csv_rows:
            print(f"{name},{us:.2f},{derived}")
        print(json.dumps(metrics, indent=2, sort_keys=True))
        failures = bench_transforms.check(metrics)
        for msg in failures:
            print(f"TRANSFORM A/B FAIL: {msg}", file=sys.stderr)
        if not failures:
            print("transform A/B: int8 beats fp32 at equal fidelity "
                  "tolerance; all transform plans fused")
        return 1 if failures else 0

    if args.mesh:
        import jax
        if len(jax.devices()) < args.mesh:
            # The pre-import peek reads sys.argv; a programmatic
            # main(argv=...) call (or an already-initialized backend)
            # cannot grow the device count retroactively — say so rather
            # than silently running unplaced.
            print(f"warning: --mesh {args.mesh} requested but only "
                  f"{len(jax.devices())} devices are visible; shards run "
                  "unplaced (metrics are unaffected)", file=sys.stderr)

    csv_rows: list = []
    fig4_utilization.run(csv_rows)
    fig5_hitrate.run(csv_rows)
    table2_area.run(csv_rows)
    table4_latency.run(csv_rows)
    bench_engine.run(csv_rows)
    runtime_metrics = bench_runtime.run(csv_rows, seed=args.seed,
                                        translation=translation)
    runtime_metrics["sharded"] = bench_sharded.run(
        csv_rows, seed=args.seed,
        fabric="sync" if args.sync_fabric else "async")
    roofline.run(csv_rows)
    print("name,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.2f},{derived}")

    out = args.out_dir / "BENCH_runtime.json"
    runtime_metrics["seed"] = args.seed
    out.write_text(json.dumps(runtime_metrics, indent=2, sort_keys=True)
                   + "\n")
    print(f"wrote {out}")

    if args.perf_mode != "skip":
        from repro.perf.sweep import default_spec, run_sweep, write_doc
        perf_out = args.out_dir / "BENCH_perf.json"
        doc = run_sweep(default_spec(args.perf_mode, args.seed,
                                     translation=translation,
                                     iotlb=not args.no_iotlb))
        write_doc(doc, str(perf_out))
        print(f"wrote {perf_out}: {len(doc['cells'])} cells "
              f"(mode={args.perf_mode}, seed={args.seed})")

    if args.trace:
        from repro.obs.record import main as record_trace
        rc = record_trace(["--out", args.trace, "--seed", str(args.seed),
                           "--mesh", str(args.mesh or 1)])
        if rc:
            return rc
    return 0


if __name__ == "__main__":
    sys.exit(main())
