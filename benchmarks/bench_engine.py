"""Framework benchmarks: JAX descriptor engine + kernel throughput (CPU).

Wall times are CPU-host numbers (interpret-mode kernels); the TPU-relevant
performance story is the roofline analysis (benchmarks/roofline.py).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chain import from_gather
from repro.core.engine import execute_blocked_2d
from repro.core.simulator import simulate_multichannel
from repro.kernels import descriptor_copy_op, moe_gather_op


def _time(fn, *args, iters=20):
    fn(*args)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(csv_rows: list) -> dict:
    rng = np.random.default_rng(0)
    out = {}
    for rows, unit in [(256, 256), (1024, 512)]:
        src = jnp.asarray(rng.standard_normal((rows, unit)), jnp.float32)
        dst = jnp.zeros((rows, unit), jnp.float32)
        idx = jnp.asarray(rng.permutation(rows), jnp.int32)
        d = from_gather(np.asarray(idx), 1)

        us = _time(lambda: execute_blocked_2d(
            type(d).create(idx, jnp.arange(rows), jnp.ones(rows)),
            src, dst)[0])
        gbps = rows * unit * 4 / (us / 1e6) / 1e9
        csv_rows.append((f"engine_blocked_{rows}x{unit}", us,
                         f"GB/s={gbps:.2f}"))
        out[f"blocked_{rows}x{unit}"] = gbps

        us = _time(lambda: descriptor_copy_op(
            idx, jnp.arange(rows, dtype=jnp.int32), src, dst))
        csv_rows.append((f"kernel_descriptor_copy_{rows}x{unit}", us,
                         "interpret_mode=True"))

        us = _time(lambda: moe_gather_op(idx, src))
        csv_rows.append((f"kernel_moe_gather_{rows}x{unit}", us,
                         "interpret_mode=True"))

    # Multi-channel cycle model: per-channel steady-state bus utilization.
    for n_ch in (2, 4):
        r = simulate_multichannel(n_ch, 13, 64, num_transfers=300)
        per = "/".join(f"{c.utilization:.3f}" for c in r.channels)
        csv_rows.append((f"sim_multichannel_{n_ch}ch_ddr3_64B", 0.0,
                         f"agg={r.aggregate_utilization:.3f} per={per}"))
        out[f"multichannel_{n_ch}ch"] = r.aggregate_utilization
    return out
