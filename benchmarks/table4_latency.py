"""Table IV reproduction: i-rf / rf-rb / r-w latencies vs LogiCORE."""
from __future__ import annotations

import time

from repro.core.simulator import table_iv


def run(csv_rows: list) -> dict:
    t0 = time.perf_counter()
    t = table_iv()
    us = (time.perf_counter() - t0) * 1e6
    for who in ("ours", "logicore"):
        for latency, val in t[who]["rf_rb"].items():
            paper = t["paper"][who]["rf_rb"][latency]
            csv_rows.append((f"table4_{who}_rfrb_L{latency}", us / 6,
                             f"measured={val:.0f};paper={paper}"))
        csv_rows.append((f"table4_{who}_irf", 0.0,
                         f"measured={t[who]['i_rf']};paper="
                         f"{t['paper'][who]['i_rf']}"))
    ours = t["ours"]["i_rf"] + t["ours"]["rf_rb"][13]
    lc = t["logicore"]["i_rf"] + t["logicore"]["rf_rb"][13]
    csv_rows.append(("table4_launch_latency_ratio", 0.0,
                     f"measured={lc/ours:.2f};paper=1.66"))
    return t
