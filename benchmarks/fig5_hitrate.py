"""Fig 5 reproduction: utilization under speculation misses (DDR3, 64 B)."""
from __future__ import annotations

import time

from repro.core.simulator import SimConfig, simulate

HIT_RATES = [0.0, 0.25, 0.5, 0.75, 1.0]


def run(csv_rows: list) -> dict:
    lc = simulate(SimConfig.logicore_ip(), 13, 64).utilization
    out = {}
    for h in HIT_RATES:
        t0 = time.perf_counter()
        r = simulate(SimConfig.speculation(), 13, 64, hit_rate=h)
        us = (time.perf_counter() - t0) * 1e6
        out[h] = r.utilization
        csv_rows.append((f"fig5_hit{int(h*100)}", us,
                         f"util={r.utilization:.4f};ratio_vs_logicore="
                         f"{r.utilization/lc:.2f};wasted_beats={r.wasted_beats}"))
    # Paper band: 1.65x..3.9x over LogiCORE across 0..100% hit rates.
    csv_rows.append(("fig5_band", 0.0,
                     f"min_ratio={out[0.0]/lc:.2f};max_ratio={out[1.0]/lc:.2f}"))
    return out
