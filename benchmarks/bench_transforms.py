"""In-flight transform A/B benchmark (``benchmarks/run.py --transforms``).

Runs the quantized-vs-identity datapath end to end, both legs for real:

* **Runtime leg** — the same seeded irregular chains are submitted twice
  through one :class:`repro.runtime.DMARuntime` (identity, then
  ``kv_int8``); the int8 leg must round-trip within the EF-int8 fidelity
  tolerance against the fp32 destination and every transform plan must be
  served by a transform-fused compiled executor.
* **Cycle-model leg** — the cached-artifact frontend at the same logical
  payload, charging full beats vs EF-int8-compressed beats; effective
  bandwidth (logical bytes per bus cycle) must strictly improve.

``check()`` returns the failure messages the CI perf-gate job turns into
a nonzero exit: the A/B is a hard claim (int8 beats fp32 at equal
fidelity tolerance), not a trend line.
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core.chain import from_segments
from repro.core.simulator import SimConfig, simulate
from repro.core.transform import kv8_roundtrip_np
from repro.optim.compress import BLOCK, compression_ratio
from repro.runtime import ChannelConfig, DMARuntime, SubmitRequest

#: Worst-case |dequant(quant(x)) - x| / max|x| of the per-block symmetric
#: EF-int8 scheme: half a quantization step at scale = max/127.
FIDELITY_TOL = 1.0 / 127.0


def _runtime_ab(seed: int, *, n_chains: int = 8, n_segments: int = 6,
                unit: int = 64) -> Dict[str, float]:
    rng = np.random.default_rng([seed, 0xAB])
    pool = 256 * unit
    rt = DMARuntime([ChannelConfig(name="ch0", tier="serial",
                                   ring_capacity=256, max_len=512)])
    src = rng.standard_normal(pool).astype(np.float32)
    rt.register_pool("src", jnp.asarray(src))
    n_slots = pool // unit
    results = {}
    for transform in (None, "kv_int8"):
        rt.register_pool("dst", jnp.zeros(pool, jnp.float32))
        chain_rng = np.random.default_rng([seed, 0xC4])
        for _ in range(n_chains):
            s = chain_rng.choice(n_slots, n_segments, replace=False)
            t = chain_rng.choice(n_slots, n_segments, replace=False)
            d = from_segments(s * unit, t * unit,
                              np.full(n_segments, unit, np.int64))
            rt.submit(SubmitRequest(chain=d, src_pool="src",
                                    dst_pool="dst", tier="serial",
                                    transform=transform))
        rt.drain_until_idle()
        results[transform or "identity"] = np.asarray(rt.pool("dst"))
    fp32, int8 = results["identity"], results["kv_int8"]
    moved = fp32 != 0
    err = float(np.max(np.abs(int8 - fp32))
                / max(float(np.max(np.abs(fp32))), 1e-12))
    # Oracle check: kv_int8 is pool-absolute, so every moved destination
    # element must sit on the numpy oracle's EF-int8 grid — same per-256
    # block, same scale, code off by at most one (device-vs-numpy scale
    # arithmetic differs at ULP level, which can flip codes right at
    # rounding boundaries). The value lookup maps each destination back
    # to its source element; continuous random floats make it unambiguous.
    oracle = kv8_roundtrip_np(src)
    order = np.argsort(src)
    src_idx = order[np.searchsorted(src[order], fp32[moved])]
    step = (np.abs(src).reshape(-1, BLOCK).max(axis=1) / 127.0)[src_idx // BLOCK]
    oracle_code_err = float(np.max(
        np.abs(int8[moved] - oracle[src_idx]) / np.maximum(step, 1e-12),
        initial=0.0))
    st = rt.translation_stats()
    return {
        "fidelity_max_rel_err": err,
        "oracle_elems_checked": int(moved.sum()),
        "oracle_code_err": oracle_code_err,
        "transform_fusion_hit_rate":
            float(st["translation.transform_fusion_hit_rate"]),
        "transform_lookups": int(st["translation.transform_lookups"]),
    }


def _cycle_ab(mem_latency: int = 13, nbytes: int = 1024,
              num_transfers: int = 512) -> Dict[str, float]:
    ratio = compression_ratio()
    fp32 = simulate(SimConfig.translated_frontend(), mem_latency, nbytes,
                    num_transfers=num_transfers)
    int8 = simulate(SimConfig.translated_frontend(), mem_latency, nbytes,
                    num_transfers=num_transfers, payload_ratio=ratio)
    bw_fp32 = num_transfers * nbytes / max(fp32.cycles, 1)
    bw_int8 = num_transfers * nbytes / max(int8.cycles, 1)
    return {
        "payload_ratio": float(ratio),
        "effective_bandwidth_fp32": float(bw_fp32),
        "effective_bandwidth_int8": float(bw_int8),
        "effective_bandwidth_gain": float(bw_int8 / max(bw_fp32, 1e-12)),
    }


def run(csv_rows: list, seed: int = 0) -> Dict[str, object]:
    runtime = _runtime_ab(seed)
    cycle = _cycle_ab()
    csv_rows.append(("transforms_kv_int8", 0.0,
                     f"gain={cycle['effective_bandwidth_gain']:.2f}x/"
                     f"fidelity={runtime['fidelity_max_rel_err']:.5f}/"
                     f"fusion={runtime['transform_fusion_hit_rate']:.2f}"))
    return {"runtime_ab": runtime, "cycle_ab": cycle}


def check(metrics: Dict[str, object]) -> List[str]:
    """Hard A/B assertions; each returned message is a CI failure."""
    failures = []
    gain = metrics["cycle_ab"]["effective_bandwidth_gain"]
    if gain <= 1.0:
        failures.append(
            f"int8 effective bandwidth does not beat fp32 (gain={gain:.3f})")
    err = metrics["runtime_ab"]["fidelity_max_rel_err"]
    if err > FIDELITY_TOL:
        failures.append(
            f"kv_int8 roundtrip error {err:.5f} exceeds the EF-int8 "
            f"fidelity tolerance {FIDELITY_TOL:.5f}")
    if err == 0.0:
        failures.append(
            "kv_int8 leg is bit-identical to fp32 — transform was skipped")
    fusion = metrics["runtime_ab"]["transform_fusion_hit_rate"]
    if fusion < 1.0:
        failures.append(
            f"transform plans not fully fused (hit rate {fusion:.2f})")
    code_err = metrics["runtime_ab"]["oracle_code_err"]
    if code_err > 1.0 + 1e-6:
        failures.append(
            f"kv_int8 datapath left the numpy EF-int8 oracle's grid "
            f"(max code error {code_err:.3f} steps)")
    return failures
