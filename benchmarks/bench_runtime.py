"""Runtime-layer benchmarks: launch latency, per-channel utilization,
coalescer effectiveness. Emits the machine-readable trajectory consumed by
``benchmarks/run.py`` (BENCH_runtime.json) so future PRs have a baseline.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.chain import from_segments
from repro.core.simulator import simulate_multichannel
from repro.runtime import SubmitRequest, coalesce, default_runtime


def _bench_launch(n_desc: int = 256, repeats: int = 5, seed: int = 0) -> dict:
    """Wall-clock submit cost per descriptor (the paper's launch latency).

    The workload is seeded, the reported microseconds are wall-clock — the
    descriptor/channel counters regenerate bit-for-bit, the timings do not
    (they live under the ``wall_clock`` key for that reason).
    """
    rt = default_runtime(4, tier="serial", ring_capacity=n_desc + 1,
                         max_len=64)
    pool = 1 << 16
    rng = np.random.default_rng(seed)
    rt.register_pool("src", jnp.zeros(pool, jnp.float32))
    rt.register_pool("dst", jnp.zeros(pool, jnp.float32))
    per_desc_us = []
    for _ in range(repeats):
        lens = rng.integers(1, 64, n_desc)
        srcs = rng.integers(0, pool - 64, n_desc)
        dsts = rng.integers(0, pool - 64, n_desc)
        d = from_segments(srcs, dsts, lens)
        t0 = time.perf_counter()
        rt.submit(SubmitRequest(chain=d, src_pool="src", dst_pool="dst"))
        per_desc_us.append((time.perf_counter() - t0) / n_desc * 1e6)
        rt.drain_until_idle()
    stats = rt.stats()
    # Every wall-clock value moves under wall_clock: runtime_stats must
    # regenerate bit-for-bit from the seed (same strip as the perf sweep's
    # _deterministic_counters).
    wall_us = stats.pop("launch_us_per_descriptor")
    drain_s = {name: ch.pop("drain_seconds")
               for name, ch in stats["channels"].items()}
    return {
        "descriptors_per_submit": n_desc,
        "runtime_stats": stats,
        "wall_clock": {
            "launch_us_per_descriptor_best": float(min(per_desc_us)),
            "launch_us_per_descriptor_mean": float(np.mean(per_desc_us)),
            "launch_us_per_descriptor": wall_us,
            "drain_seconds": drain_s,
        },
    }


def _bench_translation(n_desc: int = 256, warm_rounds: int = 5,
                       seed: int = 0, translation: bool = True) -> dict:
    """Cold-vs-warm dispatch through the chain-lowering JIT (DESIGN.md §7).

    One chain is dispatched cold (canonicalize + plan + lower + XLA
    compile all on the path) and then replayed ``warm_rounds`` times, the
    serve-shaped pattern the translation cache exists for. Timings are
    wall-clock and live under ``wall_clock``; the cache counters are
    deterministic event counts and stored alongside.
    """
    rt = default_runtime(1, tier="serial", ring_capacity=n_desc + 1,
                         max_len=64, translation=translation)
    pool = 1 << 16
    rng = np.random.default_rng(seed + 2)
    rt.register_pool("src", jnp.zeros(pool, jnp.float32))
    rt.register_pool("dst", jnp.zeros(pool, jnp.float32))
    lens = rng.integers(1, 64, n_desc)
    srcs = rng.integers(0, pool - 64, n_desc)
    dsts = rng.integers(0, pool - 64, n_desc)
    d = from_segments(srcs, dsts, lens)

    def dispatch_us() -> float:
        t0 = time.perf_counter()
        rt.submit(SubmitRequest(chain=d, src_pool="src", dst_pool="dst"))
        rt.drain_until_idle()
        return (time.perf_counter() - t0) / n_desc * 1e6

    cold = dispatch_us()
    warm = [dispatch_us() for _ in range(warm_rounds)]
    return {
        "descriptors_per_submit": n_desc,
        "warm_rounds": warm_rounds,
        "translation_enabled": translation,
        "counters": dict(rt.translation_stats()),
        "wall_clock": {
            "cold_dispatch_us_per_descriptor": float(cold),
            "warm_dispatch_us_mean": float(np.mean(warm)),
            "warm_dispatch_us_best": float(np.min(warm)),
            "cold_over_warm_best": float(cold / max(min(warm), 1e-9)),
        },
    }


def _bench_tracing(n_desc: int = 256, rounds: int = 5, seed: int = 0) -> dict:
    """Dispatch cost with the tracer detached / attached-but-sampled-out /
    fully recording (DESIGN.md §8).

    The observability contract is off-by-default-cheap: every hook site is
    one attribute test when no tracer is attached, and one sampling hash
    when one is attached at rate 0. ``tracing_off_overhead_ratio`` is the
    metric the overhead guard test bounds (<= 2%) and the wall-clock trend
    lane watches; rounds interleave the three variants so machine noise
    hits them equally.
    """
    from repro.obs.trace import Tracer

    pool = 1 << 16
    rng = np.random.default_rng(seed + 3)
    lens = rng.integers(1, 64, n_desc)
    srcs = rng.integers(0, pool - 64, n_desc)
    dsts = rng.integers(0, pool - 64, n_desc)
    d = from_segments(srcs, dsts, lens)

    def make_rt(tracer):
        rt = default_runtime(2, tier="serial", ring_capacity=n_desc + 1,
                             max_len=64)
        rt.register_pool("src", jnp.zeros(pool, jnp.float32))
        rt.register_pool("dst", jnp.zeros(pool, jnp.float32))
        if tracer is not None:
            rt.attach_tracer(tracer)
        return rt

    def dispatch_us(rt) -> float:
        t0 = time.perf_counter()
        rt.submit(SubmitRequest(chain=d, src_pool="src", dst_pool="dst"))
        rt.drain_until_idle()
        return (time.perf_counter() - t0) / n_desc * 1e6

    variants = {
        "none": make_rt(None),
        "off": make_rt(Tracer(sample_rate=0.0, seed=seed)),
        "on": make_rt(Tracer(sample_rate=1.0, seed=seed)),
    }
    for rt in variants.values():      # warm the translation caches
        dispatch_us(rt)
    us = {k: [] for k in variants}
    for _ in range(rounds):
        for k, rt in variants.items():
            us[k].append(dispatch_us(rt))
    best = {k: float(np.min(v)) for k, v in us.items()}
    return {
        "descriptors_per_submit": n_desc,
        "rounds": rounds,
        "wall_clock": {
            "dispatch_us_tracing_none_best": best["none"],
            "dispatch_us_tracing_off_best": best["off"],
            "dispatch_us_tracing_on_best": best["on"],
            "tracing_off_overhead_ratio":
                best["off"] / max(best["none"], 1e-9),
            "tracing_on_overhead_ratio":
                best["on"] / max(best["none"], 1e-9),
        },
    }


def _bench_channels(mem_latency: int = 13, transfer_bytes: int = 64) -> dict:
    out = {}
    for n in (1, 2, 4, 8):
        r = simulate_multichannel(n, mem_latency, transfer_bytes,
                                  num_transfers=300)
        out[f"{n}ch"] = {
            "aggregate_utilization": r.aggregate_utilization,
            "ideal": r.ideal,
            "per_channel": {c.channel: c.utilization for c in r.channels},
        }
    return out


def _bench_coalescer(pages: int = 256, page_elems: int = 16,
                     seed: int = 0) -> dict:
    """Contiguous-page workload: the planner should fuse page runs."""
    # A block table whose pages mostly landed sequentially (the allocator's
    # sequential preference), with a few fragmentation breaks.
    rng = np.random.default_rng(seed + 1)
    page_ids = []
    next_id = 0
    while len(page_ids) < pages:
        run = int(rng.integers(4, 32))
        page_ids.extend(range(next_id, next_id + run))
        next_id += run + int(rng.integers(1, 4))   # fragmentation gap
    page_ids = page_ids[:pages]
    src = np.asarray(page_ids, np.int64) * page_elems
    dst = np.arange(pages, dtype=np.int64) * page_elems
    d = from_segments(src, dst, np.full(pages, page_elems, np.int64))
    _, stats = coalesce(d, max_len=1 << 20)
    return {
        "n_in": stats.n_in,
        "n_out": stats.n_out,
        "merge_ratio": stats.merge_ratio,
        "input_hit_rate": stats.input_hit_rate,
        "output_hit_rate": stats.output_hit_rate,
    }


def run(csv_rows: list, seed: int = 0, translation: bool = True) -> dict:
    launch = _bench_launch(seed=seed)
    chans = _bench_channels()
    coal = _bench_coalescer(seed=seed)
    trans = _bench_translation(seed=seed, translation=translation)
    tracing = _bench_tracing(seed=seed)
    wall = launch["wall_clock"]
    csv_rows.append(("runtime_launch_per_desc",
                     wall["launch_us_per_descriptor_best"],
                     f"mean={wall['launch_us_per_descriptor_mean']:.2f}us"))
    for key, c in chans.items():
        csv_rows.append((f"runtime_bus_util_{key}",
                         0.0,
                         f"agg={c['aggregate_utilization']:.3f}/"
                         f"ideal={c['ideal']:.3f}"))
    csv_rows.append(("runtime_coalesce", 0.0,
                     f"merge_ratio={coal['merge_ratio']:.2f}"))
    twall = trans["wall_clock"]
    csv_rows.append(("runtime_translation_dispatch",
                     twall["warm_dispatch_us_best"],
                     f"cold={twall['cold_dispatch_us_per_descriptor']:.2f}us/"
                     f"warm={twall['warm_dispatch_us_mean']:.2f}us"))
    trwall = tracing["wall_clock"]
    csv_rows.append(("runtime_tracing_dispatch",
                     trwall["dispatch_us_tracing_off_best"],
                     f"off/none={trwall['tracing_off_overhead_ratio']:.3f}/"
                     f"on/none={trwall['tracing_on_overhead_ratio']:.3f}"))
    return {
        "launch": launch,
        "channels": chans,
        "coalescer": coal,
        "translation": trans,
        "tracing": tracing,
    }
