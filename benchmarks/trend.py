"""Wall-clock trend tracking: append BENCH_runtime wall-clock to a series.

The perf gate deliberately excludes wall-clock launch latency — it is not
deterministic, so gating it would make CI flaky (DESIGN.md §4). It still
matters (the paper's 1.66x launch-latency claim is a wall-clock claim), so
CI *tracks* it instead: every run appends the ``wall_clock`` section of
``BENCH_runtime.json`` to a JSON-lines series that is cached between runs
and uploaded as an artifact (``wall_clock_trend.jsonl``).

Sustained drift produces a GitHub ``::warning::`` annotation — visible on
the run, never red: alerting, not gating.

Usage::

    python benchmarks/trend.py --bench BENCH_runtime.json \\
        --series wall_clock_trend.jsonl [--sha SHA] [--run-id ID]
"""
from __future__ import annotations

import argparse
import datetime
import json
import pathlib
import sys
from typing import List, Optional, Sequence

#: The headline wall-clock scalar the drift alert watches.
DRIFT_METRIC = "launch_us_per_descriptor_mean"
#: Alert when the newest point exceeds the median of the trailing window
#: by this factor in every one of the last ``DRIFT_RUNS`` runs.
DRIFT_FACTOR = 1.5
DRIFT_RUNS = 3
DRIFT_WINDOW = 10


def append_point(series_path: pathlib.Path, bench: dict, *,
                 sha: str = "", run_id: str = "") -> dict:
    """Append one observation; returns the appended record."""
    wall = bench.get("runtime", {}).get("wall_clock") \
        or bench.get("wall_clock")
    if not wall:
        # Search one level deep: run.py nests sections by benchmark name.
        for section in bench.values():
            if isinstance(section, dict) and "wall_clock" in section:
                wall = section["wall_clock"]
                break
    if not wall:
        raise SystemExit("no wall_clock section in the bench document")
    record = {
        "sha": sha,
        "run_id": run_id,
        "recorded_at":
            datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "seed": bench.get("seed"),
        "wall_clock": wall,
    }
    with open(series_path, "a") as f:
        f.write(json.dumps(record, sort_keys=True) + "\n")
    return record


def load_series(series_path: pathlib.Path) -> List[dict]:
    if not series_path.exists():
        return []
    out = []
    for line in series_path.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError:
            # A truncated cache restore must not kill trend tracking.
            print(f"::warning::{series_path}: skipping corrupt line",
                  file=sys.stderr)
    return out


def _metric(rec: dict) -> Optional[float]:
    v = rec.get("wall_clock", {}).get(DRIFT_METRIC)
    return float(v) if isinstance(v, (int, float)) else None


def check_drift(series: List[dict]) -> Optional[str]:
    """Alert text when the last DRIFT_RUNS points all sit DRIFT_FACTOR
    above the trailing-window median — sustained drift, not one noisy run."""
    points = [m for m in (_metric(r) for r in series) if m is not None]
    if len(points) < DRIFT_RUNS + 1:
        return None
    recent = points[-DRIFT_RUNS:]
    window = points[-(DRIFT_WINDOW + DRIFT_RUNS):-DRIFT_RUNS]
    if not window:
        return None
    baseline = sorted(window)[len(window) // 2]
    if baseline <= 0:
        return None
    if all(p > DRIFT_FACTOR * baseline for p in recent):
        return (f"sustained wall-clock drift: last {DRIFT_RUNS} runs of "
                f"{DRIFT_METRIC} ({', '.join(f'{p:.2f}' for p in recent)} us)"
                f" all exceed {DRIFT_FACTOR}x the trailing median "
                f"({baseline:.2f} us)")
    return None


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Append BENCH_runtime wall-clock to a trend series "
                    "and alert (never fail) on sustained drift.")
    ap.add_argument("--bench", default="BENCH_runtime.json")
    ap.add_argument("--series", default="wall_clock_trend.jsonl")
    ap.add_argument("--sha", default="")
    ap.add_argument("--run-id", default="")
    args = ap.parse_args(argv)

    bench = json.loads(pathlib.Path(args.bench).read_text())
    series_path = pathlib.Path(args.series)
    record = append_point(series_path, bench, sha=args.sha,
                          run_id=args.run_id)
    series = load_series(series_path)
    print(f"appended point {len(series)} to {series_path}: "
          f"{DRIFT_METRIC}={_metric(record)}")
    alert = check_drift(series)
    if alert:
        # GitHub annotation — visible on the run, but exit 0: tracked,
        # never gated (ROADMAP: wall-clock trend tracking).
        print(f"::warning::{alert}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
