"""Wall-clock trend tracking: append BENCH_runtime wall-clock to a series.

The perf gate deliberately excludes wall-clock launch latency — it is not
deterministic, so gating it would make CI flaky (DESIGN.md §4). It still
matters (the paper's 1.66x launch-latency claim is a wall-clock claim), so
CI *tracks* it instead: every run appends the ``wall_clock`` section of
``BENCH_runtime.json`` to a JSON-lines series that is cached between runs
and uploaded as an artifact (``wall_clock_trend.jsonl``).

Sustained drift produces a GitHub ``::warning::`` annotation — visible on
the run, never red: alerting, not gating.

Usage::

    python benchmarks/trend.py --bench BENCH_runtime.json \\
        --series wall_clock_trend.jsonl [--sha SHA] [--run-id ID]
"""
from __future__ import annotations

import argparse
import datetime
import json
import pathlib
import sys
from typing import List, Optional, Sequence

#: The wall-clock scalars the drift alert watches: submit launch cost,
#: the warm-path dispatch cost through the chain-lowering translation
#: cache (DESIGN.md §7) — the serve hot path's steady state — and the
#: disabled-tracer dispatch overhead ratio (DESIGN.md §8: hook sites must
#: stay one attribute test; a creeping ratio means someone put work on
#: the tracing-off path).
DRIFT_METRICS = ("launch_us_per_descriptor_mean", "warm_dispatch_us_mean",
                 "tracing_off_overhead_ratio", "resize_mesh4_seconds",
                 "migration_overlap_ratio_mesh4", "tlb_hit_rate_L13",
                 "first_touch_latency_rounds_mesh4")
#: Metrics where *higher* is better: the drift check inverts for these,
#: alerting when recent points all fall DRIFT_FACTOR *below* the trailing
#: median. ``migration_overlap_ratio_mesh4`` is deterministic (DESIGN.md
#: §10) and so are the two virtual-addressing series (DESIGN.md §11):
#: ``tlb_hit_rate_L13`` (IOTLB hit rate of the DDR3 MMU cell — a drop
#: means translation prefetch detached from the §II-C stream) and
#: ``first_touch_latency_rounds_mesh4`` (fabric rounds from ownership
#: flip to residency — a rise means lazy pulls stopped being lazy).
HIGHER_IS_BETTER = frozenset({"migration_overlap_ratio_mesh4",
                              "tlb_hit_rate_L13"})
#: Headline metric echoed when a point is appended.
DRIFT_METRIC = DRIFT_METRICS[0]
#: Alert when the newest point exceeds the median of the trailing window
#: by this factor in every one of the last ``DRIFT_RUNS`` runs.
DRIFT_FACTOR = 1.5
DRIFT_RUNS = 3
DRIFT_WINDOW = 10


def _collect_wall_clock(bench: dict) -> dict:
    """Merge every ``wall_clock`` section, searching one level deep.

    run.py nests sections by benchmark name (``launch``, ``translation``,
    …); each contributes scalars to one flat record so every drift metric
    is trackable from a single series line. Key collisions are a document
    bug — later sections win, which keeps tracking alive either way.
    """
    wall: dict = {}
    if isinstance(bench.get("wall_clock"), dict):
        wall.update(bench["wall_clock"])
    for section in bench.values():
        if isinstance(section, dict) \
                and isinstance(section.get("wall_clock"), dict):
            wall.update(section["wall_clock"])
    return wall


def append_point(series_path: pathlib.Path, bench: dict, *,
                 sha: str = "", run_id: str = "") -> dict:
    """Append one observation; returns the appended record."""
    wall = _collect_wall_clock(bench)
    if not wall:
        raise SystemExit("no wall_clock section in the bench document")
    record = {
        "sha": sha,
        "run_id": run_id,
        "recorded_at":
            datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "seed": bench.get("seed"),
        "wall_clock": wall,
    }
    with open(series_path, "a") as f:
        f.write(json.dumps(record, sort_keys=True) + "\n")
    return record


def load_series(series_path: pathlib.Path) -> List[dict]:
    if not series_path.exists():
        return []
    out = []
    for line in series_path.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError:
            # A truncated cache restore must not kill trend tracking.
            print(f"::warning::{series_path}: skipping corrupt line",
                  file=sys.stderr)
    return out


def _metric(rec: dict, name: str = DRIFT_METRIC) -> Optional[float]:
    v = rec.get("wall_clock", {}).get(name)
    return float(v) if isinstance(v, (int, float)) else None


def _check_one(series: List[dict], name: str) -> Optional[str]:
    points = [m for m in (_metric(r, name) for r in series) if m is not None]
    if len(points) < DRIFT_RUNS + 1:
        return None
    recent = points[-DRIFT_RUNS:]
    window = points[-(DRIFT_WINDOW + DRIFT_RUNS):-DRIFT_RUNS]
    if not window:
        return None
    baseline = sorted(window)[len(window) // 2]
    if baseline <= 0:
        return None
    if name in HIGHER_IS_BETTER:
        if all(p < baseline / DRIFT_FACTOR for p in recent):
            return (f"sustained drift: last {DRIFT_RUNS} runs of {name} "
                    f"({', '.join(f'{p:.2f}' for p in recent)}) all fell "
                    f"below 1/{DRIFT_FACTOR}x the trailing median "
                    f"({baseline:.2f})")
        return None
    if all(p > DRIFT_FACTOR * baseline for p in recent):
        return (f"sustained wall-clock drift: last {DRIFT_RUNS} runs of "
                f"{name} ({', '.join(f'{p:.2f}' for p in recent)})"
                f" all exceed {DRIFT_FACTOR}x the trailing median "
                f"({baseline:.2f})")
    return None


def check_drift(series: List[dict]) -> List[str]:
    """Alert texts (one per watched metric) when the last DRIFT_RUNS
    points all sit DRIFT_FACTOR above the trailing-window median —
    sustained drift, not one noisy run. Metrics drift independently: a
    cold-path (submit) regression and a warm-path (cached dispatch)
    regression are different bugs and get different annotations."""
    alerts = []
    for name in DRIFT_METRICS:
        a = _check_one(series, name)
        if a:
            alerts.append(a)
    return alerts


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Append BENCH_runtime wall-clock to a trend series "
                    "and alert (never fail) on sustained drift.")
    ap.add_argument("--bench", default="BENCH_runtime.json")
    ap.add_argument("--series", default="wall_clock_trend.jsonl")
    ap.add_argument("--sha", default="")
    ap.add_argument("--run-id", default="")
    args = ap.parse_args(argv)

    bench = json.loads(pathlib.Path(args.bench).read_text())
    series_path = pathlib.Path(args.series)
    record = append_point(series_path, bench, sha=args.sha,
                          run_id=args.run_id)
    series = load_series(series_path)
    shown = ", ".join(f"{m}={_metric(record, m)}" for m in DRIFT_METRICS)
    print(f"appended point {len(series)} to {series_path}: {shown}")
    for alert in check_drift(series):
        # GitHub annotation — visible on the run, but exit 0: tracked,
        # never gated (ROADMAP: wall-clock trend tracking).
        print(f"::warning::{alert}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
