"""Roofline tables (deliverable g): read experiments/dryrun/ JSONs and emit
the per-(arch x shape x mesh) three-term table used by EXPERIMENTS.md."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_cells(mesh: str = "single"):
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, mesh, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def markdown_table(mesh: str = "single") -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "bottleneck | useful FLOPs | roofline MFU | HBM GiB/chip |",
            "|---|---|---|---|---|---|---|---|---|"]
    for c in load_cells(mesh):
        if c["status"] == "skipped":
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | "
                        f"skipped | — | — | — |")
            continue
        if c["status"] != "ok":
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | "
                        f"ERROR | — | — | — |")
            continue
        r = c["roofline"]
        hbm = c["memory"]["peak_per_device_bytes"] / 2**30
        rows.append(
            f"| {c['arch']} | {c['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['mfu']:.3f} | {hbm:.1f} |")
    return "\n".join(rows)


def run(csv_rows: list) -> dict:
    out = {}
    for mesh in ("single", "multipod"):
        for c in load_cells(mesh):
            if c["status"] != "ok":
                continue
            r = c["roofline"]
            csv_rows.append(
                (f"roofline_{mesh}_{c['arch']}_{c['shape']}", 0.0,
                 f"bottleneck={r['bottleneck']};mfu={r['mfu']:.3f};"
                 f"compute_s={r['compute_s']:.4f};memory_s="
                 f"{r['memory_s']:.4f};collective_s={r['collective_s']:.4f}"))
            out[(mesh, c["arch"], c["shape"])] = r["mfu"]
    return out


if __name__ == "__main__":
    for mesh in ("single", "multipod"):
        print(f"\n## {mesh}\n")
        print(markdown_table(mesh))
