"""Regenerate EXPERIMENTS.md's §Roofline tables and §Perf comparisons from
the dry-run JSONs. Invoked manually after sweeps:

    PYTHONPATH=src python -m benchmarks.report
"""
from __future__ import annotations

import json
import os

from benchmarks.roofline import markdown_table

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _load(path):
    with open(path) as f:
        return json.load(f)


def perf_row(tag: str, path: str) -> str:
    d = _load(path)
    if d["status"] != "ok":
        return f"| {tag} | ERROR | | | | | |"
    r = d["roofline"]
    hbm = d["memory"]["peak_per_device_bytes"] / 2**30
    return (f"| {tag} | {r['compute_s']:.4f} | {r['memory_s']:.4f} | "
            f"{r['collective_s']:.4f} | {r['bottleneck']} | "
            f"{r['mfu']:.3f} | {hbm:.1f} |")


def perf_table(title: str, rows: list[str]) -> str:
    head = (f"**{title}**\n\n"
            "| variant | compute s | memory s | collective s | bottleneck |"
            " roofline MFU | HBM GiB/chip |\n|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def main():
    out = []
    out.append("### Single-pod (16×16 = 256 chips) — all 40 cells\n")
    out.append(markdown_table("single"))
    out.append("\n### Multi-pod (2×16×16 = 512 chips)\n")
    out.append(markdown_table("multipod"))

    perf_dir = os.path.join(ROOT, "experiments", "perf")
    base = os.path.join(ROOT, "experiments", "dryrun_baseline")

    def p(variant, mesh, arch, shape):
        return os.path.join(perf_dir, variant, mesh, f"{arch}__{shape}.json")

    def b(mesh, arch, shape):
        return os.path.join(base, mesh, f"{arch}__{shape}.json")

    out.append("\n### §Perf variant measurements\n")
    out.append(perf_table(
        "Cell A — deepseek-v2-236b × train_4k × single",
        [perf_row("A0 baseline (GSPMD gather MoE)",
                  b("single", "deepseek-v2-236b", "train_4k")),
         perf_row("A1 expert-parallel shard_map dispatch",
                  p("A1_ep", "single", "deepseek-v2-236b", "train_4k")),
         perf_row("A2 + bf16 cast-before-all-gather",
                  p("A2_ep_bf16cast", "single", "deepseek-v2-236b",
                    "train_4k"))]))
    out.append("")
    out.append(perf_table(
        "Cell B — qwen3-14b × decode_32k × single",
        [perf_row("B0 baseline (training FSDP param layout)",
                  b("single", "qwen3-14b", "decode_32k")),
         perf_row("B1 TP-only serving params",
                  p("B1_tponly", "single", "qwen3-14b", "decode_32k")),
         perf_row("B2 + bf16 params",
                  p("B2_tponly_bf16", "single", "qwen3-14b", "decode_32k")),
         perf_row("B3 + KV-cache sequence sharding over TP",
                  p("B3_tponly_bf16_kvshard", "single", "qwen3-14b",
                    "decode_32k"))]))
    out.append("")
    out.append(perf_table(
        "Cell C — deepseek-v2-236b × prefill_32k × single",
        [perf_row("C0 baseline (GSPMD MoE, FSDP params)",
                  b("single", "deepseek-v2-236b", "prefill_32k")),
         perf_row("C1 expert-parallel dispatch",
                  p("C1_ep", "single", "deepseek-v2-236b", "prefill_32k")),
         perf_row("C2 + TP-only bf16 serving params",
                  p("C2_ep_tponly_bf16", "single", "deepseek-v2-236b",
                    "prefill_32k"))]))
    print("\n".join(out))


if __name__ == "__main__":
    main()
