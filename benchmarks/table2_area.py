"""Tables II-III reproduction: area model + FPGA resource table."""
from __future__ import annotations

from repro.core import area_model as A


def run(csv_rows: list) -> dict:
    points = {"base": (4, 0), "speculation": (4, 4), "scaled": (24, 24)}
    out = {}
    for name, (d, s) in points.items():
        r = A.report(name, d, s)
        out[name] = r.model_kge
        csv_rows.append((f"table2_area_{name}", 0.0,
                         f"model_kGE={r.model_kge:.1f};published="
                         f"{r.published_kge};fmax_GHz={r.fmax_ghz}"))
    sav = A.headline_fpga_savings()
    csv_rows.append(("table3_fpga_savings", 0.0,
                     f"lut_savings={sav['lut_savings']:.3f};"
                     f"ff_savings={sav['ff_savings']:.3f};paper=0.11/0.23"))
    for cfg, row in A.TABLE_III.items():
        csv_rows.append((f"table3_{cfg}", 0.0,
                         f"luts={row['luts']};ffs={row['ffs']}"))
    return out
