"""Sharded-runtime benchmarks: per-mesh migration cells for
BENCH_runtime.json (DESIGN.md §6, §10, §11).

One entry per mesh size in {1, 2, 4, 8} — the same cell spec and seeds
the perf sweep gates in BENCH_perf.json, but a *single* repeat, so any
metric downstream of the repeat median can differ from the gated
document (including the cycle model, whose cross_fraction input is that
median). The gated copies live in BENCH_perf.json; here they are
*reported*, with the wall-clock migration drain time isolated under
``wall_clock``, which never enters the deterministic section.

The ``wall_clock`` section also carries the trend series
(benchmarks/trend.py): ``resize_mesh4_seconds`` and
``migration_overlap_ratio_mesh4`` (PR 9 async fabric), plus the two
virtual-addressing series — ``tlb_hit_rate_L13``, the DDR3 MMU cell's
IOTLB hit rate under chain-lookahead prefetch, and
``first_touch_latency_rounds_mesh4``, the fabric rounds from touching an
ownership-flipped page to residency. All three echoed metrics are
deterministic, so sustained drift is a real regression, not noise.

The defrag A/B times remap-based compaction (a page-table update)
against the legacy copy leg through the DMA runtime on the *same*
fragmented layout — the pool hands out :class:`PageRef` handles and this
bench holds them end to end; the gated cycle-model copies live in the
``mmu/*`` cells of BENCH_perf.json.

``fabric="sync"`` is the escape hatch (``benchmarks/run.py
--sync-fabric``): every cell re-runs through the synchronous blocking
hop path, bit-identical to the pre-fabric migration planner.
"""
from __future__ import annotations

import dataclasses
import time

from repro.perf.mmu_cell import run_mmu_cell
from repro.perf.sharded_cell import (
    DEFAULT_SHARDED_SPEC,
    MESH_SIZES,
    _make_runtime,
    _resize_retention,
    run_sharded_cell,
)

#: Defrag A/B shape: allocate a run, free every other page, compact the
#: stride-2 survivors. Small enough for the copy leg to stay fast.
_DEFRAG_ALLOC = 48


def _defrag_ab(spec) -> dict:
    """Remap-vs-copy compaction of the same fragmented PageRef set."""
    out = {}
    for mode in ("remap", "copy"):
        _, kv, _ = _make_runtime(2, spec)
        pages = kv.alloc_on(0, _DEFRAG_ALLOC)
        live = pages[_DEFRAG_ALLOC // 2:]   # survivors sit past the hole
        kv.release(pages[:_DEFRAG_ALLOC // 2])
        t0 = time.perf_counter()
        new_refs, _, rate = kv.defragment(live, mode=mode)
        out[f"defrag_{mode}_seconds"] = time.perf_counter() - t0
        out[f"defrag_{mode}_rate"] = float(rate)
        out[f"defrag_{mode}_pages"] = len(new_refs)
    return out


def run(csv_rows: list, seed: int = 0, fabric: str = "async") -> dict:
    spec = (DEFAULT_SHARDED_SPEC if fabric == "async"
            else dataclasses.replace(DEFAULT_SHARDED_SPEC, fabric="sync"))
    cells = {}
    wall = {}
    for mesh in MESH_SIZES:
        t0 = time.perf_counter()
        metrics, counters = run_sharded_cell(seed, mesh, spec, repeats=1)
        wall[f"mesh{mesh}_seconds"] = time.perf_counter() - t0
        cells[f"mesh{mesh}"] = {"metrics": metrics, "counters": counters}
        csv_rows.append((
            f"sharded_migration_mesh{mesh}", 0.0,
            f"cycles={metrics['cross_shard_migration_cycles']:.1f}/"
            f"merge={metrics['migration_chain_merge_ratio']:.2f}/"
            f"overlap={metrics['migration_overlap_ratio']:.2f}"))

    defrag = _defrag_ab(spec)
    wall.update({k: v for k, v in defrag.items() if k.endswith("_seconds")})
    csv_rows.append((
        "sharded_defrag_remap", defrag["defrag_remap_seconds"] * 1e6,
        f"rate={defrag['defrag_remap_rate']:.2f}/"
        f"pages={defrag['defrag_remap_pages']}"))
    csv_rows.append((
        "sharded_defrag_copy", defrag["defrag_copy_seconds"] * 1e6,
        f"rate={defrag['defrag_copy_rate']:.2f}/"
        f"pages={defrag['defrag_copy_pages']}"))

    # Trend series (async only; the sync escape hatch has no fabric to
    # overlap, no paced handoff to time, and no lazy pull to measure).
    if fabric == "async":
        t0 = time.perf_counter()
        resize = _resize_retention(seed, 4, spec)
        wall["resize_mesh4_seconds"] = time.perf_counter() - t0
        wall["migration_overlap_ratio_mesh4"] = \
            cells["mesh4"]["metrics"]["migration_overlap_ratio"]
        wall["first_touch_latency_rounds_mesh4"] = \
            cells["mesh4"]["metrics"]["first_touch_latency_rounds"]
        mmu_metrics, _ = run_mmu_cell(seed, 13)
        wall["tlb_hit_rate_L13"] = mmu_metrics["tlb_hit_rate"]
        csv_rows.append((
            "sharded_resize_mesh4", wall["resize_mesh4_seconds"] * 1e6,
            f"retained={resize['retained']:.2f}/"
            f"handoff={resize['handoff_pages']}"))
        csv_rows.append((
            "mmu_iotlb_L13", 0.0,
            f"tlb_hit={mmu_metrics['tlb_hit_rate']:.3f}/"
            f"walk_stall={mmu_metrics['walk_stall_cycles']:.0f}"))
    return {"fabric": fabric, "cells": cells, "defrag": defrag,
            "wall_clock": wall}
