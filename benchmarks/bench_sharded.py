"""Sharded-runtime benchmarks: per-mesh migration cells for
BENCH_runtime.json (DESIGN.md §6, §10).

One entry per mesh size in {1, 2, 4, 8} — the same cell spec and seeds
the perf sweep gates in BENCH_perf.json, but a *single* repeat, so any
metric downstream of the repeat median can differ from the gated
document (including the cycle model, whose cross_fraction input is that
median). The gated copies live in BENCH_perf.json; here they are
*reported*, with the wall-clock migration drain time isolated under
``wall_clock``, which never enters the deterministic section.

The ``wall_clock`` section also carries the two async-fabric trend
series (benchmarks/trend.py): ``resize_mesh4_seconds`` — wall-clock of
the mesh-4 elastic-resize scenario (foreground waves racing a paced
background page handoff) — and ``migration_overlap_ratio_mesh4``, the
gated overlap ratio echoed for drift tracking (deterministic, so any
sustained *drop* is a real scheduling regression, not noise).

``fabric="sync"`` is the escape hatch (``benchmarks/run.py
--sync-fabric``): every cell re-runs through the synchronous blocking
hop path, bit-identical to the pre-fabric migration planner.
"""
from __future__ import annotations

import dataclasses
import time

from repro.perf.sharded_cell import (
    DEFAULT_SHARDED_SPEC,
    MESH_SIZES,
    _resize_retention,
    run_sharded_cell,
)


def run(csv_rows: list, seed: int = 0, fabric: str = "async") -> dict:
    spec = (DEFAULT_SHARDED_SPEC if fabric == "async"
            else dataclasses.replace(DEFAULT_SHARDED_SPEC, fabric="sync"))
    cells = {}
    wall = {}
    for mesh in MESH_SIZES:
        t0 = time.perf_counter()
        metrics, counters = run_sharded_cell(seed, mesh, spec, repeats=1)
        wall[f"mesh{mesh}_seconds"] = time.perf_counter() - t0
        cells[f"mesh{mesh}"] = {"metrics": metrics, "counters": counters}
        csv_rows.append((
            f"sharded_migration_mesh{mesh}", 0.0,
            f"cycles={metrics['cross_shard_migration_cycles']:.1f}/"
            f"merge={metrics['migration_chain_merge_ratio']:.2f}/"
            f"overlap={metrics['migration_overlap_ratio']:.2f}"))
    # Trend series (async only; the sync escape hatch has no fabric to
    # overlap and no paced handoff to time).
    if fabric == "async":
        t0 = time.perf_counter()
        resize = _resize_retention(seed, 4, spec)
        wall["resize_mesh4_seconds"] = time.perf_counter() - t0
        wall["migration_overlap_ratio_mesh4"] = \
            cells["mesh4"]["metrics"]["migration_overlap_ratio"]
        csv_rows.append((
            "sharded_resize_mesh4", wall["resize_mesh4_seconds"] * 1e6,
            f"retained={resize['retained']:.2f}/"
            f"handoff={resize['handoff_pages']}"))
    return {"fabric": fabric, "cells": cells, "wall_clock": wall}
