"""Sharded-runtime benchmarks: per-mesh migration cells for
BENCH_runtime.json (DESIGN.md §6).

One entry per mesh size in {1, 2, 4, 8} — the same cell spec and seeds
the perf sweep gates in BENCH_perf.json, but a *single* repeat, so any
metric downstream of the repeat median can differ from the gated
document (including the cycle model, whose cross_fraction input is that
median). The gated copies live in BENCH_perf.json; here they are
*reported*, with the wall-clock migration drain time isolated under
``wall_clock``, which never enters the deterministic section.
"""
from __future__ import annotations

import time

from repro.perf.sharded_cell import (
    DEFAULT_SHARDED_SPEC,
    MESH_SIZES,
    run_sharded_cell,
)


def run(csv_rows: list, seed: int = 0) -> dict:
    cells = {}
    wall = {}
    for mesh in MESH_SIZES:
        t0 = time.perf_counter()
        metrics, counters = run_sharded_cell(seed, mesh,
                                             DEFAULT_SHARDED_SPEC,
                                             repeats=1)
        wall[f"mesh{mesh}_seconds"] = time.perf_counter() - t0
        cells[f"mesh{mesh}"] = {"metrics": metrics, "counters": counters}
        csv_rows.append((
            f"sharded_migration_mesh{mesh}", 0.0,
            f"cycles={metrics['cross_shard_migration_cycles']:.1f}/"
            f"merge={metrics['migration_chain_merge_ratio']:.2f}"))
    return {"cells": cells, "wall_clock": wall}
