"""Runtime subsystem: rings, channels, coalescer, completions, scheduler.

No hypothesis dependency — this module must collect on minimal installs.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import descriptor as D
from repro.core.chain import from_segments
from repro.core.engine import completion_events, execute_chain_host
from repro.core.simulator import simulate, simulate_multichannel, SimConfig
from repro.runtime import (
    ChannelConfig,
    CompletionQueue,
    DMARuntime,
    RingFull,
    RoundRobinArbiter,
    SubmissionRing,
    SubmitRequest,
    WeightedArbiter,
    coalesce,
    default_runtime,
)


# ---------------------------------------------------------------------------
# Completion semantics (§II-D)
# ---------------------------------------------------------------------------

def test_completion_events_irq_masking():
    before = jnp.asarray([0, 0, 1, 0])
    after = jnp.asarray([1, 1, 1, 0])
    irq = jnp.asarray([1, 0, 1, 1])
    ev = np.asarray(completion_events(before, after, irq))
    # Only newly-done AND irq-enabled descriptors raise events: index 0.
    # Index 1 completed without IRQ; 2 was already done; 3 didn't complete.
    np.testing.assert_array_equal(ev, [True, False, False, False])


def test_mark_done_roundtrip_through_packed_forms():
    d = D.DescriptorArray.create([0, 8, 16], [32, 40, 48], [8, 8, 8])
    d = d.mark_done(1)
    tab = D.to_packed(d, elem_bytes=4, src_base=0x100, dst_base=0x200,
                      table_base=0x1000)
    # The done entry carries the all-ones writeback in its first 8 bytes.
    np.testing.assert_array_equal(D.is_done_packed(tab),
                                  [False, True, False])
    back = D.from_packed(tab, elem_bytes=4, src_base=0x100, dst_base=0x200,
                         table_base=0x1000)
    np.testing.assert_array_equal(np.asarray(back.done), np.asarray(d.done))
    keep = np.asarray(d.done) == 0
    for f in ("src", "dst", "length", "nxt"):
        np.testing.assert_array_equal(
            np.asarray(getattr(back, f))[keep],
            np.asarray(getattr(d, f))[keep], err_msg=f)
    # And marking the packed form is observable without any side state.
    D.mark_done_packed(tab, 2)
    np.testing.assert_array_equal(D.is_done_packed(tab),
                                  [False, True, True])


# ---------------------------------------------------------------------------
# Submission ring
# ---------------------------------------------------------------------------

def _one_packed(uid):
    return D.pack([8], [0], [D.END_OF_CHAIN], [uid], [0])[0]


def test_ring_wraparound_preserves_fifo_tickets():
    ring = SubmissionRing(4)
    retired = []
    ticket = 0
    for _ in range(5):   # 10 entries through a 4-slot ring
        for _ in range(2):
            ring.push(_one_packed(ticket), ticket)
            ticket += 1
        for slot in list(ring.live_slots()):
            ring.mark_done(int(slot))
        retired.extend(e.ticket for e in ring.retire())
    assert retired == list(range(10))
    assert ring.empty and ring.head == ring.tail == 10


def test_ring_full_backpressure_and_inorder_retirement():
    ring = SubmissionRing(2)
    ring.push(_one_packed(0), 0)
    ring.push(_one_packed(1), 1)
    with pytest.raises(RingFull):
        ring.push(_one_packed(2), 2)
    # Completing the *younger* entry does not retire it past the older one.
    ring.mark_done_ticket(1)
    assert ring.retire() == []
    ring.mark_done_ticket(0)
    assert [e.ticket for e in ring.retire()] == [0, 1]
    ring.push(_one_packed(2), 2)   # slot freed


# ---------------------------------------------------------------------------
# Arbitration
# ---------------------------------------------------------------------------

def test_round_robin_fairness():
    arb = RoundRobinArbiter(["a", "b", "c"])
    picks = [arb.pick(["a", "b", "c"]) for _ in range(9)]
    assert picks == ["a", "b", "c"] * 3
    # Ineligible channels are skipped without losing rotation fairness.
    picks = [arb.pick(["b", "c"]) for _ in range(4)]
    assert picks == ["b", "c", "b", "c"]


def test_weighted_arbiter_proportional_and_smooth():
    weights = {"a": 3, "b": 2, "c": 1}
    arb = WeightedArbiter(weights)
    picks = [arb.pick(list(weights)) for _ in range(600)]
    counts = {k: picks.count(k) for k in weights}
    assert counts == {"a": 300, "b": 200, "c": 100}
    # Smoothness: no 3-burst of the heavy channel inside one 6-pick cycle.
    assert "".join(p for p in picks[:6]).count("aa") <= 1


# ---------------------------------------------------------------------------
# Coalescer
# ---------------------------------------------------------------------------

def test_coalescer_merges_contiguous_and_matches_oracle():
    # 12 page-sized segments forming 3 contiguous runs.
    unit = 8
    runs = [(0, 4), (64, 5), (200, 3)]
    srcs, dsts, cursor = [], [], 0
    for base, n in runs:
        for k in range(n):
            srcs.append(base + k * unit)
            dsts.append(cursor)
            cursor += unit
    d = from_segments(srcs, dsts, [unit] * len(srcs))
    planned, stats = coalesce(d, max_len=1 << 16)
    assert stats.n_in == 12 and stats.n_out == 3
    assert stats.merge_ratio == pytest.approx(4.0)
    assert stats.output_hit_rate == 1.0

    rng = np.random.default_rng(0)
    src = rng.standard_normal(512).astype(np.float32)
    dst = np.zeros(256, np.float32)
    want, _ = execute_chain_host(d, src, dst)
    got, _ = execute_chain_host(planned, src, dst)
    np.testing.assert_array_equal(got, want)


def test_coalescer_splits_over_max_len_and_matches_oracle():
    d = from_segments([0], [0], [70])
    planned, stats = coalesce(d, max_len=32)
    assert stats.n_out == 3
    assert np.asarray(planned.length).max() <= 32
    assert int(np.asarray(planned.length).sum()) == 70
    src = np.arange(70, dtype=np.float32)
    want, _ = execute_chain_host(d, src, np.zeros(70, np.float32))
    got, _ = execute_chain_host(planned, src, np.zeros(70, np.float32))
    np.testing.assert_array_equal(got, want)


def test_coalescer_respects_irq_barrier_and_nonsequential_chains():
    # Array order [B, C, D, A]; chain order A -> B -> C -> D covers
    # [0..8) [8..16) [16..24) [24..32): all four abut, but A raises an
    # IRQ, so A|B stays split while B+C+D fuse.
    d = D.DescriptorArray.create(
        [8, 16, 24, 0], [8, 16, 24, 0], [8, 8, 8, 8], nxt=[1, 2, -1, 0],
        config=[0, 0, 0, int(D.CONFIG_IRQ_ENABLE)])
    planned, stats = coalesce(d, max_len=64, head=3)
    assert stats.n_out == 2
    assert stats.merged == 2
    src = np.arange(64, dtype=np.float32)
    want, _ = execute_chain_host(d, src, np.zeros(64, np.float32), head=3)
    got, _ = execute_chain_host(planned, src, np.zeros(64, np.float32))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Scheduler: multi-channel drain vs oracle (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.slow  # >=4-channel drain/sim: CI slow job
def test_four_channels_drain_irregular_transfers_bit_identical():
    rt = default_runtime(4, tier="serial", max_len=16, ring_capacity=32)
    rng = np.random.default_rng(7)
    pool = 2048
    src = rng.standard_normal(pool).astype(np.float32)
    dst = rng.standard_normal(pool).astype(np.float32)
    rt.register_pool("src", jnp.asarray(src))
    rt.register_pool("dst", jnp.asarray(dst))

    oracle = dst.copy()
    chans = set()
    for k in range(16):   # 16 interleaved submissions over 4 channels
        n = int(rng.integers(1, 7))
        lens = rng.integers(1, 13, n)
        s = rng.integers(0, pool - 16, n)
        # Disjoint destination windows per submission: result is
        # order-independent across channels (within-chain order still
        # exercised by overlapping in-chain writes below).
        t = k * 120 + np.concatenate([[0], np.cumsum(lens[:-1])])
        d = from_segments(s, t, lens)
        res = rt.submit(SubmitRequest(chain=d, src_pool="src",
                                      dst_pool="dst"))
        chans.add(res.channel)
        oracle, _ = execute_chain_host(d, src, oracle)

    assert len(chans) == 4          # all four channels carried work
    rt.drain_until_idle()
    np.testing.assert_array_equal(np.asarray(rt.pool("dst")), oracle)
    st = rt.stats()
    assert st["submitted_descriptors"] > 0
    assert all(c["retired"] == c["submitted"]
               for c in st["channels"].values())


def test_scheduler_coalesces_contiguous_page_workload():
    rt = default_runtime(1, tier="serial", max_len=2048)
    rt.register_pool("src", jnp.arange(4096, dtype=jnp.float32))
    rt.register_pool("dst", jnp.zeros(4096, jnp.float32))
    unit = 32
    d = from_segments(np.arange(64) * unit, np.arange(64) * unit,
                      [unit] * 64)   # fully contiguous page run
    res = rt.submit(SubmitRequest(chain=d, src_pool="src", dst_pool="dst"))
    assert res.coalesce is not None
    assert res.coalesce.n_out < res.coalesce.n_in  # coalescer shrank it
    assert res.coalesce.n_out == 1
    rt.drain_until_idle()
    np.testing.assert_array_equal(np.asarray(rt.pool("dst"))[:64 * unit],
                                  np.arange(64 * unit, dtype=np.float32))
    assert rt.stats()["coalesce_merge_ratio"] == pytest.approx(64.0)


def test_backpressure_block_drains_ring():
    rt = DMARuntime([ChannelConfig(name="c0", tier="serial",
                                   ring_capacity=4, max_len=8)],
                    backpressure="block")
    rt.register_pool("src", jnp.arange(64, dtype=jnp.float32))
    rt.register_pool("dst", jnp.zeros(64, jnp.float32))
    for k in range(6):   # 6 single-descriptor chains through a 4-slot ring
        rt.submit(SubmitRequest(chain=from_segments([k * 8], [k * 8], [8]),
                                src_pool="src", dst_pool="dst",
                                run_coalescer=False))
    rt.drain_until_idle()
    np.testing.assert_array_equal(np.asarray(rt.pool("dst"))[:48],
                                  np.arange(48, dtype=np.float32))


def test_backpressure_spill_replays_on_drain():
    rt = DMARuntime([ChannelConfig(name="c0", tier="serial",
                                   ring_capacity=2, max_len=8)],
                    backpressure="spill")
    rt.register_pool("src", jnp.arange(64, dtype=jnp.float32))
    rt.register_pool("dst", jnp.zeros(64, jnp.float32))
    spilled = 0
    for k in range(6):
        res = rt.submit(
            SubmitRequest(chain=from_segments([k * 8], [k * 8], [8]),
                          src_pool="src", dst_pool="dst",
                          run_coalescer=False))
        spilled += res.spilled
    assert spilled > 0
    rt.drain_until_idle()
    assert rt.stats()["spilled"] == 0
    np.testing.assert_array_equal(np.asarray(rt.pool("dst"))[:48],
                                  np.arange(48, dtype=np.float32))


def test_control_channel_out_of_band_completion_and_callbacks():
    rt = DMARuntime([ChannelConfig(name="done", tier="control",
                                   ring_capacity=8)])
    seen = []
    r0 = rt.submit_control(payload=11, channel="done",
                           on_complete=lambda rec: seen.append(rec.ticket))
    r1 = rt.submit_control(payload=22, channel="done")
    rt.drain_all()
    assert rt.poll() == []           # nothing written back yet
    rt.complete(r0.tickets[-1])
    rt.complete(r1.tickets[-1])
    rt.drain_all()
    recs = rt.poll()
    assert [r.ticket for r in recs] == [r0.tickets[-1], r1.tickets[-1]]
    assert seen == [r0.tickets[-1]]  # callback fired exactly once


def test_completion_queue_only_events_irq_or_callbacked():
    q = CompletionQueue()
    ring = SubmissionRing(4)
    ring.push(_one_packed(0), 0, irq=True)
    ring.push(_one_packed(1), 1, irq=False)
    for s in ring.live_slots():
        ring.mark_done(int(s))
    q.post_retired("ch", ring.retire())
    assert [r.ticket for r in q.poll()] == [0]
    assert q.dropped_irqless == 1


# ---------------------------------------------------------------------------
# Pallas-kernel-driven drain and fused 2d drain
# ---------------------------------------------------------------------------

def _row_move_fixture(rng, rows=16, unit=8):
    src = rng.standard_normal((rows, unit)).astype(np.float32)
    dst = np.zeros((rows, unit), np.float32)
    perm = rng.permutation(rows)
    d = D.DescriptorArray.create(perm, np.arange(rows), np.ones(rows))
    return src, dst, perm, d


def test_channel_drain_via_pallas_kernel_matches_blocked_2d():
    rng = np.random.default_rng(3)
    src, dst, perm, d = _row_move_fixture(rng)
    outs = {}
    for use_kernel in (False, True):
        rt = DMARuntime([ChannelConfig(name="c0", tier="blocked_2d",
                                       use_kernel=use_kernel)])
        rt.register_pool("src", jnp.asarray(src))
        rt.register_pool("dst", jnp.asarray(dst))
        rt.submit(SubmitRequest(chain=d, src_pool="src", dst_pool="dst"))
        rt.drain_until_idle()
        outs[use_kernel] = np.asarray(rt.pool("dst"))
    np.testing.assert_array_equal(outs[False], src[perm])
    np.testing.assert_array_equal(outs[True], outs[False])


@pytest.mark.slow  # >=4-channel drain/sim: CI slow job
def test_fused_2d_drain_across_channels():
    rng = np.random.default_rng(4)
    rows, unit = 32, 4
    src = rng.standard_normal((rows, unit)).astype(np.float32)
    rt = default_runtime(4, tier="blocked_2d")
    rt.register_pool("src", jnp.asarray(src))
    rt.register_pool("dst", jnp.zeros((rows, unit), jnp.float32))
    perm = rng.permutation(rows)
    for part in np.array_split(np.arange(rows), 4):  # 4 chains, 4 channels
        d = D.DescriptorArray.create(perm[part], part, np.ones(len(part)))
        rt.submit(SubmitRequest(chain=d, src_pool="src", dst_pool="dst"))
    rt.drain_all()   # single fused jitted call covers all four channels
    np.testing.assert_array_equal(np.asarray(rt.pool("dst")), src[perm])
    st = rt.stats()["channels"]
    assert sum(c["drained"] for c in st.values()) == rows


def test_chain_longer_than_ring_chunks_instead_of_hanging():
    rt = DMARuntime([ChannelConfig(name="c0", tier="serial",
                                   ring_capacity=4, max_len=8)],
                    backpressure="block")
    rt.register_pool("src", jnp.arange(128, dtype=jnp.float32))
    rt.register_pool("dst", jnp.zeros(128, jnp.float32))
    # 12 descriptors through a 4-slot ring in one submit call.
    d = from_segments(np.arange(12) * 8, np.arange(12) * 8, [8] * 12)
    res = rt.submit(SubmitRequest(chain=d, src_pool="src", dst_pool="dst",
                                  run_coalescer=False))
    assert len(res.tickets) == 12
    rt.drain_until_idle()
    np.testing.assert_array_equal(np.asarray(rt.pool("dst"))[:96],
                                  np.arange(96, dtype=np.float32))
    # A non-sequential serial chain cannot be cut: loud error, no hang.
    bad = D.DescriptorArray.create(np.arange(6) * 8, np.arange(6) * 8,
                                   [8] * 6, nxt=[5, 0, 1, 2, 3, -1])
    with pytest.raises(ValueError, match="not sequentially linked"):
        rt.submit(SubmitRequest(chain=bad, src_pool="src", dst_pool="dst",
                                run_coalescer=False))


def test_fused_2d_drain_respects_cross_batch_dependencies():
    src = np.arange(12, dtype=np.float32).reshape(4, 3)
    rt = DMARuntime([ChannelConfig(name="c0", tier="blocked_2d")])
    rt.register_pool("p", jnp.asarray(src))
    # Dependent moves on one channel: row0 -> row1, then row1 -> row2.
    # Sequential semantics: row2 ends up with the ORIGINAL row0.
    rt.submit(SubmitRequest(chain=D.DescriptorArray.create([0], [1], [1]),
                            src_pool="p", dst_pool="p"))
    rt.submit(SubmitRequest(chain=D.DescriptorArray.create([1], [2], [1]),
                            src_pool="p", dst_pool="p"))
    rt.drain_all()
    got = np.asarray(rt.pool("p"))
    np.testing.assert_array_equal(got[1], src[0])
    np.testing.assert_array_equal(got[2], src[0])   # not the stale row1


def test_ring_live_done_tickets_sees_out_of_order_writeback():
    # A long-running head entry must not hide younger completions from
    # the §II-D table scan (serve poll_completed relies on this).
    ring = SubmissionRing(8)
    ring.push(_one_packed(0), 0)   # old, still running
    ring.push(_one_packed(1), 1)
    ring.mark_done_ticket(1)
    assert ring.retire() == []                 # head-of-line blocked
    assert ring.live_done_tickets() == [1]     # ...but poll sees it


def test_serve_engine_rejects_runtime_without_completion_channel():
    from repro.serve.engine import ServeEngine
    # Validation fires before any model state is built, so params/cfg can
    # be inert placeholders.
    with pytest.raises(ValueError, match="control-tier channel"):
        ServeEngine(params=None, cfg=None,
                    runtime=default_runtime(2, tier="serial", max_len=8))


# ---------------------------------------------------------------------------
# KV-cache page moves through the runtime
# ---------------------------------------------------------------------------

def test_kv_defragment_through_runtime_preserves_contents():
    from repro.serve import PagedKVCache
    kv = PagedKVCache(page=4, num_pages=32, max_seqs=2, max_pages_per_seq=8,
                      kv_heads=2, head_dim=4)
    rng = np.random.default_rng(0)
    kv.admit(0)
    kv.admit(1)
    for i in range(24):   # interleaved appends fragment both slots
        kv.append(i % 2, rng.standard_normal((2, 4)),
                  rng.standard_normal((2, 4)))
    assert kv.alloc.speculation_hit_rate(0) < 1.0
    before = kv.dense_view(0)
    other = kv.dense_view(1)

    rt = default_runtime(4, tier="blocked_2d")
    rate = kv.defragment(0, rt)
    assert rate == 1.0
    after = kv.dense_view(0)
    np.testing.assert_array_equal(after[0], before[0])
    np.testing.assert_array_equal(after[1], before[1])
    # The other sequence is untouched by slot 0's defragmentation.
    np.testing.assert_array_equal(kv.dense_view(1)[0], other[0])


# ---------------------------------------------------------------------------
# Multi-channel cycle model
# ---------------------------------------------------------------------------

def test_multichannel_sim_one_channel_matches_base_config():
    one = simulate_multichannel(1, 13, 64, num_transfers=300)
    base = simulate(SimConfig.base(), 13, 64)
    assert one.aggregate_utilization == pytest.approx(base.utilization,
                                                      rel=0.05)


@pytest.mark.slow  # >=4-channel drain/sim: CI slow job
def test_multichannel_sim_scales_to_bus_saturation():
    two = simulate_multichannel(2, 13, 64, num_transfers=300)
    four = simulate_multichannel(4, 13, 64, num_transfers=300)
    assert two.aggregate_utilization > \
        1.8 * simulate_multichannel(1, 13, 64).aggregate_utilization
    assert four.aggregate_utilization == pytest.approx(four.ideal, rel=0.02)
    utils = [c.utilization for c in four.channels]
    assert max(utils) - min(utils) < 0.02   # fair arbiter: equal shares


@pytest.mark.slow  # >=4-channel drain/sim: CI slow job
def test_multichannel_sim_weighted_shares():
    r = simulate_multichannel(4, 13, 64, num_transfers=300,
                              weights=[4, 2, 1, 1])
    u = [c.utilization for c in r.channels]
    assert u[0] > u[1] > u[2]
    assert u[1] == pytest.approx(2 * u[2], rel=0.25)
    assert u[2] == pytest.approx(u[3], rel=0.1)
