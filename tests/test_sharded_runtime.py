"""Sharded DMA serving layer (DESIGN.md §6): ownership, migration chains,
single-shard pinning, mesh-shape equivalence, shardlib lifecycle.

No hypothesis dependency — this module must collect on minimal installs.
Mesh-placement tests guard on the host device count, so they run for real
in the multi-device CI lane (``--xla_force_host_platform_device_count=8``)
and skip, rather than fake, elsewhere.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.chain import from_segments
from repro.distributed import shardlib
from repro.distributed.sharded_runtime import (
    MigrationStats,
    PageOwnerMap,
    ShardedDMARuntime,
    ShardedKVPool,
    resolve_num_shards,
)
from repro.runtime import ChannelConfig, DMARuntime
from repro.runtime.submit import SubmitRequest


# ---------------------------------------------------------------------------
# shardlib mesh/rules lifecycle (regression: set_mesh(None) left stale rules)
# ---------------------------------------------------------------------------

class _FakeMesh:
    shape = {"data": 2, "model": 2}


def test_set_mesh_none_clears_rules_like_clear_mesh():
    shardlib.set_mesh(_FakeMesh())
    shardlib.set_rules({"batch": "data", "heads": "model"})
    assert shardlib.current_rules()
    shardlib.set_mesh(None)   # must be symmetric with clear_mesh()
    assert shardlib.current_mesh() is None
    assert shardlib.current_rules() == {}

    shardlib.set_mesh(_FakeMesh())
    shardlib.set_rules({"batch": "data"})
    shardlib.clear_mesh()
    assert shardlib.current_mesh() is None
    assert shardlib.current_rules() == {}


class _BigFakeMesh:
    shape = {"data": 4, "model": 2}


def test_use_mesh_restores_state_when_body_resizes_mesh_and_raises():
    # Elastic-resize hazard: the body legitimately swaps in a grown mesh
    # (and new rules), then fails mid-launch. The pre-with pair must come
    # back — not the resized one, and not a half-cleared state.
    shardlib.set_mesh(_FakeMesh())
    shardlib.set_rules({"batch": "data"})
    with pytest.raises(RuntimeError):
        with shardlib.use_mesh(_FakeMesh(), {"batch": "data"}):
            shardlib.set_mesh(_BigFakeMesh())
            shardlib.set_rules({"batch": "data", "heads": "model"})
            raise RuntimeError("resize failed mid-launch")
    assert isinstance(shardlib.current_mesh(), _FakeMesh)
    assert shardlib.current_rules() == {"batch": "data"}
    # A body that tears the mesh down entirely restores the same way.
    with pytest.raises(RuntimeError):
        with shardlib.use_mesh(_BigFakeMesh()):
            shardlib.clear_mesh()
            raise RuntimeError("boom")
    assert isinstance(shardlib.current_mesh(), _FakeMesh)
    assert shardlib.current_rules() == {"batch": "data"}
    shardlib.clear_mesh()


def test_use_mesh_restores_state_when_install_itself_throws():
    # A bad rule table must not leave the new mesh installed with the old
    # rules: the install happens inside the restore scope.
    shardlib.set_mesh(_FakeMesh())
    shardlib.set_rules({"batch": "data"})
    with pytest.raises(TypeError):
        with shardlib.use_mesh(_BigFakeMesh(), rules=42):   # not a mapping
            pragma = None   # pragma: no cover - body never runs
            del pragma
    assert isinstance(shardlib.current_mesh(), _FakeMesh)
    assert shardlib.current_rules() == {"batch": "data"}
    shardlib.clear_mesh()


def test_use_mesh_restores_previous_state_even_on_error():
    shardlib.set_mesh(None)
    with shardlib.use_mesh(_FakeMesh(), {"batch": "data"}):
        assert shardlib.current_rules() == {"batch": "data"}
    assert shardlib.current_mesh() is None
    assert shardlib.current_rules() == {}
    with pytest.raises(RuntimeError):
        with shardlib.use_mesh(_FakeMesh(), {"batch": "data"}):
            raise RuntimeError("boom")
    assert shardlib.current_mesh() is None
    assert shardlib.current_rules() == {}


def test_mesh_state_is_thread_local():
    shardlib.set_mesh(_FakeMesh())
    shardlib.set_rules({"batch": "data"})
    seen = {}

    def worker():
        seen["mesh"] = shardlib.current_mesh()
        seen["rules"] = shardlib.current_rules()
        shardlib.set_mesh(_FakeMesh())
        shardlib.set_rules({"batch": "model"})

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    # The worker saw a pristine thread and its writes never leaked back.
    assert seen == {"mesh": None, "rules": {}}
    assert shardlib.current_rules() == {"batch": "data"}
    shardlib.clear_mesh()


# ---------------------------------------------------------------------------
# Page ownership
# ---------------------------------------------------------------------------

def test_page_owner_map_partition_and_validation():
    m = PageOwnerMap(num_pages=32, num_shards=4)
    assert m.pages_per_shard == 8
    assert [m.owner(p) for p in (0, 7, 8, 31)] == [0, 0, 1, 3]
    assert m.local_row(17) == 1
    assert list(m.shard_pages(2)) == list(range(16, 24))
    with pytest.raises(IndexError):
        m.owner(32)
    with pytest.raises(ValueError, match="partition evenly"):
        PageOwnerMap(num_pages=10, num_shards=4)


def test_resolve_num_shards_is_shape_agnostic():
    class M1:
        shape = {"a": 1, "b": 4}

    class M2:
        shape = {"a": 4, "b": 1}
    assert resolve_num_shards(M1()) == resolve_num_shards(M2()) == 4
    assert resolve_num_shards(None) == 1


# ---------------------------------------------------------------------------
# Single-shard pinning: the sharded drain is bit-identical to the plain
# DMARuntime drain (the PR-2 trick — same chains, same channels, same bytes)
# ---------------------------------------------------------------------------

def test_single_shard_migration_bit_identical_to_unsharded_runtime():
    rng = np.random.default_rng(11)
    num_pages, row_elems = 32, 16
    content = rng.standard_normal(num_pages * row_elems).astype(np.float32)

    srt = ShardedDMARuntime(num_shards=1, data_channels=2, max_len=512)
    kv = ShardedKVPool(srt, num_pages=num_pages, page=row_elems,
                       kv_heads=1, head_dim=1)
    for p in range(num_pages):
        row = content[p * row_elems:(p + 1) * row_elems]
        kv.write_page(p, row, -row)
    src = [3, 4, 5, 9, 20, 21, 22, 23, 7]
    dst = [12, 13, 14, 26, 0, 1, 2, 28, 30]
    kv.move_pages(src, dst)

    # The unsharded reference: identical channel set, identically padded
    # pools, the same two chains through the same coalescer path.
    rt = DMARuntime([
        ChannelConfig(name="dma0", tier="serial", ring_capacity=256,
                      max_len=512),
        ChannelConfig(name="dma1", tier="serial", ring_capacity=256,
                      max_len=512),
        ChannelConfig(name="completion", tier="control"),
    ])
    pad = jnp.zeros(512, jnp.float32)
    rt.register_pool("kv.k", jnp.concatenate([jnp.asarray(content), pad]))
    rt.register_pool("kv.v", jnp.concatenate([jnp.asarray(-content), pad]))
    s = np.asarray(src, np.int64) * row_elems
    t = np.asarray(dst, np.int64) * row_elems
    ln = np.full(len(src), row_elems, np.int64)
    rt.submit(SubmitRequest(chain=from_segments(s, t, ln), src_pool="kv.k",
                            dst_pool="kv.k", tier="serial"))
    rt.submit(SubmitRequest(chain=from_segments(s, t, ln), src_pool="kv.v",
                            dst_pool="kv.v", tier="serial"))
    rt.drain_until_idle()

    logical = num_pages * row_elems
    np.testing.assert_array_equal(
        srt.gather_pool(ShardedKVPool.POOL_K),
        np.asarray(rt.pool("kv.k"))[:logical])
    np.testing.assert_array_equal(
        srt.gather_pool(ShardedKVPool.POOL_V),
        np.asarray(rt.pool("kv.v"))[:logical])


# ---------------------------------------------------------------------------
# Migration chains under defrag churn (contents vs oracle)
# ---------------------------------------------------------------------------

def _filled_pool(num_shards, num_pages, row_elems, seed=0, **kw):
    rng = np.random.default_rng(seed)
    srt = ShardedDMARuntime(num_shards=num_shards, **kw)
    kv = ShardedKVPool(srt, num_pages=num_pages, page=row_elems,
                       kv_heads=1, head_dim=1)
    content = rng.standard_normal((num_pages, row_elems)).astype(np.float32)
    for p in range(num_pages):
        kv.write_page(p, content[p], -content[p])
    return srt, kv, content


def test_migration_chains_correct_under_defrag_churn():
    rng = np.random.default_rng(5)
    srt, kv, content = _filled_pool(4, 64, 8, seed=5)
    # Churn: free ~a third of the pages, compact survivors onto the freed
    # low ids (disjoint src/dst by construction -> a clean numpy oracle).
    freed = rng.random(64) < 0.35
    live = np.flatnonzero(~freed)
    free = np.flatnonzero(freed)
    n = min(24, len(free))
    src, dst = live[-n:].tolist(), free[:n].tolist()
    stats = kv.move_pages(src, dst)

    assert stats.pages == n
    assert stats.cross_pages > 0            # churn crossed shard boundaries
    assert stats.hops > 0
    assert stats.hop_completions == stats.hops   # §II-D per-hop writeback
    assert stats.merge_ratio >= 1.0

    want = content.copy()
    want[dst] = content[src]
    got_k = srt.gather_pool(kv.POOL_K).reshape(64, 8)
    got_v = srt.gather_pool(kv.POOL_V).reshape(64, 8)
    np.testing.assert_array_equal(got_k, want)
    np.testing.assert_array_equal(got_v, -want)


def test_defragment_compacts_to_sequential_layout_and_frees_sources():
    srt, kv, content = _filled_pool(4, 64, 8, seed=7)
    pages = kv.alloc_on(3, 5) + kv.alloc_on(1, 3)
    before_k, _ = kv.page_rows(pages)
    free_before = sum(kv.free_pages_on(s) for s in range(4))
    new, stats, rate = kv.defragment(pages)
    assert new == list(range(len(pages)))   # lowest free run
    assert rate == 1.0                      # §II-C sequential by construction
    after_k, _ = kv.page_rows(new)
    np.testing.assert_array_equal(after_k, before_k)
    # Sources returned to their owners: net free count unchanged.
    assert sum(kv.free_pages_on(s) for s in range(4)) == free_before


def test_migration_stats_merge_and_empty_move():
    srt = ShardedDMARuntime(num_shards=2)
    kv = ShardedKVPool(srt, num_pages=8, page=4, kv_heads=1, head_dim=1)
    assert kv.move_pages([], []) == MigrationStats()
    with pytest.raises(ValueError, match="pair up"):
        kv.move_pages([1], [2, 3])


def test_migration_rejects_overlapping_and_duplicate_destinations():
    srt = ShardedDMARuntime(num_shards=2)
    kv = ShardedKVPool(srt, num_pages=8, page=4, kv_heads=1, head_dim=1)
    # A destination that is also a source is ambiguous once moves are
    # grouped by shard pair (a cross-shard swap would silently corrupt).
    with pytest.raises(ValueError, match="reads and writes"):
        kv.move_pages([0, 5], [5, 0])
    with pytest.raises(ValueError, match="duplicate destination"):
        kv.move_pages([0, 1], [6, 6])


# ---------------------------------------------------------------------------
# Mesh placement: 1xN and Nx1 meshes are the same sharded runtime
# ---------------------------------------------------------------------------

def _mesh(shape, axes):
    devs = np.asarray(jax.devices()[:int(np.prod(shape))]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)


@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs >=4 devices (the sharded CI lane)")
def test_mesh_shape_equivalence_1xN_vs_Nx1():
    outs = {}
    for name, shape in (("1x4", (1, 4)), ("4x1", (4, 1))):
        mesh = _mesh(shape, ("a", "b"))
        srt = ShardedDMARuntime(mesh=mesh)
        assert srt.num_shards == 4
        kv = ShardedKVPool(srt, num_pages=32, page=8, kv_heads=1,
                           head_dim=1)
        rng = np.random.default_rng(3)
        content = rng.standard_normal((32, 8)).astype(np.float32)
        for p in range(32):
            kv.write_page(p, content[p], -content[p])
        stats = kv.move_pages([25, 26, 27, 9, 2], [0, 1, 3, 30, 17])
        outs[name] = (srt.gather_pool(kv.POOL_K),
                      stats.cross_pages, stats.hops, stats.merge_ratio)
    np.testing.assert_array_equal(outs["1x4"][0], outs["4x1"][0])
    assert outs["1x4"][1:] == outs["4x1"][1:]


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >=2 devices (the sharded CI lane)")
def test_meshed_pools_land_on_their_shard_devices():
    mesh = _mesh((2,), ("dma",))
    srt = ShardedDMARuntime(mesh=mesh)
    kv = ShardedKVPool(srt, num_pages=8, page=4, kv_heads=1, head_dim=1)
    devs = [next(iter(srt.shards[s].pool(kv.POOL_K).devices()))
            for s in range(2)]
    assert devs[0] != devs[1]
    # and migration still round-trips across the two devices
    kv.write_page(1, np.ones(4), np.ones(4))
    kv.move_pages([1], [6])
    k, _ = kv.page_rows([6])
    np.testing.assert_array_equal(k[0], np.ones(4))


def test_mesh_shard_count_mismatch_rejected():
    class M:
        shape = {"a": 2}
        devices = np.asarray(jax.devices()[:1])
    with pytest.raises(ValueError, match="mesh has 2"):
        ShardedDMARuntime(num_shards=4, mesh=M())


def test_ambient_mesh_of_wrong_size_does_not_veto_explicit_shard_count():
    # The mesh-1 perf cell must run (unplaced) inside anyone's mesh
    # context: an *ambient* mesh only applies when the sizes agree.
    with shardlib.use_mesh(_FakeMesh()):   # 2x2 = 4 ambient shards
        srt = ShardedDMARuntime(num_shards=1)
        assert srt.num_shards == 1 and srt.mesh is None
        kv = ShardedKVPool(srt, num_pages=8, page=4, kv_heads=1,
                           head_dim=1)
        kv.write_page(0, np.ones(4), np.ones(4))
        kv.move_pages([0], [5])
        np.testing.assert_array_equal(kv.page_rows([5])[0][0], np.ones(4))


# ---------------------------------------------------------------------------
# Sharded cycle model + perf cell
# ---------------------------------------------------------------------------

def test_simulate_sharded_single_shard_has_no_migration_traffic():
    from repro.core.simulator import simulate_sharded
    r = simulate_sharded(1, 2, 13, 64, num_transfers=100,
                         cross_fraction=0.5)
    assert r.sharded.cross_transfers == 0
    assert r.sharded.migration_cycles_mean == 0.0


def test_simulate_sharded_interconnect_contention_grows_with_cross_traffic():
    from repro.core.simulator import simulate_sharded
    lo = simulate_sharded(4, 2, 13, 64, num_transfers=150,
                          cross_fraction=0.05)
    hi = simulate_sharded(4, 2, 13, 64, num_transfers=150,
                          cross_fraction=0.6)
    assert hi.sharded.cross_transfers > lo.sharded.cross_transfers
    assert hi.sharded.migration_cycles_mean > \
        lo.sharded.migration_cycles_mean
    # Shard-local buses are untouched by the fabric: same local shares.
    assert hi.sharded.per_shard_utilization == \
        pytest.approx(lo.sharded.per_shard_utilization)


def test_simulate_multichannel_default_path_unchanged_by_sharding_params():
    from repro.core.simulator import SimConfig, simulate, simulate_multichannel
    one = simulate_multichannel(1, 13, 64, num_transfers=300)
    base = simulate(SimConfig.base(), 13, 64)
    assert one.aggregate_utilization == pytest.approx(base.utilization,
                                                      rel=0.05)
    assert one.sharded is None
    with pytest.raises(ValueError, match="cross_fraction requires"):
        simulate_multichannel(2, 13, 64, cross_fraction=0.5)


@pytest.mark.slow  # full mesh axis incl. 8 shards: CI sharded/slow lane
def test_sharded_cell_deterministic_and_meets_fabric_floors():
    from repro.perf.sharded_cell import (
        MIN_OVERLAP_RATIO,
        MIN_RETAINED_THROUGHPUT,
        SHARDED_GATED_METRICS,
        run_sharded_cell,
    )
    cells = {}
    for mesh in (1, 2, 4, 8):
        m1, c1 = run_sharded_cell(0, mesh, repeats=2)
        m2, c2 = run_sharded_cell(0, mesh, repeats=2)
        assert (m1, c1) == (m2, c2), f"mesh {mesh} not deterministic"
        assert set(m1) == set(SHARDED_GATED_METRICS)
        cells[mesh] = m1
    # Mesh 1 has no fabric: every fabric-dependent metric pins to zero.
    assert cells[1]["cross_shard_migration_cycles"] == 0.0
    assert cells[1]["migration_overlap_ratio"] == 0.0
    assert cells[1]["throughput_retained_during_resize"] == 1.0
    for mesh in (2, 4, 8):
        assert cells[mesh]["cross_shard_migration_cycles"] > 0.0
        assert cells[mesh]["p99_migration_stall_cycles"] > 0.0
        assert cells[mesh]["rebalance_convergence_steps"] > 0
    # The cell enforces these floors itself at mesh >= 4 (RuntimeError);
    # assert them here too so a silently-weakened cell still fails.
    for mesh in (4, 8):
        assert cells[mesh]["migration_overlap_ratio"] >= MIN_OVERLAP_RATIO
        assert cells[mesh]["throughput_retained_during_resize"] >= \
            MIN_RETAINED_THROUGHPUT
    for m in cells.values():
        assert m["migration_chain_merge_ratio"] >= 1.0
        assert 0.0 < m["per_shard_bus_utilization"] <= 1.0


# ---------------------------------------------------------------------------
# Sharded serve path: ownership routing, remote reads become migrations
# ---------------------------------------------------------------------------

def test_sharded_serve_routes_by_ownership_and_migrates_remote_pages():
    from repro.configs.registry import get_config
    from repro.models import init_params
    from repro.serve import Request
    from repro.distributed.sharded_runtime import ShardedServeEngine

    cfg = get_config("qwen2.5-3b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    srt = ShardedDMARuntime(num_shards=2)
    kv = ShardedKVPool(srt, num_pages=32, page=2, kv_heads=2, head_dim=4)
    eng = ShardedServeEngine(params, cfg, runtime=srt, kv_pool=kv,
                             capacity=1, max_len=32)

    # Shard-local requests go to their owner; no migration happens.
    for uid in range(4):
        pages = kv.alloc_on(uid % 2, 2)
        t = eng.submit(SubmitRequest(request=Request(
            uid=uid, prompt=[1, 2, 3], max_new_tokens=2, kv_pages=pages)))
        assert t.shard == uid % 2
    assert eng.remote_page_reads == 0

    # A request whose pages straddle shards routes to the majority owner
    # and pulls the minority pages across as a migration chain.
    p0 = kv.alloc_on(0, 1)
    p1 = kv.alloc_on(1, 2)
    mixed = Request(uid=9, prompt=[4, 5], max_new_tokens=2,
                    kv_pages=p0 + p1)
    shard = eng.submit(SubmitRequest(request=mixed)).shard
    assert shard == 1
    assert eng.remote_page_reads == 1
    assert eng.migration.pages == 1 and eng.migration.hops == 1
    # The request's page list was rewritten to all-local pages.
    assert all(kv.owner.owner(p) == 1 for p in mixed.kv_pages)

    # A duplicated remote page migrates (and frees) exactly once: no
    # double-free into the allocator, no leaked allocation.
    free_before = [kv.free_pages_on(s) for s in range(2)]
    p0b = kv.alloc_on(0, 1)
    dup = Request(uid=10, prompt=[6], max_new_tokens=2,
                  kv_pages=p0b + p0b + kv.alloc_on(1, 3))
    # majority owner wins, 2 vs 3
    assert eng.submit(SubmitRequest(request=dup)).shard == 1
    assert len(set(dup.kv_pages)) == 4      # both remote copies remapped alike
    assert all(kv.owner.owner(p) == 1 for p in dup.kv_pages)
    kv.release(sorted(set(dup.kv_pages)))
    assert [kv.free_pages_on(s) for s in range(2)] == free_before
    assert sorted(set(kv._free[0] + kv._free[1])) == \
        sorted(kv._free[0] + kv._free[1])   # free lists hold no duplicates

    done = eng.run(max_steps=200)
    assert sorted(done) == [0, 1, 2, 3, 9, 10]
    assert len(eng.poll_completed()) == 6
    pc = eng.perf_counters()
    assert pc["sharded.requests_per_shard"] == [2, 4]
    assert pc["sharded.completed"] == 6


def test_shared_page_not_freed_while_another_request_reads_it():
    from repro.configs.registry import get_config
    from repro.models import init_params
    from repro.serve import Request
    from repro.distributed.sharded_runtime import ShardedServeEngine

    cfg = get_config("qwen2.5-3b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    srt = ShardedDMARuntime(num_shards=2)
    kv = ShardedKVPool(srt, num_pages=16, page=2, kv_heads=2, head_dim=4)
    eng = ShardedServeEngine(params, cfg, runtime=srt, kv_pool=kv,
                             capacity=2, max_len=16)

    (p,) = kv.alloc_on(0, 1)
    kv.write_page(p, np.full(kv.row_elems, 7.0), np.full(kv.row_elems, 7.0))
    a = Request(uid=0, prompt=[1], max_new_tokens=1, kv_pages=[p])
    eng.submit(SubmitRequest(request=a))
    # B shares page p but routes to shard 1, migrating p's contents away.
    b = Request(uid=1, prompt=[2], max_new_tokens=1,
                kv_pages=[p] + kv.alloc_on(1, 2))
    eng.submit(SubmitRequest(request=b))
    # p is still read by A: it must NOT be back on the free list...
    assert p not in kv._free[0]
    # ...and its contents survive for A (migration copies, never zeroes).
    np.testing.assert_array_equal(kv.page_rows([p])[0][0],
                                  np.full(kv.row_elems, 7.0))
    eng.run(max_steps=50)
    eng.poll_completed()
    # Last reader delivered -> the shared source page frees exactly once.
    assert kv._free[0].count(p) == 1


def test_migration_hop_does_not_steal_serve_completion_events():
    """A cross-shard hop landing on a shard must not consume that shard's
    pending serve-request completions (shared completion queue)."""
    from repro.configs.registry import get_config
    from repro.models import init_params
    from repro.serve import Request
    from repro.distributed.sharded_runtime import ShardedServeEngine

    cfg = get_config("qwen2.5-3b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    srt = ShardedDMARuntime(num_shards=2)
    kv = ShardedKVPool(srt, num_pages=16, page=2, kv_heads=2, head_dim=4)
    eng = ShardedServeEngine(params, cfg, runtime=srt, kv_pool=kv,
                             capacity=1, max_len=16)
    # Request A completes on shard 1 but is deliberately NOT polled yet.
    a = Request(uid=0, prompt=[1], max_new_tokens=1,
                kv_pages=kv.alloc_on(1, 1))
    eng.submit(SubmitRequest(request=a))
    for _ in range(10):
        eng.step()
        if 0 in eng.engines[1].completed:
            break
    assert 0 in eng.engines[1].completed
    # A remote-page admission now triggers a migration hop INTO shard 1,
    # which drains shard 1's runtime before A's writeback was polled.
    b = Request(uid=1, prompt=[2], max_new_tokens=1,
                kv_pages=kv.alloc_on(0, 1) + kv.alloc_on(1, 2))
    assert eng.submit(SubmitRequest(request=b)).shard == 1
    assert eng.migration.hops == 1
    # A's completion must still be observable through the poll path.
    delivered = {r.uid for r in eng.poll_completed()}
    assert 0 in delivered


def test_sharded_pool_rejects_reserved_staging_name():
    srt = ShardedDMARuntime(num_shards=2)
    with pytest.raises(ValueError, match="reserved"):
        srt.register_sharded_pool(
            ShardedDMARuntime.STAGE_POOL, jnp.zeros(16, jnp.float32),
            PageOwnerMap(4, 2), 2)


def test_sharded_serve_without_kv_pool_routes_round_robin():
    from repro.configs.registry import get_config
    from repro.models import init_params
    from repro.serve import Request
    from repro.distributed.sharded_runtime import ShardedServeEngine

    cfg = get_config("qwen2.5-3b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    srt = ShardedDMARuntime(num_shards=2)
    eng = ShardedServeEngine(params, cfg, runtime=srt, capacity=1,
                             max_len=16)
    # kv_pages without a pool must not crash: ownership is unknowable, so
    # the router falls back to round-robin.
    shards = [eng.submit(SubmitRequest(request=Request(
                  uid=u, prompt=[1], max_new_tokens=1,
                  kv_pages=[3] if u == 1 else None))).shard
              for u in range(4)]
    assert shards == [0, 1, 0, 1]
    assert eng.remote_page_reads == 0
