"""In-flight transform engines + the unified submit contract (DESIGN.md §9).

Covers the transform midend end to end: the EF-int8 round trip against
its numpy oracle across every registry arch's KV shape, transform-aware
coalescing (kv_int8 merges bit-identically, transpose never merges),
fused-ingress reduction, the bucketed Pallas quantize-copy kernel, the
four-layer ``SubmitRequest``/``Ticket`` contract with its deprecation
shims, the ``SimConfig.prefetch`` int coercion, the unified perf-counter
namespace, and priority channel selection.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.registry import list_archs
from repro.core.chain import from_segments
from repro.core.simulator import SimConfig, simulate
from repro.core.speculation import FixedDepth
from repro.core.transform import (
    IDENTITY,
    TransformSpec,
    as_transform,
    kv8_roundtrip,
    kv8_roundtrip_np,
    reference_apply,
)
from repro.runtime import (
    ChannelConfig,
    DMARuntime,
    SubmitRequest,
    Ticket,
    coalesce,
)

POOL = 4096


# ---------------------------------------------------------------------------
# kv_int8 round trip: fidelity + oracle agreement across every registry arch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", list_archs())
def test_kv8_roundtrip_all_archs_within_tolerance(arch):
    """Quantize→dequantize on each arch's KV shape stays within the
    EF-int8 half-step bound and lands on the numpy oracle's code grid."""
    cfg = get_config(arch, reduced=True)
    heads = cfg.num_kv_heads or 1
    hd = cfg.head_dim_ or 8
    rng = np.random.default_rng(list_archs().index(arch))
    kv = rng.standard_normal((2, heads, 16, hd)).astype(np.float32)
    got = np.asarray(kv8_roundtrip(jnp.asarray(kv)))
    oracle = kv8_roundtrip_np(kv)
    step = float(np.abs(kv).max()) / 127.0      # >= every per-block scale
    assert got.shape == kv.shape and got.dtype == kv.dtype
    assert float(np.max(np.abs(got - kv))) <= 0.5 * step + 1e-6, arch
    # Device vs numpy arithmetic may flip a code right at a rounding
    # boundary (1-ULP scale difference), never more than one step.
    assert float(np.max(np.abs(got - oracle))) <= step + 1e-6, arch


def test_kv8_roundtrip_is_idempotent():
    rng = np.random.default_rng(0)
    x = rng.standard_normal(POOL).astype(np.float32)
    once = kv8_roundtrip_np(x)
    assert np.array_equal(kv8_roundtrip_np(once), once)


# ---------------------------------------------------------------------------
# Transform-aware coalescer
# ---------------------------------------------------------------------------

def _kv8_runtime_pass(run_coalescer):
    rt = DMARuntime([ChannelConfig(name="ch0", tier="serial",
                                   ring_capacity=128, max_len=512)])
    rng = np.random.default_rng(7)
    src = rng.standard_normal(POOL).astype(np.float32)
    rt.register_pool("src", jnp.asarray(src))
    rt.register_pool("dst", jnp.zeros(POOL, jnp.float32))
    # Contiguous 64-elem segments so the merge pass genuinely fuses.
    starts = np.arange(0, 1024, 64, dtype=np.int64)
    d = from_segments(starts, starts + 2048,
                      np.full(starts.size, 64, np.int64))
    res = rt.submit(SubmitRequest(chain=d, src_pool="src", dst_pool="dst",
                                  transform="kv_int8",
                                  run_coalescer=run_coalescer))
    rt.drain_until_idle()
    return src, d, np.asarray(rt.pool("dst")), res


def test_kv8_coalesced_merge_is_bit_identical_to_unmerged():
    """kv_int8 is pool-absolute, so merged and unmerged execution move
    byte-for-byte identical payloads (the merge-safety contract)."""
    src, d, merged, res_m = _kv8_runtime_pass(True)
    _, _, unmerged, res_u = _kv8_runtime_pass(False)
    assert res_m.coalesce is not None
    assert res_m.coalesce.n_out < res_m.coalesce.n_in   # merging happened
    assert np.array_equal(merged, unmerged)
    ref = reference_apply(TransformSpec.kv_int8(), d, src,
                          np.zeros(POOL, np.float32))
    step = float(np.abs(src).max()) / 127.0
    assert float(np.max(np.abs(merged - ref))) <= step + 1e-6


def test_transpose_is_never_merged_and_matches_oracle():
    spec = TransformSpec.transpose(64, 64)
    assert not spec.merge_safe and IDENTITY.merge_safe
    assert as_transform("kv_int8").merge_safe
    starts = np.arange(0, 512, 64, dtype=np.int64)
    d = from_segments(starts, starts + 2048,
                      np.full(starts.size, 64, np.int64))
    fused, fstats = coalesce(d, max_len=512)
    unfused, ustats = coalesce(d, max_len=512, allow_merge=spec.merge_safe)
    assert fstats.n_out < fstats.n_in          # mergeable without transform
    assert ustats.n_out == ustats.n_in         # transpose submits unmerged

    rt = DMARuntime([ChannelConfig(name="ch0", tier="serial",
                                   ring_capacity=128, max_len=512)])
    rng = np.random.default_rng(11)
    src = rng.standard_normal(POOL).astype(np.float32)
    rt.register_pool("src", jnp.asarray(src))
    rt.register_pool("dst", jnp.zeros(POOL, jnp.float32))
    rt.submit(SubmitRequest(chain=d, src_pool="src", dst_pool="dst",
                            transform=spec))
    rt.drain_until_idle()
    ref = reference_apply(spec, d, src, np.zeros(POOL, np.float32))
    assert np.array_equal(np.asarray(rt.pool("dst")), ref)


def test_reduce_sum_adds_into_destination():
    rt = DMARuntime([ChannelConfig(name="ch0", tier="serial",
                                   ring_capacity=128, max_len=512)])
    rng = np.random.default_rng(13)
    src = rng.standard_normal(POOL).astype(np.float32)
    dst0 = rng.standard_normal(POOL).astype(np.float32)
    rt.register_pool("src", jnp.asarray(src))
    rt.register_pool("dst", jnp.asarray(dst0))
    starts = np.arange(0, 256, 64, dtype=np.int64)
    d = from_segments(starts, starts + 1024,
                      np.full(starts.size, 64, np.int64))
    rt.submit(SubmitRequest(chain=d, src_pool="src", dst_pool="dst",
                            transform="reduce_sum"))
    rt.drain_until_idle()
    ref = reference_apply(TransformSpec.reduce_sum(), d, src, dst0)
    got = np.asarray(rt.pool("dst"))
    np.testing.assert_allclose(got, ref, rtol=0, atol=1e-6)
    # Untouched elements keep the original destination exactly.
    touched = np.zeros(POOL, bool)
    touched[1024:1280] = True
    assert np.array_equal(got[~touched], dst0[~touched])


# ---------------------------------------------------------------------------
# Bucketed Pallas quantize-copy kernel vs the numpy oracle
# ---------------------------------------------------------------------------

def test_quantize_copy_kernel_interpret_matches_oracle():
    from repro.kernels.quantize_copy import quantize_copy_bucketed

    rows, unit = 8, 256
    rng = np.random.default_rng(3)
    src = rng.standard_normal((rows, unit)).astype(np.float32)
    dst = rng.standard_normal((rows, unit)).astype(np.float32)
    src_idx = np.array([0, 3, 5], np.int32)
    dst_idx = np.array([1, 2, 4], np.int32)
    out = np.asarray(quantize_copy_bucketed(
        jnp.asarray(src_idx), jnp.asarray(dst_idx),
        jnp.asarray(src), jnp.asarray(dst), n_bucket=4, interpret=True))
    expected = dst.copy()
    for s, t in zip(src_idx, dst_idx):
        expected[t] = kv8_roundtrip_np(src[s])
    step = float(np.abs(src).max()) / 127.0
    moved = np.zeros(rows, bool)
    moved[dst_idx] = True
    assert float(np.max(np.abs(out[moved] - expected[moved]))) \
        <= step + 1e-6
    # Inactive (padded) grid steps and unaddressed rows stay untouched.
    assert np.array_equal(out[~moved], dst[~moved])


def test_quantize_copy_rejects_non_block_rows():
    from repro.kernels.quantize_copy import quantize_copy

    with pytest.raises(ValueError, match="not a multiple"):
        quantize_copy(jnp.zeros(1, jnp.int32), jnp.zeros(1, jnp.int32),
                      jnp.zeros((2, 100), jnp.float32),
                      jnp.zeros((2, 100), jnp.float32), interpret=True)


# ---------------------------------------------------------------------------
# The unified submit contract: four layers, one SubmitRequest in, Ticket out
# ---------------------------------------------------------------------------

def _chain():
    return from_segments(np.array([0, 64], np.int64),
                         np.array([2048, 2112], np.int64),
                         np.array([64, 64], np.int64))


def _runtime():
    rt = DMARuntime([ChannelConfig(name="ch0", tier="serial",
                                   ring_capacity=64, max_len=512)])
    rt.register_pool("src", jnp.arange(POOL, dtype=jnp.float32))
    rt.register_pool("dst", jnp.zeros(POOL, jnp.float32))
    return rt


def test_runtime_submit_unified_returns_ticket_without_warning():
    rt = _runtime()
    done = []
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        res = rt.submit(SubmitRequest(chain=_chain(), src_pool="src",
                                      dst_pool="dst",
                                      on_complete=done.append))
    assert isinstance(res, Ticket)
    assert res.tickets and res.channel == "ch0"
    rt.drain_until_idle()
    rt.completion.poll()
    assert len(done) == 1


def test_runtime_legacy_keyword_submit_raises_type_error():
    rt = _runtime()
    with pytest.raises(TypeError, match="DMARuntime.submit"):
        rt.submit(_chain())
    # The unified form still carries the same pools on the request.
    res = rt.submit(SubmitRequest(chain=_chain(), src_pool="src",
                                  dst_pool="dst"))
    assert isinstance(res, Ticket) and res.tickets
    rt.drain_until_idle()
    assert np.asarray(rt.pool("dst"))[2048 + 5] == 5.0


def test_channel_submit_requires_submit_request():
    rt = _runtime()
    ch = rt.channels["ch0"]
    d = _chain()
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        t = ch.submit(SubmitRequest(chain=d, src_pool="src",
                                    dst_pool="dst"), [101, 102])
    assert isinstance(t, Ticket) and t.tickets == [101, 102]
    with pytest.raises(TypeError, match="Channel.submit"):
        ch.submit(d, [103, 104])


def test_serve_engine_submit_requires_submit_request():
    from repro.serve import Request, ServeEngine

    cfg = get_config("mamba2-780m", reduced=True)
    from repro.models import init_params
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, capacity=2, max_len=48)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        t = eng.submit(SubmitRequest(request=Request(
            uid=0, prompt=[1, 2, 3], max_new_tokens=2)))
    assert isinstance(t, Ticket) and t.uid == 0
    bare = Request(uid=1, prompt=[1, 2], max_new_tokens=2)
    with pytest.raises(TypeError, match="ServeEngine.submit"):
        eng.submit(bare)
    eng.submit(SubmitRequest(request=bare))
    with pytest.raises(ValueError, match="request"):
        eng.submit(SubmitRequest(chain=_chain()))
    done = eng.run(max_steps=200)
    assert sorted(done) == [0, 1]

    pc = eng.perf_counters()
    assert pc["serve.completed"] == 2
    # The bare-key DeprecationWarning aliases are gone: a legacy key is a
    # plain KeyError, and iteration/JSON see only the dotted namespace.
    with pytest.raises(KeyError):
        pc["completed"]
    assert pc.get("completed") is None
    assert "completed" not in pc
    assert all("." in k or k == "translation" for k in pc)


def test_sharded_serve_submit_requires_submit_request():
    from repro.distributed.sharded_runtime import (
        ShardedDMARuntime,
        ShardedKVPool,
        ShardedServeEngine,
    )
    from repro.models import init_params
    from repro.serve import Request

    cfg = get_config("qwen2.5-3b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    srt = ShardedDMARuntime(num_shards=2)
    kv = ShardedKVPool(srt, num_pages=16, page=2, kv_heads=2, head_dim=4)
    eng = ShardedServeEngine(params, cfg, runtime=srt, kv_pool=kv,
                             capacity=1, max_len=32)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        t = eng.submit(SubmitRequest(request=Request(
            uid=0, prompt=[1, 2], max_new_tokens=2,
            kv_pages=kv.alloc_on(1, 2))))
    assert isinstance(t, Ticket) and t.shard == 1 and t.uid == 0
    bare = Request(uid=1, prompt=[3], max_new_tokens=2)
    with pytest.raises(TypeError, match="ShardedServeEngine.submit"):
        eng.submit(bare)
    t2 = eng.submit(SubmitRequest(request=Request(
        uid=1, prompt=[3], max_new_tokens=2, kv_pages=kv.alloc_on(0, 2))))
    assert t2.shard == 0
    done = eng.run(max_steps=200)
    assert sorted(done) == [0, 1]
    pc = eng.perf_counters()
    assert pc["sharded.completed"] == 2
    assert pc["sharded.requests_per_shard"] == [1, 1]
    with pytest.raises(KeyError):
        pc["requests_per_shard"]


def test_priority_submission_takes_emptiest_eligible_channel():
    rt = DMARuntime([
        ChannelConfig(name="a", tier="serial", ring_capacity=64, max_len=512),
        ChannelConfig(name="b", tier="serial", ring_capacity=64, max_len=512),
    ])
    rt.register_pool("src", jnp.arange(POOL, dtype=jnp.float32))
    rt.register_pool("dst", jnp.zeros(POOL, jnp.float32))
    # Load channel "a" so "b" has strictly more free ring slots.
    rt.submit(SubmitRequest(chain=_chain(), src_pool="src", dst_pool="dst",
                            channel="a"))
    res = rt.submit(SubmitRequest(chain=_chain(), src_pool="src",
                                  dst_pool="dst", priority=1))
    assert res.channel == "b"
    rt.drain_until_idle()


# ---------------------------------------------------------------------------
# SimConfig.prefetch coercion + transform-aware cycle accounting
# ---------------------------------------------------------------------------

def test_simconfig_bare_int_prefetch_coerces_with_warning():
    with pytest.warns(DeprecationWarning, match="SimConfig.prefetch"):
        cfg = dataclasses.replace(SimConfig.base(), prefetch=4)
    assert isinstance(cfg.prefetch, FixedDepth)
    assert cfg.prefetch.depth == 4


def test_simconfig_factories_do_not_warn():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        for cfg in (SimConfig.base(), SimConfig.translated_frontend(),
                    SimConfig.logicore_ip(), SimConfig.speculation(),
                    SimConfig.scaled()):
            assert not isinstance(cfg.prefetch, int)


def test_payload_ratio_charges_fewer_beats():
    full = simulate(SimConfig.translated_frontend(), 13, 1024,
                    num_transfers=64)
    kv8 = simulate(SimConfig.translated_frontend(), 13, 1024,
                   num_transfers=64,
                   payload_ratio=TransformSpec.kv_int8().payload_ratio)
    assert kv8.cycles < full.cycles
    with pytest.raises(ValueError, match="payload_ratio"):
        simulate(SimConfig.base(), 13, 1024, num_transfers=4,
                 payload_ratio=0.0)
