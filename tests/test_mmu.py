"""Virtual paging tests (DESIGN.md §11): PageTable vs a numpy oracle,
remap-defrag ≡ copy-defrag across every registry config, no lost pages
under ownership flips racing in-flight fabric tickets, base-invariant
cached-translation drains, the PageRef deprecation shim, and the IOTLB
cycle model.

The hypothesis suite at the bottom (PageTable generation/remap
invariants) is slow-marked and skips on minimal installs; everything
else must collect without hypothesis.
"""
import dataclasses
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.core.chain import from_pages
from repro.core.pageref import PageRef, as_pageref, as_pagerefs
from repro.core.signature import canonicalize
from repro.core.simulator import SimConfig, simulate
from repro.core.speculation import FixedDepth
from repro.distributed.sharded_runtime import (
    ShardedDMARuntime,
    ShardedKVPool,
)
from repro.mmu import IOTLBParams, PageTable, remap_cycles
from repro.runtime import SubmitRequest, default_runtime
from repro.runtime.lowering import translate_chain
from repro.serve import PagedKVCache

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                  # minimal installs
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# PageTable vs an independent numpy oracle
# ---------------------------------------------------------------------------

class _OracleTable:
    """Independent re-implementation of the PageTable contract."""

    def __init__(self, num_pages, num_shards):
        per = num_pages // num_shards
        self.slot = np.arange(num_pages, dtype=np.int64)
        self.shard = self.slot // per
        self.gen = np.zeros(num_pages, np.int64)
        self.home = {}
        self.global_gen = 0

    def _bump(self, v):
        self.gen[v] += 1
        self.global_gen += 1

    def remap(self, v, s, slot):
        self.shard[v], self.slot[v] = s, slot
        self.home.pop(v, None)
        self._bump(v)

    def flip(self, v, s):
        if self.slot[v] >= 0:
            self.home[v] = (int(self.shard[v]), int(self.slot[v]))
        self.shard[v], self.slot[v] = s, -1
        self._bump(v)

    def pull(self, v, slot):
        self.home.pop(v)
        self.slot[v] = slot
        self._bump(v)


def test_page_table_matches_numpy_oracle_under_random_ops():
    rng = np.random.default_rng(0)
    t = PageTable(32, 4)
    o = _OracleTable(32, 4)
    for _ in range(400):
        v = int(rng.integers(32))
        op = int(rng.integers(3))
        if op == 0:
            s, slot = int(rng.integers(4)), int(rng.integers(32))
            t.remap(v, s, slot)
            o.remap(v, s, slot)
        elif op == 1 and not t.is_pending(v):
            s = int(rng.integers(4))
            t.flip_owner(v, s)
            o.flip(v, s)
        elif op == 2 and t.is_pending(v):
            slot = int(rng.integers(32))
            home = t.complete_pull(v, slot)
            assert home == o.home[v]
            o.pull(v, slot)
    snap = t.snapshot()
    np.testing.assert_array_equal(snap["slot"], o.slot)
    np.testing.assert_array_equal(snap["shard"], o.shard)
    np.testing.assert_array_equal(snap["gen"], o.gen)
    assert t.generation == o.global_gen
    assert t.pending_pages() == sorted(o.home)
    # Vectorized translation agrees with the scalar path (and passes the
    # block tables' -1 sentinel through untouched).
    probe = np.array([-1, 0, 5, 31, -1], np.int64)
    want = [p if p < 0 else t.slot_of(p) for p in probe]
    np.testing.assert_array_equal(t.slots_of(probe), want)


def test_rehome_slots_follows_physical_relocation_and_pending_homes():
    t = PageTable(16, 2)
    t.flip_owner(3, 1)                   # pending, home = (0, 3)
    t.remap(5, 0, 7)                     # 5 aliases slot 7
    # Slots 3 and 7 physically move (an evacuation would do this).
    t.rehome_slots({3: (1, 12), 7: (1, 13)})
    assert t.map(5) == (1, 13)
    assert t.map(7) == (1, 13)           # identity mapping of slot 7 follows
    assert t.is_pending(3) and t.home_of(3) == (1, 12)
    assert t.rehome_slots({}) is None    # empty map: no-op


def test_remap_cycles_cost_model():
    assert remap_cycles(0, 10) == 0
    assert remap_cycles(1, 10) == 1 * 3 + 10
    assert remap_cycles(24, 4) == 24 * 3 + 4


# ---------------------------------------------------------------------------
# Remap-defrag ≡ copy-defrag, all registry configs
# ---------------------------------------------------------------------------

def _fragmented_pool(arch: str, seed: int = 0) -> PagedKVCache:
    """Two interleaved sequences: seq 0's pages land on stride-2 ids."""
    cfg = get_config(arch, reduced=True)
    pool = PagedKVCache(page=4, num_pages=32, max_seqs=2,
                        max_pages_per_seq=8,
                        kv_heads=cfg.num_kv_heads or 1,
                        head_dim=cfg.head_dim_ or 8)
    rng = np.random.default_rng(seed)
    pool.admit(0)
    pool.admit(1)
    for _ in range(10):                  # 10 tokens -> 3 pages per seq
        for s in (0, 1):
            pool.append(s,
                        rng.standard_normal((pool.kv_heads, pool.head_dim)),
                        rng.standard_normal((pool.kv_heads, pool.head_dim)))
    return pool


@pytest.mark.parametrize("arch", list_archs())
def test_defrag_remap_bit_identical_to_copy_every_config(arch):
    before = _fragmented_pool(arch)
    remapped = _fragmented_pool(arch)
    copied = _fragmented_pool(arch)
    rate_r = remapped.defragment(0)                       # table writes only
    rate_c = copied.defragment(0, default_runtime(2), mode="copy")
    assert rate_r == rate_c == 1.0                        # dense run
    assert np.array_equal(remapped.tables[0], copied.tables[0])
    for s in (0, 1):
        k0, v0 = before.dense_view(s)
        kr, vr = remapped.dense_view(s)
        kc, vc = copied.dense_view(s)
        np.testing.assert_array_equal(kr, kc)             # bit-identical
        np.testing.assert_array_equal(vr, vc)
        np.testing.assert_array_equal(kr, k0)             # and lossless
        np.testing.assert_array_equal(vr, v0)
    # The remap leg never built a descriptor chain: contents stayed in
    # their physical slots, only the virtual numbering changed.
    live = [int(p) for p in remapped.tables[0] if p >= 0]
    assert live == sorted(live) and len(live) == 3


# ---------------------------------------------------------------------------
# No lost pages: ownership flips racing in-flight fabric tickets
# ---------------------------------------------------------------------------

def _assert_no_lost_pages(kv):
    """Accounting oracle: every physical slot is either on exactly one
    free list or named by exactly one claimed, resident virtual page."""
    claimed = [int(v) for v in np.flatnonzero(kv._vused)]
    seen = {}
    for v in claimed:
        s, slot = kv.table.map(v)
        assert slot >= 0, f"claimed vpage {v} still pending"
        assert (s, slot) not in seen, \
            f"vpages {seen[(s, slot)]} and {v} alias slot {(s, slot)}"
        seen[(s, slot)] = v
    free = [slot for lst in kv._free for slot in lst]
    assert len(free) == len(set(free))
    assert len(free) + len(claimed) == kv.owner.num_pages
    for s, slot in seen:
        assert kv.owner.owner(slot) == s          # slot lives on its owner
        assert slot not in free


def test_no_lost_pages_when_flips_race_inflight_tickets():
    srt = ShardedDMARuntime(num_shards=4)
    kv = ShardedKVPool(srt, num_pages=64, page=4, kv_heads=1, head_dim=1)
    src = kv.alloc_on(0, 8)
    for i, p in enumerate(src):
        row = np.full(kv.row_elems, float(i + 1), np.float32)
        kv.write_page(p, row, -row)
    dst = kv.alloc_on(1, 4)
    # Cross-shard copy left in flight — tickets live on the fabric.
    kv.move_pages(src[:4], dst, drain=False)
    assert srt.fabric_outstanding() == 1
    # Race: flip ownership while those tickets are still in flight —
    # including a page that is a *source* of the in-flight copy.
    tail = kv.flip_ownership(src[4:], 2)
    head = kv.flip_ownership([src[0]], 3)
    assert kv.owner_of(tail[0]) == 2 and kv.owner_of(head[0]) == 3
    srt.pump_until_idle()
    srt.drain_until_idle()
    # First touch pulls the flipped pages; contents must be intact.
    k_tail, _ = kv.page_rows(tail)
    k_head, _ = kv.page_rows(head)
    for j, krow in enumerate(k_tail):
        np.testing.assert_array_equal(
            krow, np.full(kv.row_elems, float(4 + j + 1), np.float32))
    np.testing.assert_array_equal(
        k_head[0], np.full(kv.row_elems, 1.0, np.float32))
    assert kv.first_touch_pulls == len(tail) + 1
    # The in-flight copy still landed the right bytes.
    k_dst, _ = kv.page_rows(dst)
    for j, krow in enumerate(k_dst):
        np.testing.assert_array_equal(
            krow, np.full(kv.row_elems, float(j + 1), np.float32))
    _assert_no_lost_pages(kv)
    # Releasing an unpulled flip returns the *home* slot, not a phantom.
    more = kv.flip_ownership(kv.alloc_on(1, 2), 3)
    kv.release(more)
    _assert_no_lost_pages(kv)


# ---------------------------------------------------------------------------
# Cached-translation drains: bit-identical pre/post remap
# ---------------------------------------------------------------------------

def test_translation_digest_base_invariant_and_drain_bit_identical():
    row = 8
    table = PageTable(16)
    rt = default_runtime(2, ring_capacity=64)
    rng = np.random.default_rng(3)
    src0 = rng.standard_normal(16 * row).astype(np.float32)
    rt.register_pool("src", jnp.asarray(src0))
    rt.register_pool("dst", jnp.zeros(16 * row, jnp.float32))
    chain = from_pages([3, 4, 5], row)           # virtual block table
    digest0 = canonicalize(chain).digest

    def _drain():
        rt.register_pool("dst", jnp.zeros(16 * row, jnp.float32))
        phys = translate_chain(chain, table, row, translate_dst=False)
        rt.submit(SubmitRequest(chain=phys, src_pool="src",
                                dst_pool="dst"))
        rt.drain_until_idle()
        return phys, np.asarray(rt.pool("dst"))

    phys1, out1 = _drain()
    # Physically relocate page 4's contents to slot 9, then remap.
    moved = src0.copy()
    moved[9 * row:10 * row] = moved[4 * row:5 * row]
    rt.register_pool("src", jnp.asarray(moved))
    table.remap(4, 0, 9)
    # The *virtual* chain is untouched: same CanonicalChain digest, so
    # signature-keyed caches keyed on the virtual form stay warm.
    assert canonicalize(chain).digest == digest0
    phys2, out2 = _drain()
    assert not np.array_equal(np.asarray(phys1.src), np.asarray(phys2.src))
    np.testing.assert_array_equal(out1, out2)    # bit-identical drain


def test_translate_chain_refuses_pending_pages():
    table = PageTable(8, 2)
    table.flip_owner(2, 1)
    chain = from_pages([1, 2], 4)
    with pytest.raises(RuntimeError, match="pending an ownership pull"):
        translate_chain(chain, table, 4)


# ---------------------------------------------------------------------------
# PageRef deprecation shim
# ---------------------------------------------------------------------------

def test_pageref_is_opaque_but_int_compatible():
    r = PageRef(7, generation=3)
    assert int(r) == 7 and r.vpage == 7 and r.generation == 3
    assert as_pageref(r) is r                     # refs pass silently
    with pytest.raises(TypeError, match="expected a PageRef"):
        as_pageref("7")


def test_bare_int_pages_warn_once_per_list_and_refs_do_not():
    srt = ShardedDMARuntime(num_shards=2)
    kv = ShardedKVPool(srt, num_pages=32, page=4, kv_heads=1, head_dim=1)
    pages = kv.alloc_on(0, 3)
    with warnings.catch_warnings():
        warnings.simplefilter("error")            # refs: no warning at all
        kv.page_rows(pages)
        kv.release(pages)
    pages = kv.alloc_on(0, 3)
    with pytest.warns(DeprecationWarning,
                      match="bare int page ids are deprecated") as rec:
        kv.page_rows([int(p) for p in pages])
    assert len(rec) == 1                          # one warning per list
    with pytest.warns(DeprecationWarning):
        (ref,) = as_pagerefs([np.int64(int(pages[0]))], api="t")
    assert isinstance(ref, PageRef)               # numpy ints coerce too


# ---------------------------------------------------------------------------
# IOTLB cycle model
# ---------------------------------------------------------------------------

def test_iotlb_none_is_bit_identical_to_pre_mmu_model():
    base = SimConfig("ours", in_flight=4, prefetch=FixedDepth(4))
    r0 = simulate(base, 13, 256, num_transfers=64)
    r1 = simulate(dataclasses.replace(base, iotlb=None), 13, 256,
                  num_transfers=64)
    assert r0.cycles == r1.cycles
    assert r1.tlb_hits == r1.tlb_misses == 0
    assert r1.walk_stall_cycles == 0


def test_iotlb_chain_lookahead_prefetch_hides_walks():
    base = SimConfig("ours", in_flight=4, prefetch=FixedDepth(4))
    pf = simulate(dataclasses.replace(base, iotlb=IOTLBParams()),
                  13, 256, num_transfers=200, hit_rate=0.95)
    demand = simulate(
        dataclasses.replace(base,
                            iotlb=IOTLBParams(prefetch=FixedDepth(0))),
        13, 256, num_transfers=200, hit_rate=0.95)
    assert pf.tlb_hit_rate >= 0.9                 # the gated floor
    assert demand.tlb_hit_rate < pf.tlb_hit_rate
    assert pf.walk_stall_cycles < demand.walk_stall_cycles
    assert pf.cycles < demand.cycles


# ---------------------------------------------------------------------------
# Hypothesis suite: PageTable generation/remap invariants (slow)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    _ops = st.lists(
        st.tuples(st.integers(0, 15), st.integers(0, 3), st.integers(0, 15)),
        max_size=60)

    @pytest.mark.slow
    @settings(max_examples=50, deadline=None)
    @given(_ops)
    def test_generations_monotone_and_global_counts_bumps(ops):
        t = PageTable(16, 4)
        per_page = np.zeros(16, np.int64)
        for v, s, slot in ops:
            before = t.page_generation(v)
            t.remap(v, s, slot)
            assert t.page_generation(v) == before + 1
            per_page[v] += 1
        snap = t.snapshot()
        np.testing.assert_array_equal(snap["gen"], per_page)
        assert t.generation == int(per_page.sum()) == t.remaps

    @pytest.mark.slow
    @settings(max_examples=50, deadline=None)
    @given(_ops)
    def test_remap_points_exactly_where_told(ops):
        t = PageTable(16, 4)
        want = {v: t.map(v) for v in range(16)}
        for v, s, slot in ops:
            t.remap(v, s, slot)
            want[v] = (s, slot)
        for v in range(16):
            assert t.map(v) == want[v]
        assert t.pending_pages() == []

    @pytest.mark.slow
    @settings(max_examples=50, deadline=None)
    @given(st.integers(0, 15), st.integers(0, 3), st.integers(0, 15))
    def test_flip_then_pull_roundtrip(v, s, slot):
        t = PageTable(16, 4)
        home0 = t.map(v)
        g0 = t.page_generation(v)
        t.flip_owner(v, s)
        assert t.is_pending(v) and t.shard_of(v) == s
        assert t.home_of(v) == home0
        assert t.complete_pull(v, slot) == home0
        assert t.map(v) == (s, slot)
        assert t.page_generation(v) == g0 + 2      # flip + pull both bump
        with pytest.raises(RuntimeError, match="not pending"):
            t.complete_pull(v, slot)
