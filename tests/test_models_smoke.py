"""Per-arch smoke tests (deliverable f): reduced config, one forward/train
step on CPU, assert output shapes + no NaNs; decode == teacher forcing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.models import decode_step, init_params, loss_fn, prefill

ARCHS = list_archs()


def _batch(cfg, key, b=2, s=32):
    ks = jax.random.split(key, 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size),
    }
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(ks[2], (b, 16, cfg.d_model))
    if cfg.prefix_len:
        batch["prefix_embeds"] = jax.random.normal(
            ks[3], (b, cfg.prefix_len, cfg.d_model))
    return batch


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    expected = {
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected


def test_assignment_extras():
    ds = get_config("deepseek-v2-236b")
    assert ds.mla.kv_lora_rank == 512
    assert ds.moe.num_experts == 160 and ds.moe.experts_per_token == 6
    assert ds.moe.num_shared_experts == 2
    assert get_config("dbrx-132b").moe.experts_per_token == 4
    g = get_config("gemma3-12b")
    assert g.block_pattern.count(("local", "dense")) == 5
    assert g.block_pattern.count(("attn", "dense")) == 1
    j = get_config("jamba-v0.1-52b")
    assert sum(1 for m, _ in j.block_pattern if m == "attn") == 1
    assert sum(1 for m, _ in j.block_pattern if m == "mamba") == 7
    assert sum(1 for _, f in j.block_pattern if f == "moe") == 4
    assert get_config("mamba2-780m").ssm.d_state == 128
    assert get_config("qwen3-14b").qk_norm
    assert get_config("qwen2.5-3b").qkv_bias
    assert get_config("seamless-m4t-medium").encoder_layers == 12
    assert get_config("phi-3-vision-4.2b").prefix_len == 576


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad_step(arch):
    """Reduced config: forward + one SGD step; shapes + finiteness."""
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)

    def step(p, b):
        return loss_fn(p, b, cfg)

    (loss, metrics), grads = jax.jit(jax.value_and_grad(step, has_aux=True))(
        params, batch)
    assert np.isfinite(float(loss)), arch
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, arch
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2, _ = jax.jit(step)(new_params, batch)
    assert np.isfinite(float(loss2)), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_matches_teacher_forcing(arch):
    """decode_step logits at position t == full-forward logits at t.

    MoE capacity dropping differs between a 1-token decode and a joint
    teacher-forced pass by design, so we disable drops for this check.
    """
    import dataclasses
    cfg = get_config(arch, reduced=True)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    b, s = 2, 16
    batch = _batch(cfg, key, b=b, s=s + 1)
    from repro.models.model import forward
    full_logits, _, _, _ = jax.jit(
        lambda p, bb: forward(p, bb, cfg))(params, batch)

    pre = {k: (v[:, :s] if k in ("tokens", "labels") else v)
           for k, v in batch.items()}
    _, state = prefill(params, pre, cfg, max_len=64)
    step_logits, _ = decode_step(params, batch["tokens"][:, s], state, cfg)
    # Teacher forcing: feeding token s after prefilling 0..s-1 must match the
    # full forward's logits at position s.
    np.testing.assert_allclose(
        np.asarray(step_logits, np.float32),
        np.asarray(full_logits[:, s], np.float32), rtol=0.08, atol=0.08)


def test_shape_applicability_matrix():
    """40 cells; long_500k skipped for pure full-attention archs."""
    runnable = skipped = 0
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            if ok:
                runnable += 1
            else:
                skipped += 1
                assert shape.name == "long_500k", (arch, shape.name)
    assert runnable + skipped == 40
    # sub-quadratic archs: mamba2, jamba, gemma3(5:1 local)
    assert skipped == 7


@pytest.mark.parametrize("arch", ["mamba2-780m", "jamba-v0.1-52b",
                                  "gemma3-12b"])
def test_subquadratic_archs_run_long_context(arch):
    cfg = get_config(arch)
    ok, _ = shape_applicable(cfg, SHAPES["long_500k"])
    assert ok


def test_param_counts_plausible():
    """Sanity-check the analytic parameter model against known sizes."""
    expect = {
        "qwen3-14b": (14e9, 0.35), "starcoder2-15b": (15e9, 0.45),
        "deepseek-v2-236b": (236e9, 0.25), "dbrx-132b": (132e9, 0.25),
        "mamba2-780m": (780e6, 0.35), "jamba-v0.1-52b": (52e9, 0.35),
        "phi-3-vision-4.2b": (4.2e9, 0.35),
    }
    for arch, (want, tol) in expect.items():
        got = get_config(arch).param_counts()["total"]
        assert abs(got - want) / want < tol, (arch, got, want)
