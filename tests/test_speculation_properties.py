"""Hypothesis property suites for the adaptive speculation controller.

Slow-marked (CI's tier-1 fast split skips them; the slow job runs them)
and skipped entirely on minimal installs without hypothesis.
"""
import pytest

from repro.core.speculation import AdaptiveDepth, FixedDepth

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402


@pytest.mark.slow
@settings(deadline=None, max_examples=60)
@given(st.data())
def test_adaptive_converges_on_stationary_traffic(data):
    """On stationary traffic the controller reaches a fixed point: the
    depth stops changing, and lands on max_depth above the deepen
    threshold / min_depth below the backoff threshold."""
    p = AdaptiveDepth()
    c = p.make_controller()
    regime = data.draw(st.sampled_from(["good", "bad", "dead"]))
    if regime == "good":
        h = data.draw(st.floats(p.deepen_threshold, 1.0))
        want = p.max_depth
    elif regime == "bad":
        h = data.draw(st.floats(0.0, p.backoff_threshold))
        want = p.min_depth
    else:
        # strictly inside the dead band the depth never moves at all
        h = data.draw(st.floats(p.backoff_threshold + 1e-6,
                                p.deepen_threshold - 1e-6,
                                exclude_min=True, exclude_max=True))
        want = p.initial_depth
    for _ in range(64):
        c.observe(h)
    settled = c.depth
    assert settled == want
    for _ in range(16):
        c.observe(h)
    assert c.depth == settled      # fixed point


@pytest.mark.slow
@settings(deadline=None, max_examples=60)
@given(st.data())
def test_adaptive_monotone_backoff_under_miss_streaks(data):
    """During an injected miss streak the depth never increases — no
    matter what traffic preceded the streak."""
    c = AdaptiveDepth().make_controller()
    for h in data.draw(st.lists(st.floats(0.0, 1.0), max_size=40)):
        c.observe(h)
    streak = data.draw(st.integers(1, 40))
    prev = c.depth
    for _ in range(streak):
        d = c.observe(0.0)
        assert d <= prev
        prev = d


@pytest.mark.slow
@settings(deadline=None, max_examples=60)
@given(st.integers(0, 32), st.lists(st.floats(0.0, 1.0), max_size=60))
def test_fixed_depth_invariant_under_any_observation_stream(depth, stream):
    c = FixedDepth(depth).make_controller()
    for h in stream:
        assert c.observe(h) == depth
    assert c.depth == depth
