"""Hypothesis property tests on system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
pytestmark = pytest.mark.slow  # property suites: run in CI's slow job
from hypothesis import given, settings, strategies as st

from repro.core.simulator import SimConfig, ideal_utilization, simulate
from repro.kernels.prefetch_pipeline import prefetched_chain_copy


# ---------------------------------------------------------------------------
# Simulator invariants
# ---------------------------------------------------------------------------

sizes = st.sampled_from([32, 64, 128, 256, 512, 1024])
latencies = st.sampled_from([1, 5, 13, 40, 100])


@settings(max_examples=25, deadline=None)
@given(size=sizes, latency=latencies)
def test_utilization_never_exceeds_eq1(size, latency):
    """Eq. 1 is a hard ceiling: payload can't beat n/(n+32) on a shared bus."""
    for cfg in (SimConfig.base(), SimConfig.speculation(),
                SimConfig.scaled()):
        r = simulate(cfg, latency, size, num_transfers=600)
        assert r.utilization <= ideal_utilization(size) + 1e-9


@settings(max_examples=20, deadline=None)
@given(size=sizes, latency=latencies)
def test_speculation_dominates_base(size, latency):
    """Perfect-hit speculation never loses to the serialized frontend."""
    b = simulate(SimConfig.base(), latency, size, num_transfers=600)
    s = simulate(SimConfig.speculation(), latency, size, num_transfers=600)
    assert s.utilization >= b.utilization - 1e-9


@settings(max_examples=20, deadline=None)
@given(size=sizes, latency=latencies)
def test_scaled_dominates_speculation(size, latency):
    s = simulate(SimConfig.speculation(), latency, size, num_transfers=600)
    sc = simulate(SimConfig.scaled(), latency, size, num_transfers=600)
    assert sc.utilization >= s.utilization - 1e-9


@settings(max_examples=15, deadline=None)
@given(size=sizes)
def test_utilization_monotone_in_latency(size):
    for cfg in (SimConfig.base(), SimConfig.logicore_ip()):
        us = [simulate(cfg, L, size, num_transfers=600).utilization
              for L in (1, 13, 100)]
        assert us[0] >= us[1] >= us[2]


@settings(max_examples=15, deadline=None)
@given(latency=latencies, seed=st.integers(0, 1000))
def test_utilization_monotone_in_hit_rate(latency, seed):
    us = [simulate(SimConfig.speculation(), latency, 64, hit_rate=h,
                   num_transfers=800, seed=seed).utilization
          for h in (0.0, 0.5, 1.0)]
    assert us[0] <= us[1] + 0.02 and us[1] <= us[2] + 0.02


@settings(max_examples=15, deadline=None)
@given(size=sizes, latency=latencies)
def test_larger_transfers_utilize_better(size, latency):
    for cfg in (SimConfig.base(), SimConfig.speculation()):
        a = simulate(cfg, latency, size, num_transfers=600).utilization
        b = simulate(cfg, latency, size * 2, num_transfers=600).utilization
        assert b >= a - 1e-9


# ---------------------------------------------------------------------------
# Prefetch-pipeline kernel == descriptor semantics at any depth
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(data=st.data())
def test_prefetch_pipeline_any_depth(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    n = data.draw(st.integers(1, 24))
    depth = data.draw(st.integers(2, 8))
    rows, unit = n + 8, 128
    src = jnp.asarray(rng.standard_normal((rows, unit)), jnp.float32)
    dst = jnp.zeros((rows, unit), jnp.float32)
    sidx = jnp.asarray(rng.choice(rows, n, replace=False), jnp.int32)
    didx = jnp.asarray(rng.choice(rows, n, replace=False), jnp.int32)
    out = prefetched_chain_copy(sidx, didx, src, dst, depth=depth,
                                interpret=True)
    want = np.zeros((rows, unit), np.float32)
    want[np.asarray(didx)] = np.asarray(src)[np.asarray(sidx)]
    np.testing.assert_array_equal(np.asarray(out), want)


# ---------------------------------------------------------------------------
# Area model linearity (the paper's scalability claim)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(d=st.integers(1, 64), s=st.integers(0, 64), k=st.integers(1, 4))
def test_area_model_linear(d, s, k):
    from repro.core.area_model import area_kge, AREA_BASE_KGE
    a1 = area_kge(d, s) - AREA_BASE_KGE
    ak = area_kge(k * d, k * s) - AREA_BASE_KGE
    assert ak == pytest.approx(k * a1, rel=1e-9)
