"""Roofline machinery: HLO collective parsing, extrapolation, core model."""
import pytest

from repro.configs import SHAPES, get_config
from repro.roofline import analysis as ra

HLO_SAMPLE = """
HloModule test
  %p = f32[16,128]{1,0} parameter(0)
  %ar = f32[16,128]{1,0} all-reduce(%p), replica_groups={}
  %ag = bf16[32,256]{1,0} all-gather(%x), dimensions={0}
  %rs = f32[8,128]{1,0} reduce-scatter(%p), dimensions={0}
  %a2a = bf16[4,64]{1,0} all-to-all(%y), dimensions={0}
  %cp = u8[1024]{0} collective-permute(%z)
  %dot = f32[16,16]{1,0} dot(%p, %p)
"""


def test_collective_bytes_parser():
    out = ra.collective_bytes(HLO_SAMPLE)
    assert out["all-reduce"] == 16 * 128 * 4 * 2.0        # ring factor 2x
    assert out["all-gather"] == 32 * 256 * 2 * 1.0
    assert out["reduce-scatter"] == 8 * 128 * 4
    assert out["all-to-all"] == 4 * 64 * 2
    assert out["collective-permute"] == 1024


def test_collective_parser_ignores_compute_ops():
    out = ra.collective_bytes("%d = f32[128,128] dot(%a, %b)\n")
    assert sum(out.values()) == 0


def test_extrapolation_affine():
    assert ra.extrapolate(10.0, 14.0, 1) == 10.0
    assert ra.extrapolate(10.0, 14.0, 2) == 14.0
    assert ra.extrapolate(10.0, 14.0, 10) == 10.0 + 9 * 4.0


def test_attention_core_local_band_is_cheaper():
    cfg = get_config("gemma3-12b")
    shape = SHAPES["prefill_32k"]
    f_full, b_full = ra.attention_core(cfg, shape, "attn")
    f_loc, b_loc = ra.attention_core(cfg, shape, "local")
    assert f_loc < f_full / 10          # 1024+512 band vs 32768 full
    assert b_loc < b_full


def test_model_flops_kinds():
    cfg = get_config("qwen3-14b")
    tr = ra.model_flops(cfg, SHAPES["train_4k"])
    pf = ra.model_flops(cfg, SHAPES["prefill_32k"])
    dc = ra.model_flops(cfg, SHAPES["decode_32k"])
    n = cfg.param_counts()["active"]
    assert tr == pytest.approx(6 * n * 256 * 4096)
    assert pf == pytest.approx(2 * n * 32 * 32768)
    assert dc == pytest.approx(2 * n * 128)


def test_moe_active_params_much_smaller_than_total():
    ds = get_config("deepseek-v2-236b").param_counts()
    assert ds["active"] < 0.15 * ds["total"]   # ~21B active of 236B


def test_roofline_terms_and_bottleneck():
    r = ra.Roofline(arch="a", shape="s", mesh="m", chips=256,
                    hlo_flops_per_chip=197e12, hlo_bytes_per_chip=819e9,
                    wire_bytes_per_chip=200e9, collectives={},
                    model_flops=197e12 * 256 * 0.5,
                    bytes_per_chip_hbm=1e9)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(2.0)
    assert r.bottleneck == "collective"
    assert r.mfu == pytest.approx(0.25)   # 0.5 useful / 2s step


def test_serving_param_specs_strip_fsdp():
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import serving_param_specs
    from repro.models import param_shapes
    import jax

    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
    cfg = get_config("qwen3-14b")
    shapes = param_shapes(cfg)
    specs = serving_param_specs(cfg, FakeMesh(), shapes)
    for spec in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        for e in spec:
            if e is None:
                continue
            axes = e if isinstance(e, tuple) else (e,)
            assert "data" not in axes and "pod" not in axes
