"""The perf-regression gate itself: tolerance bands, polarity, failure modes.

No hypothesis dependency — this module must collect on minimal installs.
"""
import copy
import json

import pytest

from repro.configs.registry import list_archs
from repro.obs.metrics import Histogram
from repro.perf import gate
from repro.perf.sweep import (
    SCHEMA_VERSION,
    default_spec,
    run_sweep,
    write_doc,
)

CELL = "archA/paged_kv/ch4/L13"


def _doc(cells=None):
    """Minimal synthetic sweep document."""
    if cells is None:
        cells = {CELL: _cell()}
    return {
        "schema_version": SCHEMA_VERSION,
        "mode": "quick",
        "seed": 0,
        "repeats": 3,
        "dimensions": {"archs": ["archA"], "workloads": ["paged_kv"],
                       "channel_counts": [4], "mem_latencies": [13],
                       "serve_cells": []},
        "gated_metrics": list(gate.GATED_METRICS),
        "serve_gated_metrics": list(gate.SERVE_GATED_METRICS),
        "cells": cells,
    }


def _cell(util=0.66, launch=36.0, merge=2.0, hit=0.95,
          spec_fixed=0.6, spec_adaptive=0.62,
          cache_hit=1.0, speedup=2.4):
    return {
        "kind": "dma",
        "arch": "archA", "workload": "paged_kv",
        "channels": 4, "mem_latency": 13,
        "metrics": {
            "bus_utilization": util,
            "launch_cycles_per_transfer": launch,
            "coalesce_merge_ratio": merge,
            "speculation_hit_rate": hit,
            "spec_bus_utilization_fixed4": spec_fixed,
            "spec_bus_utilization_adaptive": spec_adaptive,
            "translation_cache_hit_rate": cache_hit,
            "translation_launch_speedup": speedup,
        },
        "counters": {},
    }


SERVE_CELL = "serve/archA/cap2"


def _serve_cell(stall=0.5, poll=1.0, steps=4.0,
                lat=(10, 12, 13, 14, 18, 21)):
    h = Histogram()
    for v in lat:
        h.record(v)
    snap = h.snapshot()
    return {
        "kind": "serve",
        "arch": "archA", "workload": "serve",
        "capacity": 2, "n_requests": 6,
        "metrics": {
            "admission_stall_rate": stall,
            "completion_poll_latency_steps": poll,
            "serve_steps_per_request": steps,
            "request_latency_steps_p50": snap["p50"],
            "request_latency_steps_p99": snap["p99"],
            "request_latency_steps": snap,
        },
        "counters": {},
    }


# ---------------------------------------------------------------------------
# Comparison semantics
# ---------------------------------------------------------------------------

def test_identical_documents_pass():
    base = _doc()
    assert gate.compare(base, copy.deepcopy(base)) == []


def test_injected_ten_percent_utilization_regression_fails_named():
    base, cur = _doc(), _doc()
    cur["cells"][CELL]["metrics"]["bus_utilization"] = 0.66 * 0.9
    regs = gate.compare(base, cur)
    assert len(regs) == 1
    r = regs[0]
    assert r.cell == CELL
    assert r.metric == "bus_utilization"
    assert CELL in r.message and "bus_utilization" in r.message
    assert r.rel_change == pytest.approx(-0.10, abs=1e-9)


def test_within_tolerance_jitter_passes():
    base, cur = _doc(), _doc()
    m = cur["cells"][CELL]["metrics"]
    m["bus_utilization"] *= 0.99        # 1% < 3% band
    m["launch_cycles_per_transfer"] *= 1.03   # 3% < 5% band
    m["speculation_hit_rate"] *= 0.98
    assert gate.compare(base, cur) == []


def test_polarity_launch_cycles_up_fails_down_passes():
    base, up, down = _doc(), _doc(), _doc()
    up["cells"][CELL]["metrics"]["launch_cycles_per_transfer"] *= 1.2
    down["cells"][CELL]["metrics"]["launch_cycles_per_transfer"] *= 0.8
    assert [r.metric for r in gate.compare(base, up)] == \
        ["launch_cycles_per_transfer"]
    assert gate.compare(base, down) == []


def test_improvements_never_fail_however_large():
    base, cur = _doc(), _doc()
    m = cur["cells"][CELL]["metrics"]
    m["bus_utilization"] *= 1.5
    m["coalesce_merge_ratio"] *= 3.0
    m["launch_cycles_per_transfer"] *= 0.1
    assert gate.compare(base, cur) == []


def test_tolerance_override():
    base, cur = _doc(), _doc()
    cur["cells"][CELL]["metrics"]["bus_utilization"] *= 0.95   # 5% drop
    assert len(gate.compare(base, cur)) == 1
    assert gate.compare(base, cur,
                        tolerances={"bus_utilization": 0.10}) == []


# ---------------------------------------------------------------------------
# Serve cells gate their own metric set
# ---------------------------------------------------------------------------

def test_serve_cell_gates_serve_metrics_with_lower_is_better():
    base = _doc(cells={CELL: _cell(), SERVE_CELL: _serve_cell()})
    worse = _doc(cells={CELL: _cell(),
                        SERVE_CELL: _serve_cell(stall=0.7, poll=1.5)})
    regs = gate.compare(base, worse)
    assert sorted(r.metric for r in regs) == [
        "admission_stall_rate", "completion_poll_latency_steps"]
    better = _doc(cells={CELL: _cell(),
                         SERVE_CELL: _serve_cell(stall=0.1, steps=2.0)})
    assert gate.compare(base, better) == []


def test_serve_cell_missing_serve_metric_errors():
    base = _doc(cells={SERVE_CELL: _serve_cell()})
    cur = _doc(cells={SERVE_CELL: _serve_cell()})
    del cur["cells"][SERVE_CELL]["metrics"]["admission_stall_rate"]
    with pytest.raises(gate.GateError,
                       match="admission_stall_rate.*missing from current"):
        gate.compare(base, cur)


def test_serve_cell_does_not_require_dma_metrics():
    """A serve cell carries no bus_utilization — must not error."""
    base = _doc(cells={SERVE_CELL: _serve_cell()})
    assert gate.compare(base, copy.deepcopy(base)) == []


# ---------------------------------------------------------------------------
# Histogram-valued metrics (schema v5, DESIGN.md §8)
# ---------------------------------------------------------------------------

def test_serve_histogram_tail_regression_trips_gate_per_percentile():
    """A pure tail shift (one request 21 -> 60 steps) must fail at the
    gated tail percentiles and the p99 scalar, while p50 stays green."""
    base = _doc(cells={SERVE_CELL: _serve_cell()})
    worse = _doc(cells={SERVE_CELL: _serve_cell(
        lat=(10, 12, 13, 14, 18, 60))})
    regs = gate.compare(base, worse)
    assert sorted(r.metric for r in regs) == [
        "request_latency_steps.p95",
        "request_latency_steps.p99",
        "request_latency_steps_p99"]
    for r in regs:
        assert r.current == 60.0 and r.baseline == 21.0


def test_serve_histogram_improvement_never_fails():
    base = _doc(cells={SERVE_CELL: _serve_cell()})
    better = _doc(cells={SERVE_CELL: _serve_cell(lat=(2, 2, 3, 3, 4, 5))})
    assert gate.compare(base, better) == []


def test_serve_histogram_one_step_jitter_absorbed_by_floor():
    """p50 moving 2 -> 3 is +50% relative but only one decode step: the
    histogram branch's absolute floor must not fire (the strict p50/p99
    scalars still gate bit-for-bit, by design)."""
    base = _doc(cells={SERVE_CELL: _serve_cell(lat=(2,) * 6)})
    cur = _doc(cells={SERVE_CELL: _serve_cell(lat=(3,) * 6)})
    regs = gate.compare(base, cur)
    assert all("." not in r.metric for r in regs)
    assert sorted(r.metric for r in regs) == [
        "request_latency_steps_p50", "request_latency_steps_p99"]


def test_serve_histogram_non_dict_errors():
    base = _doc(cells={SERVE_CELL: _serve_cell()})
    cur = _doc(cells={SERVE_CELL: _serve_cell()})
    cur["cells"][SERVE_CELL]["metrics"]["request_latency_steps"] = 13.0
    with pytest.raises(gate.GateError, match="histogram snapshot"):
        gate.compare(base, cur)


def test_serve_histogram_missing_percentile_errors():
    base = _doc(cells={SERVE_CELL: _serve_cell()})
    cur = _doc(cells={SERVE_CELL: _serve_cell()})
    del cur["cells"][SERVE_CELL]["metrics"]["request_latency_steps"]["p95"]
    with pytest.raises(gate.GateError, match="p95"):
        gate.compare(base, cur)


def test_cli_tolerance_accepts_histogram_percentile_key(tmp_path):
    base = _write(tmp_path, "base.json",
                  _doc(cells={SERVE_CELL: _serve_cell()}))
    bad = _doc(cells={SERVE_CELL: _serve_cell(
        lat=(10, 12, 13, 14, 18, 60))})
    badp = _write(tmp_path, "bad.json", bad)
    assert gate.main(["--baseline", base, "--current", badp]) == 1
    assert gate.main(["--baseline", base, "--current", badp,
                      "--tolerance", "request_latency_steps.p95=5.0",
                      "--tolerance", "request_latency_steps.p99=5.0",
                      "--tolerance", "request_latency_steps_p99=5.0"]) == 0
    assert gate.main(["--baseline", base, "--current", badp,
                      "--tolerance",
                      "request_latency_steps.p42=0.1"]) == 2


def test_serve_latency_summary_prints_percentile_table():
    doc = _doc(cells={SERVE_CELL: _serve_cell()})
    text = gate.serve_latency_summary(doc)
    lines = text.splitlines()
    assert "p50" in lines[1] and "p99" in lines[1]
    assert SERVE_CELL in lines[2]
    assert "13.0" in lines[2] and "21.0" in lines[2]
    assert "no serve-cell histograms" in gate.serve_latency_summary(_doc())


def test_quick_subset_always_keeps_serve_cells():
    doc = _full_doc()
    doc["cells"][SERVE_CELL] = _serve_cell()
    sub, dropped = gate.quick_subset(doc)
    assert SERVE_CELL in sub["cells"]
    assert dropped == 3


# ---------------------------------------------------------------------------
# Sharded mesh cells gate their own metric set
# ---------------------------------------------------------------------------

SHARDED_CELL = "sharded/archA/mesh4"


def _sharded_cell(cycles=120.0, util=0.88, merge=1.8, mesh=4,
                  overlap=0.85, p99=140.0, rebal=5.0, retained=0.95,
                  first_touch=4.0):
    return {
        "kind": "sharded",
        "arch": "archA", "workload": "kv_migration", "mesh": mesh,
        "metrics": {
            "cross_shard_migration_cycles": cycles,
            "per_shard_bus_utilization": util,
            "migration_chain_merge_ratio": merge,
            "migration_overlap_ratio": overlap,
            "p99_migration_stall_cycles": p99,
            "rebalance_convergence_steps": rebal,
            "throughput_retained_during_resize": retained,
            "first_touch_latency_rounds": first_touch,
        },
        "counters": {},
    }


def test_sharded_cell_gates_its_metrics_with_polarity():
    base = _doc(cells={CELL: _cell(), SHARDED_CELL: _sharded_cell()})
    worse = _doc(cells={CELL: _cell(),
                        SHARDED_CELL: _sharded_cell(cycles=150.0,
                                                    merge=1.5)})
    regs = gate.compare(base, worse)
    assert sorted(r.metric for r in regs) == [
        "cross_shard_migration_cycles", "migration_chain_merge_ratio"]
    better = _doc(cells={CELL: _cell(),
                         SHARDED_CELL: _sharded_cell(cycles=50.0,
                                                     util=0.95)})
    assert gate.compare(base, better) == []


def test_sharded_fabric_metrics_gate_with_their_own_polarity():
    # Async-fabric metrics (schema v7): overlap and retained-throughput
    # regress downward; stall p99 and convergence steps regress upward.
    base = _doc(cells={SHARDED_CELL: _sharded_cell()})
    worse = _doc(cells={SHARDED_CELL: _sharded_cell(
        overlap=0.60, p99=170.0, rebal=9.0, retained=0.80)})
    regs = gate.compare(base, worse)
    assert sorted(r.metric for r in regs) == [
        "migration_overlap_ratio", "p99_migration_stall_cycles",
        "rebalance_convergence_steps", "throughput_retained_during_resize"]
    better = _doc(cells={SHARDED_CELL: _sharded_cell(
        overlap=1.0, p99=100.0, rebal=3.0, retained=1.0)})
    assert gate.compare(base, better) == []


def test_sharded_cell_does_not_require_dma_metrics():
    base = _doc(cells={SHARDED_CELL: _sharded_cell()})
    assert gate.compare(base, copy.deepcopy(base)) == []


def test_sharded_cell_missing_metric_errors():
    base = _doc(cells={SHARDED_CELL: _sharded_cell()})
    cur = _doc(cells={SHARDED_CELL: _sharded_cell()})
    del cur["cells"][SHARDED_CELL]["metrics"]["per_shard_bus_utilization"]
    with pytest.raises(gate.GateError,
                       match="per_shard_bus_utilization.*missing from current"):
        gate.compare(base, cur)


def test_quick_subset_always_keeps_sharded_cells():
    doc = _full_doc()
    doc["cells"][SHARDED_CELL] = _sharded_cell()
    sub, dropped = gate.quick_subset(doc)
    assert SHARDED_CELL in sub["cells"]
    assert dropped == 3


def test_sharded_summary_prints_per_mesh_table():
    doc = _doc(cells={
        "sharded/archA/mesh1": _sharded_cell(cycles=0.0, mesh=1),
        SHARDED_CELL: _sharded_cell(),
    })
    text = gate.sharded_summary(doc)
    lines = text.splitlines()
    assert "mesh" in lines[1]
    # rows sorted by mesh size, cycles column populated
    assert lines[2].split()[0] == "1" and lines[3].split()[0] == "4"
    assert "120.0" in lines[3]


def test_speculation_summary_names_workload_deltas():
    doc = _doc(cells={
        CELL: _cell(spec_fixed=0.5, spec_adaptive=0.6),
        "archA/moe_dispatch/ch4/L13": dict(
            _cell(spec_fixed=0.2, spec_adaptive=0.3), workload="moe_dispatch"),
    })
    text = gate.speculation_summary(doc)
    assert "paged_kv" in text and "moe_dispatch" in text
    assert "+20.0%" in text and "+50.0%" in text


# ---------------------------------------------------------------------------
# Failure modes must error clearly, never silently pass
# ---------------------------------------------------------------------------

def test_missing_metric_errors_clearly():
    base, cur = _doc(), _doc()
    del cur["cells"][CELL]["metrics"]["speculation_hit_rate"]
    with pytest.raises(gate.GateError,
                       match="speculation_hit_rate.*missing from current"):
        gate.compare(base, cur)


def test_metric_missing_from_baseline_errors():
    base, cur = _doc(), _doc()
    del base["cells"][CELL]["metrics"]["coalesce_merge_ratio"]
    with pytest.raises(gate.GateError, match="missing from.*baseline"):
        gate.compare(base, cur)


def test_missing_cell_errors_clearly():
    base, cur = _doc(), _doc(cells={})
    cur["cells"] = {"other/cell/ch1/L1": _cell()}
    with pytest.raises(gate.GateError, match="missing from current"):
        gate.compare(base, cur)


def test_schema_version_mismatch_errors_clearly():
    base, cur = _doc(), _doc()
    cur["schema_version"] = SCHEMA_VERSION + 1
    with pytest.raises(gate.GateError, match="schema_version"):
        gate.compare(base, cur)
    base["schema_version"] = 0
    with pytest.raises(gate.GateError, match="schema_version"):
        gate.compare(base, _doc())


def test_empty_document_is_not_a_baseline():
    with pytest.raises(gate.GateError, match="no cells"):
        gate.check_schema({"schema_version": SCHEMA_VERSION, "cells": {}})


def test_missing_dimensions_or_mode_errors_clearly():
    for key in ("dimensions", "mode", "seed", "repeats"):
        doc = _doc()
        del doc[key]
        with pytest.raises(gate.GateError, match="malformed"):
            gate.check_schema(doc)
    doc = _doc()
    del doc["dimensions"]["mem_latencies"]
    with pytest.raises(gate.GateError, match="dimensions"):
        gate.check_schema(doc)


def test_cli_dimensionless_baseline_exits_2_not_1(tmp_path):
    doc = _doc()
    del doc["dimensions"]
    p = _write(tmp_path, "malformed.json", doc)
    assert gate.main(["--baseline", p]) == 2


def test_baseline_cell_without_metrics_errors_not_exit1():
    base, cur = _doc(), _doc()
    del base["cells"][CELL]["metrics"]
    with pytest.raises(gate.GateError, match="malformed"):
        gate.compare(base, cur)


# ---------------------------------------------------------------------------
# --quick subset of a full baseline
# ---------------------------------------------------------------------------

def _full_doc():
    cells = {}
    for ch in (1, 4):
        for lat in (1, 13):
            c = _cell()
            c["channels"], c["mem_latency"] = ch, lat
            cells[f"archA/paged_kv/ch{ch}/L{lat}"] = c
    doc = _doc(cells=cells)
    doc["mode"] = "full"
    doc["dimensions"]["channel_counts"] = [1, 4]
    doc["dimensions"]["mem_latencies"] = [1, 13]
    return doc


def test_quick_subset_of_full_baseline_keeps_only_quick_cells():
    sub, dropped = gate.quick_subset(_full_doc())
    assert set(sub["cells"]) == {"archA/paged_kv/ch4/L13"}
    assert dropped == 3
    assert sub["mode"] == "full"   # re-run stays at the baseline's scale
    assert sub["dimensions"]["channel_counts"] == [4]
    assert sub["dimensions"]["mem_latencies"] == [13]


def test_quick_subset_errors_when_baseline_lacks_quick_dims():
    doc = _full_doc()
    doc["cells"] = {k: c for k, c in doc["cells"].items()
                    if c["channels"] != 4}
    with pytest.raises(gate.GateError, match="quick dimensions"):
        gate.quick_subset(doc)


def test_cli_quick_gates_subset_of_full_baseline(tmp_path):
    base = _write(tmp_path, "full.json", _full_doc())
    # current covers only the quick cell, with a regression in it
    cur = _doc(cells={"archA/paged_kv/ch4/L13": _cell(util=0.5)})
    curp = _write(tmp_path, "cur.json", cur)
    # without --quick the full baseline demands the missing ch1/L1 cells
    assert gate.main(["--baseline", base, "--current", curp]) == 2
    assert gate.main(["--baseline", base, "--current", curp,
                      "--quick"]) == 1
    ok = _doc(cells={"archA/paged_kv/ch4/L13": _cell()})
    okp = _write(tmp_path, "ok.json", ok)
    assert gate.main(["--baseline", base, "--current", okp,
                      "--quick"]) == 0


def test_cli_quick_update_baseline_refused(tmp_path):
    base = _write(tmp_path, "full.json", _full_doc())
    assert gate.main(["--baseline", base, "--quick",
                      "--update-baseline"]) == 2


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


def test_cli_pass_fail_and_error_exit_codes(tmp_path):
    base = _doc()
    good = _write(tmp_path, "base.json", base)
    same = _write(tmp_path, "cur.json", _doc())
    assert gate.main(["--baseline", good, "--current", same]) == 0

    bad = _doc()
    bad["cells"][CELL]["metrics"]["bus_utilization"] *= 0.8
    badp = _write(tmp_path, "bad.json", bad)
    assert gate.main(["--baseline", good, "--current", badp]) == 1

    vers = _doc()
    vers["schema_version"] = 99
    versp = _write(tmp_path, "vers.json", vers)
    assert gate.main(["--baseline", good, "--current", versp]) == 2
    assert gate.main(["--baseline", str(tmp_path / "nope.json")]) == 2


def test_cli_tolerance_flag(tmp_path):
    base = _write(tmp_path, "base.json", _doc())
    bad = _doc()
    bad["cells"][CELL]["metrics"]["bus_utilization"] *= 0.95
    badp = _write(tmp_path, "bad.json", bad)
    assert gate.main(["--baseline", base, "--current", badp]) == 1
    assert gate.main(["--baseline", base, "--current", badp,
                      "--tolerance", "bus_utilization=0.10"]) == 0
    assert gate.main(["--baseline", base, "--current", badp,
                      "--tolerance", "nonsense=0.1"]) == 2


def test_cli_update_baseline_rewrites_file(tmp_path):
    base = _write(tmp_path, "base.json", _doc())
    cur = _doc()
    cur["cells"][CELL]["metrics"]["bus_utilization"] = 0.5
    curp = _write(tmp_path, "cur.json", cur)
    assert gate.main(["--baseline", base, "--current", curp,
                      "--update-baseline"]) == 0
    rebased = json.loads((tmp_path / "base.json").read_text())
    assert rebased["cells"][CELL]["metrics"]["bus_utilization"] == 0.5


# ---------------------------------------------------------------------------
# End-to-end: real sweep, real injected regression
# ---------------------------------------------------------------------------

def _mini_spec(include_serve=False):
    return default_spec("quick", 0, archs=[list_archs()[0]],
                        workloads=["paged_kv"], channel_counts=[2],
                        mem_latencies=[100], repeats=2,
                        include_serve=include_serve,
                        include_sharded=False,
                        include_transforms=False)


def test_end_to_end_unchanged_tree_passes(tmp_path):
    doc = run_sweep(_mini_spec())
    p = str(tmp_path / "BENCH_perf.json")
    write_doc(doc, p)
    assert gate.main(["--baseline", p]) == 0


@pytest.mark.slow
def test_end_to_end_serve_cell_round_trips_through_gate(tmp_path):
    """A sweep with the serve cell re-gates cleanly (deterministic
    scheduling metrics) and spec_from_doc restores include_serve."""
    doc = run_sweep(_mini_spec(include_serve=True))
    serve_keys = [k for k, c in doc["cells"].items()
                  if c.get("kind") == "serve"]
    assert serve_keys and doc["dimensions"]["serve_cells"] == serve_keys
    p = str(tmp_path / "BENCH_perf.json")
    write_doc(doc, p)
    assert gate.main(["--baseline", p]) == 0


def test_end_to_end_simulator_constant_regression_trips_gate(
        tmp_path, monkeypatch):
    import repro.core.simulator as sim
    doc = run_sweep(_mini_spec())
    base = str(tmp_path / "BENCH_perf.json")
    write_doc(doc, base)
    # A deeper fixed pipeline is exactly the class of change the gate must
    # catch: every fetch round trip lengthens, utilization at L=100 drops.
    monkeypatch.setattr(sim, "PIPE", sim.PIPE + 10)
    worse = run_sweep(_mini_spec())
    curp = str(tmp_path / "cur.json")
    write_doc(worse, curp)
    rc = gate.main(["--baseline", base, "--current", curp])
    assert rc == 1
    regs = gate.compare(doc, worse)
    assert regs and all(r.cell.startswith(list_archs()[0]) for r in regs)
