"""Async fabric for cross-shard migration (DESIGN.md §10): link occupancy,
ticket lifecycle, overlap accounting, rebalance planning, elastic resize,
and shard loss with tickets in flight.

No hypothesis dependency — this module must collect on minimal installs.
Everything here is logical-round deterministic: no wall clock, no sleeps.
"""
import numpy as np
import pytest

from repro.distributed.fabric import (
    COMPLETED,
    EGRESS,
    IN_FLIGHT,
    INGRESS,
    AsyncFabric,
    FabricLink,
    FabricTicket,
    RebalancePlanner,
)
from repro.distributed.fault import ungraceful_resize
from repro.distributed.sharded_runtime import (
    ShardedDMARuntime,
    ShardedKVPool,
)
from repro.obs.trace import Tracer


# ---------------------------------------------------------------------------
# FabricLink / AsyncFabric units
# ---------------------------------------------------------------------------

def test_fabric_link_occupancy_and_queueing_math():
    ln = FabricLink(0, 1, latency=2, page_beats=3)
    # Idle link: deliver = now + latency + pages * page_beats.
    assert ln.send(0, 2) == 0 + 2 + 2 * 3
    assert (ln.sends, ln.pages_sent, ln.queued_rounds) == (1, 2, 0)
    assert ln.busy_rounds == 8 and ln.busy_until == 8
    # A send entering the busy link queues behind the in-flight payload.
    assert ln.send(1, 1) == 8 + 2 + 3
    assert ln.queued_rounds == 7          # waited rounds 1..8
    assert ln.busy_until == 13
    # Zero-page control payload still occupies latency + one page beat.
    assert ln.send(20, 0) == 20 + 2 + 3


def _ticket(hop_id, src, dst, pages, priority=0):
    return FabricTicket(
        hop_id=hop_id, src_shard=src, dst_shard=dst, pages=pages,
        pool_names=("kv.k",), rows_s=np.zeros(pages, np.int64),
        rows_d=np.zeros(pages, np.int64), ctrl_ticket=0, stats=None,
        priority=priority)


def test_async_fabric_clock_links_and_deliveries():
    fab = AsyncFabric(latency=1, page_beats=1)
    t = _ticket(1, 0, 1, pages=2)
    deliver = fab.send(t)
    assert t.state == IN_FLIGHT and deliver == 3
    assert fab.occupied_links() == 1
    assert fab.deliveries() == []          # nothing arrived at round 0
    for _ in range(3):
        fab.advance()
    out = fab.deliveries()
    assert out == [t] and t.state == INGRESS
    assert fab.in_flight == [] and fab.occupied_links() == 0
    # Per-link counters export in stable (src, dst) order.
    fab.send(_ticket(2, 1, 0, pages=1))
    stats = fab.link_stats()
    assert [(s["src"], s["dst"]) for s in stats] == [(0, 1), (1, 0)]
    assert stats[0]["pages_sent"] == 2
    with pytest.raises(ValueError):
        AsyncFabric(latency=-1)
    with pytest.raises(ValueError):
        AsyncFabric(page_beats=0)


# ---------------------------------------------------------------------------
# RebalancePlanner: hysteresis, heat decay, spreading plan, placement
# ---------------------------------------------------------------------------

def test_planner_hysteresis_opens_high_closes_low():
    pl = RebalancePlanner(2, window=2, high_water=1.5, low_water=1.1)
    pl.observe([10.0, 10.0])
    assert not pl.should_rebalance()
    # Imbalance crosses high_water: the episode opens...
    pl.observe([40.0, 10.0])
    pl.observe([40.0, 10.0])
    assert pl.imbalance() > 1.5 and pl.should_rebalance()
    # ...and stays open in the dead band between the thresholds...
    pl.observe([13.0, 10.0])
    pl.observe([13.0, 10.0])
    assert 1.1 < pl.imbalance() < 1.5 and pl.should_rebalance()
    # ...until the imbalance falls under low_water.
    pl.observe([10.0, 10.0])
    pl.observe([10.0, 10.0])
    assert not pl.should_rebalance()


def test_planner_heat_decays_to_nothing_without_traffic():
    pl = RebalancePlanner(2, heat_decay=0.5)
    pl.observe([1.0, 1.0], hot_pages=[5])
    assert pl.page_heat == {5: 1.0}
    for _ in range(5):                     # 1 -> .5 -> .25 -> ... -> dropped
        pl.observe([1.0, 1.0])
    assert pl.page_heat == {}


def _mesh_pool(num_shards, num_pages, row=4):
    srt = ShardedDMARuntime(num_shards=num_shards)
    kv = ShardedKVPool(srt, num_pages=num_pages, page=row, kv_heads=1,
                       head_dim=1)
    return srt, kv


def test_planner_plan_spreads_hot_pages_across_all_receivers():
    srt, kv = _mesh_pool(4, 64)
    pl = RebalancePlanner(4, window=2)
    hot = kv.alloc_on(0, 6)               # six hot pages, all on shard 0
    for _ in range(3):
        pl.observe([100.0, 10.0, 10.0, 10.0], hot_pages=hot)
    out = pl.plan(kv)
    assert out is not None
    src, dst = out
    assert sorted(src) == sorted(hot)
    # Greedy least-projected-load: the heat spreads over every receiver
    # instead of dumping the whole hot head on the single coldest shard.
    assert {kv.owner.owner(p) for p in dst} == {1, 2, 3}
    assert all(kv.owner.owner(p) == 0 for p in src)
    assert pl.plans_emitted == 1 and pl.pages_planned == 6


def test_planner_overshoot_guard_blocks_ping_pong_moves():
    srt, kv = _mesh_pool(4, 64)
    pl = RebalancePlanner(4, window=2)
    (page,) = kv.alloc_on(0, 1)
    # One page carries nearly all of the hot shard's load: moving it would
    # leave the receiver hotter than the source, so the plan must decline
    # (this is exactly the Zipf-head ping-pong failure mode).
    for _ in range(2):
        pl.observe([60.0, 30.0, 30.0, 30.0], hot_pages=[page] * 20)
    assert pl.should_rebalance()
    assert pl.plan(kv) is None
    assert pl.plans_emitted == 0


def test_planner_placement_spreads_by_free_capacity():
    srt, kv = _mesh_pool(4, 64)
    kv.alloc_on(1, 12)                    # shard 1 nearly full (4 free)
    kv.alloc_on(2, 8)                     # shard 2 half full
    pl = RebalancePlanner(4)
    pages = list(range(6))
    dst = pl.placement(kv, pages, survivors=[1, 2, 3])
    owners = [kv.owner.owner(p) for p in dst]
    # shard 3 (16 free) absorbs the most, shard 1 (4 free) the least.
    assert owners.count(3) > owners.count(1)
    assert len(dst) == len(set(dst)) == 6
    with pytest.raises(ValueError, match="at least one survivor"):
        pl.placement(kv, pages, survivors=[])


# ---------------------------------------------------------------------------
# Async fabric through the sharded runtime: equivalence, pump stepper,
# overlap accounting, priority ordering
# ---------------------------------------------------------------------------

def _filled(num_shards, num_pages, row=8, seed=0, **kw):
    rng = np.random.default_rng(seed)
    srt = ShardedDMARuntime(num_shards=num_shards, **kw)
    kv = ShardedKVPool(srt, num_pages=num_pages, page=row, kv_heads=1,
                       head_dim=1)
    content = rng.standard_normal((num_pages, row)).astype(np.float32)
    for p in range(num_pages):
        kv.write_page(p, content[p], -content[p])
    return srt, kv, content


def test_async_and_sync_fabric_agree_on_contents_and_plan_shape():
    src = [1, 2, 3, 17, 18, 40, 41, 42, 9]
    dst = [33, 34, 35, 50, 51, 10, 11, 12, 28]
    outs = {}
    for mode in ("async", "sync"):
        srt, kv, content = _filled(4, 64, seed=3, fabric=mode)
        stats = kv.move_pages(src, dst)
        outs[mode] = (srt.gather_pool(kv.POOL_K), stats)
    np.testing.assert_array_equal(outs["async"][0], outs["sync"][0])
    a, s = outs["async"][1], outs["sync"][1]
    assert (a.pages, a.cross_pages, a.local_pages, a.hops) == \
        (s.pages, s.cross_pages, s.local_pages, s.hops)
    assert a.hop_completions == a.hops == s.hop_completions
    # The sync fabric has no link model and never reports overlap.
    assert s.fabric_inflight_rounds == 0 and s.overlap_ratio == 0.0


def test_sync_fabric_rejects_pump_and_has_no_fabric_object():
    srt, kv, _ = _filled(2, 16, fabric="sync")
    assert srt.fabric is None
    with pytest.raises(RuntimeError, match="requires fabric='async'"):
        srt.pump()
    with pytest.raises(RuntimeError, match="requires fabric='async'"):
        ungraceful_resize(kv, 0)


def test_drain_false_leaves_tickets_for_the_caller_to_pump():
    srt, kv, content = _filled(2, 32, seed=1)
    stats = kv.move_pages([1, 2, 3], [20, 21, 22], drain=False)
    assert srt.fabric_outstanding() == 1
    assert srt.plan_outstanding(stats) == 1
    assert stats.hop_completions == 0      # nothing retired yet
    srt.pump_until_idle()
    srt.drain_until_idle()
    assert srt.fabric_outstanding() == 0
    assert srt.plan_outstanding(stats) == 0
    # Hops retired inside pump() still land their §II-D writebacks on the
    # plan's own stats and on the mesh aggregate exactly once.
    assert stats.hop_completions == stats.hops == 1
    assert srt.migration.hop_completions == 1
    want = content.copy()
    want[[20, 21, 22]] = content[[1, 2, 3]]
    np.testing.assert_array_equal(
        srt.gather_pool(kv.POOL_K).reshape(32, 8), want)


def test_overlap_rounds_are_global_not_per_plan():
    srt, kv, _ = _filled(2, 32, seed=2)
    plans = [kv.move_pages([1 + i], [16 + i], drain=False)
             for i in range(4)]
    srt.pump_until_idle()
    srt.drain_until_idle()
    # Rounds are mesh-wide: only the aggregate carries them, and the
    # hidden count can never exceed the in-flight count.
    agg = srt.migration
    assert agg.fabric_inflight_rounds > 0
    assert 0 <= agg.fabric_hidden_rounds <= agg.fabric_inflight_rounds
    assert 0.0 <= agg.overlap_ratio <= 1.0
    for st in plans:
        assert st.fabric_inflight_rounds == st.fabric_hidden_rounds == 0
        assert st.hop_completions == st.hops == 1


def test_priority_orders_link_access_between_ready_tickets():
    srt, kv, _ = _filled(2, 32, seed=4)
    # Background (0) submitted first, foreground (1) second; one egress
    # chain each (K only) so both tickets become ready the same round.
    bg = srt.migrate_rows((kv.POOL_K,), [1], [20], drain=False, priority=0)
    fg = srt.migrate_rows((kv.POOL_K,), [2], [21], drain=False, priority=1)
    tickets = {t.priority: t for t in srt._pending_hops}
    assert set(tickets) == {0, 1}
    srt.pump_until_idle()
    # The foreground ticket claimed the shared 0->1 link first; the
    # background payload queued behind it.
    assert tickets[1].sent_round == tickets[0].sent_round
    assert tickets[1].deliver_round < tickets[0].deliver_round
    assert srt.fabric.link(0, 1).queued_rounds > 0
    assert tickets[0].state == tickets[1].state == COMPLETED
    assert bg.hop_completions == fg.hop_completions == 1


def test_fabric_hops_emit_link_occupancy_counter_events():
    srt, kv, _ = _filled(2, 16, seed=5)
    tr = Tracer()
    srt.attach_tracer(tr)
    kv.move_pages([1, 2], [10, 11])
    counters = [e for e in tr._buf
                if e.ph == "C" and e.name.startswith("fabric.link")]
    assert counters, "fabric link counters missing from the trace"
    assert any(e.args.get("pages_in_flight", 0) > 0 for e in counters)
    # Delivery zeroes the in-flight series so Perfetto shows a pulse.
    assert any(e.args.get("pages_in_flight") == 0 for e in counters)


# ---------------------------------------------------------------------------
# Elastic resize: graceful evacuate/readmit, and shard loss with tickets
# in flight (fault.ungraceful_resize) against a numpy oracle
# ---------------------------------------------------------------------------

def test_evacuate_readmit_roundtrip_preserves_contents():
    srt, kv, content = _filled(4, 64, seed=6)
    live = kv.alloc_on(2, 5)
    remap = kv.evacuate(2)
    assert srt.active == [True, True, False, True]
    assert sorted(remap) == sorted(live)
    assert all(kv.owner.owner(p) != 2 for p in remap.values())
    for old, new in remap.items():
        np.testing.assert_array_equal(kv.page_rows([new])[0][0],
                                      content[old])
    with pytest.raises(RuntimeError, match="left the mesh"):
        kv.alloc_on(2, 1)
    kv.readmit(2)
    assert srt.active == [True] * 4
    assert kv.free_pages_on(2) == len(list(kv.owner.shard_pages(2)))


@pytest.mark.parametrize("inject_round", [0, 1, 2, 3, 5])
def test_shard_loss_with_tickets_in_flight_loses_no_pages(inject_round):
    """Satellite: ungraceful resize while hops touching the lost shard sit
    at every lifecycle stage. The numpy oracle checks each migrated page's
    content lands exactly once on a survivor — no lost, no duplicated
    destinations — whatever round the loss is injected."""
    lost = 1
    srt, kv, content = _filled(4, 64, seed=7)
    alloc = {s: kv.alloc_on(s, 8) for s in range(4)}

    # Hops INTO the lost shard (must be re-routed), OUT of it (their
    # sources leave via the fabric, not evacuation), and bystander
    # traffic that must survive untouched.
    moves = list(zip(alloc[0][:3], kv.alloc_on(lost, 3))) + \
        list(zip(alloc[lost][:3], kv.alloc_on(2, 3))) + \
        list(zip(alloc[3][:2], kv.alloc_on(0, 2)))
    src, dst = [list(x) for x in zip(*moves)]
    stats = kv.move_pages(src, dst, drain=False)
    assert stats.hops == 3

    srt.pump(inject_round)
    remap = ungraceful_resize(kv, lost)

    assert srt.active == [True, False, True, True]
    assert srt.fabric_outstanding() == 0
    assert stats.hop_completions == stats.hops      # re-routed hops retired
    # Exactly-once landing: remapped destinations are unique survivors.
    landed = list(remap.values())
    assert len(landed) == len(set(landed))
    assert all(kv.owner.owner(p) != lost for p in landed)

    # Every migrated page's content is readable at its (possibly
    # re-routed) destination.
    for s, d in moves:
        final = remap[d] if kv.owner.owner(d) == lost else d
        k, v = kv.page_rows([final])
        np.testing.assert_array_equal(k[0], content[s])
        np.testing.assert_array_equal(v[0], -content[s])
    # The lost shard's untouched live pages were evacuated with content.
    for p in alloc[lost][3:]:
        np.testing.assert_array_equal(kv.page_rows([remap[p]])[0][0],
                                      content[p])
    # Bystanders on survivors are untouched.
    for p in alloc[2][3:]:
        np.testing.assert_array_equal(kv.page_rows([p])[0][0], content[p])


def test_ungraceful_resize_rejects_already_left_shard():
    srt, kv, _ = _filled(2, 16, seed=8)
    kv.evacuate(1)
    with pytest.raises(ValueError, match="already left"):
        ungraceful_resize(kv, 1)
