"""Hypothesis property tests on the histogram merge algebra (DESIGN.md §8).

Cross-shard aggregation folds per-shard histograms in whatever order the
mesh iterates — merge must be associative and commutative, and merging
must agree with having recorded the concatenated samples directly.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis
pytestmark = pytest.mark.slow  # property suites: run in CI's slow job
from hypothesis import given, settings, strategies as st

from repro.obs.metrics import Histogram

values = st.one_of(
    st.integers(min_value=0, max_value=63),            # exact linear region
    st.integers(min_value=64, max_value=1 << 24),      # log2 region
    st.floats(min_value=0.0, max_value=1e9,
              allow_nan=False, allow_infinity=False),
)
sample_lists = st.lists(values, max_size=40)


def _h(vals):
    h = Histogram()
    for v in vals:
        h.record(v)
    return h


def _key(h):
    return (h.counts, h.n, h.min, h.max,
            [h.percentile(q) for q in (50, 95, 99)])


@settings(max_examples=60, deadline=None)
@given(a=sample_lists, b=sample_lists, c=sample_lists)
def test_merge_is_associative_and_commutative(a, b, c):
    ab_c = _h(a)
    ab_c.merge(_h(b))
    ab_c.merge(_h(c))                    # (a + b) + c
    bc = _h(b)
    bc.merge(_h(c))
    a_bc = _h(a)
    a_bc.merge(bc)                       # a + (b + c)
    ba = _h(b)
    ba.merge(_h(a))                      # b + a
    ab = _h(a)
    ab.merge(_h(b))                      # a + b
    assert _key(ab_c) == _key(a_bc)
    assert _key(ab) == _key(ba)
    # float totals associate only approximately; counts associate exactly
    assert ab_c.total == pytest.approx(a_bc.total, rel=1e-12, abs=1e-9)


@settings(max_examples=60, deadline=None)
@given(a=sample_lists, b=sample_lists)
def test_merge_equals_recording_concatenated_samples(a, b):
    merged = _h(a)
    merged.merge(_h(b))
    direct = _h(a + b)
    assert _key(merged) == _key(direct)


@settings(max_examples=60, deadline=None)
@given(samples=st.lists(st.integers(min_value=0, max_value=63),
                        min_size=1, max_size=60),
       q=st.sampled_from([1, 10, 25, 50, 75, 90, 95, 99, 100]))
def test_exact_region_percentiles_match_numpy_oracle(samples, q):
    h = _h(samples)
    assert h.percentile(q) == float(
        np.percentile(np.asarray(samples), q, method="inverted_cdf"))


@settings(max_examples=60, deadline=None)
@given(samples=sample_lists)
def test_snapshot_roundtrip_preserves_distribution(samples):
    h = _h(samples)
    back = Histogram.from_snapshot(h.snapshot())
    assert _key(back) == _key(h)
