"""The speculation-policy layer: FixedDepth ≡ legacy ints, adaptive dynamics.

The hypothesis property suites are slow-marked (CI's tier-1 fast split
skips them; the slow job runs them) and skip cleanly on minimal installs.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs.registry import get_config, list_archs
from repro.core.simulator import SimConfig, simulate
from repro.core.speculation import (
    DEFAULT_DEPTH,
    DEFAULT_POLICY,
    AdaptiveDepth,
    FixedDepth,
    as_policy,
    static_depth,
)
from repro.perf.workloads import Scale, generate
from repro.runtime import ChannelConfig, DMARuntime, SubmitRequest, coalesce

TINY = Scale("tiny", n_bursts=1, burst_len=24, pool_elems=1 << 12,
             max_len=128, ring_capacity=64, sim_transfers=60)


# ---------------------------------------------------------------------------
# Policy basics
# ---------------------------------------------------------------------------

def test_as_policy_coerces_ints_and_passes_policies_through():
    p = as_policy(7)
    assert isinstance(p, FixedDepth) and p.depth == 7
    a = AdaptiveDepth()
    assert as_policy(a) is a
    with pytest.raises(TypeError):
        as_policy("deep")
    assert static_depth(3) == 3
    assert static_depth(FixedDepth(0)) == 0
    assert static_depth(AdaptiveDepth(initial_depth=6)) == 6


def test_fixed_controller_ignores_observations():
    c = FixedDepth(5).make_controller()
    for h in (0.0, 1.0, 0.3):
        assert c.observe(h) == 5
    assert c.depth == 5 and c.enabled
    assert not FixedDepth(0).make_controller().enabled


def test_default_policy_matches_simulator_and_kernel_default():
    """Single source of truth: SimConfig.speculation() and the kernels'
    default depth both come from DEFAULT_POLICY."""
    assert DEFAULT_POLICY.depth == DEFAULT_DEPTH == 4
    assert SimConfig.speculation().prefetch == FixedDepth(DEFAULT_DEPTH)

    import inspect
    from repro.kernels import ops
    sig = inspect.signature(ops.prefetched_chain_copy_op)
    assert sig.parameters["depth"].default is None  # None -> DEFAULT_POLICY


def test_adaptive_validation():
    with pytest.raises(ValueError):
        AdaptiveDepth(min_depth=0)
    with pytest.raises(ValueError):
        AdaptiveDepth(initial_depth=30, max_depth=24)
    with pytest.raises(ValueError):
        AdaptiveDepth(deepen_threshold=0.4, backoff_threshold=0.5)
    with pytest.raises(ValueError):
        AdaptiveDepth(backoff_hysteresis=0)


def test_adaptive_deepens_on_sequential_and_backs_off_on_storms():
    c = AdaptiveDepth().make_controller()
    for _ in range(8):
        c.observe(1.0)
    assert c.depth == 24
    for _ in range(16):
        c.observe(0.0)
    assert c.depth == 1
    # recovery: the floor keeps one probing slot, so it can climb back
    for _ in range(16):
        c.observe(1.0)
    assert c.depth == 24


def test_adaptive_hysteresis_absorbs_one_bad_window():
    p = AdaptiveDepth(backoff_hysteresis=2, alpha=1.0)
    c = p.make_controller()
    for _ in range(4):
        c.observe(1.0)
    top = c.depth
    c.observe(0.0)       # one misprediction burst...
    assert c.depth == top  # ...does not move the depth
    c.observe(0.0)       # a second consecutive bad window does
    assert c.depth == top // 2


# ---------------------------------------------------------------------------
# FixedDepth ≡ legacy integer behaviour, bit for bit
# ---------------------------------------------------------------------------

def _strip(r):
    d = dataclasses.asdict(r)
    d.pop("config")
    d.pop("final_depth")
    d.pop("mean_depth")
    return d


@pytest.mark.parametrize("depth,in_flight", [(0, 4), (4, 4), (24, 24)])
@pytest.mark.parametrize("latency", [1, 13, 100])
def test_simulator_fixed_policy_equals_int_prefetch(depth, in_flight,
                                                    latency):
    for size in (64, 256):
        for hit in (1.0, 0.6):
            a = simulate(SimConfig("i", in_flight=in_flight, prefetch=depth),
                         latency, size, num_transfers=256, hit_rate=hit,
                         seed=11)
            b = simulate(SimConfig("p", in_flight=in_flight,
                                   prefetch=FixedDepth(depth)),
                         latency, size, num_transfers=256, hit_rate=hit,
                         seed=11)
            assert _strip(a) == _strip(b)


def _chain_fields(d):
    return tuple(np.asarray(getattr(d, f)).tobytes()
                 for f in ("src", "dst", "length", "nxt", "config"))


@pytest.mark.slow
@pytest.mark.parametrize("arch", list_archs())
def test_fixed_policy_runtime_identical_on_registry_configs(arch):
    """On all 10 registry archs, a FixedDepth runtime plans, executes and
    reports exactly like the pre-policy runtime (whose behaviour is pinned
    by coalesce() with spec_depth=0 and the committed baseline)."""
    cfg = get_config(arch)
    for workload in ("paged_kv", "moe_dispatch"):
        wl = generate(workload, cfg, TINY, seed=0)
        # coalescer: provisioning slack must never change the plan
        for d in wl.chains:
            legacy, s0 = coalesce(d, max_len=TINY.max_len)
            planned, s1 = coalesce(d, max_len=TINY.max_len,
                                   spec_depth=DEFAULT_DEPTH)
            assert _chain_fields(legacy) == _chain_fields(planned)
            assert s0.input_hit_rate == s1.input_hit_rate
            assert s0.merge_ratio == s1.merge_ratio
            assert s1.provisioned_slack == DEFAULT_DEPTH

        # runtime: explicit FixedDepth == default-policy runtime, and the
        # sim sees identical results through int or policy prefetch
        import jax.numpy as jnp
        stats = []
        for speculation in (None, FixedDepth(DEFAULT_DEPTH)):
            rt = DMARuntime(
                [ChannelConfig(name="a", tier="serial",
                               ring_capacity=TINY.ring_capacity,
                               max_len=TINY.max_len)],
                speculation=speculation)
            rt.register_pool("src", jnp.zeros(TINY.pool_elems, jnp.float32))
            rt.register_pool("dst", jnp.zeros(TINY.pool_elems, jnp.float32))
            for d in wl.chains:
                rt.submit(SubmitRequest(chain=d, src_pool="src",
                                        dst_pool="dst", channel="a"))
            rt.drain_until_idle()
            st = rt.stats()
            stats.append((st["coalesce_merge_ratio"],
                          st["mean_input_hit_rate"],
                          st["channels"]["a"]["drained"],
                          st["channels"]["a"]["speculation_depth"]))
        assert stats[0] == stats[1]
        assert stats[0][3] == DEFAULT_DEPTH   # fixed policy never moves
