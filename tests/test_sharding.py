"""Distribution tests: PartitionSpec policies + real sharded execution on a
small host-device mesh (subprocess owns the XLA device-count flag — nothing
here leaks 8 fake devices into other tests)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config, SHAPES
    from repro.distributed import shardlib
    from repro.distributed.sharding import (
        activation_rules, param_specs, to_named, train_state_specs,
        train_batch_specs, decode_state_specs, batch_axis)
    from repro.launch.mesh import make_debug_mesh
    from repro.models import init_params, decode_step, param_shapes
    from repro.train import TrainConfig, init_state, train_step

    out = {}
    mesh = make_debug_mesh(data=4, model=2)
    shardlib.set_mesh(mesh)
    shardlib.set_rules(activation_rules(mesh))
    cfg = get_config("%(arch)s", reduced=True)

    with mesh:
        key = jax.random.PRNGKey(0)
        params = init_params(key, cfg)
        tcfg = TrainConfig()
        state = init_state(params, tcfg)
        state_shapes = jax.eval_shape(lambda s: s, state)
        sspec = to_named(train_state_specs(cfg, mesh, state_shapes), mesh)
        state = jax.device_put(state, sspec)
        b, s = 8, 32
        batch = {
            "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        }
        if cfg.is_encdec:
            batch["frames"] = jax.random.normal(key, (b, 16, cfg.d_model))
        if cfg.prefix_len:
            batch["prefix_embeds"] = jax.random.normal(
                key, (b, cfg.prefix_len, cfg.d_model))
        bspec = to_named(train_batch_specs(mesh, b, batch), mesh)
        batch = jax.device_put(batch, bspec)
        step = jax.jit(lambda st, bb: train_step(st, bb, cfg, tcfg),
                       in_shardings=(sspec, bspec),
                       out_shardings=(sspec, None))
        state2, metrics = step(state, batch)
        out["loss"] = float(metrics["loss"])
        out["grad_norm"] = float(metrics["grad_norm"])
        # A representative param must actually be sharded over >1 device.
        leaves = jax.tree.leaves(state2.params)
        out["num_shards_max"] = max(
            len(l.sharding.device_set) for l in leaves)
    print("RESULT" + json.dumps(out))
""")


def _run(arch: str) -> dict:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT % {"arch": arch}],
                          capture_output=True, text=True, env=env,
                          timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen3-14b", "deepseek-v2-236b",
                                  "jamba-v0.1-52b"])
def test_sharded_train_step_executes(arch):
    out = _run(arch)
    assert out["num_shards_max"] == 8          # params really distributed
    assert out["grad_norm"] > 0
    import math
    assert math.isfinite(out["loss"])


def test_param_specs_cover_all_archs():
    """Every arch x mesh: specs build, divisible dims shard, rest replicate."""
    import numpy as np
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config, list_archs
    from repro.distributed.sharding import param_specs
    from repro.models import param_shapes

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    mesh = FakeMesh()
    for arch in list_archs():
        cfg = get_config(arch)
        shapes = param_shapes(cfg)
        specs = param_specs(cfg, mesh, shapes)
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_flatten_with_path(shapes)[0],
                jax.tree_util.tree_flatten_with_path(
                    specs, is_leaf=lambda x: isinstance(x, P))[0]):
            assert len(spec) <= leaf.ndim, (arch, path, spec, leaf.shape)
            for i, entry in enumerate(spec):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                size = int(np.prod([mesh.shape[a] for a in axes]))
                assert leaf.shape[i] % size == 0, \
                    (arch, path, spec, leaf.shape)


def test_batch_axis_selection():
    from repro.distributed.sharding import batch_axis

    class M1:
        shape = {"pod": 2, "data": 16, "model": 16}

    class M2:
        shape = {"data": 16, "model": 16}
    assert batch_axis(M1(), 256) == ("pod", "data")
    assert batch_axis(M1(), 2) == "pod"
    assert batch_axis(M1(), 1) is None
    assert batch_axis(M2(), 128) == "data"
    assert batch_axis(M2(), 1) is None


def test_ep_moe_matches_reference():
    """Expert-parallel shard_map MoE == meshless reference (drop-free)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models.moe import init_moe, moe_ffn
        from repro.distributed import shardlib
        from repro.distributed.sharding import activation_rules
        from repro.launch.mesh import make_debug_mesh

        cfg = get_config("dbrx-132b", reduced=True)
        cfg = dataclasses.replace(cfg, compute_dtype="float32",
            moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        p = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model)) * 0.5
        y_ref, aux_ref, _ = jax.jit(lambda p, x: moe_ffn(p, x, cfg))(p, x)
        mesh = make_debug_mesh(data=4, model=2)
        shardlib.set_mesh(mesh); shardlib.set_rules(activation_rules(mesh))
        with mesh:
            y_ep, _, _ = jax.jit(lambda p, x: moe_ffn(p, x, cfg))(p, x)
        shardlib.clear_mesh()
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ep),
                                   rtol=2e-4, atol=2e-4)
        print("RESULT{}")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, env=env,
                          timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]


def test_elastic_reshard_across_topologies():
    """Save on one topology, restore resharded for another (shrink)."""
    script = textwrap.dedent("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np
        from repro.checkpoint import Checkpointer
        from repro.configs import get_config
        from repro.distributed.fault import reshard_checkpoint
        from repro.launch.mesh import make_debug_mesh
        from repro.models import init_params
        from repro.train import TrainConfig, init_state

        cfg = get_config("qwen2.5-3b", reduced=True)
        params = init_params(jax.random.PRNGKey(0), cfg)
        state = init_state(params, TrainConfig())
        d = tempfile.mkdtemp()
        ck = Checkpointer(d)
        ck.save(5, state, blocking=True)

        shapes = jax.eval_shape(lambda s: s, state)
        small = make_debug_mesh(data=2, model=2)   # "shrunk" topology
        restored, _ = reshard_checkpoint(ck, 5, cfg, small, shapes)
        a = jax.tree.leaves(restored.params)[3]
        b = jax.tree.leaves(state.params)[3]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert max(len(l.sharding.device_set)
                   for l in jax.tree.leaves(restored.params)) == 4
        print("RESULT{}")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, env=env,
                          timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
