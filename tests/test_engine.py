"""Engines vs host oracle: serial chain-order semantics, blocked gather/scatter."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
pytestmark = pytest.mark.slow  # property suites: run in CI's slow job
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.descriptor import DescriptorArray
from repro.core.engine import (
    execute_blocked,
    execute_blocked_2d,
    execute_chain_host,
    execute_serial,
)


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_serial_engine_matches_host_oracle(data):
    n_desc = data.draw(st.integers(1, 12))
    pool = data.draw(st.integers(64, 256))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    max_len = 16
    lens = rng.integers(1, max_len + 1, n_desc)
    srcs = rng.integers(0, pool - max_len, n_desc)
    dsts = rng.integers(0, pool - max_len, n_desc)
    d = DescriptorArray.create(srcs, dsts, lens)
    src = rng.standard_normal(pool).astype(np.float32)
    dst = rng.standard_normal(pool).astype(np.float32)

    want, want_d = execute_chain_host(d, src, dst)
    got, done = execute_serial(d, jnp.asarray(src), jnp.asarray(dst),
                               max_len=max_len)
    np.testing.assert_allclose(np.asarray(got), want, rtol=0, atol=0)
    assert np.all(np.asarray(done) == 1)
    assert bool(want_d.all_done())


def test_serial_engine_preserves_chain_order_on_overlap():
    # Two descriptors writing the same destination: later-in-chain wins.
    d = DescriptorArray.create([0, 8], [0, 0], [4, 4])
    src = jnp.arange(16, dtype=jnp.float32)
    dst = jnp.zeros(16, dtype=jnp.float32)
    out, _ = execute_serial(d, src, dst, max_len=4)
    np.testing.assert_array_equal(np.asarray(out[:4]), [8, 9, 10, 11])


def test_serial_engine_respects_nonsequential_chain():
    # Chain order 1 -> 0; overlapping writes must land in chain order.
    d = DescriptorArray.create([0, 8], [0, 0], [4, 4], nxt=[-1, 0])
    src = jnp.arange(16, dtype=jnp.float32)
    out, _ = execute_serial(d, src, jnp.zeros(16, jnp.float32),
                            max_len=4, head=1)
    np.testing.assert_array_equal(np.asarray(out[:4]), [0, 1, 2, 3])


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_blocked_engine_matches_oracle_disjoint(data):
    """Vectorized engine == oracle whenever destinations are disjoint."""
    n_desc = data.draw(st.integers(1, 16))
    unit = data.draw(st.sampled_from([1, 4, 8]))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    pool = n_desc * unit + 32
    dst_slots = rng.permutation(n_desc) * unit       # disjoint destinations
    srcs = rng.integers(0, pool - unit, n_desc)
    lens = rng.integers(1, unit + 1, n_desc)
    d = DescriptorArray.create(srcs, dst_slots, lens)
    src = rng.standard_normal(pool).astype(np.float32)
    dst = np.zeros(pool, np.float32)

    want, _ = execute_chain_host(d, src, dst)
    got, done = execute_blocked(d, jnp.asarray(src), jnp.asarray(dst), unit=unit)
    np.testing.assert_allclose(np.asarray(got), want)
    assert np.all(np.asarray(done) == 1)


def test_blocked_skips_completed_descriptors():
    d = DescriptorArray.create([0, 4], [0, 4], [4, 4])
    d = d.mark_done(0)  # length becomes -1 sentinel
    src = jnp.arange(8, dtype=jnp.float32) + 100
    out, _ = execute_blocked(d, src, jnp.zeros(8, jnp.float32), unit=4)
    np.testing.assert_array_equal(np.asarray(out[:4]), [0, 0, 0, 0])
    np.testing.assert_array_equal(np.asarray(out[4:]), [104, 105, 106, 107])


def test_blocked_2d_row_moves():
    src = jnp.arange(24, dtype=jnp.float32).reshape(6, 4)
    dst = jnp.zeros((4, 4), jnp.float32)
    d = DescriptorArray.create([5, 0, 3], [0, 2, 3], [1, 1, 1])
    out, done = execute_blocked_2d(d, src, dst)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(src[5]))
    np.testing.assert_array_equal(np.asarray(out[2]), np.asarray(src[0]))
    np.testing.assert_array_equal(np.asarray(out[3]), np.asarray(src[3]))
    np.testing.assert_array_equal(np.asarray(out[1]), [0, 0, 0, 0])
    assert np.all(np.asarray(done) == 1)
