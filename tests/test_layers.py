"""Layer-level correctness: attention schedules, RoPE, Mamba2 SSD, MoE."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import SSMConfig
from repro.models.attention import blockwise_attention
from repro.models.layers import apply_rope, rms_norm, softmax_cross_entropy
from repro.models.mamba import init_mamba, mamba_decode, mamba_layer, MambaCache
from repro.models.moe import capacity, moe_dispatch_plan


# ---------------------------------------------------------------------------
# Blockwise (flash) attention vs naive
# ---------------------------------------------------------------------------

def naive_attention(q, k, v, causal=True, window=None):
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) * d ** -0.5
    qi = jnp.arange(sq)[:, None]
    ki = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= qi - ki < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(vv.dtype), vv)


@pytest.mark.parametrize("h,kv", [(4, 4), (8, 2)])
@pytest.mark.parametrize("window", [None, 7])
def test_blockwise_matches_naive(h, kv, window):
    key = jax.random.PRNGKey(0)
    b, s, d = 2, 64, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    got = blockwise_attention(q, k, v, causal=True, window=window,
                              q_block=16, kv_block=16)
    want = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_blockwise_mla_asymmetric_value_dim():
    key = jax.random.PRNGKey(1)
    b, s, h, d, dv = 1, 32, 4, 24, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, dv))
    got = blockwise_attention(q, k, v, q_block=8, kv_block=8)
    assert got.shape == (b, s, h, dv)
    want = naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_blockwise_softcap():
    key = jax.random.PRNGKey(2)
    b, s, h, d = 1, 32, 2, 8
    q = jax.random.normal(key, (b, s, h, d)) * 4
    k = jax.random.normal(key, (b, s, h, d)) * 4
    v = jax.random.normal(key, (b, s, h, d))
    got = blockwise_attention(q, k, v, softcap=20.0, q_block=8, kv_block=8)
    assert np.all(np.isfinite(np.asarray(got)))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def test_rope_relative_position_property():
    """<rope(q,m), rope(k,n)> depends only on m-n."""
    key = jax.random.PRNGKey(0)
    d = 32
    q = jax.random.normal(key, (1, 1, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))
    def dot(m, n):
        qm = apply_rope(q, jnp.array([[m]]), theta=10000.0)
        kn = apply_rope(k, jnp.array([[n]]), theta=10000.0)
        return float(jnp.sum(qm * kn))
    assert dot(5, 3) == pytest.approx(dot(105, 103), rel=1e-4)
    assert dot(0, 0) == pytest.approx(dot(77, 77), rel=1e-4)


def test_partial_rope_leaves_tail_untouched():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 4, 2, 16))
    out = apply_rope(x, jnp.arange(4)[None], theta=10000.0, fraction=0.5)
    np.testing.assert_allclose(np.asarray(out[..., 8:]),
                               np.asarray(x[..., 8:]))
    assert not np.allclose(np.asarray(out[..., :8]), np.asarray(x[..., :8]))


def test_rms_norm_unit_scale():
    x = jnp.array([[3.0, 4.0]])
    out = rms_norm(x, jnp.zeros(2), eps=0.0)
    np.testing.assert_allclose(np.asarray(jnp.mean(out**2, -1)), [1.0],
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# Mamba2 SSD: chunked scan == naive recurrence; decode == last step
# ---------------------------------------------------------------------------

def _tiny_mamba_cfg(chunk=8):
    return dataclasses.replace(
        get_config("mamba2-780m", reduced=True),
        d_model=32,
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=8,
                      n_groups=1, chunk=chunk))


def naive_ssd(params, x, cfg):
    """Literal per-step SSM recurrence (ground truth)."""
    out = []
    cache = None
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    cache = MambaCache(
        conv=jnp.zeros((x.shape[0], s.d_conv - 1, conv_ch), x.dtype),
        state=jnp.zeros((x.shape[0], d_inner // s.head_dim, s.d_state,
                         s.head_dim), jnp.float32))
    for t in range(x.shape[1]):
        y, cache = mamba_decode(params, x[:, t:t + 1], cache, cfg)
        out.append(y)
    return jnp.concatenate(out, axis=1), cache


@pytest.mark.parametrize("seqlen,chunk", [(16, 8), (32, 8), (24, 24)])
def test_ssd_chunked_matches_recurrence(seqlen, chunk):
    cfg = _tiny_mamba_cfg(chunk)
    key = jax.random.PRNGKey(0)
    params = init_mamba(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, seqlen, cfg.d_model),
                          jnp.float32) * 0.5
    y_chunk, cache_chunk = mamba_layer(params, x, cfg, return_cache=True)
    y_naive, cache_naive = naive_ssd(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               rtol=2e-2, atol=2e-2)  # bf16 compute path
    np.testing.assert_allclose(np.asarray(cache_chunk.state),
                               np.asarray(cache_naive.state),
                               rtol=2e-2, atol=2e-2)


def test_ssd_decode_continues_from_prefill_state():
    cfg = _tiny_mamba_cfg(8)
    key = jax.random.PRNGKey(0)
    params = init_mamba(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 17, cfg.d_model)) * 0.5
    # Full pass over 17 == prefill over 16 then decode 1.
    y_full, _ = naive_ssd(params, x, cfg)
    _, cache = mamba_layer(params, x[:, :16], cfg, return_cache=True)
    y_step, _ = mamba_decode(params, x[:, 16:17], cache, cfg)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full[:, 16:17]),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# MoE dispatch plan (descriptor-stream semantics)
# ---------------------------------------------------------------------------

def test_dispatch_plan_routes_topk():
    from repro.configs.base import MoEConfig
    m = MoEConfig(num_experts=4, experts_per_token=2, expert_d_ff=8,
                  capacity_factor=2.0)
    t = 16
    probs = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(0), (t, 4)), -1)
    cap = capacity(t, m)
    plan = moe_dispatch_plan(probs, m, cap)
    token_idx = np.asarray(plan.token_idx).reshape(4, cap)
    weight = np.asarray(plan.weight).reshape(4, cap)
    topv, topi = jax.lax.top_k(probs, 2)
    topv = topv / topv.sum(-1, keepdims=True)
    # Every (token, expert) top-k pair appears exactly once with its weight.
    want = {(int(tk), int(e)): float(w)
            for tk in range(t)
            for e, w in zip(np.asarray(topi)[tk], np.asarray(topv)[tk])}
    got = {}
    for e in range(4):
        for c in range(cap):
            if token_idx[e, c] >= 0:
                got[(int(token_idx[e, c]), e)] = float(weight[e, c])
    assert int(plan.num_dropped) == 0
    assert set(got) == set(want)
    for key_ in want:
        assert got[key_] == pytest.approx(want[key_], rel=1e-5)


def test_dispatch_plan_drops_over_capacity():
    from repro.configs.base import MoEConfig
    m = MoEConfig(num_experts=2, experts_per_token=1, expert_d_ff=8,
                  capacity_factor=1.0)
    # All tokens want expert 0.
    probs = jnp.tile(jnp.array([[0.99, 0.01]]), (64, 1))
    cap = capacity(64, m)
    plan = moe_dispatch_plan(probs, m, cap)
    assert int(plan.num_dropped) == 64 - cap


def test_cross_entropy_matches_manual():
    logits = jnp.array([[[2.0, 1.0, 0.0]]])
    labels = jnp.array([[0]])
    loss, m = softmax_cross_entropy(logits, labels, z_weight=0.0)
    want = -np.log(np.exp(2) / (np.exp(2) + np.exp(1) + 1))
    assert float(loss) == pytest.approx(want, rel=1e-5)
