"""Substrate tests: optimizer, data pipeline, checkpointing, compression,
paged KV cache, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data import DataConfig, DataIterator, IteratorState, make_batch
from repro.models import init_params
from repro.runtime import SubmitRequest
from repro.serve import PagedKVCache, Request, ServeEngine


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_adamw_matches_reference_math():
    cfg = optim.AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8,
                            weight_decay=0.0, grad_clip=0.0,
                            schedule="constant", warmup_steps=0)
    params = {"w": jnp.array([1.0, 2.0])}
    grads = {"w": jnp.array([0.5, -0.5])}
    state = optim.init(params)
    new_p, state, _ = optim.apply(cfg, params, grads, state)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mhat, vhat = m / 0.1, v / 0.01
    want = 1.0 - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    assert float(new_p["w"][0]) == pytest.approx(want, rel=1e-5)


def test_grad_clip_limits_update():
    cfg = optim.AdamWConfig(lr=1.0, grad_clip=1e-6, weight_decay=0.0,
                            schedule="constant", warmup_steps=0)
    params = {"w": jnp.ones(4)}
    grads = {"w": jnp.full(4, 1e6)}
    state = optim.init(params)
    _, _, metrics = optim.apply(cfg, params, grads, state)
    assert float(metrics["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


def test_schedule_warmup_and_decay():
    cfg = optim.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                            schedule="cosine", min_lr_ratio=0.1)
    assert float(optim.learning_rate(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(optim.learning_rate(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(optim.learning_rate(cfg, jnp.asarray(110))) == pytest.approx(0.1)


def test_training_reduces_loss_small_model():
    """End-to-end: a few steps of AdamW reduce loss on a fixed batch."""
    from repro.train import TrainConfig, init_state, train_step
    cfg = get_config("qwen2.5-3b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (4, 32), 0, cfg.vocab_size)}
    tcfg = TrainConfig(optimizer=optim.AdamWConfig(
        lr=1e-3, warmup_steps=0, total_steps=100, schedule="constant",
        weight_decay=0.0))
    state = init_state(params, tcfg)
    step = jax.jit(lambda s, b: train_step(s, b, cfg, tcfg))
    first = None
    for i in range(8):
        state, metrics = step(state, batch)
        if first is None:
            first = float(metrics["loss"])
    assert float(metrics["loss"]) < first


def test_microbatch_grad_accum_matches_full_batch():
    from repro.train import grads_and_metrics
    cfg = get_config("qwen2.5-3b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (4, 16), 0, cfg.vocab_size)}
    g1, _ = jax.jit(lambda p, b: grads_and_metrics(p, b, cfg, 1))(params, batch)
    g2, _ = jax.jit(lambda p, b: grads_and_metrics(p, b, cfg, 2))(params, batch)
    flat1, flat2 = jax.tree.leaves(g1), jax.tree.leaves(g2)
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-4)


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

def test_int8_compression_error_feedback_converges():
    """EF property: accumulated quantization error stays bounded and the
    long-run mean of transmitted values matches the true gradient."""
    from repro.optim.compress import _dequantize, _quantize
    rng = np.random.default_rng(0)
    g = rng.standard_normal(512).astype(np.float32)
    residual = np.zeros_like(g)
    sent_sum = np.zeros_like(g)
    for step in range(200):
        x = g + residual
        q, s = _quantize(jnp.asarray(x))
        sent = np.asarray(_dequantize(q, s))
        residual = x - sent
        sent_sum += sent
    np.testing.assert_allclose(sent_sum / 200, g, rtol=0, atol=1e-2)
    assert np.abs(residual).max() < 0.1


def test_compression_ratio_near_4x():
    assert optim.compression_ratio() == pytest.approx(0.26, abs=0.01)


def test_compressed_psum_under_shard_map():
    """Compressed allreduce over a 'pod' axis == mean of shards (approx)."""
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    devs = np.array(jax.devices()[:1])
    mesh = Mesh(devs.reshape(1), ("pod",))
    g = {"w": jnp.arange(8, dtype=jnp.float32) / 7.0}
    r = optim.init_residuals(g)

    def fn(g, r):
        return optim.compressed_psum_tree(g, r, "pod")

    out, new_r = shard_map(fn, mesh=mesh,
                           in_specs=(P(), P()), out_specs=(P(), P()))(g, r)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               atol=0.02)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def _dcfg(**kw):
    return DataConfig(vocab_size=1000, seq_len=128, global_batch=4, **kw)


def test_data_deterministic_across_restarts():
    cfg = _dcfg()
    a = make_batch(cfg, step=7)
    b = make_batch(cfg, step=7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_data_hosts_disjoint():
    a = make_batch(_dcfg(num_hosts=2, host_id=0), 0)
    b = make_batch(_dcfg(num_hosts=2, host_id=1), 0)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_iterator_resume_mid_stream():
    cfg = _dcfg()
    it = DataIterator(cfg)
    batches = [next(it) for _ in range(3)]
    state = IteratorState.from_dict(it.state.to_dict())
    it.close()
    it2 = DataIterator(cfg, state)
    b3 = next(it2)
    it2.close()
    want = make_batch(cfg, 3)
    np.testing.assert_array_equal(b3["tokens"], want["tokens"])


def test_packing_descriptors_cover_sequences():
    from repro.data import pack_documents
    cfg = _dcfg()
    rng = np.random.default_rng(0)
    tokens, seg, chain = pack_documents(cfg, rng, batch_rows=2)
    lens = np.asarray(chain.length)
    dsts = np.asarray(chain.dst)
    # Descriptors tile the packed space exactly, without overlap.
    covered = np.zeros(2 * cfg.seq_len, bool)
    for dst, ln in zip(dsts, lens):
        assert not covered[dst:dst + ln].any()
        covered[dst:dst + ln] = True
    assert covered.all()
    assert (seg > 0).all()


# ---------------------------------------------------------------------------
# Checkpointing / fault tolerance
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones(4, jnp.bfloat16)}}
    ck.save(10, tree, blocking=True, extra={"iterator": {"step": 10}})
    got, extra = ck.restore(10, tree)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    assert got["nested"]["b"].dtype == jnp.bfloat16
    assert extra["iterator"]["step"] == 10


def test_checkpoint_ignores_uncommitted(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"a": jnp.zeros(2)}
    ck.save(1, tree, blocking=True)
    # Simulate a torn write: step dir without COMMIT.
    os.makedirs(tmp_path / "step_000000002")
    assert ck.latest_step() == 1


def test_checkpoint_gc_keeps_last_k(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        ck.save(s, tree, blocking=True)
    assert ck.committed_steps() == [3, 4]


def test_trainer_resumes_after_interrupt(tmp_path):
    """Kill training mid-run; a fresh Trainer resumes from the checkpoint
    with identical data stream position."""
    from repro.train import Trainer, TrainConfig, TrainerConfig
    cfg = get_config("qwen2.5-3b", reduced=True)
    tcfg = TrainConfig(optimizer=optim.AdamWConfig(
        lr=1e-4, warmup_steps=0, schedule="constant"))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=2)
    run = TrainerConfig(total_steps=6, checkpoint_every=3,
                        checkpoint_dir=str(tmp_path), log_every=100)
    t1 = Trainer(cfg, tcfg, run, dcfg)
    r1 = t1.train()
    assert r1["final_step"] == 6
    # Resume: should detect step 6 checkpoint and do nothing more.
    run2 = TrainerConfig(total_steps=8, checkpoint_every=3,
                         checkpoint_dir=str(tmp_path), log_every=100)
    t2 = Trainer(cfg, tcfg, run2, dcfg)
    r2 = t2.train()
    assert r2["final_step"] == 8
    assert len(r2["losses"]) == 2   # only steps 6,7 ran after resume


def test_elastic_restore_to_new_sharding(tmp_path):
    """Restore a checkpoint with explicit (different) shardings — the
    elastic re-mesh path."""
    from jax.sharding import NamedSharding, PartitionSpec as P, Mesh
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    ck.save(1, tree, blocking=True)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    got, _ = ck.restore(1, tree, shardings=sh)
    assert got["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))


def test_straggler_monitor_flags_slow_steps():
    from repro.train import StragglerMonitor
    m = StragglerMonitor(threshold=2.0)
    for s in range(10):
        m.observe(s, 1.0)
    assert m.observe(10, 5.0)
    assert 10 in m.flagged


# ---------------------------------------------------------------------------
# Paged KV cache + serving engine
# ---------------------------------------------------------------------------

def test_page_allocator_and_chains():
    from repro.serve import PageAllocator
    a = PageAllocator(16)
    p0 = a.alloc(0, 3)
    assert len(p0) == 3 and a.free_pages == 13
    # Sequential allocation -> perfect speculation hit rate by construction.
    assert a.speculation_hit_rate(0) == 1.0
    chain = a.chain(0, page_elems=8)
    assert chain.num_descriptors == 3
    a.free(0)
    assert a.free_pages == 16


def test_paged_cache_append_and_dense_view():
    c = PagedKVCache(page=4, num_pages=8, max_seqs=2, max_pages_per_seq=3,
                     kv_heads=2, head_dim=8)
    c.admit(0)
    rows = [np.full((2, 8), i, np.float32) for i in range(6)]
    for r in rows:
        c.append(0, jnp.asarray(r), jnp.asarray(r * 2))
    k, v = c.dense_view(0)
    assert k.shape == (6, 2, 8)
    for i in range(6):
        np.testing.assert_array_equal(k[i], rows[i])
        np.testing.assert_array_equal(v[i], rows[i] * 2)


def test_paged_cache_kernel_consistency():
    """Engine-managed pool + Pallas paged kernel == dense attention."""
    from repro.kernels import paged_attention_op, ref
    c = PagedKVCache(page=8, num_pages=6, max_seqs=2, max_pages_per_seq=3,
                     kv_heads=2, head_dim=128)
    rng = np.random.default_rng(0)
    for slot, ln in [(0, 20), (1, 9)]:
        c.admit(slot)
        for _ in range(ln):
            c.append(slot, jnp.asarray(rng.standard_normal((2, 128)),
                                       jnp.float32),
                     jnp.asarray(rng.standard_normal((2, 128)), jnp.float32))
    q = jnp.asarray(rng.standard_normal((2, 4, 128)), jnp.float32)
    out = paged_attention_op(q, *c.kernel_args())
    want = ref.paged_attention_ref(q, *c.kernel_args())
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_out_of_pages_raises():
    from repro.serve import OutOfPages
    c = PagedKVCache(page=2, num_pages=1, max_seqs=1, max_pages_per_seq=4,
                     kv_heads=1, head_dim=4)
    c.admit(0)
    for _ in range(2):
        c.append(0, jnp.zeros((1, 4)), jnp.zeros((1, 4)))
    with pytest.raises(OutOfPages):
        c.append(0, jnp.zeros((1, 4)), jnp.zeros((1, 4)))


def test_serve_engine_continuous_batching_matches_reference():
    from repro.models import prefill, decode_step
    cfg = get_config("qwen2.5-3b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompt = list(rng.integers(1, 500, 5))
    eng = ServeEngine(params, cfg, capacity=3, max_len=64)
    eng.submit(SubmitRequest(request=Request(uid=0, prompt=prompt,
                                             max_new_tokens=4)))
    eng.submit(SubmitRequest(request=Request(
        uid=1, prompt=list(rng.integers(1, 500, 3)), max_new_tokens=4)))
    eng.submit(SubmitRequest(request=Request(
        uid=2, prompt=list(rng.integers(1, 500, 7)), max_new_tokens=4)))
    done = eng.run(max_steps=100)
    assert sorted(done) == [0, 1, 2]
    assert len(eng.poll_completed()) == 3

    logits, state = prefill(params, {"tokens": jnp.asarray([prompt])}, cfg,
                            max_len=64)
    ref_out = []
    tok = jnp.argmax(logits, -1)
    for _ in range(4):
        ref_out.append(int(tok[0]))
        logits, state = decode_step(params, tok, state, cfg)
        tok = jnp.argmax(logits, -1)
    assert done[0].output == ref_out


def test_serve_engine_slot_reuse_is_clean():
    """A request admitted into a previously-used slot must not see stale KV."""
    cfg = get_config("qwen2.5-3b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prompt = list(rng.integers(1, 500, 5))
    # Engine A: slot 0 used twice (uid 0 then uid 2).
    eng = ServeEngine(params, cfg, capacity=1, max_len=64)
    eng.submit(SubmitRequest(request=Request(
        uid=0, prompt=list(rng.integers(1, 500, 9)), max_new_tokens=3)))
    eng.submit(SubmitRequest(request=Request(uid=2, prompt=prompt,
                                             max_new_tokens=3)))
    out_reused = eng.run(max_steps=200)[2].output
    # Engine B: fresh engine, same request.
    eng2 = ServeEngine(params, cfg, capacity=1, max_len=64)
    eng2.submit(SubmitRequest(request=Request(uid=2, prompt=prompt,
                                              max_new_tokens=3)))
    out_fresh = eng2.run(max_steps=100)[2].output
    assert out_reused == out_fresh
