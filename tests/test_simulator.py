"""Cycle simulator vs the paper's published claims (Figs 4-5, Tables II-IV)."""
import pytest

from repro.core import area_model as A
from repro.core.prefetch import analytical_utilization
from repro.core.simulator import (
    SimConfig,
    ideal_utilization,
    simulate,
    table_iv,
    utilization_sweep,
)


# ---------------------------------------------------------------------------
# Eq. (1) and ideal-memory behaviour (Fig 4a)
# ---------------------------------------------------------------------------

def test_eq1_ideal_utilization():
    assert ideal_utilization(64) == pytest.approx(64 / 96)
    assert ideal_utilization(32) == pytest.approx(0.5)
    assert ideal_utilization(4096) == pytest.approx(4096 / 4128)


@pytest.mark.parametrize("size", [32, 64, 128, 256, 512, 1024, 4096])
def test_base_reaches_ideal_in_ideal_memory(size):
    """Paper: 'base already achieves ideal steady-state utilization for any
    bus-aligned transfer size' with 1-cycle memory."""
    r = simulate(SimConfig.base(), 1, size)
    assert r.utilization == pytest.approx(ideal_utilization(size), rel=0.02)


def test_headline_2_5x_at_64B_ideal_memory():
    ours = simulate(SimConfig.base(), 1, 64).utilization
    lc = simulate(SimConfig.logicore_ip(), 1, 64).utilization
    assert ours / lc == pytest.approx(2.5, rel=0.15)  # measured 2.58


# ---------------------------------------------------------------------------
# DDR3 memory (Fig 4b)
# ---------------------------------------------------------------------------

def test_ddr3_base_ideal_from_256B_not_before():
    r256 = simulate(SimConfig.base(), 13, 256)
    r128 = simulate(SimConfig.base(), 13, 128)
    assert r256.utilization == pytest.approx(ideal_utilization(256), rel=0.02)
    assert r128.utilization < 0.9 * ideal_utilization(128)


def test_ddr3_speculation_ideal_at_64B():
    r = simulate(SimConfig.speculation(), 13, 64)
    assert r.utilization == pytest.approx(ideal_utilization(64), rel=0.02)


def test_ddr3_headline_ratios():
    lc = simulate(SimConfig.logicore_ip(), 13, 64).utilization
    base = simulate(SimConfig.base(), 13, 64).utilization
    spec = simulate(SimConfig.speculation(), 13, 64).utilization
    assert base / lc == pytest.approx(1.7, rel=0.15)   # measured 1.83
    assert spec / lc == pytest.approx(3.9, rel=0.25)   # measured 4.58


# ---------------------------------------------------------------------------
# Ultra-deep memory (Fig 4c)
# ---------------------------------------------------------------------------

def test_deep_scaled_ideal_from_128B():
    for size in (128, 256, 1024):
        r = simulate(SimConfig.scaled(), 100, size)
        assert r.utilization == pytest.approx(ideal_utilization(size), rel=0.02)


def test_deep_scaled_extends_lead_at_64B():
    """Abstract: 'extend our lead in bus utilization to 3.6x' in deep memory.

    Our LogiCORE behavioural model is conservative at L=100 (fully
    serialized), so the measured lead is a comfortable superset of 3.6x.
    """
    ours = simulate(SimConfig.scaled(), 100, 64).utilization
    lc = simulate(SimConfig.logicore_ip(), 100, 64).utilization
    assert ours / lc >= 3.6


def test_deep_base_collapses_without_prefetch():
    # Serialization 2L+4 dominates: base is far from ideal in deep memory.
    r = simulate(SimConfig.base(), 100, 64)
    assert r.utilization < 0.1


# ---------------------------------------------------------------------------
# Hit-rate sweep (Fig 5)
# ---------------------------------------------------------------------------

def test_hit_rate_sweep_monotone_and_in_band():
    lc = simulate(SimConfig.logicore_ip(), 13, 64).utilization
    utils = [simulate(SimConfig.speculation(), 13, 64, hit_rate=h).utilization
             for h in (0.0, 0.25, 0.5, 0.75, 1.0)]
    assert all(b >= a - 1e-9 for a, b in zip(utils, utils[1:]))
    # Paper: 75%..0% hit rates still yield 1.65x..3.1x at 64 B.
    assert utils[0] / lc >= 1.65
    assert utils[3] / lc >= 2.4


def test_misprediction_costs_no_latency_only_contention():
    """§II-C: mispredicts add no serialization latency vs prefetch-off."""
    base = simulate(SimConfig.base(), 13, 64)
    miss_all = simulate(SimConfig.speculation(), 13, 64, hit_rate=0.0)
    # Same serialization -> utilization within contention noise of base.
    assert miss_all.utilization >= 0.9 * base.utilization
    assert miss_all.wasted_beats > 0


# ---------------------------------------------------------------------------
# Table IV latencies
# ---------------------------------------------------------------------------

def test_table_iv_ours_exact():
    t = table_iv()
    assert t["ours"]["i_rf"] == 3
    assert t["ours"]["r_w"] == 1
    for latency, want in t["paper"]["ours"]["rf_rb"].items():
        assert t["ours"]["rf_rb"][latency] == pytest.approx(want, abs=0.5)


def test_table_iv_logicore_within_2_cycles():
    t = table_iv()
    assert t["logicore"]["i_rf"] == 10
    for latency, want in t["paper"]["logicore"]["rf_rb"].items():
        assert t["logicore"]["rf_rb"][latency] == pytest.approx(want, abs=2.5)


def test_latency_improvement_1_66x():
    """Abstract: 1.66x less latency launching transfers (i-rf + rf-rb @ DDR3)."""
    t = table_iv()
    ours = t["ours"]["i_rf"] + t["ours"]["rf_rb"][13]
    lc = t["logicore"]["i_rf"] + t["logicore"]["rf_rb"][13]
    assert lc / ours == pytest.approx(1.66, rel=0.05)


# ---------------------------------------------------------------------------
# Analytical model cross-check
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("latency", [1, 13, 100])
@pytest.mark.parametrize("size", [64, 256, 1024])
def test_analytical_model_tracks_simulator(latency, size):
    sim = simulate(SimConfig.base(), latency, size).utilization
    ana = analytical_utilization(size, latency).utilization
    assert ana == pytest.approx(sim, rel=0.25)


# ---------------------------------------------------------------------------
# Area / FPGA models (Tables II-III)
# ---------------------------------------------------------------------------

def test_area_model_matches_published_configs():
    # base: d=4, s=0 -> 41.4 vs 41.2 published; speculation: d=4, s=4 -> 49.2
    assert A.area_kge(4, 0) == pytest.approx(41.2, rel=0.02)
    assert A.area_kge(4, 4) == pytest.approx(49.5, rel=0.02)
    assert A.area_kge(24, 24) == pytest.approx(188.4, rel=0.04)


def test_fpga_headline_savings():
    s = A.headline_fpga_savings()
    assert s["lut_savings"] == pytest.approx(0.11, abs=0.01)
    assert s["ff_savings"] == pytest.approx(0.23, abs=0.01)


def test_area_report_includes_fmax():
    r = A.report("speculation", 4, 4)
    assert r.fmax_ghz == 1.44
    assert r.rel_err < 0.02
