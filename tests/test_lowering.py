"""Chain-lowering JIT (DESIGN.md §7): signatures, plan memo, artifact LRU,
and cached-vs-uncached drain bit-identity against the host walker oracle.

The fast split has no hypothesis dependency; the property suite at the
bottom guards its import and is marked slow (CI's slow job).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, list_archs
from repro.core.chain import from_segments, walk_chain_host
from repro.core.descriptor import CONFIG_IRQ_ENABLE, DescriptorArray
from repro.core.signature import (
    canonicalize,
    pow2_bucket,
    signature_of,
    walk_order,
)
from repro.core.simulator import SimConfig, simulate
from repro.perf.workloads import Scale, generate
from repro.runtime import (
    ChannelConfig,
    DMARuntime,
    PerfProbe,
    SubmitRequest,
    coalesce,
)
from repro.runtime.lowering import (
    TranslationCache,
    aggregate_stats,
    disabled_stats,
)
from repro.runtime.scheduler import _is_sequential_chain

TINY = Scale("tiny", n_bursts=1, burst_len=24, pool_elems=1 << 12,
             max_len=128, ring_capacity=64, sim_transfers=60)


def _shift(d: DescriptorArray, src_by: int, dst_by: int) -> DescriptorArray:
    return DescriptorArray.create(
        np.asarray(d.src, np.int64) + src_by,
        np.asarray(d.dst, np.int64) + dst_by,
        np.asarray(d.length, np.int64),
        nxt=np.asarray(d.nxt, np.int64),
        config=np.asarray(d.config, np.int64))


def _chains_equal(a: DescriptorArray, b: DescriptorArray) -> None:
    for f in ("src", "dst", "length", "nxt", "config", "done"):
        fa, fb = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        np.testing.assert_array_equal(fa, fb, err_msg=f)
        assert fa.dtype == fb.dtype, f


# ---------------------------------------------------------------------------
# Canonicalization: walk order, base invariance, layout keys
# ---------------------------------------------------------------------------

def test_walk_order_matches_host_walk_on_permuted_storage():
    rng = np.random.default_rng(0)
    for n in (1, 2, 7, 33):
        perm = rng.permutation(n)
        nxt = np.full(n, -1, np.int64)
        nxt[perm[:-1]] = perm[1:]
        d = DescriptorArray.create(np.arange(n), np.arange(n), np.ones(n),
                                   nxt=nxt)
        order = walk_order(np.asarray(d.nxt, np.int64), int(perm[0]))
        assert order is not None
        np.testing.assert_array_equal(
            order, walk_chain_host(d, int(perm[0])))


def test_walk_order_sequential_fast_path():
    nxt = np.array([1, 2, 3, -1], np.int64)
    np.testing.assert_array_equal(walk_order(nxt, 0), [0, 1, 2, 3])


def test_walk_order_declines_on_malformed_chains():
    # Cycle: the legacy walker raises on these, so the lowering layer must
    # decline and leave the error to the canonical path.
    assert walk_order(np.array([1, 0], np.int64), 0) is None
    # Link past the table.
    assert walk_order(np.array([5, -1], np.int64), 0) is None
    d = DescriptorArray.create([0, 1], [0, 1], [1, 1], nxt=[1, 0])
    assert canonicalize(d, 0) is None


def test_digest_and_signature_invariant_under_base_shift():
    d = from_segments([0, 8, 100], [0, 8, 300], [8, 8, 16])
    s = _shift(d, 512, 1024)
    ca, cb = canonicalize(d, 0), canonicalize(s, 0)
    assert ca.digest == cb.digest
    assert signature_of(ca, tier="serial") == signature_of(cb, tier="serial")
    # ...but the bases themselves are preserved for rematerialization.
    assert cb.src_base - ca.src_base == 512
    assert cb.dst_base - ca.dst_base == 1024


def test_distinct_layouts_get_distinct_signatures_and_digests():
    seq = from_segments([0, 8, 16], [0, 8, 16], [8, 8, 8])
    strided = from_segments([0, 32, 64], [0, 8, 16], [8, 8, 8])
    gather = from_segments([96, 0, 48], [0, 8, 16], [8, 8, 8])
    sigs = {signature_of(canonicalize(d, 0), tier="serial").layout
            for d in (seq, strided, gather)}
    assert sigs == {"sequential", "strided", "gather"}
    digests = {canonicalize(d, 0).digest for d in (seq, strided, gather)}
    assert len(digests) == 3


def test_walk_order_is_part_of_the_digest():
    # Same relative segments, different storage order: the §II-C input hit
    # rate is computed over storage-order fetch addresses, so these chains
    # must NOT share a plan.
    a = from_segments([0, 8], [0, 8], [8, 8])
    b = DescriptorArray.create([8, 0], [8, 0], [8, 8], nxt=[-1, 0])
    assert canonicalize(a, 0).digest != canonicalize(b, 1).digest


def test_signature_buckets_are_pow2():
    assert [pow2_bucket(n) for n in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]
    d = from_segments(np.arange(5) * 8, np.arange(5) * 8, np.full(5, 8))
    sig = signature_of(canonicalize(d, 0), tier="serial")
    assert sig.n_class == 8 and sig.unit == 8


# ---------------------------------------------------------------------------
# Plan memo: bit-identical to the legacy coalescer
# ---------------------------------------------------------------------------

def _assert_plan_matches_coalesce(cache, d, max_len, spec_depth=0):
    res = cache.plan(d, max_len=max_len, spec_depth=spec_depth)
    assert res is not None
    want_d, want_stats = coalesce(d, max_len=max_len, spec_depth=spec_depth)
    _chains_equal(res.planned, want_d)
    assert res.stats == want_stats


def test_plan_is_bit_identical_to_coalesce_on_handcrafted_chains():
    cache = TranslationCache()
    cases = [
        from_segments([0, 8, 16], [0, 8, 16], [8, 8, 8]),     # merges to 1
        from_segments([0], [0], [500]),                        # splits
        from_segments([0, 8, 100], [0, 8, 300], [8, 8, 16]),  # merge + tail
        from_segments([5, 90, 40], [7, 300, 200], [3, 11, 60]),
        # IRQ barrier mid-run: must not merge across it.
        DescriptorArray.create([0, 8, 16], [0, 8, 16], [8, 8, 8],
                               config=[0, CONFIG_IRQ_ENABLE, 0]),
    ]
    for d in cases:
        for max_len in (64, 128):
            _assert_plan_matches_coalesce(cache, d, max_len)
    _assert_plan_matches_coalesce(cache, cases[0], 64, spec_depth=4)


def test_plan_matches_coalesce_on_permuted_storage_chain():
    cache = TranslationCache()
    perm = np.random.default_rng(7).permutation(12)
    nxt = np.full(12, -1, np.int64)
    nxt[perm[:-1]] = perm[1:]
    src = np.arange(12, dtype=np.int64) * 8
    d = DescriptorArray.create(src, src + 512, np.full(12, 8), nxt=nxt)
    res = cache.plan(d, max_len=64, head=int(perm[0]))
    want_d, want_stats = coalesce(d, max_len=64, head=int(perm[0]))
    _chains_equal(res.planned, want_d)
    assert res.stats == want_stats


def test_plan_matches_coalesce_across_workloads():
    cache = TranslationCache()
    for arch in list_archs()[:3]:
        cfg = get_config(arch)
        for name in ("paged_kv", "moe_dispatch", "chain_mix",
                     "defrag_churn"):
            for d in generate(name, cfg, TINY, seed=1).chains:
                _assert_plan_matches_coalesce(cache, d, TINY.max_len)


def test_plan_memo_hit_on_base_shift_rematerializes_new_bases():
    cache = TranslationCache()
    d = from_segments([0, 8, 100], [0, 8, 300], [8, 8, 16])
    cache.plan(d, max_len=64)
    assert (cache.plan_misses, cache.plan_hits) == (1, 0)
    s = _shift(d, 256, 512)
    res = cache.plan(s, max_len=64)
    assert (cache.plan_misses, cache.plan_hits) == (1, 1)
    want_d, want_stats = coalesce(s, max_len=64)
    _chains_equal(res.planned, want_d)
    assert res.stats == want_stats


def test_plan_memo_respects_max_len_in_the_key():
    cache = TranslationCache()
    d = from_segments([0], [0], [500])
    a = cache.plan(d, max_len=128)
    b = cache.plan(d, max_len=64)
    assert a.planned.num_descriptors != b.planned.num_descriptors
    assert cache.plan_misses == 2


def test_plan_declines_degenerate_inputs():
    cache = TranslationCache()
    d = from_segments([0], [0], [8])
    assert cache.plan(d, max_len=0) is None
    assert cache.plan(d, max_len=8, spec_depth=-1) is None


# ---------------------------------------------------------------------------
# Artifact LRU
# ---------------------------------------------------------------------------

def _sig_of(n):
    d = from_segments(np.arange(n) * 8, np.arange(n) * 8 + 512,
                      np.full(n, 8))
    return signature_of(canonicalize(d, 0), tier="serial")


def test_artifact_identity_one_compile_many_dispatches():
    cache = TranslationCache()
    sig = _sig_of(4)
    assert cache.lower(sig) is cache.lower(sig)
    assert (cache.misses, cache.hits) == (1, 1)


def test_lru_eviction_counts_and_evicts_oldest():
    cache = TranslationCache(max_entries=2)
    s1, s2, s3 = _sig_of(1), _sig_of(2), _sig_of(4)
    a1 = cache.lower(s1)
    cache.lower(s2)
    cache.lower(s3)                       # evicts s1 (oldest)
    st = cache.stats()
    assert (st["misses"], st["evictions"], st["size"]) == (3, 1, 2)
    assert cache.lower(s3) is not None and cache.hits == 1
    assert cache.lower(s1) is not a1      # recompiled after eviction
    assert cache.misses == 4


def test_probe_receives_translation_events():
    probe = PerfProbe()
    cache = TranslationCache(max_entries=1)
    cache.attach_probe(probe)
    cache.lower(_sig_of(1))
    cache.lower(_sig_of(2))               # miss + evict
    cache.lower(_sig_of(2))               # hit
    t = probe.translation
    assert (t.hits, t.misses, t.evictions) == (1, 2, 1)
    d = from_segments([0, 8], [16, 24], [8, 8])
    cache.plan(d, max_len=64)
    cache.plan(d, max_len=64)
    assert (probe.translation.plan_misses, probe.translation.plan_hits) \
        == (1, 1)


def test_stats_block_shape_and_aggregation():
    cache = TranslationCache()
    cache.lower(_sig_of(2))
    a = cache.stats()
    assert a["enabled"] and a["lookups"] == 1 and a["hit_rate"] == 0.0
    cache.lower(_sig_of(2))
    a = cache.stats()
    assert a["hit_rate"] == 0.5
    merged = aggregate_stats([a, a, disabled_stats()])
    assert merged["enabled"] is True
    assert merged["lookups"] == 4 and merged["hits"] == 2
    assert merged["hit_rate"] == 0.5
    empty = aggregate_stats([disabled_stats()])
    assert empty["enabled"] is False and empty["hit_rate"] == 0.0


# ---------------------------------------------------------------------------
# Lowered execution: identity with the oracle, decline guards
# ---------------------------------------------------------------------------

def _pools(rng, n=TINY.pool_elems):
    src = jnp.asarray(rng.standard_normal(n), jnp.float32)
    dst = jnp.zeros(n, jnp.float32)
    return src, dst


def test_lowered_vector_chain_matches_oracle():
    rng = np.random.default_rng(2)
    src, dst = _pools(rng, 1 << 10)
    d = from_segments([5, 90, 400], [7, 300, 200], [3, 11, 60])
    cache = TranslationCache()
    res = cache.plan(d, max_len=64)
    out = res.lowered(res.planned, src, dst, max_len=64)
    assert out is not None
    want, _ = execute_chain_host_np(res.planned, src, dst)
    np.testing.assert_array_equal(np.asarray(out), want)


def execute_chain_host_np(d, src, dst):
    from repro.core.engine import execute_chain_host
    return execute_chain_host(d, np.asarray(src), np.asarray(dst))


def test_lowered_overlap_chain_preserves_chain_order():
    rng = np.random.default_rng(3)
    src, dst = _pools(rng, 256)
    # dst windows overlap: descriptor 2's writes must land over 1's.
    d = from_segments([0, 64, 128], [10, 14, 18], [8, 8, 8])
    cache = TranslationCache()
    res = cache.plan(d, max_len=64)
    assert res.signature.overlap
    out = res.lowered(res.planned, src, dst, max_len=64)
    assert out is not None
    want, _ = execute_chain_host_np(res.planned, src, dst)
    np.testing.assert_array_equal(np.asarray(out), want)


def test_lowered_declines_near_pool_tail_clamp_hazard():
    # execute_serial's fixed max_len window clamps near the pool tail; the
    # artifact must decline there so the legacy path keeps its semantics.
    rng = np.random.default_rng(4)
    src, dst = _pools(rng, 128)
    d = from_segments([120], [0], [4])     # 120 + max_len(64) > 128
    cache = TranslationCache()
    res = cache.plan(d, max_len=64)
    assert res.lowered(res.planned, src, dst, max_len=64) is None


def test_lowered_declines_on_dtype_mismatch_and_oversize():
    rng = np.random.default_rng(5)
    src, dst = _pools(rng, 256)
    cache = TranslationCache()
    d = from_segments([0, 16], [32, 64], [8, 8])
    res = cache.plan(d, max_len=16)
    assert res.lowered(res.planned, src.astype(jnp.bfloat16), dst,
                       max_len=16) is None
    big = from_segments(np.arange(8) * 16, np.arange(8) * 16 + 1024,
                        np.full(8, 8))
    bigger, _ = coalesce(big, max_len=16)
    assert res.lowered(bigger, src, dst, max_len=16) is None  # n > bucket


def test_bucketed_pallas_kernel_matches_plain_row_copy():
    from repro.kernels.descriptor_copy import (
        descriptor_copy,
        descriptor_copy_bucketed,
    )
    rng = np.random.default_rng(6)
    src = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    dst = jnp.zeros((16, 8), jnp.float32)
    sidx = jnp.asarray([3, 1, -1], jnp.int32)
    didx = jnp.asarray([0, 5, -1], jnp.int32)
    plain = descriptor_copy(sidx, didx, src, dst, interpret=True)
    bucketed = descriptor_copy_bucketed(sidx, didx, src, dst, n_bucket=8,
                                        interpret=True)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(bucketed))
    with pytest.raises(ValueError, match="bucket"):
        descriptor_copy_bucketed(sidx, didx, src, dst, n_bucket=2,
                                 interpret=True)


# ---------------------------------------------------------------------------
# Runtime integration: cached == uncached == oracle, across the registry
# ---------------------------------------------------------------------------

def _drain_workload(arch, workload, *, translation, rounds=2, seed=0):
    # Pools carry a max_len tail pad (as the sharded runtime's pools do):
    # without it the legacy serial engine's fixed-window dynamic_slice
    # clamps near the pool tail and the raw-chain oracle comparison would
    # test the clamp artifact, not the drain.
    cfg = get_config(arch)
    wl = generate(workload, cfg, TINY, seed=seed)
    n_padded = wl.pool_elems + TINY.max_len
    rng = np.random.default_rng([seed, 99])
    src0 = rng.standard_normal(n_padded).astype(np.float32)
    rt = DMARuntime(
        [ChannelConfig(name="ch0", tier="serial",
                       ring_capacity=TINY.ring_capacity,
                       max_len=TINY.max_len)],
        translation=translation)
    rt.register_pool("src", jnp.asarray(src0))
    rt.register_pool("dst", jnp.zeros(n_padded, jnp.float32))
    for _ in range(rounds):
        for d in wl.chains:
            rt.submit(SubmitRequest(chain=d, src_pool="src",
                                    dst_pool="dst", channel="ch0"))
        rt.drain_until_idle()
    return np.asarray(rt.pools["dst"]), rt, wl, src0


@pytest.mark.parametrize("arch", list_archs())
def test_cached_drains_bit_identical_across_registry(arch):
    cached, rt, wl, src0 = _drain_workload(arch, "paged_kv",
                                           translation=True)
    uncached, _, _, _ = _drain_workload(arch, "paged_kv", translation=False)
    np.testing.assert_array_equal(cached, uncached)
    # ...and both equal the host walker oracle over the raw chains.
    want = np.zeros_like(src0)
    for d in wl.chains:
        want, _ = execute_chain_host_np(d, src0, want)
    np.testing.assert_array_equal(cached, want)
    st = rt.translation_stats()
    assert st["translation.enabled"] and st["translation.lookups"] > 0


@pytest.mark.parametrize("workload",
                         ["moe_dispatch", "chain_mix", "defrag_churn"])
def test_cached_drains_bit_identical_other_workloads(workload):
    for arch in ("qwen2.5-3b", "dbrx-132b"):
        cached, _, wl, src0 = _drain_workload(arch, workload,
                                              translation=True)
        uncached, _, _, _ = _drain_workload(arch, workload,
                                            translation=False)
        np.testing.assert_array_equal(cached, uncached, err_msg=arch)
        want = np.zeros_like(src0)
        for d in wl.chains:
            want, _ = execute_chain_host_np(d, src0, want)
        np.testing.assert_array_equal(cached, want, err_msg=arch)


def test_steady_state_replays_hit_both_cache_layers():
    _, rt, _, _ = _drain_workload("qwen2.5-3b", "paged_kv",
                                  translation=True, rounds=4)
    st = rt.translation_stats()
    # Rounds 2..4 resubmit identical chains: plan memo and artifact cache
    # both run hot, so hits dominate lookups by at least the replay share.
    assert st["translation.hit_rate"] >= 0.5
    assert st["translation.plan_hits"] >= 3 * st["translation.plan_misses"]


def test_runtime_stats_and_disabled_escape_hatch():
    _, rt, _, _ = _drain_workload("qwen2.5-3b", "paged_kv",
                                  translation=True, rounds=1)
    block = rt.stats()["translation_cache"]
    assert block["translation.enabled"] and block["translation.capacity"] > 0
    _, rt_off, _, _ = _drain_workload("qwen2.5-3b", "paged_kv",
                                      translation=False, rounds=1)
    off = rt_off.stats()["translation_cache"]
    # The public stats block is namespaced (DESIGN.md §9); the raw
    # bare-key block is the canonical disabled sentinel.
    assert off["translation.enabled"] is False
    assert rt_off._translation_stats_raw() == disabled_stats()
    assert rt_off.translation is None


def test_is_sequential_memo_matches_predicate():
    cache = TranslationCache()
    seq = from_segments([0, 8], [0, 8], [8, 8])
    perm = DescriptorArray.create([0, 1], [0, 1], [1, 1], nxt=[-1, 0])
    for d in (seq, perm, seq):            # third call exercises the memo
        assert cache.is_sequential(d) == _is_sequential_chain(d)


# ---------------------------------------------------------------------------
# Cycle model: the launch-speedup claim behind the gated cell
# ---------------------------------------------------------------------------

def test_translated_frontend_speedup_at_64_byte_class():
    # The gated claim: >=1.66x launch speedup vs the §II-A serialized
    # baseline at 64-byte-class units, across the sweep's latencies.
    for tb in (32, 64):
        for lat in (13, 100):
            base = simulate(SimConfig.base(), lat, tb, num_transfers=200)
            tr = simulate(SimConfig.translated_frontend(), lat, tb,
                          num_transfers=200)
            ratio = base.cycles / tr.cycles
            assert ratio >= 1.66, (tb, lat, ratio)


def test_translated_frontend_never_slower_and_saturates_large_units():
    for tb in (64, 256, 1024):
        base = simulate(SimConfig.base(), 13, tb, num_transfers=200)
        tr = simulate(SimConfig.translated_frontend(), 13, tb,
                      num_transfers=200)
        assert tr.cycles <= base.cycles
    # Bus-bound at large units: the frontend is no longer the bottleneck.
    big_b = simulate(SimConfig.base(), 13, 4096, num_transfers=100)
    big_t = simulate(SimConfig.translated_frontend(), 13, 4096,
                     num_transfers=100)
    assert big_b.cycles / big_t.cycles < 1.2


# ---------------------------------------------------------------------------
# Property suite (hypothesis; slow job)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                   # minimal installs
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    _SHARED_CACHE = TranslationCache()

    @pytest.mark.slow
    @settings(max_examples=60, deadline=None)
    @given(n=st.integers(1, 40), unit=st.integers(1, 16),
           gap=st.integers(0, 8),
           src_shift=st.integers(0, 1 << 20),
           dst_shift=st.integers(0, 1 << 20))
    def test_equal_signatures_reuse_one_artifact(n, unit, gap, src_shift,
                                                 dst_shift):
        stride = unit + gap
        src = np.arange(n, dtype=np.int64) * stride
        dst = np.arange(n, dtype=np.int64) * stride + (n * stride)
        ln = np.full(n, unit, np.int64)
        a = from_segments(src, dst, ln)
        b = from_segments(src + src_shift, dst + dst_shift, ln)
        ca, cb = canonicalize(a, 0), canonicalize(b, 0)
        assert ca.digest == cb.digest
        sa = signature_of(ca, tier="serial")
        sb = signature_of(cb, tier="serial")
        assert sa == sb
        # One signature -> one compiled artifact, whatever the bases.
        assert _SHARED_CACHE.lower(sa) is _SHARED_CACHE.lower(sb)

    @pytest.mark.slow
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 48),
           max_len=st.sampled_from([16, 64, 128]))
    def test_plan_property_bit_identical_to_coalesce(seed, n, max_len):
        rng = np.random.default_rng(seed)
        ln = rng.integers(1, 32, n)
        src = rng.integers(0, 1 << 16, n)
        dst = rng.integers(0, 1 << 16, n)
        cfg = np.where(rng.random(n) < 0.2, CONFIG_IRQ_ENABLE, 0)
        d = DescriptorArray.create(src, dst, ln, config=cfg)
        cache = TranslationCache()
        res = cache.plan(d, max_len=max_len)
        want_d, want_stats = coalesce(d, max_len=max_len)
        _chains_equal(res.planned, want_d)
        assert res.stats == want_stats
