"""Perf subsystem: workload generators, sweep determinism, probe counters.

No hypothesis dependency — this module must collect on minimal installs.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, list_archs
from repro.core.chain import from_segments
from repro.perf.workloads import (
    QUICK,
    WORKLOAD_NAMES,
    Scale,
    arch_params,
    generate,
    zipf_page_traffic,
)
from repro.perf.sweep import default_spec, run_sweep
from repro.runtime import ChannelConfig, DMARuntime, PerfProbe, SubmitRequest

TINY = Scale("tiny", n_bursts=1, burst_len=24, pool_elems=1 << 12,
             max_len=128, ring_capacity=64, sim_transfers=60)


# ---------------------------------------------------------------------------
# Workload generators
# ---------------------------------------------------------------------------

def test_generators_cover_every_arch_and_stay_in_bounds():
    for arch in list_archs():
        cfg = get_config(arch)
        for name in WORKLOAD_NAMES:
            wl = generate(name, cfg, TINY, seed=0)
            assert wl.chains, (arch, name)
            assert wl.transfer_bytes % 8 == 0 and wl.transfer_bytes >= 8
            for d in wl.chains:
                src = np.asarray(d.src, np.int64)
                dst = np.asarray(d.dst, np.int64)
                ln = np.asarray(d.length, np.int64)
                assert (ln > 0).all(), (arch, name)
                assert (src >= 0).all() and (dst >= 0).all()
                assert (src + ln <= TINY.pool_elems).all(), (arch, name)
                assert (dst + ln <= TINY.pool_elems).all(), (arch, name)


def test_generators_deterministic_in_seed():
    cfg = get_config(list_archs()[0])
    for name in WORKLOAD_NAMES:
        a = generate(name, cfg, TINY, seed=3)
        b = generate(name, cfg, TINY, seed=3)
        c = generate(name, cfg, TINY, seed=4)
        for da, db in zip(a.chains, b.chains):
            for f in ("src", "dst", "length", "nxt"):
                assert np.array_equal(np.asarray(getattr(da, f)),
                                      np.asarray(getattr(db, f)))
        # a different seed must actually change the traffic
        assert any(
            not np.array_equal(np.asarray(da.src), np.asarray(dc.src))
            for da, dc in zip(a.chains, c.chains)), name


def test_zipf_page_traffic_is_skewed_seeded_and_validated():
    rng = np.random.default_rng(0)
    t = zipf_page_traffic(64, 4096, alpha=1.1, rng=rng)
    assert t.shape == (4096,) and t.min() >= 0 and t.max() < 64
    # Zipf skew: the single hottest page dominates the median page.
    counts = np.bincount(t, minlength=64)
    assert counts.max() > 4 * np.median(counts[counts > 0])
    # Same rng state -> same traffic; hot_pages pins rank -> page.
    a = zipf_page_traffic(16, 256, rng=np.random.default_rng(7))
    b = zipf_page_traffic(16, 256, rng=np.random.default_rng(7))
    np.testing.assert_array_equal(a, b)
    ident = zipf_page_traffic(16, 256, rng=np.random.default_rng(7),
                              hot_pages=np.arange(16))
    assert np.argmax(np.bincount(ident, minlength=16)) == 0
    with pytest.raises(ValueError, match="num_pages"):
        zipf_page_traffic(0, 10, rng=rng)
    with pytest.raises(ValueError, match="alpha"):
        zipf_page_traffic(4, 10, alpha=0.0, rng=rng)
    with pytest.raises(ValueError, match="whole page space"):
        zipf_page_traffic(4, 10, rng=rng, hot_pages=np.arange(3))


def test_arch_parameterization_differs_across_archs():
    params = {a: arch_params(get_config(a)) for a in list_archs()}
    assert len({p.page_elems for p in params.values()}) > 1
    assert len({p.experts for p in params.values()}) > 1


def test_moe_storm_defeats_prefetcher_paged_kv_does_not():
    cfg = get_config("dbrx-132b")
    from repro.runtime import coalesce
    kv = generate("paged_kv", cfg, TINY, seed=0)
    moe = generate("moe_dispatch", cfg, TINY, seed=0)
    _, kv_stats = coalesce(kv.chains[0], max_len=TINY.max_len)
    _, moe_stats = coalesce(moe.chains[0], max_len=TINY.max_len)
    assert kv_stats.input_hit_rate > 0.9          # sequential table layout
    assert moe_stats.input_hit_rate < 0.5         # shuffled storm
    assert kv_stats.merge_ratio > moe_stats.merge_ratio


# ---------------------------------------------------------------------------
# Sweep
# ---------------------------------------------------------------------------

def _mini_spec(seed=0):
    return default_spec(
        "quick", seed, archs=[list_archs()[0]],
        workloads=["paged_kv", "moe_dispatch"],
        channel_counts=[2], mem_latencies=[13], repeats=2,
        include_serve=False, include_sharded=False,
        include_transforms=False, iotlb=False)


def test_sweep_document_is_bit_for_bit_deterministic():
    d1 = run_sweep(_mini_spec())
    d2 = run_sweep(_mini_spec())
    assert json.dumps(d1, sort_keys=True) == json.dumps(d2, sort_keys=True)


def test_sweep_document_schema_and_counters():
    doc = run_sweep(_mini_spec())
    assert doc["schema_version"] == 8
    assert doc["translation_cache_enabled"] is True
    assert doc["cells"]
    for key, cell in doc["cells"].items():
        assert cell["kind"] == "dma"
        assert set(cell["metrics"]) == {
            "bus_utilization", "launch_cycles_per_transfer",
            "coalesce_merge_ratio", "speculation_hit_rate",
            "spec_bus_utilization_fixed4", "spec_bus_utilization_adaptive",
            "translation_cache_hit_rate", "translation_launch_speedup"}
        assert 0.0 < cell["metrics"]["bus_utilization"] <= 1.0
        assert cell["metrics"]["coalesce_merge_ratio"] >= 1.0
        assert 0.0 < cell["metrics"]["spec_bus_utilization_fixed4"] <= 1.0
        assert 0.0 < cell["metrics"]["spec_bus_utilization_adaptive"] <= 1.0
        assert 0.0 <= cell["metrics"]["translation_cache_hit_rate"] <= 1.0
        assert cell["metrics"]["translation_launch_speedup"] >= 1.0
        # the speculation pass stores its depth trajectory for forensics
        assert set(cell["speculation"]) == {"fixed4", "adaptive"}
        assert cell["speculation"]["fixed4"]["final_depth"] == 4
        # counters come from the runtime's own probe, wall-clock stripped,
        # plus the translation-cache event counts (DESIGN.md §7)
        assert cell["counters"], key
        assert cell["counters"]["translation_cache"]["enabled"] is True
        assert cell["counters"]["translation_cache"]["lookups"] > 0
        for name, ch in cell["counters"].items():
            if name == "translation_cache":
                continue
            assert "drain_seconds" not in ch and "launch_seconds" not in ch
            assert ch["drained_descriptors"] == ch["submitted_descriptors"]


def test_sweep_mmu_cells_present_and_shaped():
    """With the IOTLB on (the default), the sweep gains one mmu cell per
    memory latency, carrying the four schema-v8 gated metrics and the
    demand-walk A/B baseline in its counters (DESIGN.md §11)."""
    spec = default_spec(
        "quick", 0, archs=[list_archs()[0]], workloads=["paged_kv"],
        channel_counts=[2], mem_latencies=[13], repeats=1,
        include_serve=False, include_sharded=False,
        include_transforms=False)
    doc = run_sweep(spec)
    assert doc["iotlb_enabled"] is True
    mmu = {k: c for k, c in doc["cells"].items() if c["kind"] == "mmu"}
    assert set(mmu) == {"mmu/paged_seq/L13"}
    cell = mmu["mmu/paged_seq/L13"]
    m = cell["metrics"]
    assert set(m) == {"tlb_hit_rate", "walk_stall_cycles",
                      "defrag_remap_cycles", "defrag_copy_cycles"}
    assert m["tlb_hit_rate"] >= 0.9                 # the in-cell floor
    assert m["defrag_remap_cycles"] < m["defrag_copy_cycles"]
    assert cell["counters"]["demand_walk_baseline"]["tlb_hit_rate"] \
        < m["tlb_hit_rate"]
    # The --no-iotlb escape hatch drops them and records the flag.
    off = run_sweep(_mini_spec())
    assert off["iotlb_enabled"] is False
    assert all(c["kind"] != "mmu" for c in off["cells"].values())


def test_sweep_counters_show_real_channel_activity():
    doc = run_sweep(_mini_spec())
    cell = next(iter(doc["cells"].values()))
    total = sum(c["submits"] for name, c in cell["counters"].items()
                if name != "translation_cache")
    assert total > 0
    assert len(cell["counters"]) >= 3    # >=2 channels + translation_cache


# ---------------------------------------------------------------------------
# Adaptive-vs-fixed speculation cells (the §II-C policy claim)
# ---------------------------------------------------------------------------

def test_adaptive_matches_fixed_on_sequential_beats_it_on_storms():
    """Fresh mini-sweep: sequential streams >= fixed-depth-4, MoE storms
    strictly higher (the adaptive policy's backoff converts wasted
    speculative beats back into payload bandwidth)."""
    spec = default_spec(
        "quick", 0, archs=[list_archs()[0]],
        workloads=["paged_kv", "moe_dispatch", "defrag_churn"],
        channel_counts=[4], mem_latencies=[13, 100], repeats=1,
        include_serve=False, include_sharded=False,
        include_transforms=False, iotlb=False)
    doc = run_sweep(spec)
    assert doc["cells"]
    for key, cell in doc["cells"].items():
        m = cell["metrics"]
        fixed = m["spec_bus_utilization_fixed4"]
        adaptive = m["spec_bus_utilization_adaptive"]
        if cell["workload"] in ("paged_kv", "defrag_churn"):
            assert adaptive >= fixed - 1e-12, key
        elif cell["workload"] == "moe_dispatch":
            assert adaptive > fixed, key
            # the trajectory shows the policy actually backed off
            assert cell["speculation"]["adaptive"]["final_depth"] < 4, key


def test_committed_baseline_upholds_adaptive_claim():
    """The committed BENCH_perf.json must gate the adaptive-vs-fixed
    relations on every cell (acceptance criterion of the policy layer)."""
    import pathlib
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_perf.json"
    doc = json.loads(path.read_text())
    assert doc["schema_version"] == 8
    checked = 0
    for key, cell in doc["cells"].items():
        if cell.get("kind") != "dma":
            continue
        m = cell["metrics"]
        fixed = m["spec_bus_utilization_fixed4"]
        adaptive = m["spec_bus_utilization_adaptive"]
        if cell["workload"] in ("paged_kv", "defrag_churn"):
            assert adaptive >= fixed - 1e-12, key
            checked += 1
        elif cell["workload"] == "moe_dispatch":
            assert adaptive > fixed, key
            checked += 1
    assert checked >= 30   # 10 archs x (2 sequential + 1 storm) x >= 1 L


# ---------------------------------------------------------------------------
# Serve-path cell
# ---------------------------------------------------------------------------

def test_serve_cell_is_deterministic_and_schedules_only():
    from repro.perf.serve_cell import DEFAULT_SERVE_SPEC, run_serve_cell
    m1, c1 = run_serve_cell(0)
    m2, c2 = run_serve_cell(0)
    assert (m1, c1) == (m2, c2)
    assert set(m1) == {"admission_stall_rate",
                       "completion_poll_latency_steps",
                       "serve_steps_per_request",
                       "request_latency_steps_p50",
                       "request_latency_steps_p99",
                       "request_latency_steps"}
    # capacity < n_requests must actually exercise admission pressure
    assert m1["admission_stall_rate"] > 0.0
    assert m1["serve_steps_per_request"] > 0.0
    # tail latency (schema v5): histogram snapshot covers every request and
    # the percentile scalars are consistent with it
    hist = m1["request_latency_steps"]
    assert hist["n"] == DEFAULT_SERVE_SPEC.n_requests
    assert 0 < m1["request_latency_steps_p50"] \
        <= m1["request_latency_steps_p99"] <= hist["max"]
    assert c1["serve"]["completions_observed"] == DEFAULT_SERVE_SPEC.n_requests
    assert "step_seconds" not in c1["serve"]   # wall-clock never stored


# ---------------------------------------------------------------------------
# Instrumentation hooks
# ---------------------------------------------------------------------------

def test_probe_counters_match_runtime_stats():
    probe = PerfProbe()
    rt = DMARuntime([ChannelConfig(name="a", tier="serial", max_len=32,
                                   ring_capacity=64)])
    rt.attach_probe(probe)
    rt.register_pool("src", jnp.arange(256, dtype=jnp.float32))
    rt.register_pool("dst", jnp.zeros(256, jnp.float32))
    d = from_segments([0, 32, 64], [0, 32, 64], [16, 16, 16])
    rt.submit(SubmitRequest(chain=d, src_pool="src", dst_pool="dst",
                            channel="a"))
    rt.drain_until_idle()
    c = probe.channels["a"]
    st = rt.stats()
    assert c.submits == 1
    assert c.coalesce_in == 3
    assert c.submitted_descriptors == st["channels"]["a"]["submitted"]
    assert c.drained_descriptors == st["channels"]["a"]["drained"]
    assert c.occupancy_peak == st["channels"]["a"]["occupancy_peak"] > 0
    assert c.drain_seconds > 0.0 and c.launch_seconds > 0.0
    assert c.mean_input_hit_rate == pytest.approx(
        st["mean_input_hit_rate"])


def test_probe_records_ring_full_backpressure():
    probe = PerfProbe()
    rt = DMARuntime([ChannelConfig(name="a", tier="serial", max_len=8,
                                   ring_capacity=4)],
                    backpressure="block")
    rt.attach_probe(probe)
    rt.register_pool("src", jnp.arange(64, dtype=jnp.float32))
    rt.register_pool("dst", jnp.zeros(64, jnp.float32))
    for k in range(3):
        d = from_segments([8 * k] * 3, [8 * k] * 3, [2, 2, 2])
        rt.submit(SubmitRequest(chain=d, src_pool="src", dst_pool="dst",
                                channel="a", run_coalescer=False))
    rt.drain_until_idle()
    assert probe.channels["a"].ring_full_events > 0
    assert probe.channels["a"].occupancy_peak <= 4


def test_probe_detach_stops_counting():
    probe = PerfProbe()
    rt = DMARuntime([ChannelConfig(name="a", tier="serial", max_len=8,
                                   ring_capacity=32)])
    rt.attach_probe(probe)
    rt.attach_probe(None)
    rt.register_pool("src", jnp.arange(64, dtype=jnp.float32))
    rt.register_pool("dst", jnp.zeros(64, jnp.float32))
    rt.submit(SubmitRequest(chain=from_segments([0], [0], [4]),
                            src_pool="src", dst_pool="dst", channel="a"))
    rt.drain_until_idle()
    assert "a" not in probe.channels


def test_channel_stats_gain_occupancy_and_drain_time_without_probe():
    rt = DMARuntime([ChannelConfig(name="a", tier="serial", max_len=8,
                                   ring_capacity=32)])
    rt.register_pool("src", jnp.arange(64, dtype=jnp.float32))
    rt.register_pool("dst", jnp.zeros(64, jnp.float32))
    rt.submit(SubmitRequest(chain=from_segments([0, 8], [0, 8], [4, 4]),
                            src_pool="src", dst_pool="dst", channel="a"))
    rt.drain_until_idle()
    st = rt.stats()["channels"]["a"]
    assert st["occupancy_peak"] > 0
    assert st["drain_seconds"] > 0.0
