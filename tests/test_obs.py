"""Observability subsystem: tracer, histograms, Perfetto export, overhead.

No hypothesis dependency — this module must collect on minimal installs.
The merge-algebra property suite lives in test_obs_properties.py (slow).
"""
import json
import time

import numpy as np
import pytest

from repro.obs import (
    Histogram,
    MetricsRegistry,
    Tracer,
    chrome_trace,
    write_chrome_trace,
    write_metrics_jsonl,
)
from repro.obs.trace import TraceEvent
from repro.runtime.instrumentation import PerfProbe


# ---------------------------------------------------------------------------
# Histogram bucket layout (DESIGN.md §8)
# ---------------------------------------------------------------------------

def test_bucket_layout_linear_below_max_exact_log2_above():
    h = Histogram(max_exact=64, log2_buckets=8)
    # width-1 linear region: bucket i holds exactly integer i
    assert h.bucket_index(0) == 0
    assert h.bucket_index(63) == 63
    assert h.bucket_index(63.9) == 63
    assert h.bucket_lo(17) == 17.0
    # log2 region: [64,128) -> 64, [128,256) -> 65, ...
    assert h.bucket_index(64) == 64
    assert h.bucket_index(127.9) == 64
    assert h.bucket_index(128) == 65
    assert h.bucket_index(255) == 65
    assert h.bucket_index(256) == 66
    assert h.bucket_lo(64) == 64.0
    assert h.bucket_lo(65) == 128.0
    # overflow clamps into the last bucket; negatives clamp to bucket 0
    assert h.bucket_index(1e30) == 64 + 8 - 1
    assert h.bucket_index(-5) == 0
    # every boundary is self-consistent: lo(idx(lo(i))) == lo(i)
    for i in range(len(h.counts)):
        lo = h.bucket_lo(i)
        assert h.bucket_index(lo) == i


def test_small_integer_percentiles_match_numpy_inverted_cdf():
    """Below max_exact the buckets are width-1, so nearest-rank percentiles
    are *exact* — bit-equal to numpy's inverted_cdf method."""
    rng = np.random.default_rng(7)
    samples = rng.integers(0, 64, 500)
    h = Histogram()
    for v in samples:
        h.record(int(v))
    for q in (1, 25, 50, 90, 95, 99, 100):
        assert h.percentile(q) == float(
            np.percentile(samples, q, method="inverted_cdf")), q
    assert h.mean == pytest.approx(float(np.mean(samples)))
    assert h.min == float(samples.min()) and h.max == float(samples.max())


def test_log2_percentile_is_lower_bucket_bound():
    h = Histogram(max_exact=64)
    for v in (100, 100, 100, 100):      # all land in [64, 128)
        h.record(v)
    assert h.percentile(50) == 64.0     # floor estimate, <=2x wide


def test_empty_histogram_reads_zero():
    h = Histogram()
    assert h.percentile(50) == 0.0 and h.percentile(99) == 0.0
    assert h.mean == 0.0
    snap = h.snapshot()
    assert snap["n"] == 0 and snap["min"] == 0.0 and snap["max"] == 0.0


def test_merge_is_order_free_and_layout_checked():
    a, b = Histogram(), Histogram()
    for v in (1, 2, 3, 100):
        a.record(v)
    for v in (3, 5, 2000):
        b.record(v)
    ab = Histogram.from_snapshot(a.snapshot())
    ab.merge(b)
    ba = Histogram.from_snapshot(b.snapshot())
    ba.merge(a)
    assert ab.counts == ba.counts
    assert (ab.n, ab.min, ab.max) == (ba.n, ba.min, ba.max)
    assert ab.total == pytest.approx(ba.total)
    for q in (50, 95, 99):
        assert ab.percentile(q) == ba.percentile(q)
    with pytest.raises(ValueError, match="bucket layouts"):
        a.merge(Histogram(max_exact=32))


def test_snapshot_roundtrip_is_json_safe_and_lossless():
    h = Histogram()
    for v in (4, 9, 9, 77, 3000):
        h.record(v)
    snap = json.loads(json.dumps(h.snapshot()))
    back = Histogram.from_snapshot(snap)
    assert back.counts == h.counts
    assert back.snapshot() == h.snapshot()


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_get_or_create_and_kind_conflicts():
    r = MetricsRegistry()
    r.counter("events").inc(3)
    assert r.counter("events").value == 3          # same instrument back
    r.gauge("depth").set(2)
    r.gauge("depth").set(5)
    assert r.gauge("depth").peak == 5.0
    r.histogram("lat").record(7)
    with pytest.raises(TypeError, match="events"):
        r.gauge("events")
    assert sorted(r.names()) == ["depth", "events", "lat"]


def test_registry_merge_folds_disjoint_and_overlapping_shards():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("reqs").inc(2)
    a.histogram("lat").record(3)
    b.counter("reqs").inc(5)
    b.counter("only_b").inc(1)
    b.histogram("lat").record(9)
    b.gauge("occ").set(4)
    a.merge(b)
    assert a.counter("reqs").value == 7
    assert a.counter("only_b").value == 1
    assert a.histogram("lat").n == 2
    assert a.gauge("occ").peak == 4.0


def test_metrics_jsonl_dump_is_sorted_valid_json(tmp_path):
    r = MetricsRegistry()
    r.counter("z").inc()
    r.histogram("a").record(2)
    p = tmp_path / "m.jsonl"
    n = write_metrics_jsonl(str(p), r,
                            extra={"mid": {"type": "counter", "value": 9}})
    lines = [json.loads(ln) for ln in p.read_text().splitlines()]
    assert n == len(lines) == 3
    assert [ln["name"] for ln in lines] == ["a", "mid", "z"]
    assert lines[0]["type"] == "histogram" and lines[0]["n"] == 1


# ---------------------------------------------------------------------------
# Tracer: ring bound, deterministic sampling, span helpers
# ---------------------------------------------------------------------------

def test_ring_is_bounded_and_dropped_is_exact():
    tr = Tracer(capacity=4)
    for k in range(10):
        tr.instant("e", "t", ts=float(k))
    assert len(tr.events()) == 4
    assert tr.emitted == 10 and tr.dropped == 6
    assert [e.ts for e in tr.events()] == [6.0, 7.0, 8.0, 9.0]
    tr.clear()
    assert tr.events() == [] and tr.dropped == 0


def test_sampling_is_deterministic_seeded_and_rate_shaped():
    a = Tracer(sample_rate=0.25, seed=3)
    b = Tracer(sample_rate=0.25, seed=3)
    c = Tracer(sample_rate=0.25, seed=4)
    keys = [("req", i) for i in range(2000)]
    da = [a.sampled(k) for k in keys]
    assert da == [b.sampled(k) for k in keys]       # same seed, same decisions
    assert da != [c.sampled(k) for k in keys]       # seed actually matters
    frac = sum(da) / len(da)
    assert 0.18 < frac < 0.32
    assert all(Tracer(sample_rate=1.0).sampled(k) for k in keys)
    assert not any(Tracer(sample_rate=0.0).sampled(k) for k in keys)


def test_span_contextmanager_and_flow_ids():
    tr = Tracer()
    with tr.span("work", "ch0", n=3):
        pass
    (ev,) = tr.events()
    assert ev.ph == "X" and ev.name == "work" and ev.track == "ch0"
    assert ev.dur >= 0.0 and ev.args == {"n": 3}
    assert tr.next_flow_id() == 1 and tr.next_flow_id() == 2


# ---------------------------------------------------------------------------
# Chrome/Perfetto export
# ---------------------------------------------------------------------------

def _mixed_events():
    return [
        TraceEvent(name="launch", ph="X", ts=1000.0, track="ch0", dur=5.0),
        TraceEvent(name="launch", ph="X", ts=1010.0, track="ch1", dur=2.0),
        TraceEvent(name="done", ph="i", ts=1012.0, track="ch0"),
        TraceEvent(name="hop", ph="s", ts=1003.0, track="ch0", id=7),
        TraceEvent(name="hop", ph="f", ts=1011.0, track="ch1", id=7),
        TraceEvent(name="payload", ph="X", ts=500.0, track="sim/ch0",
                   dur=8.0, clock="cycle", args={"transfer": 0}),
    ]


def test_chrome_trace_tracks_pids_and_per_clock_normalization(tmp_path):
    doc = write_chrome_trace(str(tmp_path / "t.json"), _mixed_events())
    # the written file is valid JSON and identical to the returned doc
    assert json.loads((tmp_path / "t.json").read_text()) == doc
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert {m["args"]["name"] for m in meta} == {"ch0", "ch1", "sim/ch0"}
    assert len({m["pid"] for m in meta}) == 3       # one pid per track
    # wall events normalize to the earliest wall ts; cycle events to the
    # earliest cycle ts — independent domains
    wall = [e for e in evs if e["ph"] != "M" and e.get("cat") != "flow"
            and e["cat"] == "wall"]
    assert min(e["ts"] for e in wall) == 0.0
    cyc = [e for e in evs if e.get("cat") == "cycle" and e["ph"] != "M"]
    assert min(e["ts"] for e in cyc) == 0.0
    # X spans carry dur; flows carry id + slice binding
    assert all("dur" in e for e in evs if e["ph"] == "X")
    flows = [e for e in evs if e["ph"] in ("s", "t", "f")]
    assert flows and all(e["bp"] == "e" and e["id"] == 7
                         and e["cat"] == "flow" for e in flows)


def test_chrome_trace_instants_are_thread_scoped():
    doc = chrome_trace(_mixed_events())
    inst = [e for e in doc["traceEvents"] if e["ph"] == "i"]
    assert inst and all(e["s"] == "t" for e in inst)


# ---------------------------------------------------------------------------
# PerfProbe: metrics registry rides the same hooks; reset clears everything
# ---------------------------------------------------------------------------

def test_probe_metrics_ride_hooks_and_stay_out_of_gated_snapshot():
    p = PerfProbe()
    p.on_submit("dma0", n_in=4, n_out=2, launch_seconds=1e-4, hit_rate=0.9)
    p.on_drain("dma0", n_descriptors=2, seconds=2e-4)
    p.on_occupancy("dma0", 3)
    p.on_serve_step(2, 1e-3)
    p.on_serve_completion(latency_steps=4)
    p.on_request_latency(11)
    m = p.metrics_snapshot()
    assert m["launch_us"]["n"] == 1
    assert m["drain_us"]["n"] == 1
    assert m["serve_step_us"]["n"] == 1
    assert m["poll_latency_steps"]["p50"] == 4.0
    assert m["request_latency_steps"]["p50"] == 11.0
    assert m["ring_occupancy.dma0"]["peak"] == 3.0
    # the gated snapshot keeps its deterministic schema: no histograms
    snap = p.snapshot()
    assert set(snap) == {"channels", "serve", "translation"}
    assert not any(isinstance(v, dict) and v.get("type") == "histogram"
                   for v in snap["channels"]["dma0"].values())


def test_probe_reset_clears_channels_serve_translation_and_metrics():
    p = PerfProbe()
    p.on_submit("dma0", n_in=1, n_out=1, launch_seconds=1e-5)
    p.on_translation("hit")
    p.on_serve_step(1, 1e-4)
    p.on_request_latency(3)
    p.reset()
    assert p.channels == {}
    assert p.serve.steps == 0 and p.serve.step_seconds == 0.0
    assert p.translation.hits == 0
    assert p.metrics_snapshot() == {}
    # the same object keeps counting after reset (fresh window)
    p.on_submit("dma0", n_in=1, n_out=1, launch_seconds=1e-5)
    assert p.channels["dma0"].submits == 1


# ---------------------------------------------------------------------------
# End-to-end: the seeded recorder produces full lifecycle traces
# ---------------------------------------------------------------------------

def test_recorded_serve_trace_covers_every_lifecycle_phase(tmp_path):
    from repro.obs.record import record_serve_trace
    tracer, probe, pc = record_serve_trace(0, mesh=1)
    evs = tracer.events()
    names = {e.name for e in evs}
    assert {"request", "request.submit", "serve.step", "writeback",
            "delivered", "payload"} <= names
    # every request's async begin has a matching end, correlated by uid
    begins = {e.id for e in evs if e.ph == "b" and e.name == "request"}
    ends = {e.id for e in evs if e.ph == "e" and e.name == "request"}
    assert begins == ends and len(begins) == 6
    # cycle-clock events live on their own tracks, wall events on theirs
    assert {e.track for e in evs if e.clock == "cycle"} == \
        {"sim/ch0", "sim/ch1"}
    assert all(e.clock == "wall" for e in evs
               if not e.track.startswith("sim/"))
    # the whole thing exports as loadable JSON
    doc = write_chrome_trace(str(tmp_path / "serve.trace.json"), evs)
    assert json.loads((tmp_path / "serve.trace.json").read_text()) == doc
    # histograms rode along on the probe
    assert probe.metrics_snapshot()["request_latency_steps"]["n"] == 6
    assert pc["serve.request_latency_steps_p50"] > 0


def test_recorded_trace_is_deterministic_in_seed():
    from repro.obs.record import record_serve_trace

    def shape(seed):
        tr, _, _ = record_serve_trace(seed, mesh=1, simulate=False)
        return [(e.name, e.ph, e.track, e.id) for e in tr.events()]

    assert shape(0) == shape(0)


def test_mesh2_trace_links_migration_hops_with_flow_arrows(tmp_path):
    from repro.obs.record import record_serve_trace
    tracer, _, pc = record_serve_trace(0, mesh=2)
    evs = tracer.events()
    names = {e.name for e in evs}
    assert {"migrate.egress", "migrate.fabric", "migrate.ingress",
            "submit", "drain", "request", "writeback"} <= names
    # hop spans land on per-shard migrate tracks plus the shared fabric
    mig_tracks = {e.track for e in evs if e.name.startswith("migrate.")}
    assert "fabric" in mig_tracks
    assert any(t.startswith("shard") and t.endswith("/migrate")
               for t in mig_tracks)
    # each flow id forms a complete s -> t -> f chain
    chains = {}
    for e in evs:
        if e.ph in ("s", "t", "f"):
            chains.setdefault(e.id, set()).add(e.ph)
    assert chains and all(phs == {"s", "t", "f"}
                          for phs in chains.values())
    # hop spans carry the originating request uid via trace_context
    egress = [e for e in evs if e.name == "migrate.egress"]
    assert egress and all("uid" in e.args and "src_shard" in e.args
                          and "dst_shard" in e.args for e in egress)
    # per-shard serve tracks exist and the mesh-wide latency gated metrics
    # agree with the merged histogram snapshot
    assert {"shard0/serve", "shard1/serve"} <= {e.track for e in evs}
    assert pc["sharded.request_latency_steps"]["n"] == 6
    write_chrome_trace(str(tmp_path / "mesh2.trace.json"), evs)


# ---------------------------------------------------------------------------
# The off-path overhead guard (DESIGN.md §8: off-by-default-cheap)
# ---------------------------------------------------------------------------

def test_disabled_tracer_dispatch_overhead_within_two_percent():
    """An attached-but-sampled-out tracer must cost <= 2% over no tracer
    at all on the warm dispatch path. Min-of-interleaved-rounds with
    retries keeps the bound meaningful on noisy CI machines."""
    import jax.numpy as jnp

    from repro.core.chain import from_segments
    from repro.runtime import SubmitRequest, default_runtime

    pool, n_desc = 1 << 14, 128
    rng = np.random.default_rng(0)
    d = from_segments(rng.integers(0, pool - 64, n_desc),
                      rng.integers(0, pool - 64, n_desc),
                      rng.integers(1, 64, n_desc))

    def make(tracer):
        rt = default_runtime(2, tier="serial", ring_capacity=n_desc + 1,
                             max_len=64)
        rt.register_pool("src", jnp.zeros(pool, jnp.float32))
        rt.register_pool("dst", jnp.zeros(pool, jnp.float32))
        if tracer is not None:
            rt.attach_tracer(tracer)
        return rt

    def dispatch(rt):
        t0 = time.perf_counter()
        rt.submit(SubmitRequest(chain=d, src_pool="src", dst_pool="dst"))
        rt.drain_until_idle()
        return time.perf_counter() - t0

    rt_none = make(None)
    rt_off = make(Tracer(sample_rate=0.0, seed=0))
    dispatch(rt_none), dispatch(rt_off)      # warm translation caches
    ratios = []
    for _ in range(4):                       # retries absorb machine noise
        none = [dispatch(rt_none) for _ in range(7)]
        off = [dispatch(rt_off) for _ in range(7)]
        ratios.append(min(off) / min(none))
        if ratios[-1] <= 1.02:
            return
    pytest.fail(f"disabled-tracer dispatch overhead exceeded 2% in every "
                f"attempt: ratios={[f'{r:.4f}' for r in ratios]}")
