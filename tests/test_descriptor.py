"""Descriptor format: packing, round trips, completion semantics."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
pytestmark = pytest.mark.slow  # property suites: run in CI's slow job
from hypothesis import given, settings, strategies as st

from repro.core import descriptor as D


def test_packed_layout_is_256_bit():
    assert D.PACKED_DTYPE.itemsize == 32
    t = D.pack([64], [0], [D.END_OF_CHAIN], [0x1000], [0x2000])
    raw = D.to_bytes(t)
    assert len(raw) == 32
    # Listing 1 field order: length, config, next, source, destination (LE).
    assert int.from_bytes(raw[0:4], "little") == 64
    assert int.from_bytes(raw[4:8], "little") == 0
    assert int.from_bytes(raw[8:16], "little") == 0xFFFF_FFFF_FFFF_FFFF
    assert int.from_bytes(raw[16:24], "little") == 0x1000
    assert int.from_bytes(raw[24:32], "little") == 0x2000


def test_end_of_chain_is_all_ones():
    # §II-B: "carries all ones (equals to -1) in the next field".
    assert D.END_OF_CHAIN == np.uint64(2**64 - 1)


def test_length_over_4gib_rejected():
    with pytest.raises(ValueError):
        D.pack([2**32], [0], [0], [0], [0])


def test_completion_writeback_first_8_bytes():
    t = D.pack([64, 128], [0, 0], [32, D.END_OF_CHAIN], [0, 0], [0, 0])
    D.mark_done_packed(t, 0)
    raw = D.to_bytes(t)
    assert raw[0:8] == b"\xff" * 8          # §II-D: first 8 B -> all ones
    assert not D.is_done_packed(t)[1]
    assert D.is_done_packed(t)[0]


@settings(max_examples=50, deadline=None)
@given(
    n=st.integers(1, 32),
    elem_bytes=st.sampled_from([1, 2, 4, 8]),
    data=st.data(),
)
def test_soa_packed_roundtrip(n, elem_bytes, data):
    src = data.draw(st.lists(st.integers(0, 2**20), min_size=n, max_size=n))
    dst = data.draw(st.lists(st.integers(0, 2**20), min_size=n, max_size=n))
    ln = data.draw(st.lists(st.integers(0, 2**16), min_size=n, max_size=n))
    d = D.DescriptorArray.create(src, dst, ln)
    packed = D.to_packed(d, elem_bytes=elem_bytes, src_base=0x1000,
                         dst_base=0x8000, table_base=0x100)
    back = D.from_packed(packed, elem_bytes=elem_bytes, src_base=0x1000,
                         dst_base=0x8000, table_base=0x100)
    np.testing.assert_array_equal(np.asarray(back.src), np.asarray(d.src))
    np.testing.assert_array_equal(np.asarray(back.dst), np.asarray(d.dst))
    np.testing.assert_array_equal(np.asarray(back.length), np.asarray(d.length))
    np.testing.assert_array_equal(np.asarray(back.nxt), np.asarray(d.nxt))


def test_roundtrip_preserves_done_flags():
    d = D.DescriptorArray.create([0, 8], [16, 24], [8, 8])
    d = d.mark_done(0)
    packed = D.to_packed(d)
    assert D.is_done_packed(packed)[0] and not D.is_done_packed(packed)[1]
    back = D.from_packed(packed)
    assert int(back.done[0]) == 1 and int(back.done[1]) == 0


def test_default_chain_is_sequential():
    d = D.DescriptorArray.create([0, 1, 2], [0, 1, 2], [1, 1, 1])
    np.testing.assert_array_equal(np.asarray(d.nxt), [1, 2, -1])
