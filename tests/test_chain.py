"""Chain building, flattening (pointer doubling vs serial walk), layout planning."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
pytestmark = pytest.mark.slow  # property suites: run in CI's slow job
from hypothesis import given, settings, strategies as st


from repro.core import chain as C
from repro.core.descriptor import DescriptorArray
from repro.core.prefetch import estimate_hit_rate


def _random_chain_perm(rng, n):
    """A DescriptorArray whose chain visits a random permutation of nodes."""
    perm = rng.permutation(n)
    nxt = np.full(n, -1, np.int64)
    for a, b in zip(perm[:-1], perm[1:]):
        nxt[a] = b
    d = DescriptorArray.create(np.arange(n), np.arange(n), np.ones(n), nxt)
    return d, perm


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 65), seed=st.integers(0, 2**31 - 1))
def test_flatten_matches_serial_walk(n, seed):
    rng = np.random.default_rng(seed)
    d, perm = _random_chain_perm(rng, n)
    head = int(perm[0])
    serial = C.walk_chain_host(d, head)
    flat, count = C.flatten_chain(d.nxt, head)
    assert int(count) == n == len(serial)
    np.testing.assert_array_equal(np.asarray(flat)[:n], serial)


def test_flatten_partial_chain():
    # Chain covering only part of the table: 2 -> 0, node 1 dangling (own EOC).
    d = DescriptorArray.create([0, 1, 2], [0, 1, 2], [1, 1, 1],
                               nxt=[-1, -1, 0])
    flat, count = C.flatten_chain(d.nxt, head=2)
    assert int(count) == 2
    np.testing.assert_array_equal(np.asarray(flat)[:2], [2, 0])


def test_walk_detects_cycle():
    d = DescriptorArray.create([0, 1], [0, 1], [1, 1], nxt=[1, 0])
    with pytest.raises(ValueError, match="cycle"):
        C.walk_chain_host(d, 0)


def test_strided_2d_descriptors():
    d = C.from_strided_2d(src_base=100, dst_base=0, row_len=16,
                          num_rows=4, src_stride=64, dst_stride=16)
    np.testing.assert_array_equal(np.asarray(d.src), [100, 164, 228, 292])
    np.testing.assert_array_equal(np.asarray(d.dst), [0, 16, 32, 48])
    assert np.all(np.asarray(d.length) == 16)


def test_strided_3d_descriptor_count():
    d = C.from_strided_3d(0, 0, 8, shape=(3, 5), src_strides=(1000, 100),
                          dst_strides=(40, 8))
    assert d.num_descriptors == 15
    assert int(d.src[-1]) == 2 * 1000 + 4 * 100


def test_concat_chains_fifo_order():
    # §II-E: driver chains committed transfers in FIFO fashion.
    a = C.from_segments([0], [0], [4])
    b = C.from_segments([10, 20], [10, 20], [4, 4])
    cat = C.concat_chains([a, b])
    assert cat.num_descriptors == 3
    assert C.walk_chain_host(cat, 0) == [0, 1, 2]


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 40), seed=st.integers(0, 2**31 - 1))
def test_sequential_layout_guarantees_speculation_hits(n, seed):
    """The software speculation contract: planner layout -> hit rate 1.0."""
    rng = np.random.default_rng(seed)
    d, perm = _random_chain_perm(rng, n)
    table, hit_rate = C.plan_sequential_layout(d, table_base=0x2000,
                                               head=int(perm[0]))
    assert hit_rate == 1.0
    assert C.measure_hit_rate(table, head_addr=0x2000, table_base=0x2000) == 1.0
    # Planner output in walk order == chain addresses strictly sequential.
    addrs = 0x2000 + np.arange(n) * 32
    assert estimate_hit_rate(addrs) == 1.0


def test_random_layout_has_poor_hit_rate():
    rng = np.random.default_rng(0)
    addrs = rng.permutation(64) * 32
    assert estimate_hit_rate(addrs) < 0.2


def test_pages_chain_is_gather():
    d = C.from_pages([7, 3, 5], page_elems=256)
    np.testing.assert_array_equal(np.asarray(d.src), [7 * 256, 3 * 256, 5 * 256])
    np.testing.assert_array_equal(np.asarray(d.dst), [0, 256, 512])
