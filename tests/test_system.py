"""End-to-end behaviour: the full train/serve paths with fault tolerance."""

import jax
import numpy as np

from repro import optim
from repro.configs import get_config
from repro.data import DataConfig
from repro.runtime import SubmitRequest
from repro.train import Trainer, TrainConfig, TrainerConfig


def _setup(tmp_path, total_steps, ckpt_every=2):
    cfg = get_config("qwen2.5-3b", reduced=True)
    tcfg = TrainConfig(optimizer=optim.AdamWConfig(
        lr=1e-3, warmup_steps=0, schedule="constant", weight_decay=0.0))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=2)
    run = TrainerConfig(total_steps=total_steps, checkpoint_every=ckpt_every,
                        checkpoint_dir=str(tmp_path), log_every=100)
    return cfg, tcfg, dcfg, run


def test_interrupted_training_equals_straight_run(tmp_path):
    """Train 6 straight == train 4, 'crash', resume to 6 — identical loss
    stream (checkpoint carries optimizer + data-iterator state)."""
    cfg, tcfg, dcfg, run6 = _setup(tmp_path / "a", 6)
    r_straight = Trainer(cfg, tcfg, run6, dcfg).train()

    cfg, tcfg, dcfg, run4 = _setup(tmp_path / "b", 4)
    Trainer(cfg, tcfg, run4, dcfg).train()
    _, _, _, run_resume = _setup(tmp_path / "b", 6)
    r_resumed = Trainer(cfg, tcfg, run_resume, dcfg).train()

    # Steps 4 and 5 of the resumed run must match the straight run.
    np.testing.assert_allclose(r_straight["losses"][4:],
                               r_resumed["losses"], rtol=1e-4)


def test_training_improves_over_data_stream(tmp_path):
    cfg, tcfg, dcfg, run = _setup(tmp_path, 30, ckpt_every=100)
    r = Trainer(cfg, tcfg, run, dcfg).train()
    first5 = np.mean(r["losses"][:5])
    last5 = np.mean(r["losses"][-5:])
    assert last5 < first5


def test_serve_engine_mixed_archs_end_to_end():
    """Continuous batching across heterogeneous families (ssm + moe)."""
    from repro.models import init_params
    from repro.serve import Request, ServeEngine
    for arch in ("mamba2-780m", "dbrx-132b"):
        cfg = get_config(arch, reduced=True)
        params = init_params(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(params, cfg, capacity=2, max_len=48)
        rng = np.random.default_rng(0)
        for uid in range(3):
            eng.submit(SubmitRequest(request=Request(
                uid=uid, prompt=list(rng.integers(1, 400, 4)),
                max_new_tokens=3)))
        done = eng.run(max_steps=200)
        assert sorted(done) == [0, 1, 2], arch
        assert all(len(r.output) == 3 for r in done.values()), arch


def test_descriptor_substrate_threads_through_data_and_serving():
    """The same descriptor currency works across pipeline layers."""
    from repro.core.engine import execute_chain_host
    from repro.data import DataConfig, pack_documents
    from repro.serve import PageAllocator

    dcfg = DataConfig(vocab_size=100, seq_len=64, global_batch=2)
    rng = np.random.default_rng(0)
    tokens, seg, chain = pack_documents(dcfg, rng, batch_rows=2)
    # Executing the packing chain over the flat doc stream reproduces the
    # packed token batch (token 0 separators aside).
    flat_docs = []
    cursor = 0
    for s, d, ln in zip(np.asarray(chain.src), np.asarray(chain.dst),
                        np.asarray(chain.length)):
        flat_docs.append(tokens.reshape(-1)[d:d + ln])
    src = np.concatenate(flat_docs)
    dst = np.zeros(tokens.size, tokens.dtype)
    out, _ = execute_chain_host(chain, src, dst)
    np.testing.assert_array_equal(out.reshape(tokens.shape), tokens)

    alloc = PageAllocator(8)
    alloc.alloc(0, 3)
    assert alloc.chain(0, 16).num_descriptors == 3
    assert alloc.speculation_hit_rate(0) == 1.0
