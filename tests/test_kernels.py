"""Pallas kernels vs ref.py oracles — shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
pytestmark = pytest.mark.slow  # property suites: run in CI's slow job
from hypothesis import given, settings, strategies as st

from repro.core.descriptor import DescriptorArray
from repro.kernels import ref
from repro.kernels.descriptor_copy import chain_copy, descriptor_copy
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_dispatch import moe_combine, moe_gather
from repro.kernels.paged_attention import paged_attention

I = dict(interpret=True)


# ---------------------------------------------------------------------------
# descriptor_copy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("unit", [128, 256])
def test_descriptor_copy_shapes_dtypes(dtype, unit):
    rng = np.random.default_rng(0)
    rows = 32
    src = jnp.asarray(rng.integers(-5, 5, (rows, unit))).astype(dtype)
    dst = jnp.zeros((rows, unit), dtype)
    sidx = jnp.asarray(rng.permutation(rows), jnp.int32)
    didx = jnp.arange(rows, dtype=jnp.int32)
    got = descriptor_copy(sidx, didx, src, dst, **I)
    want = ref.descriptor_copy_ref(sidx, didx, src, dst)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_descriptor_copy_skips_inactive():
    src = jnp.arange(4 * 128, dtype=jnp.float32).reshape(4, 128)
    dst = jnp.full((4, 128), -1.0)
    sidx = jnp.array([2, -1, 0, -1], jnp.int32)
    didx = jnp.array([0, 1, 3, 2], jnp.int32)
    got = descriptor_copy(sidx, didx, src, dst, **I)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(src[2]))
    np.testing.assert_array_equal(np.asarray(got[3]), np.asarray(src[0]))
    assert np.all(np.asarray(got[1]) == -1) and np.all(np.asarray(got[2]) == -1)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 24))
def test_chain_copy_matches_host_walk(seed, n):
    """Chained kernel == serial host walk on random permutated chains."""
    from repro.core.engine import execute_blocked_2d

    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    nxt = np.full(n, -1, np.int64)
    for a, b in zip(perm[:-1], perm[1:]):
        nxt[a] = b
    d = DescriptorArray.create(rng.integers(0, n, n), rng.permutation(n),
                               np.ones(n), nxt)
    src = jnp.asarray(rng.standard_normal((n, 128)), jnp.float32)
    dst = jnp.zeros((n, 128), jnp.float32)
    got = chain_copy(d, src, dst, head=int(perm[0]), **I)
    want, _ = execute_blocked_2d(d, src, dst)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("h,kv", [(4, 4), (4, 2)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_vs_ref(dtype, tol, h, kv, causal):
    key = jax.random.PRNGKey(0)
    b, s, d = 2, 256, 128
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    got = flash_attention(q, k, v, causal=causal, q_block=128, kv_block=128, **I)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_sliding_window():
    key = jax.random.PRNGKey(1)
    b, s, h, d = 1, 256, 2, 128
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d))
    v = jax.random.normal(jax.random.PRNGKey(3), (b, s, h, d))
    got = flash_attention(q, k, v, causal=True, window=64,
                          q_block=64, kv_block=64, **I)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("blocks", [(64, 128), (128, 64), (256, 256)])
def test_flash_attention_block_shape_sweep(blocks):
    qb, kb = blocks
    key = jax.random.PRNGKey(4)
    b, s, h, d = 1, 256, 2, 128
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(key, (b, s, h, d))
    v = jax.random.normal(key, (b, s, h, d))
    got = flash_attention(q, k, v, q_block=qb, kv_block=kb, **I)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# paged_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("h,kv", [(4, 4), (8, 2)])
def test_paged_attention_vs_ref(dtype, tol, h, kv):
    key = jax.random.PRNGKey(0)
    b, d, page, pool, maxp = 3, 128, 16, 24, 4
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    kp = jax.random.normal(ks[1], (pool, page, kv, d), dtype)
    vp = jax.random.normal(ks[2], (pool, page, kv, d), dtype)
    rng = np.random.default_rng(0)
    # Distinct pages per sequence; ragged lengths (last page partial).
    tables = rng.choice(pool, size=(b, maxp), replace=False)
    lengths = np.array([maxp * page, 2 * page + 5, 7])
    tables = np.where(np.arange(maxp)[None, :] * page
                      < lengths[:, None], tables, -1)
    got = paged_attention(q, kp, vp, jnp.asarray(tables, jnp.int32),
                          jnp.asarray(lengths, jnp.int32), **I)
    want = ref.paged_attention_ref(q, kp, vp, jnp.asarray(tables, jnp.int32),
                                   jnp.asarray(lengths, jnp.int32))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_paged_attention_matches_dense_decode():
    """Paged over a descriptor-chain layout == dense attention on the
    logically contiguous cache (the serving-engine invariant)."""
    key = jax.random.PRNGKey(7)
    b, h, d, page = 2, 4, 128, 8
    length = 3 * page
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, d))
    dense_k = jax.random.normal(ks[1], (b, length, h, d))
    dense_v = jax.random.normal(ks[2], (b, length, h, d))
    # Scatter the dense cache into a shuffled page pool.
    pool = np.zeros((b * 3 + 2, page, h, d), np.float32)
    vpool = np.zeros_like(pool)
    rng = np.random.default_rng(1)
    page_ids = rng.permutation(b * 3 + 2)[:b * 3].reshape(b, 3)
    for i in range(b):
        for j in range(3):
            pool[page_ids[i, j]] = np.asarray(dense_k[i, j * page:(j + 1) * page])
            vpool[page_ids[i, j]] = np.asarray(dense_v[i, j * page:(j + 1) * page])
    lengths = jnp.full((b,), length, jnp.int32)
    got = paged_attention(q, jnp.asarray(pool), jnp.asarray(vpool),
                          jnp.asarray(page_ids, jnp.int32), lengths, **I)
    want = ref.flash_attention_ref(q[:, None], dense_k, dense_v,
                                   causal=False)[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# moe dispatch / combine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gather_vs_ref(dtype):
    rng = np.random.default_rng(0)
    t, d, slots = 32, 128, 48
    tokens = jnp.asarray(rng.standard_normal((t, d))).astype(dtype)
    idx = jnp.asarray(rng.integers(-1, t, slots), jnp.int32)
    got = moe_gather(idx, tokens, **I)
    want = ref.moe_gather_ref(idx, tokens)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("k", [2, 4])
def test_moe_combine_vs_ref(k):
    rng = np.random.default_rng(1)
    t, d, slots = 16, 128, 64
    eo = jnp.asarray(rng.standard_normal((slots, d)), jnp.float32)
    inv_slot = jnp.asarray(rng.integers(-1, slots, (t, k)), jnp.int32)
    inv_w = jnp.asarray(rng.random((t, k)), jnp.float32)
    got = moe_combine(inv_slot, inv_w, eo, **I)
    want = ref.moe_combine_ref(inv_slot, inv_w, eo)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_moe_kernels_roundtrip_plan():
    """Kernel dispatch+combine reproduces the model's jnp MoE combine path."""
    from repro.configs.base import MoEConfig
    from repro.models.moe import capacity, moe_dispatch_plan

    m = MoEConfig(num_experts=4, experts_per_token=2, expert_d_ff=8,
                  capacity_factor=2.0)
    t, d = 32, 128
    key = jax.random.PRNGKey(0)
    tokens = jax.random.normal(key, (t, d))
    probs = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(1), (t, 4)), -1)
    cap = capacity(t, m)
    plan = moe_dispatch_plan(probs, m, cap)

    xe = moe_gather(plan.token_idx, tokens, **I)
    np.testing.assert_allclose(np.asarray(xe),
                               np.asarray(ref.moe_gather_ref(plan.token_idx,
                                                             tokens)))
    # Identity "experts": combine should reconstruct sum of top-k weights * x.
    y = moe_combine(plan.inv_slot, plan.inv_weight, xe, **I)
    want = ref.moe_combine_ref(plan.inv_slot, plan.inv_weight, xe)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # With norm_topk the weights sum to 1 -> y == tokens (no drops).
    np.testing.assert_allclose(np.asarray(y), np.asarray(tokens),
                               rtol=1e-4, atol=1e-4)
