"""Descriptor execution engines — the DMA backend's semantics in JAX.

Three tiers, all consuming :class:`DescriptorArray`:

* :func:`execute_chain_host` — numpy oracle with the RTL's serial semantics
  (walk the chain, copy segment by segment). Ground truth for everything.
* :func:`execute_serial` — jitted ``lax.fori_loop`` engine that preserves
  chain order (later descriptors may overwrite earlier ones, as in hardware).
* :func:`execute_blocked` — vectorized engine for uniform-unit streams (pages,
  expert rows): a masked gather/scatter executed in one shot. This is the form
  the Pallas kernel (:mod:`repro.kernels.descriptor_copy`) accelerates.

Completion follows §II-D: executed descriptors get the all-ones writeback
(``mark_done``), so a polling scheduler can observe progress without IRQs.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .chain import walk_chain_host
from .descriptor import DescriptorArray


# ---------------------------------------------------------------------------
# Host oracle
# ---------------------------------------------------------------------------

def execute_chain_host(
    d: DescriptorArray, src: np.ndarray, dst: np.ndarray, head: int = 0
) -> Tuple[np.ndarray, DescriptorArray]:
    """Serial reference: faithful chain-order copy on the host."""
    src = np.asarray(src)
    out = np.array(dst, copy=True)
    s, t, ln = (np.asarray(d.src), np.asarray(d.dst), np.asarray(d.length))
    order = walk_chain_host(d, head)
    done = np.asarray(d.done).copy()
    for i in order:
        out[t[i] : t[i] + ln[i]] = src[s[i] : s[i] + ln[i]]
        done[i] = 1
    dd = d.mark_done(np.asarray(order, np.int32))
    return out, dd


# ---------------------------------------------------------------------------
# Serial jitted engine (chain-order preserving)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("max_len", "head"))
def execute_serial(
    d: DescriptorArray,
    src: jax.Array,
    dst: jax.Array,
    *,
    max_len: int,
    head: int = 0,
):
    """Execute a chain serially under jit.

    ``max_len`` is the static upper bound on any descriptor's length; each
    step copies a masked fixed-size window (hardware analogue: max burst).
    """
    n = d.num_descriptors

    def body(carry):
        cur, dst_buf, done = carry
        s = d.src[cur]
        t = d.dst[cur]
        ln = d.length[cur]
        window = jax.lax.dynamic_slice(src, (s,), (max_len,))
        old = jax.lax.dynamic_slice(dst_buf, (t,), (max_len,))
        mask = jnp.arange(max_len) < ln
        merged = jnp.where(mask, window, old)
        dst_buf = jax.lax.dynamic_update_slice(dst_buf, merged, (t,))
        done = done.at[cur].set(1)
        return d.nxt[cur], dst_buf, done

    def cond(carry):
        cur, _, _ = carry
        return cur >= 0

    cur0 = jnp.asarray(head, jnp.int32)
    _, out, done = jax.lax.while_loop(cond, body, (cur0, dst, d.done))
    return out, done


# ---------------------------------------------------------------------------
# Vectorized blocked engine (uniform-unit descriptor streams)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("unit",))
def execute_blocked(
    d: DescriptorArray, src: jax.Array, dst: jax.Array, *, unit: int
):
    """Vectorized engine for streams whose lengths are all <= ``unit``.

    All descriptors execute "in parallel"; overlapping destinations are NOT
    ordered (callers needing chain-order semantics use ``execute_serial``).
    Disabled descriptors (length < 0, i.e. completed/sentinel) are skipped.
    Returns (dst', done').
    """
    n = d.num_descriptors
    offs = jnp.arange(unit, dtype=jnp.int32)
    active = d.length >= 0
    ln = jnp.maximum(d.length, 0)

    # Gather: rows of shape (n, unit) from src.
    src_idx = d.src[:, None] + offs[None, :]
    rows = src[jnp.clip(src_idx, 0, src.shape[0] - 1)]

    # Scatter with mask into dst.
    valid = (offs[None, :] < ln[:, None]) & active[:, None]
    dst_idx = jnp.where(valid, d.dst[:, None] + offs[None, :], src.shape[0])
    out = dst.at[dst_idx.reshape(-1)].set(
        jnp.where(valid, rows, 0).reshape(-1), mode="drop"
    )
    done = jnp.where(active, 1, d.done)
    return out, done


def execute_blocked_2d(
    d: DescriptorArray, src: jax.Array, dst: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Row-pool variant: src/dst are (rows, row_elems); descriptors move whole
    rows (src/dst fields are row indices, length is rows-per-descriptor == 1).

    This is the layout used by the paged-KV cache and MoE dispatch: a
    descriptor moves one fixed-size row (page line / token embedding), and
    irregularity lives entirely in the index pattern.
    """
    active = d.length >= 0
    safe_src = jnp.clip(d.src, 0, src.shape[0] - 1)
    rows = src[safe_src]
    dst_idx = jnp.where(active, d.dst, dst.shape[0])
    out = dst.at[dst_idx].set(rows, mode="drop")
    return out, jnp.where(active, 1, d.done)


# ---------------------------------------------------------------------------
# Completion / feedback logic (frontend §II-A "feedback logic")
# ---------------------------------------------------------------------------

def completion_events(done_before: jax.Array, done_after: jax.Array,
                      irq_mask: jax.Array) -> jax.Array:
    """Which descriptors completed this step AND requested notification.

    Mirrors the frontend's IRQ-optional design: descriptors with
    CONFIG_IRQ_ENABLE produce an event; everything else relies on the
    writeback being polled.
    """
    newly = (done_after == 1) & (done_before == 0)
    return newly & (irq_mask != 0)
