"""The paper's 256-bit transfer descriptor (Listing 1) — canonical formats.

Two representations, round-trippable:

1. **Packed host form** — bit-exact with the paper's Listing 1::

       struct descriptor {          // 32 bytes, little-endian
           u32 length;              // transfer length in bytes (<= 4 GiB)
           u32 config;              // front-/backend configuration bits
           u64 next;                // byte address of next descriptor, -1 = end
           u64 source;              // byte address of source
           u64 destination;         // byte address of destination
       }

   Stored as a numpy structured array; used by the cycle simulator, the
   checkpoint manifests and anything that talks "byte addresses".

2. **Device SoA form** (:class:`DescriptorArray`) — a struct-of-arrays of
   int32 *element offsets* into typed JAX buffers. JAX arrays are typed pools,
   not a flat byte space, so on-device descriptors address elements of a named
   (src_pool, dst_pool) pair. ``next`` holds the *index* of the successor
   descriptor in the table (-1 = end-of-chain), which is the natural device
   analogue of the paper's next-pointer.

Completion tracking follows §II-D: the engine overwrites the first 8 bytes of
a completed descriptor with all-ones (``DONE_SENTINEL``); on device this is a
``done`` flag vector plus the same sentinel written into (length, config).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Constants (paper §II-B / §II-D)
# ---------------------------------------------------------------------------

DESCRIPTOR_BYTES = 32              # 256-bit descriptor
END_OF_CHAIN = np.uint64(0xFFFF_FFFF_FFFF_FFFF)   # `next` == -1 terminates
END_OF_CHAIN_IDX = np.int32(-1)    # device-side successor index sentinel
DONE_SENTINEL32 = np.uint32(0xFFFF_FFFF)          # first 8 B overwritten on done
MAX_TRANSFER_BYTES = 2**32 - 1     # u32 length field -> individual <= 4 GiB

# config field bit layout (frontend low half / backend high half)
CONFIG_IRQ_ENABLE = np.uint32(1 << 0)       # raise IRQ / completion event
CONFIG_WRITEBACK = np.uint32(1 << 1)        # overwrite first 8 B on completion
CONFIG_DECOUPLE_RW = np.uint32(1 << 2)      # backend: decouple R/W channels
CONFIG_SRC_FIXED = np.uint32(1 << 8)        # backend: fixed-address source
CONFIG_DST_FIXED = np.uint32(1 << 9)        # backend: fixed-address destination
CONFIG_BURST_SHIFT = 16                      # backend: max AXI burst length

PACKED_DTYPE = np.dtype(
    [
        ("length", "<u4"),
        ("config", "<u4"),
        ("next", "<u8"),
        ("source", "<u8"),
        ("destination", "<u8"),
    ]
)
assert PACKED_DTYPE.itemsize == DESCRIPTOR_BYTES


# ---------------------------------------------------------------------------
# Packed host form
# ---------------------------------------------------------------------------

def pack(
    length: Sequence[int],
    config: Sequence[int],
    next_addr: Sequence[int],
    source: Sequence[int],
    destination: Sequence[int],
) -> np.ndarray:
    """Build a packed descriptor table (numpy structured array)."""
    length = np.asarray(length, dtype=np.uint64)
    if np.any(length > MAX_TRANSFER_BYTES):
        raise ValueError("descriptor length exceeds u32 field (4 GiB); chain instead")
    out = np.zeros(len(length), dtype=PACKED_DTYPE)
    out["length"] = length.astype(np.uint32)
    out["config"] = np.asarray(config, dtype=np.uint32)
    out["next"] = np.asarray(next_addr, dtype=np.uint64)
    out["source"] = np.asarray(source, dtype=np.uint64)
    out["destination"] = np.asarray(destination, dtype=np.uint64)
    return out


def to_bytes(table: np.ndarray) -> bytes:
    """Serialize a packed table to the exact 32 B/descriptor wire layout."""
    return table.astype(PACKED_DTYPE, copy=False).tobytes()


def from_bytes(raw: bytes) -> np.ndarray:
    if len(raw) % DESCRIPTOR_BYTES:
        raise ValueError(f"raw length {len(raw)} not a multiple of {DESCRIPTOR_BYTES}")
    return np.frombuffer(raw, dtype=PACKED_DTYPE).copy()


def mark_done_packed(table: np.ndarray, idx: int) -> None:
    """§II-D completion writeback: first 8 bytes -> all ones."""
    table["length"][idx] = DONE_SENTINEL32
    table["config"][idx] = DONE_SENTINEL32


def is_done_packed(table: np.ndarray) -> np.ndarray:
    return (table["length"] == DONE_SENTINEL32) & (table["config"] == DONE_SENTINEL32)


# ---------------------------------------------------------------------------
# Device SoA form
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DescriptorArray:
    """Struct-of-arrays descriptor table for on-device execution.

    All fields are int32 vectors of equal length N:
      src    — element offset into the source pool
      dst    — element offset into the destination pool
      length — transfer length in *elements*
      nxt    — successor descriptor index (-1 = end-of-chain)
      config — config bits (same layout as packed form, truncated to 31 bits)
      done   — completion flag (0/1); sentinel mirror of the 8-byte writeback
    """

    src: jax.Array
    dst: jax.Array
    length: jax.Array
    nxt: jax.Array
    config: jax.Array
    done: jax.Array

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return (self.src, self.dst, self.length, self.nxt, self.config, self.done), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # -- constructors -------------------------------------------------------
    @classmethod
    def create(cls, src, dst, length, nxt=None, config=None) -> "DescriptorArray":
        src = jnp.asarray(src, jnp.int32)
        dst = jnp.asarray(dst, jnp.int32)
        length = jnp.asarray(length, jnp.int32)
        n = src.shape[0]
        if nxt is None:  # default: sequential chain ending at -1
            nxt = jnp.concatenate([jnp.arange(1, n, dtype=jnp.int32),
                                   jnp.full((1,), -1, jnp.int32)])
        else:
            nxt = jnp.asarray(nxt, jnp.int32)
        if config is None:
            config = jnp.zeros((n,), jnp.int32)
        else:
            config = jnp.asarray(config, jnp.int32)
        done = jnp.zeros((n,), jnp.int32)
        return cls(src, dst, length, nxt, config, done)

    @property
    def num_descriptors(self) -> int:
        return self.src.shape[0]

    def mark_done(self, idx) -> "DescriptorArray":
        """Device analogue of the all-ones writeback."""
        return dataclasses.replace(
            self,
            done=self.done.at[idx].set(1),
            length=self.length.at[idx].set(-1),
            config=self.config.at[idx].set(-1),
        )

    def all_done(self) -> jax.Array:
        return jnp.all(self.done == 1)


def to_packed(
    d: DescriptorArray,
    *,
    elem_bytes: int = 1,
    src_base: int = 0,
    dst_base: int = 0,
    table_base: int = 0,
) -> np.ndarray:
    """Lower a device SoA table to the packed 256-bit host layout.

    Element offsets become byte addresses relative to the given pool bases;
    successor indices become byte addresses of descriptor slots (sequential
    layout at ``table_base``), matching the planner in :mod:`repro.core.chain`.
    """
    src = np.asarray(d.src, np.int64) * elem_bytes + src_base
    dst = np.asarray(d.dst, np.int64) * elem_bytes + dst_base
    length = np.asarray(d.length, np.int64) * elem_bytes
    nxt_idx = np.asarray(d.nxt, np.int64)
    nxt = np.where(
        nxt_idx < 0,
        np.int64(-1),
        table_base + nxt_idx * DESCRIPTOR_BYTES,
    ).astype(np.int64)
    cfg = np.asarray(d.config, np.int64) & 0xFFFF_FFFF
    tab = pack(
        np.where(np.asarray(d.done) == 1, 0, length),  # repacked done entries reset below
        cfg,
        nxt.view(np.uint64) if nxt.dtype == np.uint64 else nxt.astype(np.uint64),
        src.astype(np.uint64),
        dst.astype(np.uint64),
    )
    done = np.asarray(d.done) == 1
    for i in np.nonzero(done)[0]:
        mark_done_packed(tab, int(i))
    return tab


def from_packed(
    table: np.ndarray,
    *,
    elem_bytes: int = 1,
    src_base: int = 0,
    dst_base: int = 0,
    table_base: int = 0,
) -> DescriptorArray:
    """Inverse of :func:`to_packed` (requires aligned addresses)."""
    src = (table["source"].astype(np.int64) - src_base) // elem_bytes
    dst = (table["destination"].astype(np.int64) - dst_base) // elem_bytes
    done = is_done_packed(table)
    length = np.where(done, -1, table["length"].astype(np.int64) // elem_bytes)
    nxt_raw = table["next"]
    nxt = np.where(
        nxt_raw == END_OF_CHAIN,
        np.int64(-1),
        (nxt_raw.astype(np.int64) - table_base) // DESCRIPTOR_BYTES,
    )
    config = np.where(done, -1, table["config"].astype(np.int64))
    d = DescriptorArray.create(src, dst, length, nxt, config)
    return dataclasses.replace(d, done=jnp.asarray(done, jnp.int32))
