"""Unified speculation-policy layer: who decides the §II-C prefetch depth.

The paper's speculative descriptor prefetcher has one tunable — how many
sequential-address fetches may be outstanding (the ``prefetch`` column of
Table I). The reproduction historically hard-coded that depth as an ``int``
in four independent places (the cycle simulator's :class:`SimConfig`, the
analytical model, the runtime coalescer's layout planner, and the Pallas
kernels' ``depth=4``). Following the modular-frontend argument of iDMA
(arXiv 2305.05240) and XDMA (arXiv 2508.08396), the *policy* is now a
swappable module decoupled from every datapath that consumes it:

* a **policy** (:class:`FixedDepth`, :class:`AdaptiveDepth`) is an immutable
  spec — safe to embed in frozen configs and share across runs;
* a **controller** (:meth:`SpeculationPolicy.make_controller`) is the
  per-run mutable state machine. Consumers create one controller per
  measurement domain (one per simulated frontend, one per runtime channel),
  ask it :attr:`DepthController.depth` *before* planning, and feed observed
  §II-C hit rates back through :meth:`DepthController.observe`.

Feedback-loop contract (DESIGN.md §5): the *measurer* is whoever sees real
traffic (the cycle simulator's commit path, the runtime coalescer's
``input_hit_rate``), the *decider* is the controller, and depth may change
only at chain/window boundaries — never mid-flight, so outstanding
speculative fetches are always drained under the depth that issued them.

``FixedDepth(n)`` reproduces the historical integer behaviour bit-for-bit:
its controller ignores observations and every consumer degenerates to the
pre-policy code path.
"""
from __future__ import annotations

import dataclasses
import numbers
from typing import Protocol, Union, runtime_checkable

#: The historical hard-coded speculation depth (SimConfig.speculation(),
#: kernels' prefetched_chain_copy_op default). Single source of truth so the
#: simulator and the kernels cannot silently diverge again.
DEFAULT_DEPTH = 4

#: Committed descriptors per depth re-evaluation window ("chain boundary"
#: granularity in the cycle simulator and the adaptive controller's natural
#: cadence). Small enough that a 200-transfer sweep cell converges well
#: before its steady-state measurement window opens.
DEPTH_WINDOW = 8


class DepthController(Protocol):
    """Per-run mutable state: current depth + hit-rate feedback."""

    @property
    def depth(self) -> int: ...

    @property
    def enabled(self) -> bool: ...

    def observe(self, hit_rate: float) -> int:
        """Feed one observed §II-C hit rate; returns the (new) depth."""
        ...


@runtime_checkable
class SpeculationPolicy(Protocol):
    """Immutable policy spec; a factory for per-run controllers."""

    def make_controller(self) -> DepthController: ...


# ---------------------------------------------------------------------------
# FixedDepth — exactly the historical integer behaviour
# ---------------------------------------------------------------------------

class _FixedController:
    __slots__ = ("_depth",)

    def __init__(self, depth: int):
        self._depth = depth

    @property
    def depth(self) -> int:
        return self._depth

    @property
    def enabled(self) -> bool:
        return self._depth > 0

    def observe(self, hit_rate: float) -> int:
        del hit_rate  # fixed policy: observations never change the depth
        return self._depth


@dataclasses.dataclass(frozen=True)
class FixedDepth:
    """Constant speculation depth — ``FixedDepth(0)`` disables speculation.

    Bit-for-bit equivalent to the pre-policy ``prefetch: int`` plumbing.
    """

    depth: int = DEFAULT_DEPTH

    def __post_init__(self):
        if self.depth < 0:
            raise ValueError("speculation depth must be >= 0")

    def make_controller(self) -> _FixedController:
        return _FixedController(self.depth)


#: Shared default policy instance (kernels, runtime channels).
DEFAULT_POLICY = FixedDepth(DEFAULT_DEPTH)


# ---------------------------------------------------------------------------
# AdaptiveDepth — EWMA of observed hit rate with hysteresis
# ---------------------------------------------------------------------------

class _AdaptiveController:
    __slots__ = ("_p", "_depth", "_ewma", "_hi", "_lo", "_updates")

    def __init__(self, p: "AdaptiveDepth"):
        self._p = p
        self._depth = p.initial_depth
        self._ewma: float | None = None
        self._hi = 0        # consecutive windows at/above deepen_threshold
        self._lo = 0        # consecutive windows at/below backoff_threshold
        self._updates = 0

    @property
    def depth(self) -> int:
        return self._depth

    @property
    def enabled(self) -> bool:
        # min_depth >= 1: the controller always keeps one probing slot, so
        # it can re-observe the stream and recover after backing off.
        return True

    @property
    def ewma(self) -> float | None:
        return self._ewma

    def observe(self, hit_rate: float) -> int:
        p = self._p
        h = min(1.0, max(0.0, float(hit_rate)))
        self._ewma = h if self._ewma is None \
            else p.alpha * h + (1.0 - p.alpha) * self._ewma
        self._updates += 1
        if self._ewma >= p.deepen_threshold:
            self._hi += 1
            self._lo = 0
            if self._hi >= p.deepen_hysteresis:
                self._depth = min(self._depth * 2, p.max_depth)
                self._hi = 0
        elif self._ewma <= p.backoff_threshold:
            self._lo += 1
            self._hi = 0
            if self._lo >= p.backoff_hysteresis:
                self._depth = max(self._depth // 2, p.min_depth)
                self._lo = 0
        else:
            # Dead band: a misprediction burst that only dents the EWMA
            # resets the streaks instead of thrashing the depth.
            self._hi = 0
            self._lo = 0
        return self._depth


@dataclasses.dataclass(frozen=True)
class AdaptiveDepth:
    """EWMA-of-hit-rate controller: deepen on sequential streams, back off
    on MoE-storm-like irregular traffic, with hysteresis against thrash.

    Dynamics per observation window (one §II-C hit-rate sample):

    * ``ewma >= deepen_threshold`` for ``deepen_hysteresis`` consecutive
      windows -> depth doubles (capped at ``max_depth``);
    * ``ewma <= backoff_threshold`` for ``backoff_hysteresis`` consecutive
      windows -> depth halves (floored at ``min_depth``);
    * in the dead band between the thresholds the depth holds and both
      streak counters reset, so one bad window never moves the depth.

    The hysteresis is asymmetric by default (deepen after one good window,
    back off only after two bad ones): a sequential stream should reach its
    steady depth before a measurement window opens, while a lone
    misprediction burst — one bad window between good ones — must never
    thrash the depth. Backing off remains *prompt* (two windows) because
    wasted speculative fetches on a storm are pure bus contention.

    ``min_depth`` must stay >= 1: a zero-depth frontend stops speculating
    and therefore stops *observing*, which would latch the controller at
    zero forever. One probing slot keeps the feedback loop alive.
    """

    min_depth: int = 1
    max_depth: int = 24       # the paper's scaled config (Table I)
    initial_depth: int = DEFAULT_DEPTH
    alpha: float = 0.5        # EWMA smoothing (per DEPTH_WINDOW sample)
    deepen_threshold: float = 0.85
    backoff_threshold: float = 0.55
    deepen_hysteresis: int = 1   # windows of good traffic before deepening
    backoff_hysteresis: int = 2  # windows of storms before backing off

    def __post_init__(self):
        if self.min_depth < 1:
            raise ValueError("min_depth must be >= 1 (see class docstring)")
        if not self.min_depth <= self.initial_depth <= self.max_depth:
            raise ValueError("need min_depth <= initial_depth <= max_depth")
        if not 0.0 < self.alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        if not 0.0 <= self.backoff_threshold < self.deepen_threshold <= 1.0:
            raise ValueError(
                "need 0 <= backoff_threshold < deepen_threshold <= 1")
        if self.deepen_hysteresis < 1 or self.backoff_hysteresis < 1:
            raise ValueError("hysteresis must be >= 1")

    def make_controller(self) -> _AdaptiveController:
        return _AdaptiveController(self)


# ---------------------------------------------------------------------------
# Coercions — every consumer accepts int | policy through these
# ---------------------------------------------------------------------------

PolicyLike = Union[int, SpeculationPolicy]


def as_policy(value: PolicyLike) -> SpeculationPolicy:
    """Coerce the legacy ``prefetch: int`` spelling into a policy.

    Integral types include numpy scalars (``np.int64`` etc.) — the
    pre-policy plumbing accepted them, so the coercion must too.
    """
    if isinstance(value, SpeculationPolicy) \
            and not isinstance(value, numbers.Integral):
        return value
    if isinstance(value, numbers.Integral):
        return FixedDepth(int(value))
    raise TypeError(
        f"expected an int depth or a SpeculationPolicy, got {value!r}")


def static_depth(value: PolicyLike) -> int:
    """The depth a consumer without a feedback path should use (kernels,
    analytical model): a fresh controller's initial depth."""
    return as_policy(value).make_controller().depth
