"""Chain canonicalization: base-address-invariant shape/stride signatures.

The translation cache (:mod:`repro.runtime.lowering`) keys compiled
executors on the *abstract structure* of a descriptor chain, not its
concrete addresses — the jace idiom (trace once per abstract input
structure, re-dispatch the cached artifact cheaply) applied to §II-B
descriptor chains. This module computes that structure:

* :func:`walk_order` — the chain's walk permutation, vectorized with
  numpy binary lifting (no per-descriptor Python loop; the whole point of
  the cache is that steady-state submission does O(log n) vector work);
* :func:`canonicalize` — the chain's fields in walk order, re-based so
  ``src[first] == dst[first] == 0``. Two chains that differ only by a
  constant base shift canonicalize to equal relative forms;
* :class:`ChainSignature` — the bucketed cache key: segment-count bucket,
  unit-size class, sequential/strided/gather layout, overlap and
  alignment flags, speculation-depth class, engine tier. Signatures are
  deliberately coarser than canonical forms: every chain in a bucket
  dispatches through one compiled artifact (operands carry the exact
  offsets);
* :attr:`CanonicalChain.digest` — the *exact* relative-form fingerprint,
  used to memoize the coalescer plan (plan reuse needs exact-match, not
  bucket-match).

Everything here is pure numpy over host data; nothing touches JAX.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Optional

import numpy as np

from .descriptor import DescriptorArray

LAYOUT_SEQUENTIAL = "sequential"
LAYOUT_STRIDED = "strided"
LAYOUT_GATHER = "gather"


def pow2_bucket(n: int) -> int:
    """Smallest power of two >= n (bucket id; 1 for n <= 1)."""
    n = int(n)
    if n <= 1:
        return 1
    return 1 << (n - 1).bit_length()


def walk_order(nxt: np.ndarray, head: int = 0) -> Optional[np.ndarray]:
    """Chain walk permutation via numpy pointer doubling.

    Mirrors :func:`repro.core.chain.flatten_chain` (same binary-lifting
    scheme) on the host, returning the ``count``-long order array, or
    ``None`` when the chain is malformed (cycle reachable from ``head``,
    out-of-range successor) — callers fall back to the legacy walker,
    which raises the canonical error.
    """
    nxt = np.asarray(nxt, np.int64)
    n = int(nxt.size)
    if n == 0:
        return np.zeros(0, np.int64)
    if not 0 <= head < n:
        return None
    if np.any(nxt >= n):
        return None
    # Sequential fast path: the shape every coalesced chain has.
    if head == 0 and nxt[-1] < 0 and np.array_equal(
            nxt[:-1], np.arange(1, n, dtype=np.int64)):
        return np.arange(n, dtype=np.int64)

    steps = max(1, math.ceil(math.log2(max(n, 2))))
    jumps = [nxt]
    dist = np.where(nxt >= 0, 1, 0).astype(np.int64)
    j = nxt
    for _ in range(steps):
        has = j >= 0
        jc = np.maximum(j, 0)
        dist = np.where(has, dist + dist[jc], dist)
        j = np.where(has, j[jc], j)
        jumps.append(j)

    count = int(dist[head]) + 1
    if count > n:
        return None   # a reachable cycle inflates the lifted distance

    r = np.arange(count, dtype=np.int64)
    cur = np.full(count, head, np.int64)
    for k in range(steps + 1):
        take = ((r >> k) & 1) == 1
        has = cur >= 0
        stepped = np.where(has, jumps[k][np.maximum(cur, 0)], -1)
        cur = np.where(take, stepped, cur)
    if np.any(cur < 0) or np.unique(cur).size != count:
        return None
    return cur


@dataclasses.dataclass(frozen=True)
class CanonicalChain:
    """A chain's fields in walk order, relative to its first segment."""

    n_raw: int                # descriptors in the submitted array
    order: np.ndarray         # walk permutation (len == n_walk)
    rel_src: np.ndarray       # src[order] - src[order[0]]
    rel_dst: np.ndarray       # dst[order] - dst[order[0]]
    length: np.ndarray        # length[order]
    config: np.ndarray        # config[order]
    src_base: int             # src[order[0]] (0 for empty chains)
    dst_base: int

    @property
    def n_walk(self) -> int:
        return int(self.order.size)

    @property
    def digest(self) -> bytes:
        """Exact relative-form fingerprint (base-address invariant)."""
        h = hashlib.blake2b(digest_size=16)
        h.update(np.int64(self.n_raw).tobytes())
        h.update(self.order.tobytes())
        h.update(self.rel_src.tobytes())
        h.update(self.rel_dst.tobytes())
        h.update(self.length.tobytes())
        h.update(self.config.tobytes())
        return h.digest()


def canonicalize(d: DescriptorArray,
                 head: int = 0) -> Optional[CanonicalChain]:
    """Walk-ordered relative form of a chain; None when the walk fails."""
    nxt = np.asarray(d.nxt, np.int64)
    order = walk_order(nxt, head)
    if order is None:
        return None
    src = np.asarray(d.src, np.int64)[order]
    dst = np.asarray(d.dst, np.int64)[order]
    ln = np.asarray(d.length, np.int64)[order]
    cfg = np.asarray(d.config, np.int64)[order]
    src0 = int(src[0]) if src.size else 0
    dst0 = int(dst[0]) if dst.size else 0
    return CanonicalChain(
        n_raw=int(d.num_descriptors), order=order,
        rel_src=src - src0, rel_dst=dst - dst0,
        length=ln, config=cfg, src_base=src0, dst_base=dst0)


@dataclasses.dataclass(frozen=True)
class ChainSignature:
    """The translation-cache key: what a compiled executor specializes on.

    Every field is invariant under a common base-address shift of the
    chain's src/dst ranges (DESIGN.md §7). ``unit`` is the *exact*
    uniform segment length (0 when lengths are mixed): the row-lowered
    Pallas path reshapes pools into ``(rows, unit)`` and therefore needs
    the exact width as a static shape, while the masked vector path only
    needs the ``unit_class`` window.
    """

    tier: str                 # engine tier the artifact targets
    n_class: int              # pow2 bucket of active segment count
    unit_class: int           # pow2 bucket of the longest segment
    layout: str               # sequential | strided | gather
    unit: int                 # exact uniform segment length, 0 if mixed
    overlap: bool             # dst intervals overlap -> ordered execution
    aligned: bool             # rel offsets are multiples of `unit`
    depth_class: int          # pow2 bucket of the §II-C speculation depth
    transform: str = ""       # in-flight transform token ("" = identity,
                              # DESIGN.md §9) — fused into the executor


def _layout_of(rel_src: np.ndarray, rel_dst: np.ndarray,
               ln: np.ndarray) -> str:
    if ln.size <= 1:
        return LAYOUT_SEQUENTIAL
    ds, dd = np.diff(rel_src), np.diff(rel_dst)
    if np.array_equal(ds, ln[:-1]) and np.array_equal(dd, ln[:-1]):
        return LAYOUT_SEQUENTIAL
    uniform = ln.min() == ln.max()
    if (uniform and ds.min() == ds.max() and dd.min() == dd.max()):
        return LAYOUT_STRIDED
    return LAYOUT_GATHER


def _has_overlap(rel_dst: np.ndarray, ln: np.ndarray) -> bool:
    """Do any two segments' dst intervals intersect?"""
    if ln.size <= 1:
        return False
    o = np.argsort(rel_dst, kind="stable")
    t, l = rel_dst[o], ln[o]
    return bool(np.any(t[1:] < t[:-1] + l[:-1]))


def signature_of(canon: CanonicalChain, *, tier: str,
                 depth: int = 0, transform: str = "") -> ChainSignature:
    """Bucketed cache key of a canonical chain (active segments only)."""
    act = canon.length > 0
    rs, rd, ln = canon.rel_src[act], canon.rel_dst[act], canon.length[act]
    n = int(ln.size)
    if n == 0:
        return ChainSignature(tier=tier, n_class=1, unit_class=1,
                              layout=LAYOUT_SEQUENTIAL, unit=0,
                              overlap=False, aligned=False,
                              depth_class=pow2_bucket(depth) if depth else 0,
                              transform=transform)
    unit = int(ln[0]) if int(ln.min()) == int(ln.max()) else 0
    aligned = bool(unit > 0
                   and not np.any(rs % unit)
                   and not np.any(rd % unit))
    return ChainSignature(
        tier=tier,
        n_class=pow2_bucket(n),
        unit_class=pow2_bucket(int(ln.max())),
        layout=_layout_of(rs, rd, ln),
        unit=unit,
        overlap=_has_overlap(rd, ln),
        aligned=aligned,
        depth_class=pow2_bucket(depth) if depth else 0,
        transform=transform,
    )
