"""In-flight transform stage for the descriptor datapath (DESIGN.md §9).

XDMA (arXiv 2508.08396) extends DMA datapaths with pluggable transform
engines so data is reshaped *during* the transfer; iDMA (arXiv 2305.05240)
shows the frontend/midend/backend split that makes such stages composable.
This module is the reproduction's midend: a :class:`TransformSpec`
attached to a descriptor-chain submission names what happens to every
payload byte between the source read and the destination write:

* ``identity``   — plain copy (the default; bit-identical legacy path);
* ``transpose``  — the source pool is read through a ``(rows, cols)``
  transposed view (layout-mismatched engine tiers). Not merge-safe: the
  coalescer must not fuse descriptors whose *source-view* contiguity
  differs from pool contiguity, so transformed chains submit unmerged;
* ``kv_int8``    — KV-cache quantize/dequantize in flight: every payload
  element is read through the EF-int8 per-256-block symmetric round trip
  of :mod:`repro.optim.compress`. The wire carries int8 blocks + fp32
  scales (``payload_ratio`` ≈ 0.254 — the cycle simulator charges fewer
  bus beats), the destination receives dequantized values. Because the
  round trip is a pure function of the *source pool*, the transform is
  merge/split-invariant: coalesced execution is bit-identical to
  unmerged execution;
* ``reduce_sum`` — fused ingress reduction (MoE combine): transferred
  bytes *add into* the destination instead of overwriting it
  (``dst' = dst + copy(d, src, zeros)``; overlapping writes inside one
  chain resolve last-write-wins before the add, matching the serial
  engine's chain-order semantics).

``cache_token`` joins :class:`repro.core.signature.ChainSignature` so the
chain-lowering JIT compiles transform-fused executors per signature
bucket. :func:`reference_apply` is the numpy oracle every executor is
tested against.
"""
from __future__ import annotations

import dataclasses
from typing import Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.compress import BLOCK, compression_ratio

#: Transform kinds and their signature tokens (identity's token is ""
#: so untransformed signatures — and their cached artifacts — are
#: unchanged from the pre-transform cache layout).
KINDS = ("identity", "transpose", "kv_int8", "reduce_sum")


@dataclasses.dataclass(frozen=True)
class TransformSpec:
    """What happens to payload bytes in flight (immutable, hashable).

    ``rows``/``cols`` parameterize ``transpose`` only (the source pool is
    read as a ``(rows, cols)`` matrix, transposed); other kinds ignore
    them.
    """

    kind: str = "identity"
    rows: int = 0
    cols: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown transform {self.kind!r}; "
                             f"one of {KINDS}")
        if self.kind == "transpose" and (self.rows < 1 or self.cols < 1):
            raise ValueError("transpose needs rows >= 1 and cols >= 1")

    # -- constructors --------------------------------------------------------
    @staticmethod
    def identity() -> "TransformSpec":
        return TransformSpec("identity")

    @staticmethod
    def transpose(rows: int, cols: int) -> "TransformSpec":
        return TransformSpec("transpose", rows=rows, cols=cols)

    @staticmethod
    def kv_int8() -> "TransformSpec":
        return TransformSpec("kv_int8")

    @staticmethod
    def reduce_sum() -> "TransformSpec":
        return TransformSpec("reduce_sum")

    # -- contract ------------------------------------------------------------
    @property
    def is_identity(self) -> bool:
        return self.kind == "identity"

    @property
    def payload_ratio(self) -> float:
        """Wire bytes per logical byte — what the cycle simulator charges."""
        return compression_ratio() if self.kind == "kv_int8" else 1.0

    @property
    def merge_safe(self) -> bool:
        """May the coalescer fuse adjacent descriptors under this transform?

        True whenever the transform is a pure function of the source pool
        (merged and unmerged execution read identical bytes). Transposed
        reads break pool contiguity, so ``transpose`` submits unmerged.
        """
        return self.kind != "transpose"

    @property
    def cache_token(self) -> str:
        """The transform's component of the chain-lowering signature key."""
        if self.kind == "identity":
            return ""
        if self.kind == "kv_int8":
            return "kv8"
        if self.kind == "reduce_sum":
            return "sum"
        return f"t{self.rows}x{self.cols}"


#: Shared identity instance (the default on every submission path).
IDENTITY = TransformSpec.identity()

TransformLike = Union[None, str, TransformSpec]

_BY_NAME = {
    "identity": IDENTITY,
    "kv_int8": TransformSpec.kv_int8(),
    "reduce_sum": TransformSpec.reduce_sum(),
}


def as_transform(spec: TransformLike) -> TransformSpec:
    """Coerce ``None`` / a kind name / a spec to a :class:`TransformSpec`."""
    if spec is None:
        return IDENTITY
    if isinstance(spec, TransformSpec):
        return spec
    if isinstance(spec, str):
        t = _BY_NAME.get(spec)
        if t is None:
            raise ValueError(
                f"unknown transform {spec!r}; one of {sorted(_BY_NAME)} "
                "(transpose needs TransformSpec.transpose(rows, cols))")
        return t
    raise TypeError(f"cannot interpret {spec!r} as a TransformSpec")


# ---------------------------------------------------------------------------
# The kv_int8 round trip (traced jnp + numpy oracle)
# ---------------------------------------------------------------------------

@jax.jit
def kv8_roundtrip(x: jax.Array) -> jax.Array:
    """dequantize(quantize(x)) through EF-int8 per-256-block scales.

    Pool-absolute semantics: blocks partition the *flattened pool* (zero
    padding to a BLOCK multiple), so the round trip is independent of any
    descriptor layout — the property that makes ``kv_int8`` merge-safe.
    Returns ``x``'s shape and dtype.
    """
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.maximum(
        jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[:flat.size]
    return deq.reshape(x.shape).astype(x.dtype)


def kv8_roundtrip_np(x) -> np.ndarray:
    """Numpy oracle of :func:`kv8_roundtrip` (same blocks, same rounding)."""
    x = np.asarray(x)
    flat = x.astype(np.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    blocks = np.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = np.maximum(
        np.max(np.abs(blocks), axis=1, keepdims=True) / np.float32(127.0),
        np.float32(1e-12))
    q = np.clip(np.round(blocks / scale), -127, 127).astype(np.int8)
    deq = (q.astype(np.float32) * scale).reshape(-1)[:flat.size]
    return deq.reshape(x.shape).astype(x.dtype)


def transform_source_view(spec: TransformSpec, src: jax.Array) -> jax.Array:
    """The effective source pool a transformed copy reads from.

    Applies to the *read side* only; ``reduce_sum`` (a write-side
    transform) and ``identity`` return ``src`` unchanged.
    """
    if spec.kind == "kv_int8":
        return kv8_roundtrip(src)
    if spec.kind == "transpose":
        if src.ndim != 1:
            raise ValueError("transpose transform needs a flat source pool")
        if src.shape[0] != spec.rows * spec.cols:
            raise ValueError(
                f"transpose({spec.rows}x{spec.cols}) does not tile a "
                f"pool of {src.shape[0]} elements")
        return src.reshape(spec.rows, spec.cols).T.reshape(-1)
    return src


def reference_apply(spec: TransformSpec, d, src, dst,
                    head: int = 0) -> np.ndarray:
    """Numpy oracle: execute chain ``d`` with ``spec`` on host pools.

    Walks the chain in link order (last write wins, as the serial engine
    does) and applies the transform's read-side view / write-side
    reduction. Every lowered executor and channel drain is tested
    bit-identical (or, for ``kv_int8``, value-identical) to this.
    """
    from repro.core.signature import walk_order

    src = np.asarray(src)
    out = np.array(dst, copy=True)
    order = walk_order(np.asarray(d.nxt, np.int64), head)
    if order is None:
        raise ValueError("malformed chain")
    if spec.kind == "kv_int8":
        src = kv8_roundtrip_np(src)
    elif spec.kind == "transpose":
        if src.ndim != 1 or src.shape[0] != spec.rows * spec.cols:
            raise ValueError("transpose view does not tile the source pool")
        src = np.ascontiguousarray(
            src.reshape(spec.rows, spec.cols).T).reshape(-1)
    target = np.zeros_like(out) if spec.kind == "reduce_sum" else out
    lengths = np.asarray(d.length, np.int64)
    srcs = np.asarray(d.src, np.int64)
    dsts = np.asarray(d.dst, np.int64)
    for i in order:
        ln = int(lengths[i])
        if ln <= 0:
            continue
        s, t = int(srcs[i]), int(dsts[i])
        target[t:t + ln] = src[s:s + ln]
    if spec.kind == "reduce_sum":
        out = out + target
    return out
