"""Cycle-level OOC simulator of the DMAC (§III-A testbench, Figs 4-5, Table IV).

Reproduces the paper's out-of-context evaluation: the DMAC's two AXI manager
ports share a latency-configurable memory system through a fair arbiter
(Fig 3); we measure *steady-state* bus utilization (useful payload beats /
cycles at the backend manager interface) and the Table-IV latency probes.

Memory model
------------
* 64-bit data bus (8 B/beat), matching the CVA6 target system.
* One-way request latency ``L`` cycles; responses stream 1 beat/cycle on a
  shared return bus, FCFS in issue order (the fair RR arbiter's long-run
  behaviour).
* A fetch issued at ``t`` with ``b`` beats occupies the return bus during
  ``[max(t + 2L + PIPE, bus_free), +b)`` — request path L, response path L,
  plus ``PIPE`` = 2 fixed pipeline stages. This reproduces Table IV exactly
  for our DMAC: descriptor round trip ``rf-rb = 2L + 2 + 4 beats = 2L + 6``
  -> 8 / 32 / 206 cycles at L = 1 / 13 / 100.

Our frontend (§II-A/C)
----------------------
* Descriptor fetch = 4 beats (32 B @ 64-bit). The ``next`` field occupies
  bytes 8..16, i.e. it arrives with response *beat 2*, so a serialized
  next-fetch can issue two beats before the descriptor completes.
* Without prefetching, the next in-chain fetch waits for the ``next`` field —
  the serialization the paper attacks (period ``2L + 4`` at 64-bit).
* With ``prefetch`` = S, up to S speculative fetches at sequential addresses
  are outstanding; hits pipeline the descriptor stream, a miss re-issues from
  the true address in the same cycle ``next`` arrives (zero added latency,
  §II-C) while already-issued speculative fetches still burn return-bus
  beats — the paper's "minimal additional contention".
* ``in_flight`` = D caps descriptors fetched-but-not-retired.

LogiCORE model (behavioural, calibrated to the paper's measurements)
--------------------------------------------------------------------
32-bit descriptor port -> 8 word-beats per (partial, 416-bit) descriptor
read + 12 cycles descriptor processing (Table IV rf-rb = 2L + 22:
we produce 24/48/222 vs published 22/48/222) + 6 cycles launch/status
overhead, with descriptor handling serialized against transfer launch and a
single outstanding payload burst. This lands the published 2.5x utilization
gap at 64 B in ideal memory exactly; remaining headline ratios come out
within ~15 % (EXPERIMENTS.md reports measured vs published side by side).
"""
from __future__ import annotations

import dataclasses
import numbers
import warnings
from collections import deque
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

import numpy as np

if TYPE_CHECKING:  # layering: core never imports mmu at module load
    from repro.mmu.iotlb import IOTLBParams

from .speculation import (
    DEFAULT_DEPTH,
    DEPTH_WINDOW,
    AdaptiveDepth,
    FixedDepth,
    PolicyLike,
    as_policy,
)

BUS_BYTES = 8          # 64-bit data bus
PIPE = 2               # fixed request+response pipeline stages
DESC_BYTES = 32        # our 256-bit descriptor
OURS_DESC_BEATS = DESC_BYTES // BUS_BYTES   # 4 beats
NEXT_FIELD_BEAT = 2    # `next` (bytes 8..16) arrives with beat 2 of 4
LC_DESC_BEATS = 8      # LogiCORE reads 8x32-bit words over its 32-bit port
LC_PROC = 10           # LogiCORE descriptor processing (fits Table IV rf-rb +-2)
LC_LAUNCH = 6          # LogiCORE launch/status overhead per transfer
OURS_I_RF = 3          # Table IV: CPU CSR write -> first read request
LC_I_RF = 10
R_W = 1                # read->write latency inside the backend (both DMACs)


def ideal_utilization(n_bytes: int) -> float:
    """Eq. (1): every n-byte payload costs one 32 B descriptor of bus traffic."""
    return n_bytes / (n_bytes + DESC_BYTES)


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Compile-time parameters (paper Table I).

    ``prefetch`` names the frontend's speculation *policy*: either the
    legacy integer slot count (coerced to
    :class:`repro.core.speculation.FixedDepth`, bit-for-bit identical) or
    any :class:`repro.core.speculation.SpeculationPolicy`. The simulator
    instantiates a fresh controller per run and — for adaptive policies —
    re-evaluates the depth every
    :data:`repro.core.speculation.DEPTH_WINDOW` committed descriptors from
    its *own* measured hit rate (the frontend is the measurer; the policy
    is the decider).
    """

    name: str
    in_flight: int = 4
    prefetch: PolicyLike = FixedDepth(0)  # speculation policy (depth API)
    logicore: bool = False     # behavioural LogiCORE IP DMA model
    translated: bool = False   # chain pre-lowered by the translation cache
    # MMU-aware mode (DESIGN.md §11): when set, payload launches must
    # translate their page through an engine-side IOTLB — walk stalls on
    # misses, translation prefetches riding the speculative descriptor
    # stream. ``None`` (default) is bit-for-bit the pre-MMU simulator.
    iotlb: Optional["IOTLBParams"] = None

    def __post_init__(self):
        # The speculation-policy layer is the single depth API: a bare int
        # still works for one release (coerced through FixedDepth, which
        # as_policy makes bit-for-bit identical) but warns.
        if isinstance(self.prefetch, numbers.Integral):
            warnings.warn(
                "SimConfig.prefetch as a bare int is deprecated; pass a "
                "speculation policy (repro.core.speculation.FixedDepth(n))."
                " The int form is removed one release after 0.4.",
                DeprecationWarning, stacklevel=3)
            object.__setattr__(self, "prefetch",
                               FixedDepth(int(self.prefetch)))

    @staticmethod
    def base() -> "SimConfig":
        return SimConfig("base", in_flight=4, prefetch=FixedDepth(0))

    @staticmethod
    def translated_frontend() -> "SimConfig":
        """Frontend driven by a cached lowered chain (DESIGN.md §7).

        The compiled artifact already knows every descriptor address, so
        fetches issue back-to-back (1/cycle) with no ``next``-field wait —
        the software analogue of removing §II-A's serialization entirely.
        Payloads still pay full descriptor traffic and bus contention.
        """
        return SimConfig("translated", in_flight=4, prefetch=FixedDepth(0),
                         translated=True)

    @staticmethod
    def speculation() -> "SimConfig":
        return SimConfig("speculation", in_flight=4,
                         prefetch=FixedDepth(DEFAULT_DEPTH))

    @staticmethod
    def scaled() -> "SimConfig":
        return SimConfig("scaled", in_flight=24, prefetch=FixedDepth(24))

    @staticmethod
    def adaptive(policy: Optional[AdaptiveDepth] = None) -> "SimConfig":
        p = policy or AdaptiveDepth()
        return SimConfig("adaptive", in_flight=p.max_depth, prefetch=p)

    @staticmethod
    def fixed(depth: int = DEFAULT_DEPTH) -> "SimConfig":
        """Fixed-depth frontend via the policy layer (== speculation())."""
        return SimConfig(f"fixed{depth}", in_flight=4,
                         prefetch=FixedDepth(depth))

    @staticmethod
    def logicore_ip() -> "SimConfig":
        return SimConfig("LogiCORE", in_flight=4, prefetch=FixedDepth(0),
                         logicore=True)


# Memory-system configurations of §III-A.
MEMORY_CONFIGS: Dict[str, int] = {
    "ideal": 1,        # SRAM-like
    "ddr3": 13,        # Genesys-2 DDR3
    "ultra_deep": 100, # large NoC
}


@dataclasses.dataclass
class SimResult:
    config: str
    mem_latency: int
    transfer_bytes: int
    hit_rate: float
    utilization: float
    ideal: float
    cycles: int
    payload_beats: int
    desc_beats: int
    wasted_beats: int      # discarded speculative descriptor traffic
    rf_rb: float           # descriptor-fetch round trip (Table IV)
    i_rf: int
    r_w: int
    # Speculation-policy trajectory (constant for FixedDepth frontends).
    final_depth: int = 0
    mean_depth: float = 0.0
    # IOTLB metrics (DESIGN.md §11); all zero when SimConfig.iotlb is None.
    tlb_hits: int = 0
    tlb_misses: int = 0
    tlb_hit_rate: float = 0.0
    walk_stall_cycles: float = 0.0


class _Bus:
    """Shared return-data bus: FCFS beat scheduler (grant in issue order)."""

    def __init__(self, latency: int):
        self.lat = latency
        self.free = 0.0

    def fetch(self, t_issue: float, beats: int) -> tuple[float, float]:
        """Schedule a fetch; returns (first_beat_start, last_beat_end)."""
        start = max(t_issue + 2 * self.lat + PIPE, self.free)
        self.free = start + beats
        return start, self.free


def _simulate_ours(
    cfg: SimConfig,
    mem_latency: int,
    transfer_bytes: int,
    num_transfers: int,
    hit_rate: float,
    seed: int,
    payload_ratio: float = 1.0,
) -> SimResult:
    rng = np.random.default_rng(seed)
    bus = _Bus(mem_latency)
    payload_beats_each = max(1, int(transfer_bytes * payload_ratio) // BUS_BYTES)
    spec = as_policy(cfg.prefetch).make_controller()
    cur_depth = spec.depth
    spec_on = spec.enabled
    depth_sum, depth_n = cur_depth, 1    # trajectory stats (per window)
    window_hits = window_n = 0           # the frontend's own measurement

    # MMU-aware mode (DESIGN.md §11): payload launches translate their
    # page through the IOTLB; translation prefetches ride the speculative
    # descriptor stream under their own lookahead policy. One page per
    # descriptor (the paged-KV shape: page == transfer unit).
    tlb = tlb_ctrl = None
    tlb_depth = 0
    tlb_window_h = tlb_window_n = 0
    pages = None
    far_page = 2 * num_transfers         # spec-miss jump target stream
    pred_next_page = 1                   # chain-lookahead prediction anchor
    if cfg.iotlb is not None:
        from repro.mmu.iotlb import IOTLB
        tlb = IOTLB(cfg.iotlb, mem_latency=mem_latency)
        tlb_ctrl = as_policy(cfg.iotlb.prefetch).make_controller()
        tlb_depth = tlb_ctrl.depth
        pages = np.zeros(num_transfers, np.int64)

    next_known = np.zeros(num_transfers)   # cycle `next` field arrives
    desc_end = np.zeros(num_transfers)     # cycle descriptor fully arrived
    payload_end = np.zeros(num_transfers)
    desc_beats_total = 0
    wasted_beats = 0
    rf_rb_first = None

    # Outstanding speculative fetches for positions > last committed:
    # deque of (pos, issue, next_known, data_end).
    spec_queue: deque = deque()
    last_spec_issue = 0.0
    last_spec_pos = 0

    def issue_desc(pos: int, t_issue: float):
        nonlocal desc_beats_total, rf_rb_first
        start, end = bus.fetch(t_issue, OURS_DESC_BEATS)
        desc_beats_total += OURS_DESC_BEATS
        if rf_rb_first is None:
            rf_rb_first = end - t_issue
        return start + NEXT_FIELD_BEAT, end

    def top_up_spec(now: float, committed: int):
        """Issue speculative fetches at sequential addresses.

        Speculation keys off the *last issued* address (§II-C: requests go
        out "with sequential addresses" as soon as a slot is available), so
        the issue time follows the previous issue, not data arrival.
        """
        nonlocal last_spec_issue, last_spec_pos, pred_next_page
        while (len(spec_queue) < cur_depth
               and last_spec_pos + 1 < num_transfers
               and (last_spec_pos + 1) - committed <= cfg.in_flight):
            pos = last_spec_pos + 1
            t_issue = max(last_spec_issue + 1, now)
            if tlb is not None and len(spec_queue) < tlb_depth:
                # Chain-lookahead translation prefetch (arXiv 1808.09751):
                # the speculative fetch's predicted sequential page starts
                # its walk the cycle the fetch issues.
                tlb.prefetch(pred_next_page, t_issue)
            pred_next_page += 1
            nk, end = issue_desc(pos, t_issue)
            spec_queue.append((pos, t_issue, nk, end))
            last_spec_issue, last_spec_pos = t_issue, pos

    def launch_payload(idx: int):
        """Payload launch for committed descriptor ``idx``: in MMU mode
        the launch first translates its page; a miss stalls the walk."""
        nonlocal tlb_window_h, tlb_window_n, tlb_depth
        t_launch = desc_end[idx] + 1
        if tlb is not None:
            before = tlb.hits
            t_launch += tlb.access(int(pages[idx]), t_launch)
            tlb_window_n += 1
            tlb_window_h += int(tlb.hits > before)
            if tlb_window_n >= DEPTH_WINDOW:
                tlb_depth = tlb_ctrl.observe(tlb_window_h / tlb_window_n)
                tlb_window_h = tlb_window_n = 0
        _, payload_end[idx] = bus.fetch(t_launch, payload_beats_each)

    # Descriptor 0: its address came from the CSR write (always known) —
    # in MMU mode its translation walk starts just as early.
    if tlb is not None and tlb_depth > 0:
        tlb.prefetch(0, 0.0)
    nk, end = issue_desc(0, 0.0)
    next_known[0], desc_end[0] = nk, end
    if spec_on:
        last_spec_issue, last_spec_pos = 0.0, 0
        top_up_spec(1.0, committed=1)

    for k in range(1, num_transfers):
        # NOTE on call order: the shared bus grants FCFS by issue time, and
        # bursts are granted in *call* order here, so within an iteration we
        # schedule in nondecreasing issue order: (re-)fetch of descriptor k
        # (issue = next_known[k-1]) and its speculative successors
        # (issue+1, ...) strictly precede the payload launch for k-1
        # (issue = desc_end[k-1] + 1 = next_known[k-1] + 3).
        speculated = spec_on and bool(spec_queue)
        hit = bool(speculated and rng.random() < hit_rate)
        if speculated:
            # The frontend measures its own §II-C hit rate: one observation
            # per chain boundary where speculation was actually in flight.
            window_n += 1
            window_hits += int(hit)
        if hit:
            pos, t_issue, nk, end = spec_queue.popleft()
            assert pos == k
            if pages is not None:
                pages[k] = pages[k - 1] + 1   # sequential: prediction held
            next_known[k] = max(nk, next_known[k - 1])
            desc_end[k] = max(end, next_known[k - 1])
            launch_payload(k - 1)
            # Commit frees a speculation slot.
            top_up_spec(next_known[k], committed=k + 1)
        else:
            if speculated:
                # Mispredict: discard outstanding speculative data (its bus
                # beats were already consumed = pure contention), re-issue
                # the true fetch in the same cycle `next` arrived.
                wasted_beats += OURS_DESC_BEATS * len(spec_queue)
                spec_queue.clear()
            if pages is not None:
                if speculated:
                    # The chain jumped: the true target is a far page the
                    # lookahead never walked (prefetched predictions were
                    # wasted walker work, like wasted descriptor beats).
                    pages[k] = far_page
                    far_page += num_transfers
                else:
                    pages[k] = pages[k - 1] + 1
                pred_next_page = pages[k] + 1
            t_issue = next_known[k - 1]
            nk, end = issue_desc(k, t_issue)
            next_known[k], desc_end[k] = nk, end
            if spec_on:
                # Speculation restarts from the re-fetched address.
                last_spec_issue, last_spec_pos = t_issue, k
                top_up_spec(t_issue + 1, committed=k)
            launch_payload(k - 1)
        if window_n >= DEPTH_WINDOW:
            # Chain boundary: the measured window feeds the policy. A new
            # depth only affects future top-ups — fetches already
            # outstanding drain under the depth that issued them.
            cur_depth = spec.observe(window_hits / window_n)
            depth_sum += cur_depth
            depth_n += 1
            window_hits = window_n = 0

    launch_payload(num_transfers - 1)

    lo, hi = num_transfers // 4, 3 * num_transfers // 4
    window_cycles = payload_end[hi] - payload_end[lo]
    util = (hi - lo) * payload_beats_each / max(window_cycles, 1e-9)

    return SimResult(
        config=cfg.name, mem_latency=mem_latency,
        transfer_bytes=transfer_bytes, hit_rate=hit_rate,
        utilization=float(min(util, ideal_utilization(transfer_bytes))),
        ideal=ideal_utilization(transfer_bytes),
        cycles=int(payload_end[-1]),
        payload_beats=num_transfers * payload_beats_each,
        desc_beats=desc_beats_total, wasted_beats=int(wasted_beats),
        # Table IV probes single-transfer latency: the uncongested first fetch.
        rf_rb=float(rf_rb_first), i_rf=OURS_I_RF, r_w=R_W,
        final_depth=cur_depth, mean_depth=depth_sum / depth_n,
        tlb_hits=tlb.hits if tlb is not None else 0,
        tlb_misses=tlb.misses if tlb is not None else 0,
        tlb_hit_rate=tlb.hit_rate if tlb is not None else 0.0,
        walk_stall_cycles=float(tlb.walk_stall_cycles)
        if tlb is not None else 0.0,
    )


def _simulate_translated(
    cfg: SimConfig, mem_latency: int, transfer_bytes: int, num_transfers: int,
    payload_ratio: float = 1.0,
) -> SimResult:
    """Launch model for a cached lowered chain.

    Every descriptor address is embedded in the compiled artifact, so the
    frontend issues fetches back-to-back at 1/cycle instead of waiting
    ``2L + NEXT_FIELD_BEAT`` for each ``next`` pointer; each payload
    launches one cycle after its descriptor data lands. All traffic still
    shares the FCFS return bus (grant in *issue-time* order, via a heap —
    descriptor k+1's early issue rightly outranks payload k's later one),
    so the steady-state floor is the pure bus occupancy of
    ``4 + payload`` beats per transfer. Deterministic: no speculation, no
    randomness.
    """
    import heapq

    bus = _Bus(mem_latency)
    payload_beats_each = max(1, int(transfer_bytes * payload_ratio) // BUS_BYTES)
    desc_end = np.zeros(num_transfers)
    payload_end = np.zeros(num_transfers)
    rf_rb_first = None

    events: List[Tuple[float, int, int, int]] = []  # (issue, seq, kind, idx)
    seq = 0
    for k in range(num_transfers):       # kind 0 = descriptor fetch
        heapq.heappush(events, (float(k), seq, 0, k))
        seq += 1
    while events:
        t_issue, _, kind, idx = heapq.heappop(events)
        if kind == 0:
            _, end = bus.fetch(t_issue, OURS_DESC_BEATS)
            desc_end[idx] = end
            if rf_rb_first is None:
                rf_rb_first = end - t_issue
            heapq.heappush(events, (end + 1, seq, 1, idx))
            seq += 1
        else:
            _, payload_end[idx] = bus.fetch(t_issue, payload_beats_each)

    lo, hi = num_transfers // 4, 3 * num_transfers // 4
    window_cycles = payload_end[hi] - payload_end[lo]
    util = (hi - lo) * payload_beats_each / max(window_cycles, 1e-9)
    return SimResult(
        config=cfg.name, mem_latency=mem_latency,
        transfer_bytes=transfer_bytes, hit_rate=1.0,
        utilization=float(min(util, ideal_utilization(transfer_bytes))),
        ideal=ideal_utilization(transfer_bytes),
        cycles=int(payload_end[-1]),
        payload_beats=num_transfers * payload_beats_each,
        desc_beats=num_transfers * OURS_DESC_BEATS, wasted_beats=0,
        rf_rb=float(rf_rb_first), i_rf=OURS_I_RF, r_w=R_W,
    )


def _simulate_logicore(
    cfg: SimConfig, mem_latency: int, transfer_bytes: int, num_transfers: int,
    seed: int, payload_ratio: float = 1.0,
) -> SimResult:
    """Serialized descriptor engine; see module docstring for calibration."""
    bus = _Bus(mem_latency)
    payload_beats_each = max(1, int(transfer_bytes * payload_ratio) // BUS_BYTES)
    rf_rb = 2 * mem_latency + PIPE + LC_DESC_BEATS + LC_PROC
    payload_ends = np.zeros(num_transfers)
    desc_beats_total = 0
    t = 0.0
    prev_payload_end = 0.0
    for i in range(num_transfers):
        _, fetch_end = bus.fetch(t, LC_DESC_BEATS)
        desc_beats_total += LC_DESC_BEATS
        proc_done = fetch_end + LC_PROC
        # Single outstanding payload burst; next descriptor fetch overlaps the
        # payload data return but not processing/launch.
        payload_issue = max(proc_done + 1, prev_payload_end)
        _, prev_payload_end = bus.fetch(payload_issue, payload_beats_each)
        payload_ends[i] = prev_payload_end
        t = proc_done + LC_LAUNCH
    lo, hi = num_transfers // 4, 3 * num_transfers // 4
    window = payload_ends[hi] - payload_ends[lo]
    util = (hi - lo) * payload_beats_each / max(window, 1e-9)
    return SimResult(
        config=cfg.name, mem_latency=mem_latency,
        transfer_bytes=transfer_bytes, hit_rate=1.0,
        utilization=float(util), ideal=ideal_utilization(transfer_bytes),
        cycles=int(payload_ends[-1]),
        payload_beats=num_transfers * payload_beats_each,
        desc_beats=desc_beats_total, wasted_beats=0,
        rf_rb=float(rf_rb), i_rf=LC_I_RF, r_w=R_W,
    )


def simulate(
    cfg: SimConfig,
    mem_latency: int,
    transfer_bytes: int,
    *,
    num_transfers: int = 2000,
    hit_rate: float = 1.0,
    seed: int = 0,
    payload_ratio: float = 1.0,
) -> SimResult:
    """Steady-state bus utilization of one (config, memory, size) point.

    ``payload_ratio`` models an in-flight transform in the datapath: the
    frontend still walks ``transfer_bytes`` of logical payload per
    descriptor, but only ``transfer_bytes * payload_ratio`` bytes cross
    the return bus (e.g. ~0.254 for EF-int8 KV quantization). Descriptor
    traffic is unchanged — transforms act on payload beats only.
    """
    if transfer_bytes % BUS_BYTES:
        raise ValueError("paper evaluates bus-aligned transfer sizes")
    if not 0.0 < payload_ratio <= 1.0:
        raise ValueError("payload_ratio must be in (0, 1]")
    if cfg.logicore:
        return _simulate_logicore(cfg, mem_latency, transfer_bytes,
                                  num_transfers, seed, payload_ratio)
    if cfg.translated:
        return _simulate_translated(cfg, mem_latency, transfer_bytes,
                                    num_transfers, payload_ratio)
    return _simulate_ours(cfg, mem_latency, transfer_bytes, num_transfers,
                          hit_rate, seed, payload_ratio)


def utilization_sweep(
    cfg: SimConfig,
    mem_latency: int,
    sizes: Optional[List[int]] = None,
    hit_rate: float = 1.0,
) -> List[SimResult]:
    """One curve of Fig 4 (or Fig 5 at a given hit rate)."""
    sizes = sizes or [32, 64, 128, 256, 512, 1024, 2048, 4096]
    return [simulate(cfg, mem_latency, s, hit_rate=hit_rate) for s in sizes]


# ---------------------------------------------------------------------------
# Multi-channel mode (runtime layer): N frontends sharing the bus
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ChannelSimResult:
    channel: str
    weight: int
    transfers: int
    payload_beats: int
    desc_beats: int
    utilization: float     # this channel's payload beats / shared-bus cycles
    mean_launch_gap: float # cycles between consecutive launches on channel
    shard: int = 0         # frontend group (0 for the unsharded model)


@dataclasses.dataclass
class ShardedBusResult:
    """Cross-shard contention summary of a sharded multichannel run.

    The per-shard local buses model a shard's own memory system; the
    shared interconnect carries cross-shard page-migration payloads plus
    one §II-D writeback beat per hop (the control-channel completion
    riding along). ``migration_cycles_mean`` is the added cycles a
    migrated transfer spends between finishing on its local bus and its
    hop (payload + writeback) clearing the interconnect.
    """

    num_shards: int
    per_shard_utilization: List[float]
    mean_shard_utilization: float
    cross_transfers: int
    cross_fraction: float
    interconnect_latency: int
    migration_cycles_mean: float
    interconnect_busy_beats: int
    # Contended mode (per-directed-link buses) additions; the shared-bus
    # default leaves num_links at 0 and keeps its original numbers.
    interconnect_mode: str = "shared"
    migration_cycles_p99: float = 0.0
    num_links: int = 0
    link_busy_beats_max: int = 0


@dataclasses.dataclass
class MultiChannelResult:
    mem_latency: int
    transfer_bytes: int
    aggregate_utilization: float
    ideal: float
    cycles: int
    channels: List[ChannelSimResult]
    sharded: Optional[ShardedBusResult] = None


def _multichannel_pass(
    num_channels: int,
    bus: _Bus,
    payload_beats_each: int,
    num_transfers: int,
    weights: List[int],
):
    """One group of serialized frontends contending on one shared bus.

    Returns per-channel launch times, payload end times, and beat counts;
    callers build steady-state windows (and, for sharded runs, feed the
    payload ends into the interconnect phase).
    """
    # Backlogged-channel model: offered load tracks weight, so every channel
    # stays busy across the whole measurement window and the reported
    # shares reflect arbitration, not early completion.
    remaining = np.asarray([num_transfers * w for w in weights])
    launches: List[List[float]] = [[] for _ in range(num_channels)]
    ends: List[List[float]] = [[] for _ in range(num_channels)]
    desc_beats = np.zeros(num_channels, np.int64)
    payload_beats = np.zeros(num_channels, np.int64)
    credit = np.zeros(num_channels)
    last_end = 0.0

    # Event-driven: (issue_time, seq, channel, kind). The bus is granted in
    # issue order; requests already issued when the bus frees contend, and
    # the smooth-WRR credits pick the winner (equal weights == fair RR).
    import heapq
    pend: List[tuple] = []
    seq = 0
    for c in range(num_channels):
        heapq.heappush(pend, (0.0, seq, c, "desc")); seq += 1

    while pend:
        horizon = max(bus.free, pend[0][0])
        batch = []
        while pend and pend[0][0] <= horizon:
            batch.append(heapq.heappop(pend))
        credit += weights
        batch.sort(key=lambda e: (-credit[e[2]], e[0], e[1]))
        t_issue, sq, c, kind = batch[0]
        for e in batch[1:]:
            heapq.heappush(pend, e)
        credit[c] -= sum(weights)

        if kind == "desc":
            start, end = bus.fetch(t_issue, OURS_DESC_BEATS)
            desc_beats[c] += OURS_DESC_BEATS
            heapq.heappush(pend, (end + 1, seq, c, "payload")); seq += 1
            remaining[c] -= 1
            if remaining[c] > 0:
                # §II-A serialization: the next in-chain fetch may only
                # issue once this descriptor's `next` field has arrived.
                heapq.heappush(
                    pend, (start + NEXT_FIELD_BEAT, seq, c, "desc")); seq += 1
        else:
            _, p_end = bus.fetch(t_issue, payload_beats_each)
            payload_beats[c] += payload_beats_each
            launches[c].append(t_issue)
            ends[c].append(p_end)
            last_end = max(last_end, p_end)

    return launches, ends, desc_beats, payload_beats, last_end


def _channel_results(
    launches: List[List[float]],
    desc_beats: np.ndarray,
    payload_beats: np.ndarray,
    payload_beats_each: int,
    num_transfers: int,
    weights: List[int],
    shard_of: List[int],
) -> Tuple[List[ChannelSimResult], float]:
    """Per-channel utilization over the middle half of the global launches."""
    all_launch = np.sort(np.concatenate([np.asarray(l) for l in launches]))
    lo, hi = all_launch[len(all_launch) // 4], all_launch[3 * len(all_launch) // 4]
    window = max(hi - lo, 1e-9)
    chans = []
    for c in range(len(launches)):
        l = np.asarray(launches[c])
        in_win = ((l >= lo) & (l < hi)).sum()
        gaps = np.diff(l)
        chans.append(ChannelSimResult(
            channel=f"ch{c}", weight=weights[c],
            transfers=num_transfers * weights[c],
            payload_beats=int(payload_beats[c]),
            desc_beats=int(desc_beats[c]),
            utilization=float(in_win * payload_beats_each / window),
            mean_launch_gap=float(gaps.mean()) if len(gaps) else 0.0,
            shard=shard_of[c],
        ))
    return chans, window


def _trace_channels(tracer, track_prefix: str, launches, ends,
                    shard_of: List[int]) -> None:
    """Emit one cycle-clock payload span per simulated transfer.

    Simulated cycles are their own clock domain (``clock="cycle"``): the
    exporter renders them on separate tracks at 1 cycle == 1 µs, so a
    sweep cell's bus behaviour loads in Perfetto next to (not interleaved
    with) wall-clock runtime spans (DESIGN.md §8).
    """
    for c, (l, e) in enumerate(zip(launches, ends)):
        track = f"{track_prefix}shard{shard_of[c]}/ch{c}" \
            if len(set(shard_of)) > 1 else f"{track_prefix}ch{c}"
        for i, (t0, t1) in enumerate(zip(l, e)):
            tracer.complete("payload", track, float(t0), float(t1 - t0),
                            clock="cycle", transfer=i)


def simulate_multichannel(
    num_channels: int,
    mem_latency: int,
    transfer_bytes: int,
    *,
    num_transfers: int = 500,
    weights: Optional[List[int]] = None,
    arbitration: str = "weighted_rr",
    shard_of: Optional[List[int]] = None,
    cross_fraction: float = 0.0,
    interconnect_latency: Optional[int] = None,
    interconnect_mode: str = "shared",
    seed: int = 0,
    tracer=None,
    trace_track_prefix: str = "sim/",
) -> MultiChannelResult:
    """N serialized frontends (base config) interleaved on shared buses.

    Each channel alone suffers the §II-A descriptor serialization (its next
    fetch waits for the previous ``next`` field); the multi-channel runtime
    hides that latency with *inter-channel* parallelism: while channel A
    waits on its round trip, B..N own the bus. The arbiter is the smooth
    weighted round-robin used by :class:`repro.runtime.WeightedArbiter`
    (all-equal weights == fair RR, the paper's §III-A arbiter).

    **Per-shard frontend grouping** (sharded serving, DESIGN.md §6): with
    ``shard_of`` (one group id per channel), each shard's channels contend
    on their *own* local bus, and a deterministic ``cross_fraction`` of
    every shard's transfers are cross-shard migrations: after finishing on
    the local bus they traverse one shared interconnect
    (``interconnect_latency``, default ``4 * mem_latency`` — the slow
    fabric between shards) carrying the payload plus one per-hop §II-D
    writeback beat. ``shard_of=None`` is the original single-bus model,
    bit-for-bit.

    ``interconnect_mode`` picks the fabric model: ``"shared"`` (default,
    bit-for-bit the original) serializes every hop through one bus;
    ``"contended"`` gives each *directed* (src, dst) shard pair its own
    link — hops only queue behind traffic on their own link, each hop's
    destination drawn deterministically from the same per-channel rng
    stream — and reports the per-hop stall tail
    (``migration_cycles_p99``) the async fabric is gated against.
    """
    if interconnect_mode not in ("shared", "contended"):
        raise ValueError(
            f"interconnect_mode must be 'shared' or 'contended', "
            f"got {interconnect_mode!r}")
    if transfer_bytes % BUS_BYTES:
        raise ValueError("paper evaluates bus-aligned transfer sizes")
    if num_channels < 1:
        raise ValueError("need >= 1 channel")
    weights = list(weights) if weights else [1] * num_channels
    if len(weights) != num_channels:
        raise ValueError("one weight per channel")
    del arbitration  # single policy today; named for config clarity
    payload_beats_each = max(1, transfer_bytes // BUS_BYTES)
    ideal = ideal_utilization(transfer_bytes)

    if shard_of is None:
        if cross_fraction:
            raise ValueError("cross_fraction requires shard_of grouping")
        bus = _Bus(mem_latency)
        launches, ends, desc_beats, payload_beats, last_end = \
            _multichannel_pass(num_channels, bus, payload_beats_each,
                               num_transfers, weights)
        if tracer is not None:
            _trace_channels(tracer, trace_track_prefix, launches, ends,
                            [0] * num_channels)
        chans, _ = _channel_results(
            launches, desc_beats, payload_beats, payload_beats_each,
            num_transfers, weights, [0] * num_channels)
        agg = float(sum(ch.utilization for ch in chans))
        return MultiChannelResult(
            mem_latency=mem_latency, transfer_bytes=transfer_bytes,
            aggregate_utilization=min(agg, ideal), ideal=ideal,
            cycles=int(last_end), channels=chans)

    # -- sharded grouping ---------------------------------------------------
    if len(shard_of) != num_channels:
        raise ValueError("one shard id per channel")
    if not 0.0 <= cross_fraction <= 1.0:
        raise ValueError("cross_fraction must be in [0, 1]")
    shards = sorted(set(shard_of))
    if interconnect_latency is None:
        interconnect_latency = 4 * mem_latency

    launches = [None] * num_channels
    ends = [None] * num_channels
    desc_beats = np.zeros(num_channels, np.int64)
    payload_beats = np.zeros(num_channels, np.int64)
    last_end = 0.0
    for s in shards:
        members = [c for c in range(num_channels) if shard_of[c] == s]
        bus = _Bus(mem_latency)
        l, e, db, pb, le = _multichannel_pass(
            len(members), bus, payload_beats_each, num_transfers,
            [weights[c] for c in members])
        for k, c in enumerate(members):
            launches[c], ends[c] = l[k], e[k]
            desc_beats[c], payload_beats[c] = db[k], pb[k]
        last_end = max(last_end, le)

    if tracer is not None:
        _trace_channels(tracer, trace_track_prefix, launches, ends,
                        list(shard_of))

    chans, window = _channel_results(
        launches, desc_beats, payload_beats, payload_beats_each,
        num_transfers, weights, list(shard_of))
    per_shard = [
        float(sum(ch.utilization for ch in chans if ch.shard == s))
        for s in shards]

    # Interconnect phase: a deterministic subset of each channel's
    # transfers migrate to a remote shard. Hops are granted FCFS in
    # local-completion order; each occupies the interconnect for the
    # payload plus the per-hop completion writeback beat.
    hop_beats = payload_beats_each + 1   # payload + §II-D writeback beat
    added: List[float] = []
    num_links = 0
    link_busy_max = 0
    if interconnect_mode == "shared":
        hop_times: List[float] = []
        if len(shards) > 1 and cross_fraction > 0.0:
            for c in range(num_channels):
                rng = np.random.default_rng([seed, shard_of[c], c])
                e = np.asarray(ends[c])
                hop_times.extend(
                    e[rng.random(len(e)) < cross_fraction].tolist())
        hop_times.sort()
        ibus = _Bus(interconnect_latency)
        for t in hop_times:
            _, hop_end = ibus.fetch(t + 1, hop_beats)
            added.append(hop_end - t)
            last_end = max(last_end, hop_end)
            if tracer is not None:
                tracer.complete("migration.hop",
                                f"{trace_track_prefix}interconnect",
                                float(t), float(hop_end - t), clock="cycle",
                                beats=hop_beats)
        n_hops = len(hop_times)
    else:
        # Contended fabric: one bus per *directed* (src, dst) pair, so a
        # hop only stalls behind earlier traffic on its own link. The
        # selection draws are identical to shared mode (same rng
        # prefix); the destination draw comes after, so flipping the
        # mode never changes *which* transfers migrate.
        hops: List[Tuple[float, int, int]] = []
        if len(shards) > 1 and cross_fraction > 0.0:
            for c in range(num_channels):
                rng = np.random.default_rng([seed, shard_of[c], c])
                e = np.asarray(ends[c])
                sel = rng.random(len(e)) < cross_fraction
                remotes = [s for s in shards if s != shard_of[c]]
                dst_idx = rng.integers(0, len(remotes), int(sel.sum()))
                hops.extend(
                    (float(t), shard_of[c], remotes[int(d)])
                    for t, d in zip(e[sel], dst_idx))
        hops.sort()
        links: Dict[Tuple[int, int], _Bus] = {}
        busy: Dict[Tuple[int, int], int] = {}
        for t, s, d in hops:
            ln = links.get((s, d))
            if ln is None:
                ln = links[(s, d)] = _Bus(interconnect_latency)
            _, hop_end = ln.fetch(t + 1, hop_beats)
            busy[(s, d)] = busy.get((s, d), 0) + hop_beats
            added.append(hop_end - t)
            last_end = max(last_end, hop_end)
            if tracer is not None:
                tracer.complete(
                    "migration.hop",
                    f"{trace_track_prefix}interconnect/link{s}-{d}",
                    float(t), float(hop_end - t), clock="cycle",
                    beats=hop_beats, src=s, dst=d)
        n_hops = len(hops)
        num_links = len(links)
        link_busy_max = max(busy.values(), default=0)
    sharded = ShardedBusResult(
        num_shards=len(shards),
        per_shard_utilization=per_shard,
        mean_shard_utilization=float(np.mean(per_shard)),
        cross_transfers=n_hops,
        cross_fraction=cross_fraction,
        interconnect_latency=interconnect_latency,
        migration_cycles_mean=float(np.mean(added)) if added else 0.0,
        interconnect_busy_beats=n_hops * hop_beats,
        interconnect_mode=interconnect_mode,
        migration_cycles_p99=float(np.percentile(added, 99))
        if added else 0.0,
        num_links=num_links,
        link_busy_beats_max=link_busy_max,
    )
    agg = float(sum(per_shard))
    return MultiChannelResult(
        mem_latency=mem_latency, transfer_bytes=transfer_bytes,
        # Shard-local buses scale the aggregate past one bus's Eq.-1
        # ideal; cap at the mesh-wide ideal instead (S local buses).
        aggregate_utilization=min(agg, ideal * len(shards)), ideal=ideal,
        cycles=int(last_end), channels=chans, sharded=sharded)


def simulate_sharded(
    num_shards: int,
    channels_per_shard: int,
    mem_latency: int,
    transfer_bytes: int,
    *,
    num_transfers: int = 500,
    cross_fraction: float = 0.0,
    interconnect_latency: Optional[int] = None,
    interconnect_mode: str = "shared",
    seed: int = 0,
    tracer=None,
) -> MultiChannelResult:
    """S shard groups of N frontends each: the sharded runtime's bus model."""
    if num_shards < 1:
        raise ValueError("need >= 1 shard")
    shard_of = [s for s in range(num_shards)
                for _ in range(channels_per_shard)]
    return simulate_multichannel(
        num_shards * channels_per_shard, mem_latency, transfer_bytes,
        num_transfers=num_transfers, shard_of=shard_of,
        cross_fraction=cross_fraction if num_shards > 1 else 0.0,
        interconnect_latency=interconnect_latency,
        interconnect_mode=interconnect_mode, seed=seed,
        tracer=tracer)


def table_iv(mem_latencies=(1, 13, 100)) -> Dict[str, Dict]:
    """Latency probes (Table IV): i-rf, rf-rb per memory latency, r-w."""
    ours, lc = {}, {}
    for L in mem_latencies:
        r_o = simulate(SimConfig.scaled(), L, 64, num_transfers=64)
        r_l = simulate(SimConfig.logicore_ip(), L, 64, num_transfers=64)
        ours[L], lc[L] = r_o.rf_rb, r_l.rf_rb
    return {
        "ours": {"i_rf": OURS_I_RF, "rf_rb": ours, "r_w": R_W},
        "logicore": {"i_rf": LC_I_RF, "rf_rb": lc, "r_w": R_W},
        "paper": {
            "ours": {"i_rf": 3, "rf_rb": {1: 8, 13: 32, 100: 206}, "r_w": 1},
            "logicore": {"i_rf": 10, "rf_rb": {1: 22, 13: 48, 100: 222}, "r_w": 1},
        },
    }
