"""Core library: the paper's DMAC as a composable descriptor subsystem."""
from .descriptor import (  # noqa: F401
    DESCRIPTOR_BYTES,
    END_OF_CHAIN,
    DescriptorArray,
    from_bytes,
    from_packed,
    is_done_packed,
    mark_done_packed,
    pack,
    to_bytes,
    to_packed,
)
from .chain import (  # noqa: F401
    concat_chains,
    flatten_chain,
    from_gather,
    from_pages,
    from_scatter,
    from_segments,
    from_strided_2d,
    from_strided_3d,
    plan_sequential_layout,
    walk_chain_host,
)
from .engine import (  # noqa: F401
    execute_blocked,
    execute_blocked_2d,
    execute_chain_host,
    execute_serial,
)
from .simulator import (  # noqa: F401
    MEMORY_CONFIGS,
    SimConfig,
    SimResult,
    ideal_utilization,
    simulate,
    table_iv,
    utilization_sweep,
)
from .transform import (  # noqa: F401
    IDENTITY,
    TransformSpec,
    as_transform,
    kv8_roundtrip,
    kv8_roundtrip_np,
    reference_apply,
    transform_source_view,
)
from .area_model import area_kge, headline_fpga_savings, report  # noqa: F401
from .prefetch import analytical_utilization, estimate_hit_rate  # noqa: F401
from .speculation import (  # noqa: F401
    DEFAULT_DEPTH,
    DEFAULT_POLICY,
    DEPTH_WINDOW,
    AdaptiveDepth,
    DepthController,
    FixedDepth,
    SpeculationPolicy,
    as_policy,
    static_depth,
)
