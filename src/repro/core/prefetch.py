"""Speculative-prefetch policy layer (§II-C) — planning and modelling.

The hardware speculates sequential descriptor addresses. This module hosts
(1) the analytical utilization model used to sanity-check the cycle
simulator, and (2) the *software speculation contract*: given an allocator
that owns descriptor placement, sequential layout makes speculation perfect
(see :func:`repro.core.chain.plan_sequential_layout`); given an external
layout, :func:`estimate_hit_rate` predicts what the prefetcher will achieve.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .descriptor import DESCRIPTOR_BYTES
from .simulator import BUS_BYTES, PIPE, OURS_DESC_BEATS, ideal_utilization
from .speculation import DEFAULT_DEPTH, PolicyLike, static_depth


@dataclasses.dataclass(frozen=True)
class AnalyticalPoint:
    utilization: float
    bound: str  # "bus" | "descriptor-serialization" | "slot-rate"


def analytical_utilization(
    transfer_bytes: int,
    mem_latency: int,
    *,
    prefetch: PolicyLike = 0,
    in_flight: int = 4,
    hit_rate: float = 1.0,
) -> AnalyticalPoint:
    """Closed-form steady-state utilization (cross-check for the simulator).

    Per transfer the shared bus carries ``4 + n/8`` beats (descriptor +
    payload; Eq. 1). Three candidate period bounds:

    * bus:        ``beats = 4 + n/8`` (+ wasted speculative beats on misses)
    * serialization (no prefetch / miss): descriptor round trip ``2L + 6``
    * slot rate (prefetch on): ``(2L + 6) / min(prefetch, in_flight)``
    """
    # The closed-form model has no feedback path, so a policy contributes
    # its static (initial) depth — the adaptive trajectory lives in the
    # cycle simulator only.
    prefetch = static_depth(prefetch)
    rt = 2 * mem_latency + PIPE + OURS_DESC_BEATS
    payload_beats = transfer_bytes // BUS_BYTES
    bus = OURS_DESC_BEATS + payload_beats
    if prefetch == 0:
        period = max(rt, bus)
        bound = "bus" if bus >= rt else "descriptor-serialization"
    else:
        slots = max(1, min(prefetch, in_flight))
        slot_rate = rt / slots
        miss = 1.0 - hit_rate
        # A miss serializes that boundary and wastes ~E[outstanding] fetches.
        outstanding = min(slots, max(1, round(rt / max(bus, 1))))
        eff_bus = bus + miss * outstanding * OURS_DESC_BEATS
        period = max(hit_rate * slot_rate + miss * rt, eff_bus)
        bound = ("bus" if eff_bus >= hit_rate * slot_rate + miss * rt
                 else "slot-rate" if hit_rate > 0.5 else "descriptor-serialization")
    return AnalyticalPoint(utilization=min(payload_beats / period,
                                           ideal_utilization(transfer_bytes)),
                           bound=bound)


def estimate_hit_rate(descriptor_addrs: np.ndarray) -> float:
    """Hit rate a sequential speculator sees on a chain laid out at ``addrs``.

    ``descriptor_addrs[k]`` is the byte address of the k-th descriptor in
    *chain order*; a hit means addr[k+1] == addr[k] + 32.
    """
    a = np.asarray(descriptor_addrs, np.int64)
    if a.size <= 1:
        return 1.0
    return float(np.mean(a[1:] == a[:-1] + DESCRIPTOR_BYTES))


def speculation_breakeven(mem_latency: int, transfer_bytes: int) -> float:
    """Hit rate above which speculation beats the serialized frontend.

    Speculation never adds latency (§II-C); it only adds contention. The
    breakeven is where wasted descriptor beats outweigh hidden round trips —
    for bus-bound sizes that is h > 0 (always worth it); for
    serialization-bound sizes any h > 0 already helps. Returns 0.0 unless
    the workload is so bus-saturated that waste dominates.
    """
    base = analytical_utilization(transfer_bytes, mem_latency, prefetch=0)
    lo, hi = 0.0, 1.0
    for _ in range(20):
        mid = (lo + hi) / 2
        u = analytical_utilization(transfer_bytes, mem_latency,
                                   prefetch=DEFAULT_DEPTH,
                                   hit_rate=mid).utilization
        if u >= base.utilization:
            hi = mid
        else:
            lo = mid
    return hi
