"""Opaque page handles: the virtual-addressing API boundary (DESIGN.md §11).

Callers that *hold* pages — ``Request.kv_pages``, the sharded pool's
alloc/free/move surfaces, migration planner inputs — hold
:class:`PageRef` handles, not raw physical slot indices. A ``PageRef``
names a *virtual* page id plus the page-table generation it was minted
under; the owning pool's :class:`repro.mmu.PageTable` translates it to a
(shard, physical slot) pair at touch time. Remap-based defragmentation
and ownership-first migration change that translation without invalidating
the handle's identity.

Compatibility bridge (one release, mirroring the PR 8 ``SubmitRequest``
migration): ``PageRef`` subclasses ``int`` so every legacy consumer that
treats a page id as an index keeps working bit-for-bit while call sites
migrate, and :func:`as_pageref` coerces a bare ``int`` argument with a
``DeprecationWarning``. The int-ness is NOT part of the contract — new
code must treat the handle as opaque (``tools/lint_pageref_api.py``
hard-fails new internal bare-int call sites) — and is removed one release
after 0.8.
"""
from __future__ import annotations

import numbers
import warnings
from typing import Iterable, List, Sequence, Union

__all__ = ["PageRef", "PageRefLike", "as_pageref", "as_pagerefs", "vpage"]


class PageRef(int):
    """Opaque handle to one virtual page.

    ``vpage`` is the virtual page id (== the integer value, during the
    compatibility bridge); ``generation`` is the page-table generation the
    handle was minted under — a stale handle still resolves (virtual ids
    are stable across remaps), the generation exists so tooling can tell
    *when* a handle predates a remap.
    """

    # (int subclasses cannot carry nonempty __slots__; the instance dict
    # holds only `generation`.)

    def __new__(cls, vpage: int, generation: int = 0) -> "PageRef":
        self = super().__new__(cls, int(vpage))
        self.generation = int(generation)
        return self

    @property
    def vpage(self) -> int:
        return int(self)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"PageRef({int(self)}, gen={self.generation})"


PageRefLike = Union[PageRef, int]


def _warn_bare_int(api: str) -> None:
    warnings.warn(
        f"{api}: bare int page ids are deprecated; pass PageRef handles "
        "(returned by the pool's alloc/defragment/flip surfaces). The int "
        "form is removed one release after 0.8.",
        DeprecationWarning, stacklevel=4)


def as_pageref(value: PageRefLike, *, api: str = "page API") -> PageRef:
    """Coerce one page argument to a :class:`PageRef`.

    A bare integer (including numpy scalars — legacy plumbing passed
    those) coerces with a one-release ``DeprecationWarning``.
    """
    if isinstance(value, PageRef):
        return value
    if isinstance(value, numbers.Integral):
        _warn_bare_int(api)
        return PageRef(int(value))
    raise TypeError(f"{api}: expected a PageRef or int page id, "
                    f"got {value!r}")


def as_pagerefs(values: Iterable[PageRefLike], *,
                api: str = "page API") -> List[PageRef]:
    """Coerce a page list; one warning covers the whole list."""
    out: List[PageRef] = []
    warned = False
    for v in values:
        if isinstance(v, PageRef):
            out.append(v)
        elif isinstance(v, numbers.Integral):
            if not warned:
                _warn_bare_int(api)
                warned = True
            out.append(PageRef(int(v)))
        else:
            raise TypeError(f"{api}: expected PageRef or int page ids, "
                            f"got {v!r}")
    return out


def vpage(value: PageRefLike) -> int:
    """The virtual page id behind a handle (internal unwrap helper)."""
    return int(value)


def vpages(values: Sequence[PageRefLike]) -> List[int]:
    """Unwrap a handle list to virtual ids (internal helper)."""
    return [int(v) for v in values]
