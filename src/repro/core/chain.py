"""Descriptor chains (§II-B) — builders, walkers, and the TPU-parallel flatten.

The paper constructs "arbitrary and irregular transfers from simple linear
transfers" by chaining descriptors through the ``next`` field. This module
provides:

* builders that express common irregular patterns (strided 2-D/3-D tiles,
  gather/scatter index lists, KV-cache page lists) as descriptor chains;
* a host-side walker (the faithful serial semantics);
* :func:`flatten_chain` — pointer-doubling list ranking in O(log N) JAX steps.
  The RTL frontend walks chains serially at ~1 descriptor / (2L+6) cycles;
  a TPU is a vector machine, so we parallelize the walk instead (beyond-paper
  adaptation recorded in DESIGN.md §2);
* :func:`plan_sequential_layout` — the software speculation guarantee: the
  paper speculates that the *next* descriptor sits at the sequentially next
  address (§II-C). When we own allocation we can *make that true*, so the
  planner lays chains out contiguously and reports the hit rate a hardware
  prefetcher would see.
"""
from __future__ import annotations

import math
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .descriptor import (
    DESCRIPTOR_BYTES,
    END_OF_CHAIN,
    DescriptorArray,
    pack,
)

# ---------------------------------------------------------------------------
# Builders (device SoA form)
# ---------------------------------------------------------------------------

def from_segments(src_offsets, dst_offsets, lengths) -> DescriptorArray:
    """One descriptor per (src, dst, length) linear segment, chained in order."""
    return DescriptorArray.create(src_offsets, dst_offsets, lengths)


def from_strided_2d(
    src_base: int,
    dst_base: int,
    row_len: int,
    num_rows: int,
    src_stride: int,
    dst_stride: int,
) -> DescriptorArray:
    """A 2-D tile copy as a chain of per-row linear descriptors (CubeDMA-style)."""
    rows = np.arange(num_rows, dtype=np.int64)
    return DescriptorArray.create(
        src_base + rows * src_stride,
        dst_base + rows * dst_stride,
        np.full(num_rows, row_len, np.int64),
    )


def from_strided_3d(
    src_base: int,
    dst_base: int,
    row_len: int,
    shape: Tuple[int, int],           # (planes, rows)
    src_strides: Tuple[int, int],     # (plane, row)
    dst_strides: Tuple[int, int],
) -> DescriptorArray:
    planes, rows = shape
    p = np.repeat(np.arange(planes, dtype=np.int64), rows)
    r = np.tile(np.arange(rows, dtype=np.int64), planes)
    return DescriptorArray.create(
        src_base + p * src_strides[0] + r * src_strides[1],
        dst_base + p * dst_strides[0] + r * dst_strides[1],
        np.full(planes * rows, row_len, np.int64),
    )


def from_gather(indices, unit: int, dst_base: int = 0) -> DescriptorArray:
    """Gather `unit`-element rows at `indices` into a contiguous destination."""
    idx = np.asarray(indices, np.int64)
    n = idx.shape[0]
    return DescriptorArray.create(
        idx * unit,
        dst_base + np.arange(n, dtype=np.int64) * unit,
        np.full(n, unit, np.int64),
    )


def from_scatter(indices, unit: int, src_base: int = 0) -> DescriptorArray:
    """Scatter contiguous `unit`-element rows out to `indices`."""
    idx = np.asarray(indices, np.int64)
    n = idx.shape[0]
    return DescriptorArray.create(
        src_base + np.arange(n, dtype=np.int64) * unit,
        idx * unit,
        np.full(n, unit, np.int64),
    )


def from_pages(page_ids, page_elems: int, dst_base: int = 0) -> DescriptorArray:
    """A KV-cache page list as a descriptor chain (one page = one descriptor).

    This is the serving-side embodiment of the paper's format: a sequence's
    block table is exactly a chain whose last entry carries end-of-chain.
    """
    return from_gather(page_ids, page_elems, dst_base)


def concat_chains(chains: Sequence[DescriptorArray]) -> DescriptorArray:
    """FIFO-chain multiple chains into one table (§II-E driver 'commit' step).

    Successor indices are rebased; each chain's end-of-chain is rewired to the
    next chain's head, except the last.
    """
    srcs, dsts, lens, nxts, cfgs = [], [], [], [], []
    base = 0
    for i, c in enumerate(chains):
        n = c.num_descriptors
        nxt = np.asarray(c.nxt, np.int64).copy()
        tail = nxt < 0
        nxt = nxt + base
        if i + 1 < len(chains):
            nxt[tail] = base + n  # assumes each chain is head-at-0 contiguous
        else:
            nxt[tail] = -1
        srcs.append(np.asarray(c.src)); dsts.append(np.asarray(c.dst))
        lens.append(np.asarray(c.length)); nxts.append(nxt)
        cfgs.append(np.asarray(c.config))
        base += n
    return DescriptorArray.create(
        np.concatenate(srcs), np.concatenate(dsts), np.concatenate(lens),
        np.concatenate(nxts), np.concatenate(cfgs))


# ---------------------------------------------------------------------------
# Walkers
# ---------------------------------------------------------------------------

def walk_chain_host(d: DescriptorArray, head: int = 0) -> List[int]:
    """Faithful serial chain walk (reference semantics; host only)."""
    nxt = np.asarray(d.nxt)
    order, cur, seen = [], head, set()
    while cur != -1:
        if cur in seen:
            raise ValueError(f"descriptor chain contains a cycle at index {cur}")
        seen.add(cur)
        order.append(cur)
        cur = int(nxt[cur])
    return order


def flatten_chain(nxt: jax.Array, head=0) -> Tuple[jax.Array, jax.Array]:
    """Pointer-doubling list ranking: chain order in O(log N) vector steps.

    Args:
      nxt: int32[N] successor indices, -1 terminates.
      head: index of the chain head.

    Returns:
      (perm, count): ``perm[k]`` = index of the k-th descriptor in chain
      order (entries past the chain length are -1), ``count`` = chain length.
      Nodes not reachable from ``head`` are excluded.
    """
    n = nxt.shape[0]
    nxt = jnp.asarray(nxt, jnp.int32)
    steps = max(1, math.ceil(math.log2(max(n, 2))))

    # Binary lifting: J[k][i] = 2^k-th successor of i (-1 past the end), and
    # dist[i] = #hops from i to end-of-chain via the same doubling.
    jumps = [nxt]
    dist = jnp.where(nxt >= 0, 1, 0).astype(jnp.int32)
    j = nxt
    for _ in range(steps):
        has = j >= 0
        jc = jnp.maximum(j, 0)
        dist = jnp.where(has, dist + dist[jc], dist)
        j = jnp.where(has, j[jc], j)
        jumps.append(j)

    head = jnp.asarray(head, jnp.int32)
    count = dist[head] + 1

    # perm[r] = the node r hops from head: apply jump tables by bits of r.
    r = jnp.arange(n, dtype=jnp.int32)
    cur = jnp.full((n,), head, jnp.int32)
    for k in range(steps + 1):
        take = ((r >> k) & 1) == 1
        has = cur >= 0
        stepped = jnp.where(has, jumps[k][jnp.maximum(cur, 0)], -1)
        cur = jnp.where(take, stepped, cur)
    perm = jnp.where(r < count, cur, -1)
    return perm, count


# ---------------------------------------------------------------------------
# Speculative-layout planner (§II-C, software guarantee)
# ---------------------------------------------------------------------------

def plan_sequential_layout(
    d: DescriptorArray,
    table_base: int = 0x1000,
    head: int = 0,
) -> Tuple[np.ndarray, float]:
    """Assign byte addresses to descriptor slots so speculation hits.

    The hardware speculates address ``a + 32`` after fetching the descriptor
    at ``a``. Laying out the chain in walk order at consecutive addresses
    makes every speculation hit. Returns (packed_table_in_walk_order,
    predicted_hit_rate); the hit rate is 1.0 by construction unless the chain
    branches/was pre-placed (we recompute it honestly from the layout).
    """
    order = walk_chain_host(d, head)
    addr = {idx: table_base + k * DESCRIPTOR_BYTES for k, idx in enumerate(order)}
    nxt_np = np.asarray(d.nxt)
    next_addrs, hits = [], 0
    for k, idx in enumerate(order):
        nx = int(nxt_np[idx])
        na = END_OF_CHAIN if nx == -1 else np.uint64(addr[nx])
        next_addrs.append(na)
        if nx != -1 and addr[nx] == addr[idx] + DESCRIPTOR_BYTES:
            hits += 1
    denom = max(len(order) - 1, 1)
    hit_rate = hits / denom if len(order) > 1 else 1.0
    table = pack(
        np.asarray(d.length)[order],
        np.asarray(d.config)[order],
        next_addrs,
        np.asarray(d.src)[order],
        np.asarray(d.dst)[order],
    )
    return table, hit_rate


def measure_hit_rate(table: np.ndarray, head_addr: int, table_base: int) -> float:
    """Hit rate a sequential speculator would observe on a packed table."""
    n = len(table)
    if n <= 1:
        return 1.0
    addr_of = lambda i: table_base + i * DESCRIPTOR_BYTES
    index_of = {addr_of(i): i for i in range(n)}
    cur = index_of[head_addr]
    hits = total = 0
    while True:
        nxt = int(table["next"][cur])
        if np.uint64(nxt) == END_OF_CHAIN:
            break
        total += 1
        if nxt == addr_of(cur) + DESCRIPTOR_BYTES:
            hits += 1
        cur = index_of[nxt]
    return hits / max(total, 1)
