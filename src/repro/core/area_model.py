"""Area/timing models (§III-A, Tables II-III) — the paper's fitted formulas.

We cannot synthesize RTL here; the paper itself distills its synthesis
campaign into a linear model, which we reproduce and validate against the
published configuration points.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

# A[kGE] = 20.30 + 5.28 d + 1.94 s  (d = descriptors in flight, s = spec slots)
AREA_BASE_KGE = 20.30
AREA_PER_INFLIGHT_KGE = 5.28
AREA_PER_SPEC_KGE = 1.94

# Table II (GF12LP+, typical corner, 25C, 0.8V)
TABLE_II: Dict[str, Dict] = {
    "base":        {"frontend_kge": 25.8, "backend_kge": 15.4, "total_kge": 41.2, "fmax_ghz": 1.71},
    "speculation": {"frontend_kge": 34.8, "backend_kge": 14.7, "total_kge": 49.5, "fmax_ghz": 1.44},
    "scaled":      {"frontend_kge": 151.1, "backend_kge": 37.3, "total_kge": 188.4, "fmax_ghz": 1.23},
}

# Table III (Kintex-7 @ 200 MHz)
TABLE_III: Dict[str, Dict] = {
    "base":        {"luts": 2610, "ffs": 3090, "brams": 0},
    "speculation": {"luts": 2480, "ffs": 3935, "brams": 0},
    "scaled":      {"luts": 6764, "ffs": 11353, "brams": 0},
    "LogiCORE":    {"luts": 2784, "ffs": 5133, "brams": None},  # paper: ours needs none
}

# Whole-SoC context (CVA6 SoC on Genesys 2): 79142 LUTs / 58086 FFs.
SOC_LUTS, SOC_FFS = 79142, 58086


def area_kge(in_flight: int, spec_slots: int) -> float:
    """The paper's fitted area model; linear in d and s (scalability claim)."""
    return AREA_BASE_KGE + AREA_PER_INFLIGHT_KGE * in_flight + AREA_PER_SPEC_KGE * spec_slots


@dataclasses.dataclass(frozen=True)
class AreaReport:
    config: str
    in_flight: int
    spec_slots: int
    model_kge: float
    published_kge: float | None
    fmax_ghz: float | None

    @property
    def rel_err(self) -> float | None:
        if self.published_kge is None:
            return None
        return abs(self.model_kge - self.published_kge) / self.published_kge


def report(config: str, in_flight: int, spec_slots: int) -> AreaReport:
    pub = TABLE_II.get(config)
    return AreaReport(
        config=config, in_flight=in_flight, spec_slots=spec_slots,
        model_kge=area_kge(in_flight, spec_slots),
        published_kge=pub["total_kge"] if pub else None,
        fmax_ghz=pub["fmax_ghz"] if pub else None,
    )


def headline_fpga_savings() -> Dict[str, float]:
    """Paper abstract: 11% fewer LUTs / 23% fewer FFs vs LogiCORE (speculation cfg)."""
    ours, lc = TABLE_III["speculation"], TABLE_III["LogiCORE"]
    return {
        "lut_savings": 1 - ours["luts"] / lc["luts"],
        "ff_savings": 1 - ours["ffs"] / lc["ffs"],
    }
