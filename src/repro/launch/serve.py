"""Serving launcher: continuous batching with the descriptor-paged KV path.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --requests 8 --capacity 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.runtime import SubmitRequest
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = ServeEngine(params, cfg, capacity=args.capacity,
                         max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    for uid in range(args.requests):
        engine.submit(SubmitRequest(request=Request(
            uid=uid,
            prompt=list(rng.integers(1, cfg.vocab_size, rng.integers(4, 16))),
            max_new_tokens=args.max_new_tokens)))
    done = engine.run(max_steps=10000)
    dt = time.perf_counter() - t0
    tokens = sum(len(r.output) for r in done.values())
    print(f"{len(done)}/{args.requests} requests, {tokens} tokens, "
          f"{engine.steps} steps, {dt:.1f}s "
          f"({tokens/max(dt,1e-9):.1f} tok/s aggregate)")
    for uid, r in sorted(done.items()):
        print(f"  req {uid}: {r.output}")


if __name__ == "__main__":
    main()
