import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import: jax locks device count on first init.

"""Multi-pod dry-run (deliverable e): AOT lower+compile every
(arch x shape x mesh) cell on placeholder devices; record memory analysis,
cost analysis and the collective schedule for the roofline (deliverable g).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
        --shape train_4k --mesh single            # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
    PYTHONPATH=src python -m repro.launch.dryrun --all --subprocess
        # one subprocess per cell (isolates compile memory), resumable:
        # existing JSONs under experiments/dryrun/ are skipped.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.distributed import shardlib
from repro.distributed.sharding import (
    activation_rules,
    decode_state_specs,
    param_specs,
    to_named,
    train_batch_specs,
    train_state_specs,
)
from repro.launch.inputs import (
    decode_state_shapes,
    prefill_input_specs,
    train_input_specs,
    train_state_specs_shapes,
)
from repro.launch.mesh import make_production_mesh
from repro.roofline import analysis as ra

OUT_DIR = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "..", "..", "..",
                 "experiments", "dryrun"))


def _out_path(mesh_name, arch, shape_name):
    d = os.path.abspath(os.path.join(OUT_DIR, mesh_name))
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{arch}__{shape_name}.json")


def _with_periods(cfg, n: int):
    """Reduced-depth clone: first_k_dense prefix + n periods (same widths)."""
    import dataclasses
    kw = dict(
        num_layers=cfg.first_k_dense + n * len(cfg.block_pattern),
        attention_impl="proj_only",
        scan_periods=False,
    )
    if cfg.is_encdec:
        enc_per_period = cfg.encoder_layers // (
            (cfg.num_layers - cfg.first_k_dense) // len(cfg.block_pattern))
        kw["encoder_layers"] = max(1, n * enc_per_period)
    return dataclasses.replace(cfg, **kw)


def _lower_for(cfg, shape, mesh):
    if shape.kind == "train":
        return _lower_train(cfg, shape, mesh)
    if shape.kind == "prefill":
        return _lower_prefill(cfg, shape, mesh)
    return _lower_decode(cfg, shape, mesh)


def _measure(cfg, shape, mesh) -> dict:
    """Lower+compile one module; return flops/bytes/collectives (per chip)."""
    lowered = _lower_for(cfg, shape, mesh)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = ra.collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "collectives": coll}


def run_cell(arch: str, shape_name: str, mesh_name: str,
             force: bool = False) -> dict:
    path = _out_path(mesh_name, arch, shape_name)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    model_opts = {}
    for kv in filter(None, os.environ.get("REPRO_MODEL_OPTS", "").split(",")):
        k, v = kv.split("=")
        model_opts[k] = v
    if model_opts:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **model_opts)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                  "status": "skipped", "reason": why}
        with open(path, "w") as f:
            json.dump(result, f, indent=2)
        return result

    multi_pod = mesh_name == "multipod"
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    shardlib.set_mesh(mesh)
    shardlib.set_rules(activation_rules(mesh))
    t0 = time.time()

    try:
        with mesh:
            # (1) Full-depth compile: memory analysis + the "it fits" proof.
            lowered = _lower_for(cfg, shape, mesh)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            print(mem)    # proves it fits (bytes per device)
            print({k: cost.get(k) for k in ("flops", "bytes accessed")})

            # (2) Loop-aware totals: P=1 / P=2 extrapolation (see §Roofline).
            periods = (cfg.num_layers - cfg.first_k_dense) \
                // len(cfg.block_pattern)
            decode_kind = shape.kind == "decode"
            import dataclasses as dc
            cfg1 = _with_periods(cfg, 1)
            cfg2 = _with_periods(cfg, 2)
            if decode_kind:   # decode path has no inner loops: measure real core
                cfg1 = dc.replace(cfg1, attention_impl="blockwise")
                cfg2 = dc.replace(cfg2, attention_impl="blockwise")
            m1 = _measure(cfg1, shape, mesh)
            m2 = _measure(cfg2, shape, mesh)

        ext = lambda k: ra.extrapolate(m1[k], m2[k], periods)
        flops_pc = ext("flops")
        bytes_pc = ext("bytes")
        coll_pc = {k: ra.extrapolate(m1["collectives"][k],
                                     m2["collectives"][k], periods)
                   for k in m1["collectives"]}
        if not decode_kind:
            core_f, core_b = ra.core_totals(cfg, shape)   # global -> per chip
            flops_pc += core_f / chips
            bytes_pc += core_b / chips

        peak = getattr(mem, "temp_size_in_bytes", 0) + \
            getattr(mem, "argument_size_in_bytes", 0) + \
            getattr(mem, "output_size_in_bytes", 0)
        roof = ra.Roofline(
            arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
            hlo_flops_per_chip=flops_pc, hlo_bytes_per_chip=bytes_pc,
            wire_bytes_per_chip=float(sum(coll_pc.values())),
            collectives=coll_pc,
            model_flops=ra.model_flops(cfg, shape),
            bytes_per_chip_hbm=float(getattr(mem, "temp_size_in_bytes", 0)),
        )
        result = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "ok", "chips": chips,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "memory": {
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "peak_per_device_bytes": peak,
            },
            "raw_cost_analysis": {"flops": float(cost.get("flops", 0.0)),
                                  "bytes": float(cost.get("bytes accessed",
                                                          0.0))},
            "extrapolation": {"p1": m1, "p2": m2, "periods": periods},
            "roofline": roof.to_dict(),
        }
    except Exception as e:  # noqa: BLE001 — recorded, re-raised by --all
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                  "status": "error", "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:]}
    finally:
        shardlib.clear_mesh()

    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    return result


def _train_config():
    """TrainConfig for lowering; perf variants via REPRO_TRAIN_OPTS
    (comma-separated k=v, e.g. 'cast_params_bf16=1,microbatches=2')."""
    from repro.train import TrainConfig
    opts = {}
    for kv in filter(None, os.environ.get("REPRO_TRAIN_OPTS", "").split(",")):
        k, v = kv.split("=")
        opts[k] = (v == "1") if v in ("0", "1") else v
    return TrainConfig(**opts)


def _lower_train(cfg, shape, mesh):
    from repro.train import train_step
    tcfg = _train_config()
    state_shapes = train_state_specs_shapes(cfg, tcfg)
    batch_shapes = train_input_specs(cfg, shape)
    state_sh = to_named(train_state_specs(cfg, mesh, state_shapes), mesh)
    batch_sh = to_named(
        train_batch_specs(mesh, shape.global_batch, batch_shapes), mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(mesh, P())
    fn = lambda s, b: train_step(s, b, cfg, tcfg)
    return jax.jit(
        fn,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    ).lower(state_shapes, batch_shapes)


def _lower_prefill(cfg, shape, mesh):
    from repro.models.model import forward
    batch_shapes = prefill_input_specs(cfg, shape)
    batch_sh = to_named(
        train_batch_specs(mesh, shape.global_batch, batch_shapes), mesh)
    p_shapes, p_sh = _serving_params(cfg, mesh)

    def prefill_step(params, batch):
        logits, _, _, _ = forward(params, batch, cfg)
        return logits

    return jax.jit(prefill_step,
                   in_shardings=(p_sh, batch_sh)).lower(p_shapes, batch_shapes)


def _serve_opts():
    opts = {}
    for kv in filter(None, os.environ.get("REPRO_SERVE_OPTS", "").split(",")):
        k, v = kv.split("=")
        opts[k] = v == "1"
    return opts


def _serving_params(cfg, mesh):
    """(shapes, shardings) for decode/prefill params, honoring
    REPRO_SERVE_OPTS=tp_only=1,bf16=1 perf variants."""
    from repro.models import param_shapes
    from repro.distributed.sharding import serving_param_specs
    opts = _serve_opts()
    p_shapes = param_shapes(cfg)
    if opts.get("bf16"):
        p_shapes = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, cfg.cdtype)
            if l.dtype == jnp.float32 and len(l.shape) >= 2 else l, p_shapes)
    spec_fn = serving_param_specs if opts.get("tp_only") else param_specs
    return p_shapes, to_named(spec_fn(cfg, mesh, p_shapes), mesh)


def _lower_decode(cfg, shape, mesh):
    from repro.models import decode_step
    from repro.distributed.sharding import batch_axis
    from jax.sharding import NamedSharding, PartitionSpec as P

    state_shapes, token_shapes = decode_state_shapes(cfg, shape)
    p_shapes, p_sh = _serving_params(cfg, mesh)
    kv_seq = "model" if _serve_opts().get("kv_seq_shard") else None
    s_sh = to_named(
        decode_state_specs(cfg, mesh, state_shapes, shape.global_batch,
                           kv_seq_axis=kv_seq), mesh)
    BA = batch_axis(mesh, shape.global_batch)
    tok_sh = NamedSharding(mesh, P(BA))

    def serve_step(params, tokens, state):
        return decode_step(params, tokens, state, cfg)

    return jax.jit(
        serve_step,
        in_shardings=(p_sh, tok_sh, s_sh),
        out_shardings=(None, s_sh),
        donate_argnums=(2,),
    ).lower(p_shapes, token_shapes, state_shapes)


def all_cells(mesh_names):
    cells = []
    for arch in list_archs():
        for shape_name in SHAPES:
            for mesh_name in mesh_names:
                cells.append((arch, shape_name, mesh_name))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--subprocess", action="store_true",
                    help="run each cell in a fresh subprocess (memory hygiene)")
    args = ap.parse_args()

    mesh_names = ["single", "multipod"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = all_cells(mesh_names)
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape, m) for m in mesh_names]

    failures = 0
    for arch, shape_name, mesh_name in cells:
        path = _out_path(mesh_name, arch, shape_name)
        if os.path.exists(path) and not args.force:
            with open(path) as f:
                r = json.load(f)
            print(f"[cached] {mesh_name:8s} {arch:22s} {shape_name:12s} "
                  f"{r['status']}")
            continue
        if args.subprocess:
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape_name, "--mesh", mesh_name]
            if args.force:
                cmd.append("--force")
            env = dict(os.environ)
            proc = subprocess.run(cmd, env=env, capture_output=True,
                                  text=True)
            status = "?"
            if os.path.exists(path):
                with open(path) as f:
                    status = json.load(f)["status"]
            print(f"[subproc] {mesh_name:8s} {arch:22s} {shape_name:12s} "
                  f"{status} (rc={proc.returncode})")
            if status != "ok" and status != "skipped":
                failures += 1
        else:
            r = run_cell(arch, shape_name, mesh_name, force=args.force)
            print(f"[run]    {mesh_name:8s} {arch:22s} {shape_name:12s} "
                  f"{r['status']}"
                  + (f" ({r.get('error','')[:120]})"
                     if r["status"] == "error" else ""))
            if r["status"] == "error":
                failures += 1
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
