"""ShapeDtypeStruct stand-ins for every model input/state (no allocation)."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    text_s = s - cfg.prefix_len if cfg.prefix_len else s
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, text_s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, text_s), jnp.int32),
        "loss_mask": jax.ShapeDtypeStruct((b, text_s), jnp.float32),
    }
    if cfg.is_encdec:
        # Audio stub: precomputed frame embeddings (assignment: frontend STUB).
        batch["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                               jnp.bfloat16)
    if cfg.prefix_len:
        # Vision stub: precomputed patch embeddings.
        batch["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.prefix_len, cfg.d_model), jnp.bfloat16)
    return batch


def train_state_specs_shapes(cfg: ModelConfig, tcfg) -> Any:
    """eval_shape of TrainState init."""
    from repro.models import init_params
    from repro.train import init_state

    def mk(key):
        params = init_params(key, cfg)
        return init_state(params, tcfg)
    return jax.eval_shape(mk, jax.ShapeDtypeStruct((2,), jnp.uint32))


def decode_state_shapes(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[Any, Any]:
    """(DecodeState shapes, token shapes) for serve_step lowering."""
    from repro.models.model import DecodeState
    from repro.models.transformer import init_decode_caches

    b = shape.global_batch

    def mk():
        if cfg.is_encdec:
            # Cross-attention caches need encoder memory + params; the
            # decode-shape dry-run covers the self-attention path (cross-KV
            # is static memory traffic computed at prefill).
            caches = init_decode_caches(cfg, b, shape.seq_len)
        else:
            caches = init_decode_caches(cfg, b, shape.seq_len)
        return DecodeState(caches, jnp.zeros((b,), jnp.int32))

    state = jax.eval_shape(mk)
    tokens = jax.ShapeDtypeStruct((b,), jnp.int32)
    return state, tokens


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    text_s = s - cfg.prefix_len if cfg.prefix_len else s
    batch = {"tokens": jax.ShapeDtypeStruct((b, text_s), jnp.int32)}
    if cfg.is_encdec:
        batch["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                               jnp.bfloat16)
    if cfg.prefix_len:
        batch["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.prefix_len, cfg.d_model), jnp.bfloat16)
    return batch
