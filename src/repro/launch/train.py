"""Production training launcher.

On a real fleet each host runs this under its TPU runtime (jax.distributed
initializes from the cluster env); on CPU it runs reduced configs end to end.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduced \
        --steps 100 --ckpt-dir /tmp/run1
    # multi-host (sketch): srun ... python -m repro.launch.train --arch ... \
    #     --mesh-data 16 --mesh-model 16 [--multi-pod] [--compress-pods]
"""
from __future__ import annotations

import argparse

import jax

from repro import optim
from repro.configs import get_config
from repro.data import DataConfig
from repro.distributed import shardlib
from repro.distributed.sharding import activation_rules
from repro.train import Trainer, TrainConfig, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh-data", type=int, default=0,
                    help=">0: build a (data, model) mesh and shard")
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--compress-pods", action="store_true",
                    help="error-feedback int8 allreduce on the pod axis")
    ap.add_argument("--distributed-init", action="store_true",
                    help="call jax.distributed.initialize() (real clusters)")
    args = ap.parse_args()

    if args.distributed_init:
        jax.distributed.initialize()

    cfg = get_config(args.arch, reduced=args.reduced)
    if args.mesh_data:
        if args.multi_pod:
            mesh = jax.make_mesh((2, args.mesh_data, args.mesh_model),
                                 ("pod", "data", "model"))
        else:
            mesh = jax.make_mesh((args.mesh_data, args.mesh_model),
                                 ("data", "model"))
        shardlib.set_mesh(mesh)
        shardlib.set_rules(activation_rules(mesh))

    tcfg = TrainConfig(
        optimizer=optim.AdamWConfig(lr=args.lr, warmup_steps=args.steps // 10,
                                    total_steps=args.steps),
        microbatches=args.microbatches,
        compress_pod_axis="pod" if args.compress_pods else None,
    )
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.global_batch,
                      num_hosts=jax.process_count(),
                      host_id=jax.process_index())
    run = TrainerConfig(total_steps=args.steps,
                        checkpoint_every=args.ckpt_every,
                        checkpoint_dir=args.ckpt_dir, log_every=10)

    def log(step, metrics):
        print(f"step {step}: " + " ".join(
            f"{k}={float(v):.4f}" if hasattr(v, "__float__") else f"{k}={v}"
            for k, v in metrics.items()), flush=True)

    result = Trainer(cfg, tcfg, run, dcfg, log_fn=log).train()
    print(f"finished at step {result['final_step']}; "
          f"{len(result['stragglers'])} straggler steps")


if __name__ == "__main__":
    main()
