"""Production meshes. Functions only — importing this module never touches
jax device state (required: smoke tests must see 1 device)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods.

    Axes: (pod,) data, model — `pod` is the slow inter-pod (DCN/optical)
    axis, `data` the FSDP/batch axis, `model` the TP/EP axis.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CI-scale sharding tests (host devices)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
