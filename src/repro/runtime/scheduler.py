"""The DMA runtime scheduler: pools, backpressure, and batch drain.

:class:`DMARuntime` is the single object workload code talks to. It owns

* **named pools** — JAX arrays registered once; descriptors address pool
  elements/rows, so submissions are (chain, src_pool, dst_pool) triples;
* **N virtual channels** (:mod:`repro.runtime.channel`), picked by explicit
  name or by the configured arbiter;
* **the coalescer** (:mod:`repro.runtime.coalesce`) — run on every serial/
  blocked submission; its per-batch §II-C hit-rate estimate and merge ratio
  accumulate into runtime stats;
* **backpressure** — a full ring either *blocks* (the submitter drains the
  channel until space frees, the paper's driver busy-wait) or *spills*
  into an unbounded software queue replayed at the next drain;
* **batch drain** — :meth:`drain_all` advances every channel; row-move
  batches that share a (src, dst) pool pair are fused and executed in one
  jitted engine call (the "single doorbell" step).

Launch-side cost is tracked per descriptor (wall-clock submit latency),
mirroring the paper's launch-latency measurement (1.66x claim).
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.descriptor import CONFIG_IRQ_ENABLE, DescriptorArray
from repro.core.engine import execute_blocked_2d
from repro.core.speculation import (
    DEFAULT_POLICY,
    PolicyLike,
    SpeculationPolicy,
    as_policy,
)
from repro.core.transform import TransformSpec, as_transform

from repro.obs.counters import PerfCounters, namespaced
from repro.obs.trace import Tracer, monotonic

from .channel import (
    Channel,
    ChannelConfig,
    RoundRobinArbiter,
    WeightedArbiter,
)
from .coalesce import CoalesceStats, coalesce
from .completion import CompletionQueue, CompletionRecord
from .instrumentation import PerfProbe
from .lowering import TranslationCache, disabled_stats
from .ring import RingFull
from .submit import SubmitRequest, SubmitResult, Ticket, reject_legacy_submit

__all__ = [
    "DMARuntime", "SubmitRequest", "SubmitResult", "Ticket",
    "default_runtime",
]


@dataclasses.dataclass
class _Spilled:
    d: DescriptorArray
    tickets: List[int]
    channel: str
    src_pool: Optional[str]
    dst_pool: Optional[str]
    transform: Optional[TransformSpec] = None


def _is_sequential_chain(d: DescriptorArray) -> bool:
    n = d.num_descriptors
    want = np.concatenate([np.arange(1, n), [-1]])
    return bool(np.array_equal(np.asarray(d.nxt), want))


@functools.lru_cache(maxsize=256)
def _split_bounds(n: int, piece: int) -> Tuple[Tuple[int, int], ...]:
    """Memoized cut points for ring-sized chunking (shape-only)."""
    return tuple((lo, min(lo + piece, n)) for lo in range(0, n, piece))


def _split_chain(d: DescriptorArray, piece: int) -> List[DescriptorArray]:
    """Cut a chain into ring-sized sequentially-chained pieces."""
    return [DescriptorArray.create(
        d.src[lo:hi], d.dst[lo:hi], d.length[lo:hi],
        config=d.config[lo:hi])
        for lo, hi in _split_bounds(d.num_descriptors, piece)]


class DMARuntime:
    def __init__(
        self,
        channels: Sequence[ChannelConfig],
        *,
        arbitration: str = "round_robin",   # "round_robin" | "weighted"
        backpressure: str = "block",        # "block" | "spill"
        coalesce_max_len: int = 1 << 20,
        speculation: Optional[PolicyLike] = None,
        translation: "bool | TranslationCache" = True,
    ):
        if not channels:
            raise ValueError("need at least one channel")
        if backpressure not in ("block", "spill"):
            raise ValueError(f"unknown backpressure policy {backpressure!r}")
        # One speculation policy per runtime, one *controller* per channel:
        # each channel adapts to its own traffic (DESIGN.md §5). The default
        # FixedDepth policy reproduces the pre-policy runtime bit-for-bit.
        self.speculation: SpeculationPolicy = as_policy(
            DEFAULT_POLICY if speculation is None else speculation)
        self.completion = CompletionQueue()
        self.channels: Dict[str, Channel] = {
            c.name: Channel(c, self.completion,
                            spec=self.speculation.make_controller())
            for c in channels}
        if arbitration == "round_robin":
            self.arbiter = RoundRobinArbiter([c.name for c in channels])
        elif arbitration == "weighted":
            self.arbiter = WeightedArbiter(
                {c.name: c.weight for c in channels})
        else:
            raise ValueError(f"unknown arbitration {arbitration!r}")
        self.backpressure = backpressure
        self.coalesce_max_len = coalesce_max_len
        # Chain-lowering JIT (DESIGN.md §7): signature-keyed cache of
        # compiled drain executors + digest-keyed coalescer-plan memo.
        # True builds a private cache; a TranslationCache instance may be
        # shared across runtimes (sharded serving); False disables lowering
        # entirely (the --no-translation-cache A/B escape hatch).
        if translation is True:
            self.translation: Optional[TranslationCache] = TranslationCache()
        elif translation is False or translation is None:
            self.translation = None
        else:
            self.translation = translation
        self.probe: Optional[PerfProbe] = None
        self.tracer: Optional[Tracer] = None
        self.pools: Dict[str, jax.Array] = {}
        self._spill: Deque[_Spilled] = deque()
        self._next_ticket = 0
        self._ticket_channel: Dict[int, str] = {}
        # launch-side accounting (paper: launch latency, Table IV i-rf)
        self.submitted_descriptors = 0
        self.launch_seconds = 0.0
        self.coalesce_in = 0
        self.coalesce_out = 0
        self._hit_rates: List[float] = []

    # -- instrumentation ----------------------------------------------------
    def attach_probe(self, probe: Optional[PerfProbe]) -> None:
        """Attach (or with None, detach) a perf counter sink.

        The probe observes every channel of this runtime; the perf sweep
        (:mod:`repro.perf.sweep`) reads its snapshot instead of re-deriving
        counters from submission-side bookkeeping.
        """
        self.probe = probe
        for ch in self.channels.values():
            ch.probe = probe
        if self.translation is not None:
            self.translation.attach_probe(probe)

    def attach_tracer(self, tracer: Optional[Tracer], *,
                      track_prefix: str = "") -> None:
        """Attach (or with None, detach) a lifecycle span tracer.

        Propagates to every channel, the completion queue, and the
        translation cache. ``track_prefix`` namespaces this runtime's
        tracks — the sharded runtime passes ``"shard{i}/"`` so an exported
        timeline shows one track group per shard (DESIGN.md §8).
        """
        self.tracer = tracer
        for ch in self.channels.values():
            ch.tracer = tracer
            ch.track = track_prefix + ch.name
        self.completion.tracer = tracer
        self.completion.track = track_prefix + "completion"
        if self.translation is not None:
            self.translation.attach_tracer(tracer)

    # -- pools --------------------------------------------------------------
    def register_pool(self, name: str, array: jax.Array) -> None:
        self.pools[name] = array

    def pool(self, name: str) -> jax.Array:
        return self.pools[name]

    # -- submission ---------------------------------------------------------
    def _take_tickets(self, n: int, channel: str) -> List[int]:
        t = list(range(self._next_ticket, self._next_ticket + n))
        self._next_ticket += n
        for tk in t:
            self._ticket_channel[tk] = channel
        return t

    def _pick_channel(self, tier: Optional[str], priority: int = 0) -> str:
        eligible = [name for name, ch in self.channels.items()
                    if tier is None or ch.cfg.tier == tier]
        if not eligible:
            raise ValueError(f"no channel with tier {tier!r}")
        if priority > 0:
            # High-priority submissions bypass arbitration and take the
            # eligible channel with the most free ring slots (head-of-line
            # avoidance); ties break on name for determinism.
            return min(eligible,
                       key=lambda n: (-self.channels[n].ring.free_slots, n))
        name = self.arbiter.pick(eligible)
        return name if name is not None else eligible[0]

    def submit(self, d, **kw) -> Ticket:
        """Plan a chain and enqueue it on a channel ring.

        Unified form (DESIGN.md §9): ``submit(SubmitRequest) -> Ticket``,
        carrying chain + pools + transform + priority + completion
        callback. The legacy keyword form
        ``submit(chain, src_pool=..., dst_pool=..., tier=...)`` was
        removed one release after 0.4 and now raises ``TypeError``.

        Returns tickets (one per *planned* descriptor; the last ticket of
        a submission always exists, so callers wanting one completion per
        logical transfer hang their callback on ``tickets[-1]``).
        """
        if not isinstance(d, SubmitRequest):
            reject_legacy_submit("DMARuntime.submit", d)
        if kw:
            raise TypeError(
                "unified submit takes a single SubmitRequest; put "
                f"{sorted(kw)} on the request")
        return self._submit_impl(
            d.chain, src_pool=d.src_pool, dst_pool=d.dst_pool,
            channel=d.channel, tier=d.tier, on_complete=d.on_complete,
            run_coalescer=d.run_coalescer,
            transform=as_transform(d.transform), priority=d.priority)

    def _submit_impl(
        self,
        d: DescriptorArray,
        *,
        src_pool: Optional[str] = None,
        dst_pool: Optional[str] = None,
        channel: Optional[str] = None,
        tier: Optional[str] = None,
        on_complete: Optional[Callable[[CompletionRecord], None]] = None,
        run_coalescer: Optional[bool] = None,
        transform: Optional[TransformSpec] = None,
        priority: int = 0,
    ) -> Ticket:
        spec = as_transform(transform)
        t0 = monotonic()
        n_raw = d.num_descriptors
        # Sampling key = the first ticket this submission will take; the
        # decision is made once here and reused by every child span.
        tr = self.tracer
        rec = tr is not None and tr.sampled(self._next_ticket)
        first_ticket = self._next_ticket
        name = channel if channel is not None \
            else self._pick_channel(tier, priority)
        ch = self.channels[name]

        stats: Optional[CoalesceStats] = None
        lowered = None
        if run_coalescer is None:
            # Row-move and control streams have positional semantics the
            # merge pass must not disturb; linear-byte tiers benefit.
            run_coalescer = ch.cfg.tier in ("serial", "blocked")
        if run_coalescer and d.num_descriptors:
            max_len = (ch.cfg.max_len if ch.cfg.tier == "serial"
                       else min(ch.cfg.unit, self.coalesce_max_len)
                       if ch.cfg.tier == "blocked" else self.coalesce_max_len)
            # Ask-then-observe (DESIGN.md §5): the planner provisions the
            # layout slack the channel's policy currently wants, then the
            # measured input hit rate feeds back and may move the depth —
            # for the *next* submission, never this one.
            c0 = monotonic() if rec else 0.0
            planned = None
            if self.translation is not None:
                # Chain-lowering fast path (DESIGN.md §7): plan through
                # the digest-keyed memo (bit-identical to coalesce) and
                # pick up the signature's compiled drain executor. A None
                # plan (malformed chain) falls back to the legacy walker,
                # which raises the canonical error.
                planned = self.translation.plan(
                    d, max_len=max_len, spec_depth=ch.speculation_depth,
                    tier=ch.cfg.tier, transform=spec)
            if planned is not None:
                d, stats, lowered = (planned.planned, planned.stats,
                                     planned.lowered)
            else:
                d, stats = coalesce(d, max_len=max_len,
                                    spec_depth=ch.speculation_depth,
                                    allow_merge=spec.merge_safe)
            self.coalesce_in += stats.n_in
            self.coalesce_out += stats.n_out
            self._hit_rates.append(stats.input_hit_rate)
            ch.observe_speculation(stats.input_hit_rate)
            if rec:
                tr.complete("coalesce", ch.track, c0 * 1e6,
                            (monotonic() - c0) * 1e6,
                            ticket=first_ticket, n_in=stats.n_in,
                            n_out=stats.n_out,
                            hit_rate=stats.input_hit_rate,
                            planned=planned is not None)

        n = d.num_descriptors
        if n == 0:
            dt = monotonic() - t0
            if self.probe is not None:
                self.probe.on_submit(
                    name, n_in=n_raw, n_out=0, launch_seconds=dt,
                    hit_rate=stats.input_hit_rate if stats else None)
            if rec:
                tr.complete("submit", ch.track, t0 * 1e6, dt * 1e6,
                            ticket=first_ticket, channel=name,
                            n_in=n_raw, n_out=0)
            return Ticket([], name, False, stats,
                          transform=spec.cache_token)

        # A chain longer than the ring is submitted in ring-sized pieces
        # (the driver can never map more descriptors than slots at once).
        # Safe when execution order across pieces equals chain order: true
        # for sequentially-chained streams (every coalesced chain) and for
        # the order-free blocked tiers; a serial-tier chain with arbitrary
        # `nxt` links cannot be cut, so reject it loudly instead of hanging.
        chunks = [d]
        if n > ch.ring.capacity:
            sequential = (self.translation.is_sequential(d)
                          if self.translation is not None
                          else _is_sequential_chain(d))
            if ch.cfg.tier == "serial" and not sequential:
                raise ValueError(
                    f"chain of {n} descriptors exceeds ring capacity "
                    f"{ch.ring.capacity} and is not sequentially linked; "
                    "coalesce it or enlarge the ring")
            chunks = _split_chain(d, ch.ring.capacity)
            lowered = None   # pieces have new shapes; drain them legacy

        tickets = self._take_tickets(n, name)
        if on_complete is not None:
            self.completion.register(tickets[-1], on_complete)

        spilled = False
        cursor = 0
        for piece in chunks:
            k = piece.num_descriptors
            piece_tickets = tickets[cursor:cursor + k]
            cursor += k
            while True:
                try:
                    ch.submit(SubmitRequest(chain=piece, src_pool=src_pool,
                                            dst_pool=dst_pool,
                                            transform=spec),
                              piece_tickets, lowered=lowered)
                    break
                except RingFull:
                    if self.backpressure == "block":
                        # Paper driver semantics: the submitter waits on
                        # the device; "waiting" = advancing the consumer.
                        if not ch.drain_one(self.pools) and ch.ring.full:
                            raise  # ring full of unacknowledged work
                    else:
                        self._spill.append(_Spilled(
                            piece, piece_tickets, name, src_pool, dst_pool,
                            spec))
                        spilled = True
                        break
        self.submitted_descriptors += n
        launch = monotonic() - t0
        self.launch_seconds += launch
        if self.probe is not None:
            self.probe.on_submit(
                name, n_in=n_raw, n_out=n, launch_seconds=launch,
                hit_rate=stats.input_hit_rate if stats else None)
        if rec:
            tr.complete("submit", ch.track, t0 * 1e6, launch * 1e6,
                        ticket=tickets[0], channel=name,
                        n_in=n_raw, n_out=n, spilled=spilled)
        return Ticket(tickets, name, spilled, stats,
                      transform=spec.cache_token)

    def submit_control(self, payload: int = 0, *,
                       channel: Optional[str] = None,
                       on_complete=None) -> Ticket:
        """One IRQ-enabled control descriptor (no data movement)."""
        d = DescriptorArray.create(
            [payload], [0], [0],
            nxt=[-1], config=[int(CONFIG_IRQ_ENABLE)])
        return self.submit(SubmitRequest(
            chain=d, channel=channel, tier=None if channel else "control",
            on_complete=on_complete, run_coalescer=False))

    # -- out-of-band completion (control descriptors) -----------------------
    def complete(self, ticket: int) -> None:
        """§II-D writeback for a control descriptor, by ticket."""
        name = self._ticket_channel.get(ticket)
        if name is None:
            raise KeyError(f"unknown ticket {ticket}")
        self.channels[name].ring.mark_done_ticket(ticket)

    # -- drain --------------------------------------------------------------
    def _admit_spill(self) -> None:
        still: Deque[_Spilled] = deque()
        while self._spill:
            s = self._spill.popleft()
            ch = self.channels[s.channel]
            if ch.can_accept(s.d.num_descriptors):
                ch.submit(SubmitRequest(chain=s.d, src_pool=s.src_pool,
                                        dst_pool=s.dst_pool,
                                        transform=s.transform), s.tickets)
            else:
                still.append(s)
        self._spill = still

    def drain_channel(self, name: str, max_batches: int = 1) -> int:
        ch = self.channels[name]
        ran = 0
        for _ in range(max_batches):
            if not ch.drain_one(self.pools):
                break
            ran += 1
        return ran

    def drain_all(self, max_batches_per_channel: int = 1) -> int:
        """Advance every channel one step; fuse row-move batches.

        Pending ``blocked_2d`` batches (non-kernel) across *all* channels
        that target the same (src_pool, dst_pool) pair are concatenated and
        executed in a single jitted :func:`execute_blocked_2d` call — the
        multi-channel doorbell. Everything else drains per channel.
        """
        ran = self._drain_fused_2d()
        for name in self.channels:
            ran += self.drain_channel(name, max_batches_per_channel)
        for ch in self.channels.values():
            ch._retire()
        self._admit_spill()
        return ran

    def _drain_fused_2d(self) -> int:
        groups: Dict[Tuple[str, str], List[Tuple[Channel, object]]] = {}
        for ch in self.channels.values():
            if ch.cfg.tier != "blocked_2d" or ch.cfg.use_kernel:
                continue
            while ch.pending:
                # Fusion concatenates descriptor streams, which is only
                # sound when every batch moves raw bytes: a transformed
                # batch stays pending and drains (with its transform) via
                # the per-channel path, blocking later batches on this
                # channel from fusing ahead of it this round.
                if ch.pending[0].transform is not None \
                        and not ch.pending[0].transform.is_identity:
                    break
                b = ch.pending.popleft()
                groups.setdefault((b.src_pool, b.dst_pool), []).append((ch, b))
        ran = 0
        for (src_name, dst_name), items in groups.items():
            # Fusion executes every batch's reads against the pre-drain
            # pool, so a batch that reads (RAW) or rewrites (WAW) a row an
            # earlier fused batch wrote must start a new fused call.
            sub: List[Tuple[Channel, object]] = []
            written: set = set()
            for ch, b in items:
                src_rows = set(np.asarray(b.descs.src).tolist())
                dst_rows = set(np.asarray(b.descs.dst).tolist())
                if sub and (src_rows & written or dst_rows & written):
                    self._execute_fused(sub, src_name, dst_name)
                    ran += len(sub)
                    sub, written = [], set()
                sub.append((ch, b))
                written |= dst_rows
            if sub:
                self._execute_fused(sub, src_name, dst_name)
                ran += len(sub)
        return ran

    def _execute_fused(self, items: List[Tuple[Channel, object]],
                       src_name: str, dst_name: str) -> None:
        descs = [b.descs for _, b in items]
        fused = DescriptorArray.create(
            jnp.concatenate([d.src for d in descs]),
            jnp.concatenate([d.dst for d in descs]),
            jnp.concatenate([d.length for d in descs]),
            nxt=jnp.concatenate([jnp.asarray(d.nxt) for d in descs]),
            config=jnp.concatenate([d.config for d in descs]),
        )
        t0 = monotonic()
        out = None
        if self.translation is not None:
            # Lowered fused drain: the whole multi-channel batch through
            # one bucketed Pallas mega-kernel (declines off-TPU and on
            # duplicate destination rows — legacy path is authoritative).
            out = self.translation.execute_rows_2d(
                fused, self.pools[src_name], self.pools[dst_name])
        if out is None:
            out, _ = execute_blocked_2d(
                fused, self.pools[src_name], self.pools[dst_name])
        dt = monotonic() - t0
        self.pools[dst_name] = out
        tr = self.tracer
        if tr is not None and items[0][1].tickets \
                and tr.sampled(items[0][1].tickets[0]):
            tr.complete("drain", items[0][0].track, t0 * 1e6, dt * 1e6,
                        ticket=items[0][1].tickets[0],
                        n=fused.num_descriptors, fused=True)
        # The fused call's wall-clock is apportioned per batch by descriptor
        # share, so per-channel drain_seconds stay comparable across paths.
        total = max(fused.num_descriptors, 1)
        for ch, b in items:
            n_b = b.descs.num_descriptors
            share = dt * n_b / total
            for slot in b.slots:
                ch.ring.mark_done(slot)
            ch.stats.drained += n_b
            ch.stats.batches += 1
            ch.stats.drain_seconds += share
            if ch.probe is not None:
                ch.probe.on_drain(ch.name, n_descriptors=n_b,
                                  seconds=share, fused=True)
            ch._retire()

    def drain_until_idle(self, max_rounds: int = 1024) -> None:
        for _ in range(max_rounds):
            if not any(ch.has_work for ch in self.channels.values()) \
                    and not self._spill:
                return
            self.drain_all()
        raise RuntimeError("runtime did not quiesce")

    # -- completion-side API -------------------------------------------------
    def poll(self, max_events: Optional[int] = None):
        return self.completion.poll(max_events)

    # -- speculation ---------------------------------------------------------
    def speculation_depths(self) -> Dict[str, int]:
        """Live §II-C depth per channel (the policy's current decision)."""
        return {name: ch.speculation_depth
                for name, ch in self.channels.items()}

    # -- stats ---------------------------------------------------------------
    def _translation_stats_raw(self) -> Dict[str, object]:
        """Bare-key counter block (internal aggregation / wrapping input)."""
        if self.translation is None:
            return disabled_stats()
        return self.translation.stats()

    def translation_stats(self) -> PerfCounters:
        """Translation-cache counters, unified ``translation.*`` namespace.

        The bare-key deprecated aliases were removed one release after
        0.4 (DESIGN.md §9). Zeros + ``translation.enabled`` False when
        lowering is off.
        """
        return namespaced(self._translation_stats_raw(), "translation")

    def stats(self) -> Dict[str, object]:
        per_channel = {
            name: dataclasses.asdict(ch.stats)
            for name, ch in self.channels.items()
        }
        n = max(self.submitted_descriptors, 1)
        return {
            "channels": per_channel,
            "submitted_descriptors": self.submitted_descriptors,
            "launch_us_per_descriptor": 1e6 * self.launch_seconds / n,
            "coalesce_merge_ratio":
                (self.coalesce_in / self.coalesce_out
                 if self.coalesce_out else 1.0),
            "mean_input_hit_rate":
                float(np.mean(self._hit_rates)) if self._hit_rates else 1.0,
            "spilled": len(self._spill),
            "completions_delivered": self.completion.delivered,
            "translation_cache": self.translation_stats(),
        }


def default_runtime(
    n_channels: int = 4,
    *,
    tier: str = "blocked_2d",
    ring_capacity: int = 64,
    arbitration: str = "round_robin",
    backpressure: str = "block",
    speculation: Optional[PolicyLike] = None,
    translation: "bool | TranslationCache" = True,
    **channel_kw,
) -> DMARuntime:
    """N homogeneous channels — the common serving configuration."""
    cfgs = [ChannelConfig(name=f"ch{i}", tier=tier,
                          ring_capacity=ring_capacity, **channel_kw)
            for i in range(n_channels)]
    return DMARuntime(cfgs, arbitration=arbitration,
                      backpressure=backpressure, speculation=speculation,
                      translation=translation)
