"""Multi-channel DMA runtime: rings, channels, coalescing, completions.

The layer between workload code and the execution engines (DESIGN.md §3):
submission rings of packed descriptors (§II-D writeback as the completion
signal), N virtual channels with per-tier engines and RR/weighted
arbitration, a pre-submission coalescer, polled completion queues, and a
backpressure-aware scheduler with a fused batch-drain step.
"""
from .ring import RingEmpty, RingEntry, RingFull, SubmissionRing  # noqa: F401
from .channel import (  # noqa: F401
    Channel,
    ChannelConfig,
    ChannelStats,
    RoundRobinArbiter,
    WeightedArbiter,
)
from .coalesce import CoalesceStats, coalesce, input_hit_rate  # noqa: F401
from .completion import CompletionQueue, CompletionRecord  # noqa: F401
from .instrumentation import (  # noqa: F401
    ChannelCounters,
    PerfProbe,
    ServeCounters,
    TranslationCounters,
)
from .lowering import (  # noqa: F401
    LoweredChain,
    PlanResult,
    TranslationCache,
)
from .scheduler import (  # noqa: F401
    DMARuntime,
    SubmitResult,
    default_runtime,
)
from .submit import SubmitRequest, Ticket  # noqa: F401
