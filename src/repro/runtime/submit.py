"""The unified submit contract (DESIGN.md §9).

Historically the four submission layers took divergent signatures and
returned different ticket types:

* ``Channel.submit(d, tickets, *, src_pool=, dst_pool=)``  → ``List[int]``
* ``DMARuntime.submit(d, *, src_pool=, dst_pool=, tier=)`` → ``SubmitResult``
* ``ServeEngine.submit(request)``                          → ``None``
* ``ShardedServeEngine.submit(request)``                   → ``int`` (shard)

This module defines the one contract all four now accept: a
:class:`SubmitRequest` (chain + transform + priority + completion
callback) in, a :class:`Ticket` out. The legacy keyword forms keep
working for one release behind deprecation shims (each layer detects a
non-``SubmitRequest`` first argument, emits a :class:`DeprecationWarning`
via :func:`warn_legacy_submit`, and returns the legacy type).

``Ticket`` subsumes the old ``SubmitResult`` — same leading fields in
the same positional order — so ``SubmitResult`` is now an alias and
existing unpacking/attribute code is unaffected.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, List, Optional

from repro.core.transform import TransformLike


def warn_legacy_submit(api: str) -> None:
    """One DeprecationWarning per legacy-keyword submit call site."""
    warnings.warn(
        f"{api} with legacy keyword arguments is deprecated; pass a "
        "SubmitRequest (repro.runtime.SubmitRequest). The keyword form "
        "is removed one release after 0.4.",
        DeprecationWarning, stacklevel=3)


@dataclasses.dataclass
class SubmitRequest:
    """One descriptor-chain (or serve-request) submission, any layer.

    ``chain`` + pool names drive the runtime/channel layers; ``request``
    carries a serve-level ``Request`` for the engine layers. ``transform``
    is anything :func:`repro.core.transform.as_transform` accepts.
    ``priority > 0`` asks the scheduler to place the chain on the
    eligible channel with the most free ring slots (head-of-line
    avoidance) instead of round-robin arbitration.
    """

    chain: Any = None
    request: Any = None
    src_pool: Optional[str] = None
    dst_pool: Optional[str] = None
    channel: Optional[str] = None
    tier: Optional[str] = None
    transform: TransformLike = None
    priority: int = 0
    on_complete: Optional[Callable[[Any], None]] = None
    run_coalescer: Optional[bool] = None


@dataclasses.dataclass
class Ticket:
    """What every unified submit path returns.

    The first four fields are the old ``SubmitResult`` layout (position
    and name); the trailing fields are filled by whichever layer has
    them (``slots`` by channels, ``shard`` by the sharded engine,
    ``uid`` by the serve engines, ``transform`` whenever a non-identity
    transform rode the submission).
    """

    tickets: List[int]
    channel: str
    spilled: bool
    coalesce: Any = None
    slots: Optional[List[int]] = None
    shard: Optional[int] = None
    uid: Optional[int] = None
    transform: str = ""


#: Deprecated alias — ``DMARuntime.submit`` used to return this.
SubmitResult = Ticket
