"""The unified submit contract (DESIGN.md §9).

Historically the four submission layers took divergent signatures and
returned different ticket types:

* ``Channel.submit(d, tickets, *, src_pool=, dst_pool=)``  → ``List[int]``
* ``DMARuntime.submit(d, *, src_pool=, dst_pool=, tier=)`` → ``SubmitResult``
* ``ServeEngine.submit(request)``                          → ``None``
* ``ShardedServeEngine.submit(request)``                   → ``int`` (shard)

This module defines the one contract all four now accept: a
:class:`SubmitRequest` (chain + transform + priority + completion
callback) in, a :class:`Ticket` out. The legacy keyword forms were
removed one release after 0.4 as promised: a non-``SubmitRequest``
first argument now raises ``TypeError`` at every layer
(``tools/lint_submit_api.py`` hard-fails on any resurrected form).

``Ticket`` subsumes the old ``SubmitResult`` — same leading fields in
the same positional order — so ``SubmitResult`` is now an alias and
existing unpacking/attribute code is unaffected.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional

from repro.core.transform import TransformLike


def reject_legacy_submit(api: str, first_arg: Any) -> None:
    """Uniform TypeError for the removed legacy keyword forms."""
    raise TypeError(
        f"{api} requires a SubmitRequest "
        "(repro.runtime.SubmitRequest); the legacy keyword form was "
        f"removed one release after 0.4 (got {type(first_arg).__name__})")


@dataclasses.dataclass
class SubmitRequest:
    """One descriptor-chain (or serve-request) submission, any layer.

    ``chain`` + pool names drive the runtime/channel layers; ``request``
    carries a serve-level ``Request`` for the engine layers. ``transform``
    is anything :func:`repro.core.transform.as_transform` accepts.
    ``priority > 0`` asks the scheduler to place the chain on the
    eligible channel with the most free ring slots (head-of-line
    avoidance) instead of round-robin arbitration.
    """

    chain: Any = None
    request: Any = None
    src_pool: Optional[str] = None
    dst_pool: Optional[str] = None
    channel: Optional[str] = None
    tier: Optional[str] = None
    transform: TransformLike = None
    priority: int = 0
    on_complete: Optional[Callable[[Any], None]] = None
    run_coalescer: Optional[bool] = None


@dataclasses.dataclass
class Ticket:
    """What every unified submit path returns.

    The first four fields are the old ``SubmitResult`` layout (position
    and name); the trailing fields are filled by whichever layer has
    them (``slots`` by channels, ``shard`` by the sharded engine,
    ``uid`` by the serve engines, ``transform`` whenever a non-identity
    transform rode the submission).
    """

    tickets: List[int]
    channel: str
    spilled: bool
    coalesce: Any = None
    slots: Optional[List[int]] = None
    shard: Optional[int] = None
    uid: Optional[int] = None
    transform: str = ""


#: Deprecated alias — ``DMARuntime.submit`` used to return this.
SubmitResult = Ticket
