"""Pre-submission descriptor planner: merge, split, lay out sequentially.

The paper builds irregular transfers from chains of simple linear segments
(§II-B); the runtime's coalescer is the software pass that makes those
chains cheap to execute:

* **merge** — adjacent-in-chain descriptors whose source AND destination
  ranges abut are fused into one longer descriptor (fewer launches, closer
  to Eq. 1's ideal payload/descriptor ratio);
* **split** — any descriptor longer than the engine's ``max_len`` burst is
  cut into ``max_len``-sized pieces (the u32 length field / max-burst rule);
* **layout** — the output chain is laid out in walk order at sequential
  table addresses, so the §II-C speculative prefetcher's hit rate is 1.0 by
  construction; :func:`coalesce` reports both the pre-layout hit rate the
  input chain would have seen and the post-layout rate, via
  :func:`repro.core.prefetch.estimate_hit_rate`.

Merging never crosses a descriptor with ``CONFIG_IRQ_ENABLE`` set (its
completion event is a per-descriptor contract) and only fuses descriptors
with identical config bits.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core.chain import walk_chain_host
from repro.core.descriptor import (
    DESCRIPTOR_BYTES,
    CONFIG_IRQ_ENABLE,
    DescriptorArray,
)
from repro.core.prefetch import estimate_hit_rate


@dataclasses.dataclass(frozen=True)
class CoalesceStats:
    n_in: int
    n_out: int
    merged: int            # descriptors eliminated by fusion
    split: int             # descriptors added by max_len splitting
    input_hit_rate: float  # §II-C hit rate of the chain as submitted
    output_hit_rate: float # hit rate after sequential layout (1.0 by constr.)
    provisioned_slack: int = 0  # sequential-layout slack the speculation
                                # policy asked for at plan time (0 = legacy
                                # caller without a policy)

    @property
    def merge_ratio(self) -> float:
        """n_in / n_out — >1 means the planner shrank the stream."""
        return self.n_in / max(self.n_out, 1)


def _chain_order_fields(d: DescriptorArray, head: int):
    order = walk_chain_host(d, head)
    src = np.asarray(d.src, np.int64)[order]
    dst = np.asarray(d.dst, np.int64)[order]
    ln = np.asarray(d.length, np.int64)[order]
    cfg = np.asarray(d.config, np.int64)[order]
    return order, src, dst, ln, cfg


def input_hit_rate(d: DescriptorArray, head: int = 0,
                   table_base: int = 0) -> float:
    """Hit rate a sequential speculator sees on the chain *as submitted*,
    i.e. with descriptor k stored at slot k of a sequential table."""
    order = walk_chain_host(d, head)
    addrs = table_base + np.asarray(order, np.int64) * DESCRIPTOR_BYTES
    return estimate_hit_rate(addrs)


def coalesce(
    d: DescriptorArray,
    *,
    max_len: int,
    head: int = 0,
    spec_depth: int = 0,
    allow_merge: bool = True,
) -> Tuple[DescriptorArray, CoalesceStats]:
    """Plan a chain for submission: merge, split, sequential layout.

    Returns ``(planned, stats)`` where ``planned`` executes bit-identically
    to ``d`` under serial chain semantics (same bytes moved in the same
    order), holds no descriptor longer than ``max_len``, and is chained
    ``0 -> 1 -> ... -> n-1`` (sequential layout).

    ``spec_depth`` is the sequential-layout slack the caller's speculation
    policy asked for (DESIGN.md §5): the planner must guarantee a §II-C
    prefetcher with that many outstanding slots never fetches off a
    sequential run. The full walk-order layout satisfies any depth by
    construction, so the depth is recorded in
    :attr:`CoalesceStats.provisioned_slack` (the planner's side of the
    feedback contract) rather than changing the plan; it never alters the
    planned chain, keeping ``FixedDepth`` callers bit-identical to the
    pre-policy planner.

    ``allow_merge=False`` disables the merge pass (split and sequential
    layout still run). The runtime sets it from the submission's
    :attr:`repro.core.transform.TransformSpec.merge_safe`: a transform
    whose source-view contiguity differs from pool contiguity (transpose)
    must execute its descriptors unfused.
    """
    if max_len < 1:
        raise ValueError("max_len must be >= 1")
    if spec_depth < 0:
        raise ValueError("spec_depth must be >= 0")
    n_in = d.num_descriptors
    order, src, dst, ln, cfg = _chain_order_fields(d, head)
    in_hit = estimate_hit_rate(
        np.asarray(order, np.int64) * DESCRIPTOR_BYTES)

    # -- merge pass (over chain order) -------------------------------------
    m_src: List[int] = []
    m_dst: List[int] = []
    m_len: List[int] = []
    m_cfg: List[int] = []
    merged = 0
    for k in range(len(order)):
        if ln[k] <= 0:
            continue   # completed / sentinel entries carry no payload
        if m_src:
            contiguous = (m_src[-1] + m_len[-1] == src[k]
                          and m_dst[-1] + m_len[-1] == dst[k])
            same_cfg = m_cfg[-1] == cfg[k]
            irq_barrier = bool(m_cfg[-1] & CONFIG_IRQ_ENABLE)
            if allow_merge and contiguous and same_cfg and not irq_barrier:
                m_len[-1] += int(ln[k])
                merged += 1
                continue
        m_src.append(int(src[k]))
        m_dst.append(int(dst[k]))
        m_len.append(int(ln[k]))
        m_cfg.append(int(cfg[k]))

    # -- split pass (max burst) --------------------------------------------
    o_src: List[int] = []
    o_dst: List[int] = []
    o_len: List[int] = []
    o_cfg: List[int] = []
    split = 0
    for s, t, l, c in zip(m_src, m_dst, m_len, m_cfg):
        off = 0
        first = True
        while l > 0:
            piece = min(l, max_len)
            o_src.append(s + off)
            o_dst.append(t + off)
            o_len.append(piece)
            # IRQ fires once per logical descriptor: keep it on the tail
            # piece only, so the event means "all bytes landed".
            if l > piece:
                o_cfg.append(c & ~int(CONFIG_IRQ_ENABLE))
            else:
                o_cfg.append(c)
            off += piece
            l -= piece
            if not first:
                split += 1
            first = False

    if not o_src:   # fully-sentinel input: keep a well-formed empty chain
        planned = DescriptorArray.create(
            np.zeros(0, np.int64), np.zeros(0, np.int64), np.zeros(0, np.int64))
        stats = CoalesceStats(n_in, 0, merged, split, in_hit, 1.0,
                              provisioned_slack=spec_depth)
        return planned, stats

    # -- sequential layout: 0 -> 1 -> ... -> -1 (hits by construction) -----
    planned = DescriptorArray.create(
        np.asarray(o_src, np.int64),
        np.asarray(o_dst, np.int64),
        np.asarray(o_len, np.int64),
        config=np.asarray(o_cfg, np.int64),
    )
    out_addrs = np.arange(len(o_src), dtype=np.int64) * DESCRIPTOR_BYTES
    stats = CoalesceStats(
        n_in=n_in,
        n_out=len(o_src),
        merged=merged,
        split=split,
        input_hit_rate=in_hit,
        output_hit_rate=estimate_hit_rate(out_addrs),
        provisioned_slack=spec_depth,
    )
    return planned, stats
