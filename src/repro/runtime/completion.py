"""Polled completion queues — §II-D writeback + optional IRQ-style events.

There are no interrupts on TPU (DESIGN.md §2), so completions are delivered
exactly the way the paper's frontend does when IRQs are masked: the engine
writes the all-ones sentinel into the descriptor's first 8 bytes, and a
poller observes it. On top of that, descriptors submitted with
``CONFIG_IRQ_ENABLE`` get an *event record* pushed into a per-runtime
completion queue the moment their ring entry retires — the software
analogue of the frontend's feedback logic (:func:`repro.core.engine
.completion_events`), still delivered by polling, never by preemption.

Callbacks registered per ticket run synchronously inside :meth:`poll` —
callers control exactly when completion code executes.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

from repro.obs.trace import Tracer, monotonic

from .ring import RingEntry


@dataclasses.dataclass(frozen=True)
class CompletionRecord:
    ticket: int
    channel: str
    slot: int
    irq: bool


class CompletionQueue:
    """FIFO of retired-descriptor events, drained by polling."""

    def __init__(self, maxlen: Optional[int] = None):
        self._events: Deque[CompletionRecord] = deque(maxlen=maxlen)
        self._callbacks: Dict[int, Callable[[CompletionRecord], None]] = {}
        self.delivered = 0
        self.dropped_irqless = 0
        self.tracer: Optional[Tracer] = None  # set via DMARuntime.attach_tracer
        self.track = "completion"

    def register(self, ticket: int,
                 callback: Callable[[CompletionRecord], None]) -> None:
        """Attach a per-descriptor callback, fired on poll after retirement."""
        self._callbacks[ticket] = callback

    def post_retired(self, channel: str, entries: List[RingEntry]) -> int:
        """Ingest retired ring entries; IRQ-enabled ones become events.

        Non-IRQ descriptors rely purely on the writeback being observed in
        the ring (mirroring hardware: no event, no trace) unless a callback
        was registered — a registered callback is an explicit request for
        notification, so those always enqueue.
        """
        n = 0
        for e in entries:
            wants_event = e.irq or e.ticket in self._callbacks
            if not wants_event:
                self.dropped_irqless += 1
                continue
            self._events.append(CompletionRecord(
                ticket=e.ticket, channel=channel, slot=e.slot, irq=e.irq))
            n += 1
        tr = self.tracer
        if n and tr is not None and tr.sampled(entries[0].ticket):
            tr.instant("retire", self.track, channel=channel, n_events=n,
                       first_ticket=int(entries[0].ticket))
        return n

    def __len__(self) -> int:
        return len(self._events)

    def poll(self, max_events: Optional[int] = None) -> List[CompletionRecord]:
        """Drain up to ``max_events`` records, firing callbacks in order."""
        tr = self.tracer
        t0 = monotonic() if tr is not None else 0.0
        out: List[CompletionRecord] = []
        while self._events and (max_events is None or len(out) < max_events):
            rec = self._events.popleft()
            cb = self._callbacks.pop(rec.ticket, None)
            if cb is not None:
                cb(rec)
            out.append(rec)
            self.delivered += 1
        if out and tr is not None and tr.sampled(out[0].ticket):
            tr.complete("completion.poll", self.track, t0 * 1e6,
                        (monotonic() - t0) * 1e6,
                        n_events=len(out), first_ticket=int(out[0].ticket))
        return out
