"""Chain-lowering JIT: a signature-keyed translation cache for dispatch.

The serve hot path submits structurally-identical descriptor chains step
after step (page reads against new bases, expert rows for new tokens).
Legacy dispatch re-plans each one with the Python coalescer and re-enters
the engine through shape-polymorphic jit entry points. This module is the
jace idiom applied to that path — translate once per abstract structure,
re-dispatch the cached artifact cheaply:

* :meth:`TranslationCache.plan` canonicalizes the chain
  (:mod:`repro.core.signature`), memoizes the *coalescer plan* on the
  chain's exact relative digest, and rebuilds the planned chain as pure
  vector ops — bit-identical to :func:`repro.runtime.coalesce.coalesce`
  (same descriptors, same stats), with the Python merge loop replaced by
  ``reduceat``/``repeat`` vector passes on a miss and a table lookup on a
  hit;
* :meth:`TranslationCache.lower` maps the plan's bucketed
  :class:`~repro.core.signature.ChainSignature` to a compiled
  :class:`LoweredChain` executor under an LRU bound, counting
  hit/miss/evict events into the attached
  :class:`~repro.runtime.instrumentation.PerfProbe`;
* :class:`LoweredChain` executes a planned chain through one of three
  fixed-shape artifacts — an ordered ``fori_loop`` copy for overlapping
  writes, a one-shot masked gather/scatter for disjoint chains, or the
  Pallas descriptor-copy mega-kernel for aligned uniform-unit chains and
  the fused ``blocked_2d`` drain. Operands are padded to the signature's
  pow2 buckets, so every chain in a bucket re-enters the same compiled
  code.

Correctness contract: a lowered drain must be bit-identical to the legacy
drain it replaces. ``LoweredChain.__call__`` therefore *declines* (returns
``None``) whenever the legacy engine's semantics could differ from the
oracle copy — the serial engine's fixed ``max_len`` window clamps near the
pool tail — or when pool dtypes mismatch; the caller then falls back to
the legacy path, trivially identical.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import OrderedDict
from typing import Dict, Optional, Tuple

from repro.obs.trace import monotonic

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.descriptor import (
    CONFIG_IRQ_ENABLE,
    DESCRIPTOR_BYTES,
    DescriptorArray,
)
from repro.core.prefetch import estimate_hit_rate
from repro.core.signature import (
    CanonicalChain,
    ChainSignature,
    canonicalize,
    pow2_bucket,
    signature_of,
)
from repro.core.transform import as_transform, kv8_roundtrip
from repro.optim.compress import BLOCK

from .coalesce import CoalesceStats
from .instrumentation import PerfProbe

DEFAULT_ARTIFACT_ENTRIES = 64
DEFAULT_PLAN_ENTRIES = 256


# ---------------------------------------------------------------------------
# Fixed-shape executors (module-level jits: shared across cache instances)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("width",))
def _vector_copy(src_off, dst_off, ln, src, dst, *, width: int):
    """One-shot masked gather/scatter over a padded descriptor block.

    Safe for any offsets (clip + ``mode="drop"``); padded entries carry
    ``ln < 0`` and write nothing. Requires disjoint dst ranges for
    chain-order equivalence — guaranteed by ``sig.overlap == False``.
    """
    offs = jnp.arange(width, dtype=jnp.int32)
    lnc = jnp.maximum(ln, 0)
    active = ln > 0
    sidx = jnp.clip(src_off[:, None] + offs[None, :], 0, src.shape[0] - 1)
    rows = src[sidx]
    valid = (offs[None, :] < lnc[:, None]) & active[:, None]
    didx = jnp.where(valid, dst_off[:, None] + offs[None, :], dst.shape[0])
    return dst.at[didx.reshape(-1)].set(
        jnp.where(valid, rows, 0).reshape(-1), mode="drop")


@functools.partial(jax.jit, static_argnames=("width",))
def _serial_copy(src_off, dst_off, ln, src, dst, *, width: int):
    """Chain-order copy: descriptor k's writes land after k-1's.

    Reads come from the original ``src`` operand throughout (the engines
    and the host oracle all snapshot the source pool before executing).
    """
    offs = jnp.arange(width, dtype=jnp.int32)
    n = src_off.shape[0]

    def body(k, buf):
        valid = (offs < ln[k]) & (ln[k] > 0)
        vals = src[jnp.clip(src_off[k] + offs, 0, src.shape[0] - 1)]
        didx = jnp.where(valid, dst_off[k] + offs, buf.shape[0])
        return buf.at[didx].set(jnp.where(valid, vals, 0), mode="drop")

    return jax.lax.fori_loop(0, n, body, dst)


# Transform-fused variants (DESIGN.md §9). jit-of-jit traces inline, so
# each is ONE fused XLA program: the kv8 round trip / zero-target + add
# compiles into the same artifact as the copy — no extra dispatch.

@functools.partial(jax.jit, static_argnames=("width",))
def _vector_copy_kv8(src_off, dst_off, ln, src, dst, *, width: int):
    return _vector_copy(src_off, dst_off, ln, kv8_roundtrip(src), dst,
                        width=width)


@functools.partial(jax.jit, static_argnames=("width",))
def _serial_copy_kv8(src_off, dst_off, ln, src, dst, *, width: int):
    return _serial_copy(src_off, dst_off, ln, kv8_roundtrip(src), dst,
                        width=width)


@functools.partial(jax.jit, static_argnames=("width",))
def _vector_copy_sum(src_off, dst_off, ln, src, dst, *, width: int):
    return dst + _vector_copy(src_off, dst_off, ln, src,
                              jnp.zeros_like(dst), width=width)


@functools.partial(jax.jit, static_argnames=("width",))
def _serial_copy_sum(src_off, dst_off, ln, src, dst, *, width: int):
    return dst + _serial_copy(src_off, dst_off, ln, src,
                              jnp.zeros_like(dst), width=width)


#: (mode, transform token) -> fused executor. Tokens outside this table
#: (transpose) have no compiled artifact: the lowered path declines and
#: the channel's legacy transformed drain runs instead.
_EXEC = {
    ("vector", ""): _vector_copy,
    ("serial", ""): _serial_copy,
    ("vector", "kv8"): _vector_copy_kv8,
    ("serial", "kv8"): _serial_copy_kv8,
    ("vector", "sum"): _vector_copy_sum,
    ("serial", "sum"): _serial_copy_sum,
}

#: Tokens the lowered serial path can fuse.
FUSEABLE_TOKENS = ("", "kv8", "sum")


def _pad_block(so: np.ndarray, do: np.ndarray, ln: np.ndarray,
               n_pad: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad operands to the signature's descriptor bucket (ln == -1 idle)."""
    pad = n_pad - so.shape[0]
    if pad <= 0:
        return so, do, ln
    z = np.zeros(pad, so.dtype)
    return (np.concatenate([so, z]), np.concatenate([do, z]),
            np.concatenate([ln, np.full(pad, -1, ln.dtype)]))


class LoweredChain:
    """The compiled artifact for one signature bucket.

    Callable as ``lowered(descs, src, dst, max_len=...) -> dst' | None``;
    ``None`` means "not safe to substitute for the legacy engine here —
    run the legacy path". ``dispatches`` counts successful substitutions
    (one artifact, many dispatches, is the whole point).
    """

    def __init__(self, sig: ChainSignature):
        self.sig = sig
        if sig.tier == "blocked_2d":
            self.mode = "rows2d"
        elif sig.overlap:
            self.mode = "serial"
        else:
            self.mode = "vector"
        self.dispatches = 0

    # -- row-pool artifact (fused blocked_2d drain) --------------------------
    def _call_rows2d(self, d: DescriptorArray, src: jax.Array,
                     dst: jax.Array) -> Optional[jax.Array]:
        from repro.kernels.descriptor_copy import descriptor_copy_bucketed
        from repro.kernels.ops import _interpret

        if self.sig.transform:
            return None   # fused 2-D batches are identity-only
        shape = dst.shape
        src2 = src.reshape(src.shape[0], -1)
        dst2 = dst.reshape(dst.shape[0], -1)
        if src2.shape[1] != dst2.shape[1] or src2.dtype != dst2.dtype:
            return None
        active = np.asarray(d.length) >= 0
        sidx = np.where(active, np.asarray(d.src, np.int32), -1)
        didx = np.where(active, np.asarray(d.dst, np.int32), -1)
        self.dispatches += 1
        out = descriptor_copy_bucketed(
            jnp.asarray(sidx), jnp.asarray(didx), src2, dst2,
            n_bucket=self.sig.n_class, interpret=_interpret())
        return out.reshape(shape)

    # -- linear-pool artifacts (serial tier) ---------------------------------
    def __call__(self, d: DescriptorArray, src: jax.Array, dst: jax.Array,
                 *, max_len: int = 0) -> Optional[jax.Array]:
        if self.mode == "rows2d":
            return self._call_rows2d(d, src, dst)
        n = d.num_descriptors
        if n > self.sig.n_class or src.ndim != 1 or dst.ndim != 1 \
                or src.dtype != dst.dtype:
            return None
        so = np.asarray(d.src, np.int32)
        do = np.asarray(d.dst, np.int32)
        ln = np.asarray(d.length, np.int32)
        if n and max_len > 0:
            # Legacy-fidelity guard: execute_serial copies through a fixed
            # max_len window whose dynamic_slice clamps near the pool tail,
            # diverging from the oracle there. Decline rather than differ.
            if int(so.max()) + max_len > src.shape[0] \
                    or int(do.max()) + max_len > dst.shape[0]:
                return None
        so, do, ln = _pad_block(so, do, ln, self.sig.n_class)
        unit = self.sig.unit
        token = self.sig.transform
        if (self.mode == "vector" and unit > 0 and self.sig.aligned
                and token in ("", "kv8")
                and src.shape[0] % unit == 0 and dst.shape[0] % unit == 0
                and not np.any(so % unit) and not np.any(do % unit)):
            from repro.kernels.descriptor_copy import descriptor_copy_bucketed
            from repro.kernels.ops import _interpret
            # The kv8 Pallas route needs row-local 256-blocks to equal the
            # pool-absolute blocks of the transform contract: offsets are
            # unit-multiples and the pool is a unit-multiple long, so
            # unit % BLOCK == 0 makes the partitions coincide exactly.
            kv8_ok = (token == "kv8" and unit % BLOCK == 0
                      and src.dtype == jnp.float32)
            if not _interpret() and (token == "" or kv8_ok):
                # Uniform aligned units on TPU: whole-row moves through the
                # Pallas mega-kernel over the unit-reshaped pools.
                sidx = jnp.asarray(np.where(ln == unit, so // unit, -1))
                didx = jnp.asarray(np.where(ln == unit, do // unit, -1))
                self.dispatches += 1
                if token == "kv8":
                    from repro.kernels.quantize_copy import (
                        quantize_copy_bucketed,
                    )
                    out = quantize_copy_bucketed(
                        sidx, didx, src.reshape(-1, unit),
                        dst.reshape(-1, unit),
                        n_bucket=self.sig.n_class, interpret=False)
                else:
                    out = descriptor_copy_bucketed(
                        sidx, didx, src.reshape(-1, unit),
                        dst.reshape(-1, unit),
                        n_bucket=self.sig.n_class, interpret=False)
                return out.reshape(dst.shape)
        fn = _EXEC.get((self.mode, token))
        if fn is None:
            return None
        self.dispatches += 1
        return fn(jnp.asarray(so), jnp.asarray(do), jnp.asarray(ln),
                  src, dst, width=self.sig.unit_class)


# ---------------------------------------------------------------------------
# Vectorized coalescer plan (bit-identical to runtime.coalesce.coalesce)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Plan:
    """Memoized, base-address-relative coalescer output for one digest."""

    n_in: int
    n_out: int
    merged: int
    split: int
    in_hit: float
    out_hit: float
    rel_src: np.ndarray
    rel_dst: np.ndarray
    length: np.ndarray
    config: np.ndarray
    sig0: ChainSignature     # tier=""/depth=0 template; rebound per call


def _plan_relative(canon: CanonicalChain, max_len: int,
                   allow_merge: bool = True) -> _Plan:
    """Merge + split + sequential layout as vector passes.

    Element-wise contiguity against the predecessor is equivalent to the
    legacy loop's check against the accumulated run end: a run's end
    always equals its last member's end, so the transitive closure of the
    pairwise predicate reproduces the greedy loop exactly.
    ``allow_merge=False`` mirrors ``coalesce(..., allow_merge=False)``:
    every descriptor starts its own run (merge-unsafe transforms).
    """
    irq = int(CONFIG_IRQ_ENABLE)
    in_hit = estimate_hit_rate(canon.order * DESCRIPTOR_BYTES)
    act = canon.length > 0
    src, dst = canon.rel_src[act], canon.rel_dst[act]
    ln, cfg = canon.length[act], canon.config[act]
    n = int(ln.size)
    if n == 0:
        empty = np.zeros(0, np.int64)
        sig0 = signature_of(
            CanonicalChain(0, empty, empty, empty, empty, empty, 0, 0),
            tier="")
        return _Plan(canon.n_raw, 0, 0, 0, in_hit, 1.0,
                     empty, empty, empty, empty, sig0)

    if allow_merge:
        mergeable = ((src[1:] == src[:-1] + ln[:-1])
                     & (dst[1:] == dst[:-1] + ln[:-1])
                     & (cfg[1:] == cfg[:-1])
                     & ((cfg[:-1] & irq) == 0))
    else:
        mergeable = np.zeros(max(n - 1, 0), bool)
    brk = np.empty(n, bool)
    brk[0] = True
    brk[1:] = ~mergeable
    starts = np.flatnonzero(brk)
    run_len = np.add.reduceat(ln, starts)
    run_src, run_dst, run_cfg = src[starts], dst[starts], cfg[starts]

    pieces = -(-run_len // max_len)          # ceil-div, run_len > 0
    n_out = int(pieces.sum())
    rep = np.repeat(np.arange(starts.size), pieces)
    first = np.zeros(starts.size, np.int64)
    np.cumsum(pieces[:-1], out=first[1:])
    off = (np.arange(n_out, dtype=np.int64) - first[rep]) * max_len
    o_src = run_src[rep] + off
    o_dst = run_dst[rep] + off
    o_len = np.minimum(run_len[rep] - off, max_len)
    tail = off + o_len == run_len[rep]       # IRQ only once all bytes landed
    o_cfg = np.where(tail, run_cfg[rep], run_cfg[rep] & ~irq)

    sig0 = signature_of(
        CanonicalChain(n_out, np.arange(n_out, dtype=np.int64),
                       o_src - o_src[0], o_dst - o_dst[0],
                       o_len, o_cfg, 0, 0),
        tier="")
    return _Plan(
        n_in=canon.n_raw, n_out=n_out,
        merged=n - int(starts.size), split=n_out - int(starts.size),
        in_hit=in_hit,
        out_hit=estimate_hit_rate(
            np.arange(n_out, dtype=np.int64) * DESCRIPTOR_BYTES),
        rel_src=o_src, rel_dst=o_dst, length=o_len, config=o_cfg,
        sig0=sig0)


# ---------------------------------------------------------------------------
# The cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PlanResult:
    """What :meth:`TranslationCache.plan` hands the scheduler."""

    planned: DescriptorArray
    stats: CoalesceStats
    signature: ChainSignature
    lowered: Optional[LoweredChain]
    digest: bytes


def disabled_stats() -> Dict[str, object]:
    """The counter block reported when translation is switched off."""
    return {"enabled": False, "hits": 0, "misses": 0, "evictions": 0,
            "size": 0, "capacity": 0, "lookups": 0, "hit_rate": 0.0,
            "plan_hits": 0, "plan_misses": 0,
            "transform_lookups": 0, "transform_fused": 0,
            "transform_fusion_hit_rate": 0.0}


def aggregate_stats(blocks) -> Dict[str, object]:
    """Sum per-shard translation-cache counter blocks (sharded serving).

    Inputs and output are *raw* bare-key blocks; the public surfaces wrap
    the result in the unified namespace (``repro.obs.counters``).
    """
    out = disabled_stats()
    for b in blocks:
        out["enabled"] = out["enabled"] or bool(b.get("enabled"))
        for k in ("hits", "misses", "evictions", "size", "capacity",
                  "lookups", "plan_hits", "plan_misses",
                  "transform_lookups", "transform_fused"):
            out[k] += int(b.get(k, 0))
    out["hit_rate"] = out["hits"] / out["lookups"] if out["lookups"] else 0.0
    out["transform_fusion_hit_rate"] = (
        out["transform_fused"] / out["transform_lookups"]
        if out["transform_lookups"] else 0.0)
    return out


def translate_chain(d: DescriptorArray, table, row_elems: int,
                    *, translate_dst: bool = True) -> DescriptorArray:
    """Lower a *virtual* page chain onto physical slots (DESIGN.md §11).

    Each descriptor's src/dst offset is split into (vpage, in-page
    offset) at ``row_elems`` granularity and the vpage is rewritten to
    the owning :class:`repro.mmu.PageTable` slot. Chain structure (order,
    lengths, config, links) is untouched, so the *virtual* chain's
    :class:`~repro.core.signature.CanonicalChain` digest is stable across
    remaps — remapping changes only where this lowering lands it.
    Pending (slot ``-1``) pages must be resolved by the pool before
    translation; they raise here rather than corrupt an address.
    """
    if row_elems < 1:
        raise ValueError("row_elems must be >= 1")

    def _xlate(off: np.ndarray) -> np.ndarray:
        vp, rem = np.divmod(np.asarray(off, np.int64), row_elems)
        slots = table.slots_of(vp)
        if np.any(slots < 0):
            bad = sorted(np.asarray(vp)[slots < 0].tolist())
            raise RuntimeError(
                f"translate_chain: vpages {bad[:8]} are pending an "
                "ownership pull; resolve residency before lowering")
        return slots * row_elems + rem

    src = _xlate(d.src)
    dst = _xlate(d.dst) if translate_dst else np.asarray(d.dst, np.int64)
    return DescriptorArray.create(src, dst, np.asarray(d.length, np.int64),
                                  nxt=np.asarray(d.nxt, np.int64),
                                  config=np.asarray(d.config, np.int64))


class TranslationCache:
    """Signature-keyed artifact LRU + digest-keyed plan memo."""

    def __init__(self, max_entries: int = DEFAULT_ARTIFACT_ENTRIES,
                 plan_entries: int = DEFAULT_PLAN_ENTRIES):
        if max_entries < 1 or plan_entries < 1:
            raise ValueError("cache bounds must be >= 1")
        self.max_entries = max_entries
        self.plan_entries = plan_entries
        self._artifacts: "OrderedDict[ChainSignature, LoweredChain]" = \
            OrderedDict()
        self._plans: "OrderedDict[Tuple[bytes, int], _Plan]" = OrderedDict()
        self._seq: "OrderedDict[bytes, bool]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.plan_hits = 0
        self.plan_misses = 0
        self.transform_lookups = 0
        self.transform_fused = 0
        self.probe: Optional[PerfProbe] = None
        self.tracer = None          # repro.obs.trace.Tracer, via attach_tracer
        self.track = "translation"

    # -- instrumentation -----------------------------------------------------
    def attach_probe(self, probe: Optional[PerfProbe]) -> None:
        self.probe = probe

    def attach_tracer(self, tracer) -> None:
        """Attach (or with None, detach) a lifecycle span tracer."""
        self.tracer = tracer

    def _event(self, event: str) -> None:
        if self.probe is not None:
            self.probe.on_translation(event)

    def stats(self) -> Dict[str, object]:
        lookups = self.hits + self.misses
        return {
            "enabled": True,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "size": len(self._artifacts),
            "capacity": self.max_entries,
            "lookups": lookups,
            "hit_rate": self.hits / lookups if lookups else 0.0,
            "plan_hits": self.plan_hits,
            "plan_misses": self.plan_misses,
            "transform_lookups": self.transform_lookups,
            "transform_fused": self.transform_fused,
            "transform_fusion_hit_rate": (
                self.transform_fused / self.transform_lookups
                if self.transform_lookups else 0.0),
        }

    # -- plan memo -----------------------------------------------------------
    def plan(self, d: DescriptorArray, *, max_len: int, spec_depth: int = 0,
             tier: str = "serial", head: int = 0,
             transform=None) -> Optional[PlanResult]:
        """Coalesce ``d`` through the memo; None -> caller runs legacy.

        The returned planned chain and stats are bit-identical to
        ``coalesce(d, max_len=max_len, spec_depth=spec_depth,
        allow_merge=transform.merge_safe)``; malformed chains (cycles,
        bad links) decline so the legacy walker raises its canonical
        error. A non-identity ``transform`` joins the signature as its
        :attr:`~repro.core.transform.TransformSpec.cache_token`, so the
        compiled artifact fuses the transform (DESIGN.md §9).
        """
        if max_len < 1 or spec_depth < 0:
            return None
        spec = as_transform(transform)
        token = spec.cache_token
        allow_merge = spec.merge_safe
        tr = self.tracer
        rec = tr is not None and tr.sampled(self.plan_hits
                                            + self.plan_misses)
        p0 = monotonic() if rec else 0.0
        canon = canonicalize(d, head)
        if canon is None:
            return None
        key = (canon.digest, int(max_len), allow_merge)
        plan = self._plans.get(key)
        plan_was_hit = plan is not None
        if plan is not None:
            self._plans.move_to_end(key)
            self.plan_hits += 1
            self._event("plan_hit")
        else:
            plan = _plan_relative(canon, max_len, allow_merge)
            self._plans[key] = plan
            self.plan_misses += 1
            self._event("plan_miss")
            while len(self._plans) > self.plan_entries:
                self._plans.popitem(last=False)

        if plan.n_out == 0:
            planned = DescriptorArray.create(
                np.zeros(0, np.int64), np.zeros(0, np.int64),
                np.zeros(0, np.int64))
        else:
            planned = DescriptorArray.create(
                plan.rel_src + canon.src_base,
                plan.rel_dst + canon.dst_base,
                plan.length, config=plan.config)
        stats = CoalesceStats(
            n_in=plan.n_in, n_out=plan.n_out, merged=plan.merged,
            split=plan.split, input_hit_rate=plan.in_hit,
            output_hit_rate=plan.out_hit, provisioned_slack=spec_depth)
        sig = dataclasses.replace(
            plan.sig0, tier=tier,
            depth_class=pow2_bucket(spec_depth) if spec_depth else 0,
            transform=token)
        fuseable = token in FUSEABLE_TOKENS
        lowered = self.lower(sig) \
            if tier == "serial" and plan.n_out and fuseable else None
        if token:
            self.transform_lookups += 1
            self._event("transform_lookup")
            if lowered is not None:
                self.transform_fused += 1
                self._event("transform_fused")
        if rec:
            tr.complete("translate.plan", self.track, p0 * 1e6,
                        (monotonic() - p0) * 1e6,
                        result="plan_hit" if plan_was_hit else "plan_miss",
                        digest=canon.digest[:6].hex(),
                        n_out=plan.n_out)
        return PlanResult(planned, stats, sig, lowered, canon.digest)

    # -- artifact LRU --------------------------------------------------------
    def lower(self, sig: ChainSignature) -> LoweredChain:
        """Artifact for a signature: LRU get-or-compile with counters."""
        tr = self.tracer
        rec = tr is not None and tr.sampled(self.hits + self.misses)
        art = self._artifacts.get(sig)
        if art is not None:
            self._artifacts.move_to_end(sig)
            self.hits += 1
            self._event("hit")
            if rec:
                tr.instant("translate.hit", self.track, tier=sig.tier)
            return art
        t0 = monotonic() if rec else 0.0
        art = LoweredChain(sig)
        self.misses += 1
        self._event("miss")
        if rec:
            tr.complete("translate.compile", self.track, t0 * 1e6,
                        (monotonic() - t0) * 1e6, tier=sig.tier)
        self._artifacts[sig] = art
        while len(self._artifacts) > self.max_entries:
            self._artifacts.popitem(last=False)
            self.evictions += 1
            self._event("evict")
        return art

    # -- fused blocked_2d route ---------------------------------------------
    def execute_rows_2d(self, d: DescriptorArray, src: jax.Array,
                        dst: jax.Array) -> Optional[jax.Array]:
        """Lowered drain for a fused row-move batch; None -> legacy path.

        Engages only on TPU (interpret-mode Pallas would serialize the
        grid in Python) and only when every active destination row is
        unique — duplicate rows rely on the legacy scatter's resolution
        order, which the in-order kernel grid must not silently change.
        """
        from repro.kernels.ops import _interpret
        if _interpret() or src.ndim < 2 or dst.ndim < 2:
            return None
        if src.reshape(src.shape[0], -1).shape[1] \
                != dst.reshape(dst.shape[0], -1).shape[1] \
                or src.dtype != dst.dtype:
            return None
        ad = np.asarray(d.dst)[np.asarray(d.length) >= 0]
        if np.unique(ad).size != ad.size:
            return None
        sig = ChainSignature(
            tier="blocked_2d", n_class=pow2_bucket(d.num_descriptors),
            unit_class=1, layout="gather", unit=1, overlap=False,
            aligned=True, depth_class=0)
        return self.lower(sig)(d, src, dst)

    # -- memoized chain-shape predicates (scheduler satellites) --------------
    def is_sequential(self, d: DescriptorArray) -> bool:
        """Digest-memoized `nxt == [1..n-1, -1]` check."""
        key = np.asarray(d.nxt, np.int64).tobytes()
        hit = self._seq.get(key)
        if hit is not None:
            self._seq.move_to_end(key)
            return hit
        n = d.num_descriptors
        want = np.concatenate([np.arange(1, n), [-1]])
        res = bool(np.array_equal(np.asarray(d.nxt), want))
        self._seq[key] = res
        while len(self._seq) > self.plan_entries:
            self._seq.popitem(last=False)
        return res
