"""Virtual DMA channels: one submission ring + one engine tier each.

The paper's DMAC exposes a single frontend; related engines (iDMA,
arXiv:2305.05240) generalize this to multiple frontends feeding a shared
backend through explicit request queues. The runtime's :class:`Channel` is
that frontend: callers submit descriptor chains into the channel's ring,
and a later *drain* step executes them on the channel's engine tier:

* ``serial``     — :func:`repro.core.engine.execute_serial`, chain-order
                   preserving (irregular streams with overlapping writes);
* ``blocked``    — :func:`repro.core.engine.execute_blocked`, vectorized
                   uniform-unit streams over 1-D pools;
* ``blocked_2d`` — :func:`repro.core.engine.execute_blocked_2d` row moves
                   over row pools; with ``use_kernel=True`` the drain is
                   driven through the Pallas descriptor-copy kernel
                   (:func:`repro.kernels.descriptor_copy_op`);
* ``control``    — no data movement: entries complete only via the owner's
                   out-of-band §II-D writeback (serve-request markers).

Arbitration between channels is round-robin or smooth weighted round-robin,
mirroring the fair RR bus arbiter of the paper's §III-A testbench.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.descriptor import (
    CONFIG_IRQ_ENABLE,
    DescriptorArray,
    to_packed,
)
from repro.core.speculation import DEFAULT_POLICY, DepthController
from repro.core.engine import (
    execute_blocked,
    execute_blocked_2d,
    execute_serial,
)
from repro.core.transform import (
    TransformSpec,
    as_transform,
    transform_source_view,
)

from repro.obs.trace import Tracer, monotonic

from .completion import CompletionQueue
from .instrumentation import PerfProbe
from .ring import RingFull, SubmissionRing
from .submit import SubmitRequest, Ticket, reject_legacy_submit

TIERS = ("serial", "blocked", "blocked_2d", "control")


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    name: str
    tier: str = "serial"
    ring_capacity: int = 64
    weight: int = 1            # weighted-arbitration share
    max_len: int = 128         # serial tier: static max burst (elements)
    unit: int = 1              # blocked tier: uniform transfer unit
    use_kernel: bool = False   # blocked_2d tier: drain via Pallas kernel

    def __post_init__(self):
        if self.tier not in TIERS:
            raise ValueError(f"unknown tier {self.tier!r}; one of {TIERS}")
        if self.weight < 1:
            raise ValueError("channel weight must be >= 1")


@dataclasses.dataclass
class _Batch:
    """One submitted chain, pending execution on the channel's tier."""

    tickets: List[int]
    slots: List[int]
    descs: DescriptorArray
    src_pool: Optional[str]
    dst_pool: Optional[str]
    # Compiled executor from the translation cache (repro.runtime.lowering);
    # None drains through the legacy tier engine.
    lowered: Optional[object] = None
    # In-flight transform riding this chain (DESIGN.md §9); None/identity
    # drains exactly as before.
    transform: Optional[TransformSpec] = None


@dataclasses.dataclass
class ChannelStats:
    submitted: int = 0         # descriptors accepted into the ring
    drained: int = 0           # descriptors executed
    batches: int = 0           # drain calls that executed work
    retired: int = 0           # ring entries retired past head
    ring_full_events: int = 0  # backpressure occurrences
    occupancy_peak: int = 0    # ring high-water mark (slots in use)
    drain_seconds: float = 0.0 # wall-clock spent executing batches
    speculation_depth: int = 0 # live §II-C depth of this channel's policy


class Channel:
    def __init__(self, cfg: ChannelConfig, completion: CompletionQueue,
                 spec: Optional[DepthController] = None):
        self.cfg = cfg
        self.ring = SubmissionRing(cfg.ring_capacity)
        self.completion = completion
        self.pending: Deque[_Batch] = deque()
        self.stats = ChannelStats()
        self.probe: Optional[PerfProbe] = None  # set via DMARuntime.attach_probe
        self.tracer: Optional[Tracer] = None    # set via DMARuntime.attach_tracer
        self.track = cfg.name                   # tracer track (shard-prefixed)
        # Per-channel speculation controller (DESIGN.md §5): the coalescer
        # asks it for layout slack before planning; the measured input hit
        # rate of each submission feeds back through observe_speculation.
        self.spec: DepthController = spec or DEFAULT_POLICY.make_controller()
        self.stats.speculation_depth = self.spec.depth

    @property
    def name(self) -> str:
        return self.cfg.name

    @property
    def speculation_depth(self) -> int:
        """Live depth of this channel's speculation policy."""
        return self.spec.depth

    def observe_speculation(self, hit_rate: float) -> int:
        """Close the §II-C feedback loop for one submission.

        The *measurer* is the coalescer (input hit rate of the submitted
        chain); the *decider* is the channel's policy controller. Depth may
        change only here — between submissions, never mid-drain.
        """
        depth = self.spec.observe(hit_rate)
        self.stats.speculation_depth = depth
        if self.probe is not None:
            self.probe.on_depth(self.name, depth)
        return depth

    # -- submission ---------------------------------------------------------
    def can_accept(self, n_descriptors: int) -> bool:
        return self.ring.free_slots >= n_descriptors

    def submit(
        self,
        d,
        tickets: Sequence[int],
        *,
        lowered: Optional[object] = None,
    ) -> Ticket:
        """Push one chain into the ring; raises RingFull under backpressure.

        Unified form (DESIGN.md §9): ``submit(SubmitRequest, tickets,
        lowered=...) -> Ticket``. ``tickets`` and ``lowered`` stay
        call-level operands (the scheduler allocates tickets and holds
        the compiled artifact). The legacy keyword form was removed one
        release after 0.4; a bare chain raises ``TypeError``.
        """
        if not isinstance(d, SubmitRequest):
            reject_legacy_submit("Channel.submit", d)
        spec = as_transform(d.transform)
        slots = self._push(d.chain, tickets, d.src_pool, d.dst_pool,
                           lowered, spec)
        return Ticket(tickets=list(map(int, tickets)),
                      channel=self.name, spilled=False,
                      slots=slots, transform=spec.cache_token)

    def _push(
        self,
        d: DescriptorArray,
        tickets: Sequence[int],
        src_pool: Optional[str],
        dst_pool: Optional[str],
        lowered: Optional[object],
        transform: Optional[TransformSpec],
    ) -> List[int]:
        n = d.num_descriptors
        if n != len(tickets):
            raise ValueError("one ticket per descriptor")
        packed = to_packed(d)
        irq = (np.asarray(d.config) & int(CONFIG_IRQ_ENABLE)) != 0
        try:
            slots = self.ring.push_table(packed, tickets, irq=irq)
        except RingFull:
            self.stats.ring_full_events += 1
            if self.probe is not None:
                self.probe.on_ring_full(self.name)
            tr = self.tracer
            if tr is not None and tickets and tr.sampled(tickets[0]):
                tr.instant("ring_full", self.track, ticket=int(tickets[0]),
                           n=n)
            raise
        self.stats.submitted += n
        occupancy = self.ring.capacity - self.ring.free_slots
        if occupancy > self.stats.occupancy_peak:
            self.stats.occupancy_peak = occupancy
        if self.probe is not None:
            self.probe.on_occupancy(self.name, occupancy)
        if self.cfg.tier != "control":
            self.pending.append(_Batch(list(map(int, tickets)), slots, d,
                                       src_pool, dst_pool, lowered,
                                       transform))
        return slots

    # -- execution ----------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self.pending)

    def _execute(self, d: DescriptorArray, src: jax.Array,
                 dst: jax.Array) -> jax.Array:
        tier = self.cfg.tier
        if tier == "serial":
            out, _ = execute_serial(d, src, dst, max_len=self.cfg.max_len)
        elif tier == "blocked":
            out, _ = execute_blocked(d, src, dst, unit=self.cfg.unit)
        elif tier == "blocked_2d":
            if self.cfg.use_kernel:
                from repro.kernels import descriptor_copy_op
                shape = dst.shape
                src2 = src.reshape(src.shape[0], -1)
                dst2 = dst.reshape(dst.shape[0], -1)
                active = np.asarray(d.length) >= 0
                sidx = jnp.where(jnp.asarray(active), d.src, -1)
                didx = jnp.where(jnp.asarray(active), d.dst, -1)
                out = descriptor_copy_op(sidx, didx, src2, dst2).reshape(shape)
            else:
                out, _ = execute_blocked_2d(d, src, dst)
        else:
            raise ValueError(f"tier {tier!r} carries no data")
        return out

    def _execute_transformed(self, t: Optional[TransformSpec],
                             d: DescriptorArray, src: jax.Array,
                             dst: jax.Array) -> jax.Array:
        """Legacy-engine drain with the in-flight transform applied.

        Read-side transforms (kv_int8, transpose) substitute the source
        pool with its transformed view; reduce_sum copies into a zero
        target (chain-order last-write-wins) and adds it into the
        destination — the semantics :func:`repro.core.transform.
        reference_apply` oracles.
        """
        if t is None or t.is_identity:
            return self._execute(d, src, dst)
        if t.kind == "reduce_sum":
            copied = self._execute(d, src, jnp.zeros_like(dst))
            return dst + copied
        return self._execute(d, transform_source_view(t, src), dst)

    def drain_one(self, pools: Dict[str, jax.Array]) -> bool:
        """Execute the oldest pending batch against the named pools.

        Mutates ``pools[dst_pool]`` with the transferred data, writes the
        §II-D completion into every ring slot of the batch, then retires
        the ring into the completion queue. Returns True if work ran.
        """
        if not self.pending:
            return self._retire()
        b = self.pending.popleft()
        src = pools[b.src_pool]
        dst = pools[b.dst_pool]
        t0 = monotonic()
        out = None
        if b.lowered is not None:
            # Translation-cache fast path: a compiled artifact for this
            # chain's signature (transform token included, so a fused
            # artifact applies the transform). It declines (None) whenever
            # substituting for the legacy engine could change a single bit.
            out = b.lowered(b.descs, src, dst, max_len=self.cfg.max_len)
        if out is None:
            out = self._execute_transformed(b.transform, b.descs, src, dst)
        pools[b.dst_pool] = out
        dt = monotonic() - t0
        for slot in b.slots:
            self.ring.mark_done(slot)
        self.stats.drained += b.descs.num_descriptors
        self.stats.batches += 1
        self.stats.drain_seconds += dt
        if self.probe is not None:
            self.probe.on_drain(self.name,
                                n_descriptors=b.descs.num_descriptors,
                                seconds=dt)
        tr = self.tracer
        if tr is not None and b.tickets and tr.sampled(b.tickets[0]):
            tr.complete("drain", self.track, t0 * 1e6, dt * 1e6,
                        ticket=b.tickets[0],
                        n=b.descs.num_descriptors,
                        lowered=b.lowered is not None)
            # every slot of the batch just received its §II-D all-ones
            # writeback (mark_done above) — one instant marks the batch
            tr.instant("writeback", self.track, ticket=b.tickets[0],
                       n_slots=len(b.slots))
        self._retire()
        return True

    def _retire(self) -> bool:
        entries = self.ring.retire()
        if entries:
            self.stats.retired += len(entries)
            self.completion.post_retired(self.name, entries)
        return False


# ---------------------------------------------------------------------------
# Arbitration
# ---------------------------------------------------------------------------

class RoundRobinArbiter:
    """Fair RR over channel names; skips ineligible channels."""

    def __init__(self, names: Sequence[str]):
        self._names = list(names)
        self._i = 0

    def pick(self, eligible: Sequence[str]) -> Optional[str]:
        if not self._names:
            return None
        eligible = set(eligible)
        for k in range(len(self._names)):
            cand = self._names[(self._i + k) % len(self._names)]
            if cand in eligible:
                self._i = (self._i + k + 1) % len(self._names)
                return cand
        return None


class WeightedArbiter:
    """Smooth weighted round-robin (nginx-style): each pick, every
    channel's credit grows by its weight; the max-credit eligible channel
    wins and pays back the total weight. Long-run selection frequencies are
    proportional to weights, with no bursts."""

    def __init__(self, weights: Dict[str, int]):
        if not weights:
            raise ValueError("need at least one channel")
        self._weights = dict(weights)
        self._credit = {k: 0 for k in weights}

    def pick(self, eligible: Sequence[str]) -> Optional[str]:
        eligible = [e for e in eligible if e in self._weights]
        if not eligible:
            return None
        for k, w in self._weights.items():
            self._credit[k] += w
        best = max(eligible, key=lambda k: (self._credit[k], k))
        self._credit[best] -= sum(self._weights.values())
        return best
