"""Perf instrumentation: the counters the scenario sweep observes.

The perf-regression gate (:mod:`repro.perf`) must measure what the runtime
*actually did* — descriptors accepted, coalescer output, ring occupancy,
drain batches — not re-derive those numbers from its own bookkeeping. A
:class:`PerfProbe` is a passive per-channel counter sink attached to a
:class:`repro.runtime.DMARuntime` (``attach_probe``) and, optionally, a
:class:`repro.serve.engine.ServeEngine`. Hook sites:

* ``DMARuntime.submit``   — post-coalesce descriptor counts, §II-C input
                            hit rate, wall-clock launch seconds;
* ``Channel.submit``      — ring occupancy high-water mark, ring-full
                            backpressure events;
* ``Channel.drain_one`` / ``DMARuntime._execute_fused``
                          — drained descriptor counts and drain seconds
                            (fused batches credited per channel);
* ``Channel.observe_speculation``
                          — speculation-policy depth updates (live depth,
                            update count, peak/floor — DESIGN.md §5);
* ``ServeEngine.step``    — active-slot occupancy, step seconds, and
                            admission stalls (queued requests, no slot);
* ``ServeEngine.poll_completed``
                          — completion events with §II-D writeback ->
                            poll latency in decode steps.

Probes never change behaviour: every hook is a no-op when no probe is
attached, and a probe failure is a bug, not a recoverable condition (no
exception guards — the probe is trusted first-party code).

Alongside the scalar dataclass counters (which feed the *deterministic*
``snapshot()`` gated in BENCH_perf.json), every probe owns a
:class:`repro.obs.metrics.MetricsRegistry` of histograms/gauges fed from
the same hooks — wall-clock distributions (launch/drain/step µs), ring
occupancy, poll and request latencies. Those are exported separately via
``metrics_snapshot()`` and the JSONL dump, **never** mixed into
``snapshot()`` (wall-clock in the gated document would break bit-for-bit
reproducibility — DESIGN.md §4/§8).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.obs.metrics import MetricsRegistry


@dataclasses.dataclass
class ChannelCounters:
    """What one channel did while a probe was attached."""

    submits: int = 0                 # DMARuntime.submit calls routed here
    submitted_descriptors: int = 0   # post-coalesce descriptors accepted
    coalesce_in: int = 0             # descriptors before the planner
    coalesce_out: int = 0            # descriptors after merge+split
    drained_descriptors: int = 0
    drain_batches: int = 0
    fused_batches: int = 0           # batches executed via the fused 2-D path
    drain_seconds: float = 0.0
    launch_seconds: float = 0.0      # wall-clock submit-side cost
    ring_full_events: int = 0
    occupancy_peak: int = 0          # ring high-water mark (slots in use)
    hit_rate_sum: float = 0.0        # §II-C input hit rate, summed
    hit_rate_n: int = 0
    # Speculation-policy trajectory (DESIGN.md §5): live depth after the
    # last observation, number of feedback updates, and the extremes the
    # policy visited while this probe was attached.
    speculation_depth: int = 0
    depth_updates: int = 0
    depth_peak: int = 0
    depth_floor: int = 0

    @property
    def merge_ratio(self) -> float:
        return self.coalesce_in / max(self.coalesce_out, 1)

    @property
    def mean_input_hit_rate(self) -> float:
        return self.hit_rate_sum / self.hit_rate_n if self.hit_rate_n else 1.0


@dataclasses.dataclass
class TranslationCounters:
    """Translation-cache events (chain-lowering JIT — DESIGN.md §7)."""

    hits: int = 0          # artifact LRU hits (compiled executor reused)
    misses: int = 0        # artifact LRU misses (new signature lowered)
    evictions: int = 0     # artifacts dropped past the LRU bound
    plan_hits: int = 0     # coalescer-plan memo hits (digest match)
    plan_misses: int = 0   # plans computed fresh
    transform_lookups: int = 0  # plans requested with a non-identity
                                # transform token (DESIGN.md §9)
    transform_fused: int = 0    # of those, served by a transform-fused
                                # compiled executor


@dataclasses.dataclass
class ServeCounters:
    """Serve-engine observations (one decode step = one event)."""

    steps: int = 0
    step_seconds: float = 0.0
    active_slot_steps: int = 0       # sum of busy slots over steps
    completions_observed: int = 0    # requests seen via §II-D writeback
    admission_stalls: int = 0        # steps with queued requests but no slot
    poll_latency_steps_sum: int = 0  # §II-D writeback -> poll observation


class PerfProbe:
    """Passive counter sink; one instance per measurement window."""

    def __init__(self) -> None:
        self.channels: Dict[str, ChannelCounters] = {}
        self.serve = ServeCounters()
        self.translation = TranslationCounters()
        self.metrics = MetricsRegistry()

    def reset(self) -> None:
        """Clear *all* counters — channels, serve, translation, metrics.

        Starts a fresh measurement window on the same probe object, so
        long-lived runtimes can reuse one attached probe across windows
        without re-plumbing ``attach_probe``.
        """
        self.channels.clear()
        self.serve = ServeCounters()
        self.translation = TranslationCounters()
        self.metrics.reset()

    def _ch(self, channel: str) -> ChannelCounters:
        c = self.channels.get(channel)
        if c is None:
            c = self.channels[channel] = ChannelCounters()
        return c

    # -- runtime-side hooks --------------------------------------------------
    def on_submit(self, channel: str, *, n_in: int, n_out: int,
                  launch_seconds: float,
                  hit_rate: Optional[float] = None) -> None:
        c = self._ch(channel)
        c.submits += 1
        c.submitted_descriptors += n_out
        c.coalesce_in += n_in
        c.coalesce_out += n_out
        c.launch_seconds += launch_seconds
        if hit_rate is not None:
            c.hit_rate_sum += hit_rate
            c.hit_rate_n += 1
        self.metrics.histogram("launch_us").record(launch_seconds * 1e6)

    def on_occupancy(self, channel: str, occupancy: int) -> None:
        c = self._ch(channel)
        if occupancy > c.occupancy_peak:
            c.occupancy_peak = occupancy
        self.metrics.gauge(f"ring_occupancy.{channel}").set(occupancy)

    def on_ring_full(self, channel: str) -> None:
        self._ch(channel).ring_full_events += 1

    def on_depth(self, channel: str, depth: int) -> None:
        """One speculation-policy feedback update (post-observation depth)."""
        c = self._ch(channel)
        c.speculation_depth = depth
        c.depth_peak = depth if c.depth_updates == 0 \
            else max(c.depth_peak, depth)
        c.depth_floor = depth if c.depth_updates == 0 \
            else min(c.depth_floor, depth)
        c.depth_updates += 1

    def on_drain(self, channel: str, *, n_descriptors: int, seconds: float,
                 fused: bool = False) -> None:
        c = self._ch(channel)
        c.drained_descriptors += n_descriptors
        c.drain_batches += 1
        c.fused_batches += int(fused)
        c.drain_seconds += seconds
        self.metrics.histogram("drain_us").record(seconds * 1e6)

    # -- translation-cache hooks ---------------------------------------------
    def on_translation(self, event: str) -> None:
        """One translation-cache event: hit/miss/evict/plan_hit/plan_miss."""
        t = self.translation
        if event == "hit":
            t.hits += 1
        elif event == "miss":
            t.misses += 1
        elif event == "evict":
            t.evictions += 1
        elif event == "plan_hit":
            t.plan_hits += 1
        elif event == "plan_miss":
            t.plan_misses += 1
        elif event == "transform_lookup":
            t.transform_lookups += 1
        elif event == "transform_fused":
            t.transform_fused += 1
        else:
            raise ValueError(f"unknown translation event {event!r}")

    # -- serve-side hooks ----------------------------------------------------
    def on_serve_step(self, active_slots: int, seconds: float) -> None:
        self.serve.steps += 1
        self.serve.active_slot_steps += active_slots
        self.serve.step_seconds += seconds
        self.metrics.histogram("serve_step_us").record(seconds * 1e6)
        self.metrics.gauge("serve_active_slots").set(active_slots)

    def on_serve_completion(self, n: int = 1,
                            latency_steps: Optional[int] = None) -> None:
        self.serve.completions_observed += n
        if latency_steps is not None:
            self.serve.poll_latency_steps_sum += latency_steps
            self.metrics.histogram("poll_latency_steps").record(latency_steps)

    def on_request_latency(self, steps: int) -> None:
        """End-to-end request latency (submit -> completion, decode steps)."""
        self.metrics.histogram("request_latency_steps").record(steps)

    def on_admission_stall(self) -> None:
        """One engine step that left requests queued behind full slots."""
        self.serve.admission_stalls += 1

    # -- export --------------------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        """JSON-ready counter dump (ints/floats only).

        Deterministic-schema contract: the perf sweep stores parts of this
        verbatim in BENCH_perf.json, so new observability surface goes in
        ``metrics_snapshot()``, never here.
        """
        return {
            "channels": {name: dataclasses.asdict(c)
                         for name, c in sorted(self.channels.items())},
            "serve": dataclasses.asdict(self.serve),
            "translation": dataclasses.asdict(self.translation),
        }

    def metrics_snapshot(self) -> Dict[str, Dict[str, object]]:
        """Histogram/gauge registry dump (wall-clock-bearing; not gated)."""
        return self.metrics.snapshot()

    def perf_counters(self):
        """Flat unified-namespace view of :meth:`snapshot` (DESIGN.md §9).

        Canonical keys: ``channels.<name>.<field>``, ``serve.<field>``,
        ``translation.<field>``. The bare-key deprecated aliases were
        removed one release after 0.4. ``snapshot()`` keeps the nested
        legacy layout for stored BENCH documents.
        """
        from repro.obs.counters import PerfCounters
        data: Dict[str, object] = {}
        for name, c in sorted(self.channels.items()):
            for k, v in dataclasses.asdict(c).items():
                data[f"channels.{name}.{k}"] = v
        for prefix, block in (
                ("serve", dataclasses.asdict(self.serve)),
                ("translation", dataclasses.asdict(self.translation))):
            for k, v in block.items():
                data[f"{prefix}.{k}"] = v
        return PerfCounters(data)
