"""Fixed-capacity submission rings of packed descriptors (runtime layer).

A :class:`SubmissionRing` is the software analogue of the DMAC driver's
in-memory descriptor region (§II-E): a circular buffer of 256-bit packed
descriptors with monotonically increasing producer (``tail``) and consumer
(``head``) counters. A slot's only completion signal is the paper's §II-D
writeback — the first 8 bytes of the descriptor overwritten with all-ones —
so a polling consumer needs no side-band state to observe progress.

Invariants:

* ``head <= tail <= head + capacity`` (counters are monotonic; the slot for
  entry ``k`` is ``k % capacity``).
* A slot is live from ``push`` until ``retire`` advances ``head`` past it.
* Retirement is **in order**: ``retire`` stops at the first not-done slot,
  exactly like a hardware ring whose head pointer chases completions.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.core.descriptor import (
    PACKED_DTYPE,
    is_done_packed,
    mark_done_packed,
)


class RingFull(RuntimeError):
    """Submission would overrun the consumer (backpressure signal)."""


class RingEmpty(RuntimeError):
    pass


@dataclasses.dataclass
class RingEntry:
    """A retired ring entry handed back to the completion layer."""

    ticket: int
    slot: int
    descriptor: np.ndarray   # 1-element packed view (copy) of the slot
    irq: bool


class SubmissionRing:
    """Circular packed-descriptor buffer with §II-D writeback completion."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("ring capacity must be >= 1")
        self.capacity = capacity
        self.table = np.zeros(capacity, dtype=PACKED_DTYPE)
        self._tickets = np.full(capacity, -1, np.int64)
        self._irq = np.zeros(capacity, bool)
        self.head = 0   # monotonic consumer counter
        self.tail = 0   # monotonic producer counter
        # ticket -> monotonic entry index, for out-of-band completion
        # (e.g. the serve scheduler marking a request's descriptor done).
        self._by_ticket: Dict[int, int] = {}

    # -- occupancy ----------------------------------------------------------
    @property
    def occupancy(self) -> int:
        return self.tail - self.head

    @property
    def free_slots(self) -> int:
        return self.capacity - self.occupancy

    @property
    def full(self) -> bool:
        return self.free_slots == 0

    @property
    def empty(self) -> bool:
        return self.occupancy == 0

    # -- producer side ------------------------------------------------------
    def push(self, packed_row: np.ndarray, ticket: int, *,
             irq: bool = False) -> int:
        """Append one packed descriptor; returns its slot index.

        Raises :class:`RingFull` when the consumer has not yet retired the
        slot — the caller (scheduler) turns that into block-or-spill policy.
        """
        if self.full:
            raise RingFull(
                f"ring full: capacity={self.capacity} head={self.head} "
                f"tail={self.tail}")
        slot = self.tail % self.capacity
        self.table[slot] = packed_row
        self._tickets[slot] = ticket
        self._irq[slot] = irq
        self._by_ticket[ticket] = self.tail
        self.tail += 1
        return slot

    def push_table(self, table: np.ndarray, tickets, *,
                   irq=None) -> List[int]:
        """Push a whole packed table (one chain); all-or-nothing."""
        n = len(table)
        if n > self.free_slots:
            raise RingFull(
                f"need {n} slots, have {self.free_slots} "
                f"(capacity {self.capacity})")
        if irq is None:
            irq = [False] * n
        return [self.push(table[i], int(tickets[i]), irq=bool(irq[i]))
                for i in range(n)]

    # -- completion (the §II-D writeback is the ONLY signal) ----------------
    def mark_done(self, slot: int) -> None:
        mark_done_packed(self.table, slot)

    def mark_done_ticket(self, ticket: int) -> None:
        """Out-of-band completion for control descriptors (serve scheduler)."""
        entry = self._by_ticket.get(ticket)
        if entry is None or entry < self.head:
            raise KeyError(f"ticket {ticket} not live in ring")
        self.mark_done(entry % self.capacity)

    def done_mask(self) -> np.ndarray:
        """Done flags for live slots, in submission order (oldest first)."""
        idx = np.arange(self.head, self.tail) % self.capacity
        return is_done_packed(self.table[idx]) if len(idx) else \
            np.zeros(0, bool)

    def live_slots(self) -> np.ndarray:
        return np.arange(self.head, self.tail) % self.capacity

    def live_done_tickets(self) -> List[int]:
        """Tickets of live entries carrying the writeback, head order.

        The §II-D poll: a scheduler scanning the descriptor table sees
        completions immediately, even while in-order retirement is
        head-of-line blocked behind an older in-flight descriptor.
        """
        slots = self.live_slots()
        if not len(slots):
            return []
        done = is_done_packed(self.table[slots])
        return [int(self._tickets[s]) for s, d in zip(slots, done) if d]

    # -- consumer side ------------------------------------------------------
    def peek(self) -> Tuple[int, np.ndarray]:
        if self.empty:
            raise RingEmpty("ring empty")
        slot = self.head % self.capacity
        return slot, self.table[slot:slot + 1]

    def retire(self) -> List[RingEntry]:
        """Advance head past completed entries (in order); return them."""
        out: List[RingEntry] = []
        while not self.empty:
            slot = self.head % self.capacity
            if not is_done_packed(self.table[slot:slot + 1])[0]:
                break
            out.append(RingEntry(
                ticket=int(self._tickets[slot]),
                slot=slot,
                descriptor=self.table[slot:slot + 1].copy(),
                irq=bool(self._irq[slot]),
            ))
            self._by_ticket.pop(int(self._tickets[slot]), None)
            self._tickets[slot] = -1
            self.head += 1
        return out
