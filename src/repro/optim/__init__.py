"""Optimizers, schedules, gradient compression."""
from .optimizer import (  # noqa: F401
    AdamWConfig,
    AdamWState,
    apply,
    global_norm,
    init,
    learning_rate,
)
from .compress import (  # noqa: F401
    compress_allreduce_leaf,
    compressed_psum_tree,
    compression_ratio,
    init_residuals,
)
