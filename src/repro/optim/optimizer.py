"""AdamW with decoupled weight decay, global-norm clipping, LR schedules.

State is a pytree congruent with params (m, v in fp32), so the sharding
policy for parameters applies verbatim to optimizer state (ZeRO-style when
params are FSDP-sharded).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"      # cosine | linear | constant
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def learning_rate(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_ratio) * frac
    else:
        decay = jnp.asarray(1.0)
    return cfg.lr * warm * decay


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply(cfg: AdamWConfig, params, grads, state: AdamWState,
          ) -> Tuple[Any, AdamWState, dict]:
    """One AdamW update. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else jnp.asarray(1.0)
    step = state.step + 1
    lr = learning_rate(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
