"""Error-feedback int8 gradient compression for the slow (cross-pod) axis.

Within a pod, gradients reduce in full precision over ICI; across pods the
links are ~10x slower, so the pod-axis all-reduce optionally runs on int8
blocks with per-block scales and an error-feedback residual (Seide et al. /
EF-SGD style), keeping the update unbiased in the long run.

Implemented with shard_map + psum over the named "pod" axis.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8 quantization. x: flat fp32 (padded)."""
    blocks = x.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return (q.astype(jnp.float32) * scale).reshape(-1)


def compress_allreduce_leaf(g: jax.Array, residual: jax.Array,
                            axis_name: str) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback compressed psum of one leaf over `axis_name`.

    Returns (mean-reduced gradient, new residual). Call inside shard_map.
    """
    shape = g.shape
    flat = g.astype(jnp.float32).reshape(-1) + residual.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat_p = jnp.pad(flat, (0, pad))
    q, scale = _quantize(flat_p)
    sent = _dequantize(q, scale)[:flat.size]
    new_residual = (flat - sent).reshape(shape)
    # int8 payloads cross the slow axis; the sum itself accumulates in f32.
    reduced = jax.lax.psum(sent.reshape(shape), axis_name) \
        / jax.lax.psum(jnp.ones(()), axis_name)
    return reduced, new_residual


def init_residuals(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum_tree(grads, residuals, axis_name: str):
    """Apply EF-int8 allreduce leaf-wise. Use inside shard_map over pods."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out = [compress_allreduce_leaf(g, r, axis_name)
           for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_r = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_g, new_r


def compression_ratio() -> float:
    """Wire bytes vs fp32: int8 payload + fp32 scale per 256-block."""
    return (BLOCK * 1 + 4) / (BLOCK * 4)
