"""One-shot seeded trace recorder: the ``--trace`` / CI-artifact entrypoint.

``python -m repro.obs.record --out serve.trace.json --mesh 2 --seed 0``
runs the reduced serve scenario with the tracer attached to every layer —
request-lifecycle async spans, channel launch/drain spans, translation
lookups, §II-D completion instants, and (at ``--mesh`` >= 2) cross-shard
migration hops linked by Perfetto flow arrows — plus a short cycle-clock
simulator pass, then writes the Chrome/Perfetto ``trace_event`` JSON
(DESIGN.md §8).  ``--metrics-out`` additionally dumps the probe's metric
registry as flat JSONL.

Everything is seeded: the same ``--seed`` replays the same request mix
and the same sampling decisions, so a CI-archived trace reproduces at a
developer's desk with one command.
"""
from __future__ import annotations

import argparse
import sys
import zlib
from typing import Optional, Sequence, Tuple

from repro.obs.export import write_chrome_trace, write_metrics_jsonl
from repro.obs.trace import Tracer

#: The reduced serve scenario (mirrors the gated serve cell's shape).
_ARCH = "qwen2.5-3b"
_N_REQUESTS_PER_SHARD = 3
_CAPACITY = 2
_MAX_LEN = 32
_MAX_NEW_TOKENS = 4
_POLL_EVERY = 3
_MAX_STEPS = 400


def record_serve_trace(
    seed: int = 0,
    *,
    mesh: int = 1,
    sample_rate: float = 1.0,
    capacity: int = 65536,
    simulate: bool = True,
) -> Tuple[Tracer, object, dict]:
    """Run the seeded serve scenario under a tracer.

    Returns ``(tracer, probe, perf_counters)``.  ``mesh == 1`` drives a
    plain :class:`repro.serve.ServeEngine`; ``mesh >= 2`` drives a
    :class:`repro.distributed.ShardedServeEngine` with every third
    request's KV pages straddling shards, so the trace contains real
    migration hops (egress -> fabric -> ingress flow arrows).
    """
    import jax
    import numpy as np

    from repro.configs.registry import get_config
    from repro.models import init_params
    from repro.runtime.instrumentation import PerfProbe
    from repro.runtime import SubmitRequest
    from repro.serve import Request, ServeEngine

    if mesh < 1:
        raise ValueError("mesh must be >= 1")
    tracer = Tracer(capacity=capacity, sample_rate=sample_rate, seed=seed)
    probe = PerfProbe()
    cfg = get_config(_ARCH, reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng([seed, zlib.crc32(b"obs.record")])

    def _prompt():
        n = int(rng.integers(2, 7))
        return [int(t) for t in rng.integers(1, cfg.vocab_size, n)]

    if mesh == 1:
        eng = ServeEngine(params, cfg, capacity=_CAPACITY, max_len=_MAX_LEN)
        eng.attach_probe(probe)
        eng.attach_tracer(tracer)
        for uid in range(2 * _N_REQUESTS_PER_SHARD):
            eng.submit(SubmitRequest(request=Request(
                uid=uid, prompt=_prompt(),
                max_new_tokens=_MAX_NEW_TOKENS)))
        while ((eng.queue or any(s.busy for s in eng.slots))
               and eng.steps < _MAX_STEPS):
            eng.step()
            if eng.steps % _POLL_EVERY == 0:
                eng.poll_completed()
        eng.poll_completed()
        pc = eng.perf_counters()
    else:
        from repro.distributed.sharded_runtime import (
            ShardedDMARuntime,
            ShardedKVPool,
            ShardedServeEngine,
        )
        srt = ShardedDMARuntime(num_shards=mesh)
        kv = ShardedKVPool(srt, num_pages=16 * mesh, page=2,
                           kv_heads=2, head_dim=4)
        eng = ShardedServeEngine(params, cfg, runtime=srt, kv_pool=kv,
                                 capacity=_CAPACITY, max_len=_MAX_LEN)
        eng.attach_probe(probe)
        eng.attach_tracer(tracer)
        for uid in range(mesh * _N_REQUESTS_PER_SHARD):
            home = uid % mesh
            pages = kv.alloc_on(home, 2)
            if uid % 3 == 2:
                # Straddle shards: the majority owner wins the route and
                # pulls the minority page across -> a real migration hop.
                pages = pages + kv.alloc_on((home + 1) % mesh, 1)
            eng.submit(SubmitRequest(request=Request(
                uid=uid, prompt=_prompt(),
                max_new_tokens=_MAX_NEW_TOKENS, kv_pages=pages)))
        eng.run(max_steps=_MAX_STEPS)
        pc = eng.perf_counters()

    if simulate:
        # A short cycle-clock pass so the exported timeline carries the
        # simulator's bus view (its own clock domain, own tracks).
        from repro.core.simulator import simulate_multichannel
        if mesh > 1:
            from repro.core.simulator import simulate_sharded
            simulate_sharded(mesh, 2, 13, 64, num_transfers=40,
                             cross_fraction=0.25, seed=seed, tracer=tracer)
        else:
            simulate_multichannel(2, 13, 64, num_transfers=40, seed=seed,
                                  tracer=tracer)
    return tracer, probe, pc


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.record",
        description="Record a seeded serve(+sharded) lifecycle trace as "
                    "Perfetto-loadable trace_event JSON (DESIGN.md §8).")
    ap.add_argument("--out", default="serve.trace.json",
                    help="trace JSON path (load at ui.perfetto.dev)")
    ap.add_argument("--metrics-out",
                    help="also dump the probe's metric registry as JSONL")
    ap.add_argument("--seed", type=int, default=0,
                    help="scenario + sampling seed (same seed, same trace "
                         "structure)")
    ap.add_argument("--mesh", type=int, default=1,
                    help=">= 2 runs the sharded serve path: per-shard "
                         "track groups plus migration-hop flow arrows")
    ap.add_argument("--sample-rate", type=float, default=1.0,
                    help="deterministic per-key sampling fraction")
    ap.add_argument("--capacity", type=int, default=65536,
                    help="tracer ring size (oldest events drop beyond it)")
    ap.add_argument("--no-sim", action="store_true",
                    help="skip the cycle-clock simulator pass")
    args = ap.parse_args(argv)

    tracer, probe, pc = record_serve_trace(
        args.seed, mesh=args.mesh, sample_rate=args.sample_rate,
        capacity=args.capacity, simulate=not args.no_sim)
    events = tracer.events()
    doc = write_chrome_trace(args.out, events)
    tracks = sorted({e.track for e in events})
    names = sorted({e.name for e in events})
    print(f"wrote {args.out}: {len(doc['traceEvents'])} trace events "
          f"({len(events)} recorded, {tracer.dropped} dropped) on "
          f"{len(tracks)} tracks")
    print(f"  tracks: {', '.join(tracks)}")
    print(f"  events: {', '.join(names)}")
    ns = "sharded" if args.mesh > 1 else "serve"
    print(f"  request latency steps: "
          f"p50={pc[f'{ns}.request_latency_steps_p50']:.1f} "
          f"p99={pc[f'{ns}.request_latency_steps_p99']:.1f} "
          f"(n={pc[f'{ns}.request_latency_steps']['n']})")
    if args.metrics_out:
        n = write_metrics_jsonl(args.metrics_out, probe.metrics)
        print(f"wrote {args.metrics_out}: {n} metrics")
    return 0


if __name__ == "__main__":
    sys.exit(main())
