"""Counters, gauges, and mergeable fixed-bucket histograms.

The histogram layout (DESIGN.md §8) is width-1 *linear* buckets below
``max_exact`` followed by log2 buckets above it:

* bucket ``i`` for ``i < max_exact`` holds exactly the integer value ``i``
  (so percentiles over small-integer samples — serve request latencies in
  steps, poll latencies — are *exact*, matching
  ``np.percentile(..., method="inverted_cdf")``);
* bucket ``max_exact + k`` holds ``[max_exact * 2**k, max_exact * 2**(k+1))``
  (log2 width, bounded relative error for large wall-clock samples).

Buckets are plain count lists, so cross-shard merge is element-wise
addition — associative and commutative by construction, which is what lets
per-shard registries fold into one document in any order.
"""
from __future__ import annotations

import json
import math
from typing import Dict, Iterator, List, Optional, Union

Number = Union[int, float]


class Counter:
    """Monotonic event count."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def merge(self, other: "Counter") -> None:
        self.value += other.value

    def snapshot(self) -> Dict[str, Number]:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-written value plus its observed peak."""

    __slots__ = ("value", "peak", "n")

    def __init__(self) -> None:
        self.value = 0.0
        self.peak = 0.0
        self.n = 0

    def set(self, v: Number) -> None:
        self.value = float(v)
        self.n += 1
        if v > self.peak:
            self.peak = float(v)

    def merge(self, other: "Gauge") -> None:
        # merge keeps the peak; "last value" across shards is ill-defined,
        # so the merged value is the max as well.
        self.n += other.n
        self.peak = max(self.peak, other.peak)
        self.value = max(self.value, other.value)

    def snapshot(self) -> Dict[str, Number]:
        return {"type": "gauge", "value": self.value, "peak": self.peak,
                "n": self.n}


class Histogram:
    """Fixed-bucket histogram: width-1 linear below ``max_exact``, log2 above.

    Percentiles use the nearest-rank definition (the smallest recorded
    bucket whose cumulative count reaches ``ceil(q/100 * n)``), returning
    the bucket *lower bound* — exact for integer samples below
    ``max_exact``, a <=2x-wide floor for the log2 range.
    """

    __slots__ = ("max_exact", "log2_buckets", "counts", "n", "total",
                 "min", "max")

    def __init__(self, max_exact: int = 64, log2_buckets: int = 32) -> None:
        if max_exact < 1 or log2_buckets < 1:
            raise ValueError("max_exact and log2_buckets must be >= 1")
        self.max_exact = int(max_exact)
        self.log2_buckets = int(log2_buckets)
        self.counts: List[int] = [0] * (self.max_exact + self.log2_buckets)
        self.n = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    # -- recording ---------------------------------------------------------

    def bucket_index(self, v: Number) -> int:
        if v < 0:
            v = 0
        if v < self.max_exact:
            return int(v)
        k = int(math.floor(math.log2(float(v) / self.max_exact)))
        if k >= self.log2_buckets:
            k = self.log2_buckets - 1
        return self.max_exact + k

    def bucket_lo(self, i: int) -> float:
        """Inclusive lower bound of bucket ``i`` (the percentile estimate)."""
        if i < self.max_exact:
            return float(i)
        return float(self.max_exact * (2 ** (i - self.max_exact)))

    def record(self, v: Number) -> None:
        fv = float(v)
        self.counts[self.bucket_index(v)] += 1
        self.n += 1
        self.total += fv
        if self.min is None or fv < self.min:
            self.min = fv
        if self.max is None or fv > self.max:
            self.max = fv

    # -- reading -----------------------------------------------------------

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile (lower bucket bound); 0.0 when empty."""
        if self.n == 0:
            return 0.0
        rank = max(1, math.ceil(q / 100.0 * self.n))
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank:
                return self.bucket_lo(i)
        return self.bucket_lo(len(self.counts) - 1)   # unreachable guard

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    # -- merge / serialization --------------------------------------------

    def merge(self, other: "Histogram") -> None:
        if (other.max_exact != self.max_exact
                or other.log2_buckets != self.log2_buckets):
            raise ValueError("cannot merge histograms with different "
                             "bucket layouts")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.n += other.n
        self.total += other.total
        if other.min is not None:
            self.min = other.min if self.min is None else min(self.min,
                                                              other.min)
        if other.max is not None:
            self.max = other.max if self.max is None else max(self.max,
                                                              other.max)

    def snapshot(self) -> Dict[str, object]:
        return {
            "type": "histogram",
            "max_exact": self.max_exact,
            "log2_buckets": self.log2_buckets,
            "counts": list(self.counts),
            "n": self.n,
            "sum": self.total,
            "min": 0.0 if self.min is None else self.min,
            "max": 0.0 if self.max is None else self.max,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }

    @classmethod
    def from_snapshot(cls, snap: Dict[str, object]) -> "Histogram":
        h = cls(max_exact=int(snap["max_exact"]),
                log2_buckets=int(snap["log2_buckets"]))
        counts = list(snap["counts"])
        if len(counts) != len(h.counts):
            raise ValueError("snapshot counts length does not match layout")
        h.counts = [int(c) for c in counts]
        h.n = int(snap["n"])
        h.total = float(snap["sum"])
        if h.n:
            h.min = float(snap["min"])
            h.max = float(snap["max"])
        return h


Instrument = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Name -> instrument map with get-or-create accessors.

    ``merge`` folds another registry in (cross-shard aggregation);
    instruments are created on demand so shards with disjoint metric sets
    merge cleanly.
    """

    def __init__(self) -> None:
        self._instruments: Dict[str, Instrument] = {}

    def _get(self, name, kind, factory):
        inst = self._instruments.get(name)
        if inst is None:
            inst = factory()
            self._instruments[name] = inst
        elif not isinstance(inst, kind):
            raise TypeError(f"metric {name!r} is {type(inst).__name__}, "
                            f"not {kind.__name__}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge, Gauge)

    def histogram(self, name: str, *, max_exact: int = 64,
                  log2_buckets: int = 32) -> Histogram:
        return self._get(
            name, Histogram,
            lambda: Histogram(max_exact=max_exact,
                              log2_buckets=log2_buckets))

    def __contains__(self, name: str) -> bool:
        return name in self._instruments

    def __len__(self) -> int:
        return len(self._instruments)

    def names(self) -> List[str]:
        return sorted(self._instruments)

    def get(self, name: str) -> Optional[Instrument]:
        return self._instruments.get(name)

    def merge(self, other: "MetricsRegistry") -> None:
        for name in other.names():
            inst = other._instruments[name]
            if isinstance(inst, Counter):
                self.counter(name).merge(inst)
            elif isinstance(inst, Gauge):
                self.gauge(name).merge(inst)
            else:
                mine = self.histogram(name, max_exact=inst.max_exact,
                                      log2_buckets=inst.log2_buckets)
                mine.merge(inst)

    def reset(self) -> None:
        self._instruments.clear()

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        return {name: self._instruments[name].snapshot()
                for name in self.names()}

    def jsonl_lines(self) -> Iterator[str]:
        """One JSON object per metric, name-sorted (the flat dump format)."""
        for name, snap in self.snapshot().items():
            yield json.dumps({"name": name, **snap}, sort_keys=True)
