"""Unified perf-counter key namespace (DESIGN.md §9).

The four ``perf_counters()`` surfaces — ``ServeEngine``,
``ShardedServeEngine``, ``DMARuntime.translation_stats`` and
``PerfProbe`` — historically returned four ad-hoc dict layouts. They now
share one documented namespace:

* ``serve.*``        — serve-engine step/latency/admission counters
  (``serve.steps``, ``serve.completed``, ``serve.request_latency_steps_p50``,
  …);
* ``sharded.*``      — mesh-level counters (``sharded.num_shards``,
  ``sharded.requests_per_shard``, ``sharded.remote_page_reads``,
  ``sharded.migration``, ``sharded.per_shard``);
* ``translation.*``  — chain-lowering cache counters
  (``translation.hits``, ``translation.lookups``,
  ``translation.transform_fusion_hit_rate``, …), plus a nested
  ``translation`` block on the serve/sharded surfaces;
* ``channels.*``     — per-channel probe snapshots
  (``channels.<name>.<field>``).

:class:`PerfCounters` is a plain ``dict`` whose *stored* keys are the
canonical ones (so ``json.dumps`` and iteration see only the new
namespace) plus an alias table: reading an old key through ``[]`` or
``.get`` still works for one release but emits a
:class:`DeprecationWarning`. ``in`` stays silent so feature probes don't
spam.

Internal producers (``TranslationCache.stats()``, ``aggregate_stats``)
keep returning *raw* bare-key dicts; wrapping happens once, at each
public surface, via :func:`namespaced`.
"""
from __future__ import annotations

import warnings
from typing import Any, Dict, Mapping, Optional


class PerfCounters(dict):
    """Canonical-key counter dict with deprecated-alias reads."""

    def __init__(self, data: Optional[Mapping[str, Any]] = None,
                 aliases: Optional[Mapping[str, str]] = None):
        super().__init__(data or {})
        self._aliases: Dict[str, str] = dict(aliases or {})

    def _resolve(self, key: str, warn: bool = True) -> str:
        canonical = self._aliases.get(key)
        if canonical is None or dict.__contains__(self, key):
            return key
        if warn:
            warnings.warn(
                f"perf counter key {key!r} is deprecated; read "
                f"{canonical!r} (unified namespace, DESIGN.md §9). The "
                "alias is removed one release after 0.4.",
                DeprecationWarning, stacklevel=3)
        return canonical

    def __getitem__(self, key):
        return dict.__getitem__(self, self._resolve(key))

    def get(self, key, default=None):
        k = self._resolve(key)
        return dict.__getitem__(self, k) if dict.__contains__(self, k) \
            else default

    def __contains__(self, key):
        return (dict.__contains__(self, key)
                or self._resolve(key, warn=False) != key)

    @property
    def aliases(self) -> Dict[str, str]:
        return dict(self._aliases)


def namespaced(raw: Mapping[str, Any], prefix: str, *,
               extra: Optional[Mapping[str, Any]] = None,
               extra_aliases: Optional[Mapping[str, str]] = None
               ) -> PerfCounters:
    """Wrap a raw bare-key block as ``{prefix}.{key}`` canonical keys.

    Every bare key becomes a deprecated alias for its dotted form;
    ``extra`` entries are stored verbatim (already-canonical keys such
    as a nested ``translation`` block) and ``extra_aliases`` adds
    old-name → canonical-name mappings beyond the mechanical ones.
    """
    data = {f"{prefix}.{k}": v for k, v in raw.items()}
    aliases = {k: f"{prefix}.{k}" for k in raw}
    if extra:
        data.update(extra)
    if extra_aliases:
        aliases.update(extra_aliases)
    return PerfCounters(data, aliases=aliases)
