"""Unified perf-counter key namespace (DESIGN.md §9).

The four ``perf_counters()`` surfaces — ``ServeEngine``,
``ShardedServeEngine``, ``DMARuntime.translation_stats`` and
``PerfProbe`` — historically returned four ad-hoc dict layouts. They now
share one documented namespace:

* ``serve.*``        — serve-engine step/latency/admission counters
  (``serve.steps``, ``serve.completed``, ``serve.request_latency_steps_p50``,
  …);
* ``sharded.*``      — mesh-level counters (``sharded.num_shards``,
  ``sharded.requests_per_shard``, ``sharded.remote_page_reads``,
  ``sharded.migration``, ``sharded.per_shard``, plus the DESIGN.md §11
  virtual-paging block: ``sharded.first_touch_pulls``,
  ``sharded.page_table_generation``, ``sharded.page_table_remaps``,
  ``sharded.pending_pages``);
* ``translation.*``  — chain-lowering cache counters
  (``translation.hits``, ``translation.lookups``,
  ``translation.transform_fusion_hit_rate``, …), plus a nested
  ``translation`` block on the serve/sharded surfaces;
* ``channels.*``     — per-channel probe snapshots
  (``channels.<name>.<field>``).

:class:`PerfCounters` is a plain ``dict`` whose keys are the canonical
dotted ones. The bare-key DeprecationWarning aliases shipped for one
release after 0.4 and are now removed: reading an old bare key is a
plain ``KeyError``, exactly like any other missing key.

Internal producers (``TranslationCache.stats()``, ``aggregate_stats``)
keep returning *raw* bare-key dicts; wrapping happens once, at each
public surface, via :func:`namespaced`.
"""
from __future__ import annotations

from typing import Any, Mapping, Optional


class PerfCounters(dict):
    """Canonical-key counter dict (dotted unified namespace)."""

    def __init__(self, data: Optional[Mapping[str, Any]] = None):
        super().__init__(data or {})


def namespaced(raw: Mapping[str, Any], prefix: str, *,
               extra: Optional[Mapping[str, Any]] = None) -> PerfCounters:
    """Wrap a raw bare-key block as ``{prefix}.{key}`` canonical keys.

    ``extra`` entries are stored verbatim (already-canonical keys such
    as a nested ``translation`` block).
    """
    data = {f"{prefix}.{k}": v for k, v in raw.items()}
    if extra:
        data.update(extra)
    return PerfCounters(data)
