"""Low-overhead span/event recorder for descriptor-lifecycle tracing.

Design constraints (DESIGN.md §8):

* **off-by-default-cheap** — the runtime stores ``tracer = None`` and every
  hook site is a single attribute test; no object is built, no clock read,
  when tracing is off.  The overhead guard test and the ``tracing`` bench
  section in BENCH_runtime.json keep this honest.
* **bounded** — events land in a ``deque(maxlen=capacity)`` ring; the
  ``emitted`` counter keeps counting so ``dropped`` is exact.
* **sampled deterministically** — ``sampled(key)`` hashes ``seed:key`` with
  crc32 against ``sample_rate * 2**32``.  The same (seed, key) samples the
  same way on every shard and every run, so cross-shard traces of one
  request either all record or all skip.
* **dual clocks** — wall events timestamp with ``time.monotonic()``
  microseconds; simulator events pass explicit cycle timestamps with
  ``clock="cycle"`` and are rendered on separate tracks (1 cycle == 1 µs
  in the exported timeline).
"""
from __future__ import annotations

import time
import zlib
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, List, Optional

monotonic = time.monotonic
"""The one clock used for every wall-time measurement in the runtime.

``time.time()`` is subject to NTP steps and DST jumps; ``perf_counter``
is per-process.  ``monotonic`` is steady and comparable across the whole
process, which is all the probe and tracer need.
"""


def monotonic_us() -> float:
    return monotonic() * 1e6


@dataclass
class TraceEvent:
    """One trace_event-shaped record (pre-export, track not yet a pid)."""

    name: str
    ph: str                       # X, i, b, e, s, t, f, C
    ts: float                     # µs (wall) or cycles (clock="cycle")
    track: str                    # exported as one Perfetto process/track
    dur: Optional[float] = None   # X only
    id: Optional[int] = None      # async + flow events
    clock: str = "wall"           # "wall" | "cycle"
    args: Dict[str, object] = field(default_factory=dict)


class Tracer:
    """Ring-buffered event recorder with seeded sampling.

    All emit helpers are unconditional — *callers* gate on
    ``tracer is not None and tracer.sampled(key)`` so the disabled path
    stays one attribute load.
    """

    def __init__(self, capacity: int = 65536, sample_rate: float = 1.0,
                 seed: int = 0) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.sample_rate = float(sample_rate)
        self.seed = seed
        self.emitted = 0
        self._buf: deque = deque(maxlen=capacity)
        self._next_flow = 1
        self._threshold = int(min(max(self.sample_rate, 0.0), 1.0) * 2**32)

    # -- sampling ----------------------------------------------------------

    def sampled(self, key: object) -> bool:
        """Deterministic hash-based sampling decision for ``key``.

        Keys are stable identities (first ticket of a submission, request
        uid, translation-lookup ordinal) so the decision is reproducible
        and shard-independent.
        """
        if self.sample_rate >= 1.0:
            return True
        if self.sample_rate <= 0.0:
            return False
        return zlib.crc32(f"{self.seed}:{key}".encode()) < self._threshold

    # -- clock -------------------------------------------------------------

    def now_us(self) -> float:
        return monotonic() * 1e6

    # -- emission ----------------------------------------------------------

    def emit(self, event: TraceEvent) -> None:
        self.emitted += 1
        self._buf.append(event)

    def complete(self, name: str, track: str, t0_us: float, dur_us: float,
                 *, clock: str = "wall", **args) -> None:
        """A closed span ("X"): began at ``t0_us``, lasted ``dur_us``."""
        self.emit(TraceEvent(name=name, ph="X", ts=t0_us, track=track,
                             dur=max(dur_us, 0.0), clock=clock, args=args))

    def instant(self, name: str, track: str, ts: Optional[float] = None,
                *, clock: str = "wall", **args) -> None:
        if ts is None:
            ts = self.now_us()
        self.emit(TraceEvent(name=name, ph="i", ts=ts, track=track,
                             clock=clock, args=args))

    def counter(self, name: str, track: str, ts: Optional[float] = None,
                *, clock: str = "wall", **values) -> None:
        """A counter sample ("C"): Perfetto renders each numeric value in
        ``values`` as a series on the named counter track (per-link
        fabric occupancy uses one counter per directed link)."""
        if ts is None:
            ts = self.now_us()
        self.emit(TraceEvent(name=name, ph="C", ts=ts, track=track,
                             clock=clock, args=values))

    def async_begin(self, name: str, track: str, id: int,
                    ts: Optional[float] = None, **args) -> None:
        if ts is None:
            ts = self.now_us()
        self.emit(TraceEvent(name=name, ph="b", ts=ts, track=track, id=id,
                             args=args))

    def async_end(self, name: str, track: str, id: int,
                  ts: Optional[float] = None, **args) -> None:
        if ts is None:
            ts = self.now_us()
        self.emit(TraceEvent(name=name, ph="e", ts=ts, track=track, id=id,
                             args=args))

    def flow_start(self, name: str, track: str, id: int,
                   ts: Optional[float] = None, **args) -> None:
        if ts is None:
            ts = self.now_us()
        self.emit(TraceEvent(name=name, ph="s", ts=ts, track=track, id=id,
                             args=args))

    def flow_step(self, name: str, track: str, id: int,
                  ts: Optional[float] = None, **args) -> None:
        if ts is None:
            ts = self.now_us()
        self.emit(TraceEvent(name=name, ph="t", ts=ts, track=track, id=id,
                             args=args))

    def flow_end(self, name: str, track: str, id: int,
                 ts: Optional[float] = None, **args) -> None:
        if ts is None:
            ts = self.now_us()
        self.emit(TraceEvent(name=name, ph="f", ts=ts, track=track, id=id,
                             args=args))

    @contextmanager
    def span(self, name: str, track: str, **args):
        """``with tracer.span("drain", "dma0", n=8): ...`` — wall clock."""
        t0 = self.now_us()
        try:
            yield
        finally:
            self.complete(name, track, t0, self.now_us() - t0, **args)

    def next_flow_id(self) -> int:
        """Fresh process-unique id for one flow arrow (s -> t -> f)."""
        fid = self._next_flow
        self._next_flow += 1
        return fid

    # -- reading -----------------------------------------------------------

    @property
    def dropped(self) -> int:
        return self.emitted - len(self._buf)

    def events(self) -> List[TraceEvent]:
        return list(self._buf)

    def clear(self) -> None:
        self._buf.clear()
        self.emitted = 0
