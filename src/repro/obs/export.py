"""Chrome/Perfetto ``trace_event`` JSON export + flat JSONL metrics dump.

The exported document is the JSON-object form of the trace_event format
(loadable at https://ui.perfetto.dev and chrome://tracing): one *process*
per tracer track (channel, shard-qualified channel, serve loop, fabric,
simulator config), named via "M"/``process_name`` metadata events.

Timestamps are normalized per clock domain: all wall events shift so the
earliest wall event is t=0, and all cycle events likewise (1 simulated
cycle is rendered as 1 µs on its own tracks) — the two domains share a
viewport without pretending to share a clock.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import TraceEvent


def _track_pids(events: Iterable[TraceEvent]) -> Dict[str, int]:
    """Assign pids to tracks in first-appearance order (stable export)."""
    pids: Dict[str, int] = {}
    for ev in events:
        if ev.track not in pids:
            pids[ev.track] = len(pids) + 1
    return pids


def chrome_trace(events: List[TraceEvent]) -> Dict[str, object]:
    """Render tracer events as a trace_event JSON document (dict)."""
    pids = _track_pids(events)
    mins: Dict[str, float] = {}
    for ev in events:
        cur = mins.get(ev.clock)
        if cur is None or ev.ts < cur:
            mins[ev.clock] = ev.ts

    out: List[Dict[str, object]] = []
    for track, pid in pids.items():
        out.append({"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                    "args": {"name": track}})
    for ev in events:
        rec: Dict[str, object] = {
            "name": ev.name,
            "cat": ev.clock,
            "ph": ev.ph,
            "ts": ev.ts - mins[ev.clock],
            "pid": pids[ev.track],
            "tid": 0,
        }
        if ev.ph == "X":
            rec["dur"] = ev.dur if ev.dur is not None else 0.0
        if ev.ph == "i":
            rec["s"] = "t"                      # thread-scoped instant
        if ev.ph in ("b", "e", "s", "t", "f"):
            rec["id"] = ev.id
            rec["cat"] = "flow" if ev.ph in ("s", "t", "f") else ev.clock
        if ev.ph in ("s", "t", "f"):
            rec["bp"] = "e"                     # bind to enclosing slice
        if ev.args:
            rec["args"] = dict(ev.args)
        out.append(rec)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, events: List[TraceEvent]) -> Dict[str, object]:
    doc = chrome_trace(events)
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return doc


def write_metrics_jsonl(path: str,
                        registry: Optional[MetricsRegistry] = None,
                        *,
                        extra: Optional[Dict[str, Dict[str, object]]] = None,
                        ) -> int:
    """Flat metrics dump: one JSON object per line, name-sorted.

    ``extra`` merges additional pre-snapshotted metric dicts (e.g. per-shard
    registries already folded, or probe scalar counters wrapped as
    ``{"type": "counter", "value": ...}``).
    """
    merged: Dict[str, Dict[str, object]] = {}
    if registry is not None:
        merged.update(registry.snapshot())
    if extra:
        merged.update(extra)
    n = 0
    with open(path, "w") as fh:
        for name in sorted(merged):
            fh.write(json.dumps({"name": name, **merged[name]},
                                sort_keys=True) + "\n")
            n += 1
    return n
