"""Observability subsystem: lifecycle tracing, metrics, Perfetto export.

Layered *under* the existing ``PerfProbe`` (DESIGN.md §8): the probe keeps
its deterministic scalar counters (gated in BENCH_perf.json), while this
package adds

* ``trace``   — ring-buffered span/event recorder with seeded sampling and
  dual wall-clock / simulated-cycle timestamps;
* ``metrics`` — counters, gauges, and mergeable fixed-bucket histograms
  with exact small-integer percentiles;
* ``export``  — Chrome/Perfetto ``trace_event`` JSON + flat JSONL metrics;
* ``record``  — one-shot seeded serve/sharded/simulator trace recorder
  (the ``benchmarks/run.py --trace`` and CI-artifact entrypoint).
"""
from repro.obs.counters import PerfCounters, namespaced
from repro.obs.export import (
    chrome_trace,
    write_chrome_trace,
    write_metrics_jsonl,
)
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import TraceEvent, Tracer, monotonic, monotonic_us

__all__ = [
    "Counter",
    "PerfCounters",
    "namespaced",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "TraceEvent",
    "Tracer",
    "chrome_trace",
    "monotonic",
    "monotonic_us",
    "write_chrome_trace",
    "write_metrics_jsonl",
]
