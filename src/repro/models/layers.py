"""Shared layers: norms, RoPE, MLPs, embeddings. Pure functional, dict params."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import shard


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, in_axis: int = 0):
    fan_in = shape[in_axis] if isinstance(in_axis, int) else 1
    scale = 1.0 / jnp.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def init_rms_norm(dim: int, dtype=jnp.float32):
    # Stored as offset-from-one (gemma convention); rms_norm adds the 1.
    return {"scale": jnp.zeros((dim,), dtype)}


# ---------------------------------------------------------------------------
# Rotary position embeddings (partial-dim capable)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, rope_fraction: float, theta: float):
    rot_dim = int(head_dim * rope_fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    return inv, rot_dim


def apply_rope(x: jax.Array, positions: jax.Array, *, theta: float,
               fraction: float = 1.0) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    inv, rot_dim = rope_freqs(head_dim, fraction, theta)
    if rot_dim == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., seq, rot/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., :, None, :]  # broadcast over heads
    cos = cos[..., :, None, :]
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype, gated: bool = True):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }
    if gated:
        p["w_gate"] = dense_init(k1, (d_model, d_ff), dtype)
    return p


def mlp(params, x: jax.Array, act_fn: str = "silu",
        dtype=jnp.bfloat16) -> jax.Array:
    act = jax.nn.silu if act_fn == "silu" else jax.nn.gelu
    up = jnp.einsum("...d,df->...f", x, params["w_up"].astype(dtype))
    if "w_gate" in params:
        gate = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(dtype))
        h = act(gate) * up
    else:
        h = act(up)
    h = shard(h, *(("batch",) + (None,) * (h.ndim - 2) + ("d_ff",)))
    return jnp.einsum("...f,fd->...d", h, params["w_down"].astype(dtype))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d_model: int, dtype, tie: bool):
    k1, k2 = jax.random.split(key)
    p = {"embedding": embed_init(k1, (vocab, d_model), dtype)}
    if not tie:
        p["unembed"] = dense_init(k2, (d_model, vocab), dtype)
    return p


def embed(params, tokens: jax.Array, dtype) -> jax.Array:
    out = params["embedding"].astype(dtype)[tokens]
    return shard(out, "batch", "seq", None)


def unembed(params, x: jax.Array, dtype) -> jax.Array:
    if "unembed" in params:
        w = params["unembed"].astype(dtype)
    else:
        w = params["embedding"].astype(dtype).T
    logits = jnp.einsum("...d,dv->...v", x, w)
    return shard(logits, *(("batch",) + (None,) * (logits.ndim - 2) + ("vocab",)))


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None,
                          z_weight: float = 1e-4):
    """Token-mean CE with z-loss; logits (..., V) in any dtype -> fp32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = lse - ll
    z = jnp.square(lse)
    if mask is None:
        mask = jnp.ones_like(ce)
    denom = jnp.maximum(mask.sum(), 1.0)
    ce_mean = (ce * mask).sum() / denom
    z_mean = (z * mask).sum() / denom
    return ce_mean + z_weight * z_mean, {"ce": ce_mean, "z_loss": z_mean}
