"""Top-level model: embeddings -> stack(s) -> head; train / prefill / decode.

Multimodal archs ([audio]/[vlm]) take *precomputed* frontend embeddings
(`prefix_embeds` / encoder `frames`) per the assignment — the modality
frontend is a stub; the backbone is exact.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import (
    embed,
    init_embed,
    init_rms_norm,
    rms_norm,
    softmax_cross_entropy,
    unembed,
)
from .transformer import (
    init_decode_caches,
    init_stack,
    stack_decode,
    stack_forward,
)


class DecodeState(NamedTuple):
    caches: Any
    cur_pos: jax.Array      # (B,) int32 — next position to write


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Dict:
    ks = jax.random.split(key, 4)
    p = {
        "embed": init_embed(ks[0], cfg.padded_vocab, cfg.d_model, cfg.pdtype,
                            cfg.tie_embeddings),
        "stack": init_stack(ks[1], cfg, cross_attn=cfg.is_encdec),
        "final_norm": init_rms_norm(cfg.d_model, cfg.pdtype),
    }
    if cfg.is_encdec:
        p["encoder"] = init_stack(ks[2], cfg, encoder=True)
        p["enc_norm"] = init_rms_norm(cfg.d_model, cfg.pdtype)
    return p


def param_shapes(cfg: ModelConfig) -> Dict:
    """Shape-only init (no FLOPs/allocation) for AOT lowering."""
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _encode(params, batch, cfg: ModelConfig):
    frames = batch["frames"].astype(cfg.cdtype)   # (B, S_enc, d) stub embeds
    pos = jnp.broadcast_to(
        jnp.arange(frames.shape[1], dtype=jnp.int32),
        frames.shape[:2])
    h, _, _ = stack_forward(params["encoder"], frames, pos, cfg, encoder=True)
    return rms_norm(h, params["enc_norm"]["scale"], cfg.norm_eps)


def _embed_inputs(params, batch, cfg: ModelConfig):
    """Token embeddings, with optional multimodal prefix concatenation."""
    x = embed(params["embed"], batch["tokens"], cfg.cdtype)
    if cfg.prefix_len and "prefix_embeds" in batch:
        pre = batch["prefix_embeds"].astype(cfg.cdtype)   # (B, P, d)
        x = jnp.concatenate([pre, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return x, positions


def forward(params, batch, cfg: ModelConfig, *, return_caches: bool = False):
    """Full forward: logits over the (prefix+)token sequence."""
    memory = _encode(params, batch, cfg) if cfg.is_encdec else None
    x, positions = _embed_inputs(params, batch, cfg)
    x, aux, caches = stack_forward(params["stack"], x, positions, cfg,
                                   memory=memory, return_caches=return_caches)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.prefix_len and "prefix_embeds" in batch:
        x = x[:, batch["prefix_embeds"].shape[1]:]
    logits = unembed(params["embed"], x, cfg.cdtype)
    return logits, aux, caches, memory


def loss_fn(params, batch, cfg: ModelConfig):
    logits, aux, _, _ = forward(params, batch, cfg)
    mask = batch.get("loss_mask")
    loss, metrics = softmax_cross_entropy(logits, batch["labels"], mask)
    total = loss + aux
    metrics = dict(metrics, aux=aux, loss=total)
    return total, metrics


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def prefill(params, batch, cfg: ModelConfig, max_len: int
            ) -> Tuple[jax.Array, DecodeState]:
    """Run the full prompt, build position-tagged decode caches.

    Note: full-cache views from `stack_forward` are re-laid-out into the
    (possibly ring-buffered) decode caches.
    """
    logits, _, caches, memory = forward(params, batch, cfg,
                                        return_caches=True)
    b, s = batch["tokens"].shape[0], batch["tokens"].shape[1]
    if cfg.prefix_len and "prefix_embeds" in batch:
        s = s + batch["prefix_embeds"].shape[1]
    state = init_decode_caches(cfg, b, max_len, memory=memory,
                               params=params["stack"])
    state = _load_prefill_caches(state, caches, cfg, s, max_len)
    cur = jnp.full((b,), s, jnp.int32)
    return logits[:, -1], DecodeState(state, cur)


def _load_prefill_caches(decode_caches, full_caches, cfg: ModelConfig,
                         seq: int, max_len: int):
    """Copy prefill KV/ssm caches into the decode layout (tagged ring)."""
    def load(dst, src):
        if src is None:
            return dst
        if hasattr(src, "kv_pos"):          # KVCacheView
            cache_len = dst.k.shape[-3] if dst.k.ndim == 4 else dst.k.shape[-3]
            # Write the last `cache_len` positions into ring slots.
            take = min(seq, dst.k.shape[-3])
            pos = jnp.arange(seq - take, seq, dtype=jnp.int32)
            slots = pos % dst.k.shape[-3]
            k = dst.k.at[..., slots, :, :].set(src.k[..., -take:, :, :])
            v = dst.v
            if dst.v.shape[-1]:
                v = dst.v.at[..., slots, :, :].set(src.v[..., -take:, :, :])
            kv_pos = dst.kv_pos.at[..., slots].set(
                jnp.broadcast_to(pos, src.kv_pos[..., -take:].shape))
            return type(src)(k, v, kv_pos)
        return src                           # MambaCache: final state already

    def load_tree(dst, src):
        return jax.tree.map(load, dst, src,
                            is_leaf=lambda x: hasattr(x, "kv_pos")
                            or hasattr(x, "conv"))

    out = dict(decode_caches)
    out["prefix"] = [load_tree(d, s) for d, s in
                     zip(decode_caches["prefix"], full_caches["prefix"])]
    out["slots"] = tuple(
        load_tree(d, s) for d, s in
        zip(decode_caches["slots"], full_caches["slots"]))
    return out


def decode_step(params, tokens, state: DecodeState, cfg: ModelConfig
                ) -> Tuple[jax.Array, DecodeState]:
    """tokens: (B,) int32 -> (logits (B, V), new state)."""
    x = embed(params["embed"], tokens[:, None], cfg.cdtype)   # (B,1,d)
    x, caches = stack_decode(params["stack"], x, state.caches, state.cur_pos,
                             cfg)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg.cdtype)[:, 0]
    return logits, DecodeState(caches, state.cur_pos + 1)
