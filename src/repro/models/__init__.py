"""Model zoo: unified transformer/SSM/MoE/hybrid stacks."""
from .model import (  # noqa: F401
    DecodeState,
    decode_step,
    forward,
    init_params,
    loss_fn,
    param_shapes,
    prefill,
)
