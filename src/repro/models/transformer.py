"""Layer-stack assembly: heterogeneous block *periods* under ``lax.scan``.

A model is ``first_k_dense`` unstacked prefix layers plus N identical
*periods*; each period is the config's ``block_pattern`` (e.g. Gemma-3:
5 local + 1 global; Jamba: 7 mamba + 1 attn with alternating MoE). Scanning
over periods keeps the lowered HLO size independent of depth — critical for
the 40-cell dry-run compile budget.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .attention import (
    KVCacheView,
    attention,
    decode_attention,
    init_attention,
    init_cache,
)
from .layers import init_mlp, init_rms_norm, mlp, rms_norm
from .mamba import MambaCache, init_mamba, mamba_decode, mamba_layer
from .moe import init_moe, moe_ffn


class CrossCache(NamedTuple):
    k: jax.Array   # (B, S_enc, KV, D)
    v: jax.Array


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, mixer: str, ffn: str,
               cross_attn: bool = False):
    ks = jax.random.split(key, 6)
    p: dict = {"norm1": init_rms_norm(cfg.d_model, cfg.pdtype),
               "norm2": init_rms_norm(cfg.d_model, cfg.pdtype)}
    if mixer in ("attn", "local"):
        p["mixer"] = init_attention(ks[0], cfg)
    else:
        p["mixer"] = init_mamba(ks[1], cfg)
    if ffn == "moe":
        p["ffn"] = init_moe(ks[2], cfg)
    elif ffn == "dense":
        p["ffn"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.pdtype,
                            gated=cfg.mlp_gated)
    # ffn == "none" (e.g. pure Mamba-2): no FFN params, norm2 unused.
    if cross_attn:
        p["cross"] = init_attention(ks[4], cfg)
        p["norm_c"] = init_rms_norm(cfg.d_model, cfg.pdtype)
    return p


def block_forward(p, x, positions, cfg: ModelConfig, mixer: str, ffn: str,
                  *, causal: bool = True, memory: Optional[jax.Array] = None,
                  return_cache: bool = False):
    """Pre-norm block. Returns (x, aux_loss, cache|None)."""
    h = rms_norm(x, p["norm1"]["scale"], cfg.norm_eps)
    cache = None
    if mixer in ("attn", "local"):
        out = attention(p["mixer"], h, positions, cfg, kind=mixer,
                        causal=causal, return_cache=return_cache)
        if return_cache:
            out, cache = out
    else:
        out = mamba_layer(p["mixer"], h, cfg, return_cache=return_cache)
        if return_cache:
            out, cache = out
    x = x + out

    if memory is not None and "cross" in p:
        hc = rms_norm(x, p["norm_c"]["scale"], cfg.norm_eps)
        # Cross-attention: q from decoder, kv from encoder memory, non-causal.
        xattn = _cross_attention(p["cross"], hc, memory, cfg)
        x = x + xattn

    aux = jnp.zeros((), jnp.float32)
    if ffn == "none":
        return x, aux, cache
    h2 = rms_norm(x, p["norm2"]["scale"], cfg.norm_eps)
    if ffn == "moe":
        y, aux, _ = moe_ffn(p["ffn"], h2, cfg, cfg.act_fn)
    else:
        y = mlp(p["ffn"], h2, cfg.act_fn, cfg.cdtype)
    return x + y, aux, cache


def _cross_attention(p, x, memory, cfg: ModelConfig,
                     kv: Optional[CrossCache] = None):
    dt = cfg.cdtype
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(dt))
    if kv is None:
        k = jnp.einsum("bsd,dke->bske", memory, p["wk"].astype(dt))
        v = jnp.einsum("bsd,dke->bske", memory, p["wv"].astype(dt))
    else:
        k, v = kv.k, kv.v
    g = cfg.num_heads // cfg.num_kv_heads
    b, s, h, d = q.shape
    scores = jnp.einsum(
        "bqkgd,bskd->bkgqs", q.reshape(b, s, cfg.num_kv_heads, g, d), k,
        preferred_element_type=jnp.float32) * d ** -0.5
    pr = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bkgqd", pr.astype(dt), v)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, d)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(dt))


def cross_kv(p, memory, cfg: ModelConfig) -> CrossCache:
    dt = cfg.cdtype
    return CrossCache(
        k=jnp.einsum("bsd,dke->bske", memory, p["wk"].astype(dt)),
        v=jnp.einsum("bsd,dke->bske", memory, p["wv"].astype(dt)))


# ---------------------------------------------------------------------------
# Stack: prefix layers + scanned periods
# ---------------------------------------------------------------------------

def _pattern(cfg: ModelConfig, encoder: bool):
    if encoder:
        return (("attn", "dense"),)
    return cfg.block_pattern


def _n_periods(cfg: ModelConfig, encoder: bool) -> int:
    if encoder:
        return cfg.encoder_layers
    n = cfg.num_layers - cfg.first_k_dense
    assert n % len(cfg.block_pattern) == 0
    return n // len(cfg.block_pattern)


def init_stack(key, cfg: ModelConfig, *, encoder: bool = False,
               cross_attn: bool = False):
    pattern = _pattern(cfg, encoder)
    periods = _n_periods(cfg, encoder)
    keys = jax.random.split(key, periods * len(pattern) + cfg.first_k_dense)
    prefix = []
    if not encoder:
        for i in range(cfg.first_k_dense):
            mixer = pattern[0][0]
            prefix.append(init_block(keys[i], cfg, mixer, "dense",
                                     cross_attn=cross_attn))
    # Stacked period params: leading axis = periods for each pattern slot.
    slots = []
    for j, (mixer, ffn) in enumerate(pattern):
        per = [init_block(keys[cfg.first_k_dense + i * len(pattern) + j],
                          cfg, mixer, ffn, cross_attn=cross_attn)
               for i in range(periods)]
        slots.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
    return {"prefix": prefix, "slots": tuple(slots)}


def stack_forward(params, x, positions, cfg: ModelConfig, *,
                  encoder: bool = False, memory: Optional[jax.Array] = None,
                  return_caches: bool = False):
    """Full-sequence pass. Returns (x, aux_loss, caches).

    caches: {"prefix": [...], "slots": tuple per slot, stacked over periods}
    """
    pattern = _pattern(cfg, encoder)
    causal = not encoder

    aux_total = jnp.zeros((), jnp.float32)
    prefix_caches = []
    for p, (mixer, _) in zip(params["prefix"],
                             [pattern[0]] * len(params["prefix"])):
        x, aux, c = block_forward(p, x, positions, cfg, mixer, "dense",
                                  causal=causal, memory=memory,
                                  return_cache=return_caches)
        aux_total += aux
        prefix_caches.append(c)

    def period_fn(carry, slot_params):
        x, aux_acc = carry
        caches = []
        for j, (mixer, ffn) in enumerate(pattern):
            x, aux, c = block_forward(slot_params[j], x, positions, cfg,
                                      mixer, ffn, causal=causal,
                                      memory=memory,
                                      return_cache=return_caches)
            aux_acc = aux_acc + aux
            caches.append(c)
        return (x, aux_acc), tuple(caches)

    if cfg.remat_policy != "none":
        policy = (None if cfg.remat_policy == "full"
                  else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        period_fn = jax.checkpoint(
            period_fn, policy=policy, prevent_cse=False)

    if cfg.scan_periods:
        (x, aux_total), slot_caches = jax.lax.scan(
            period_fn, (x, aux_total), params["slots"])
    else:
        # Flat unroll (dry-run cost accounting; XLA counts loop bodies once).
        n = jax.tree.leaves(params["slots"])[0].shape[0]
        ys = []
        carry = (x, aux_total)
        for i in range(n):
            carry, y = period_fn(carry,
                                 jax.tree.map(lambda p: p[i],
                                              params["slots"]))
            ys.append(y)
        (x, aux_total) = carry
        slot_caches = jax.tree.map(lambda *zs: jnp.stack(zs), *ys) \
            if return_caches else tuple(None for _ in pattern)
    caches = {"prefix": prefix_caches, "slots": slot_caches} \
        if return_caches else None
    return x, aux_total, caches


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------

def init_decode_caches(cfg: ModelConfig, batch: int, max_len: int,
                       memory: Optional[jax.Array] = None,
                       params=None, dtype=None):
    """Allocate caches for the decoder stack (+ cross-KV for enc-dec)."""
    pattern = _pattern(cfg, encoder=False)
    periods = _n_periods(cfg, encoder=False)

    def one(mixer):
        if mixer in ("attn", "local"):
            return init_cache(cfg, batch, max_len, mixer, dtype)
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        conv_ch = d_inner + 2 * s.n_groups * s.d_state
        return MambaCache(
            conv=jnp.zeros((batch, s.d_conv - 1, conv_ch), dtype or cfg.cdtype),
            state=jnp.zeros((batch, d_inner // s.head_dim, s.d_state,
                             s.head_dim), jnp.float32))

    prefix = [one(pattern[0][0]) for _ in range(cfg.first_k_dense)]
    slots = tuple(
        jax.tree.map(lambda x: jnp.broadcast_to(x, (periods,) + x.shape), one(m))
        for (m, _) in pattern)
    caches = {"prefix": prefix, "slots": slots}
    if memory is not None and params is not None:
        xk = [cross_kv(p["cross"], memory, cfg) for p in params["prefix"]]
        caches["cross_prefix"] = xk
        caches["cross_slots"] = tuple(
            jax.vmap(lambda sp: cross_kv(sp["cross"], memory, cfg))(
                params["slots"][j])
            for j in range(len(pattern)))
    return caches


def stack_decode(params, x, caches, cur_pos, cfg: ModelConfig):
    """One-token decode through the stack. x: (B, 1, d). Returns (x, caches')."""
    pattern = _pattern(cfg, encoder=False)

    def block_step(p, x, cache, mixer, ffn, cross=None):
        h = rms_norm(x, p["norm1"]["scale"], cfg.norm_eps)
        if mixer in ("attn", "local"):
            out, cache = decode_attention(p["mixer"], h, cache, cur_pos, cfg,
                                          kind=mixer)
        else:
            out, cache = mamba_decode(p["mixer"], h, cache, cfg)
        x = x + out
        if cross is not None:
            hc = rms_norm(x, p["norm_c"]["scale"], cfg.norm_eps)
            x = x + _cross_attention(p["cross"], hc, None, cfg, kv=cross)
        if ffn == "none":
            return x, cache
        h2 = rms_norm(x, p["norm2"]["scale"], cfg.norm_eps)
        if ffn == "moe":
            y, _, _ = moe_ffn(p["ffn"], h2, cfg, cfg.act_fn)
        else:
            y = mlp(p["ffn"], h2, cfg.act_fn, cfg.cdtype)
        return x + y, cache

    new_prefix = []
    for i, p in enumerate(params["prefix"]):
        cross = caches.get("cross_prefix", [None] * 99)[i] \
            if "cross_prefix" in caches else None
        x, c = block_step(p, x, caches["prefix"][i], pattern[0][0], "dense",
                          cross)
        new_prefix.append(c)

    has_cross = "cross_slots" in caches

    def period_fn(x, xs):
        slot_params, slot_caches, cross_caches = xs
        new_caches = []
        for j, (mixer, ffn) in enumerate(pattern):
            cross = cross_caches[j] if has_cross else None
            x, c = block_step(slot_params[j], x, slot_caches[j], mixer, ffn,
                              cross)
            new_caches.append(c)
        return x, tuple(new_caches)

    cross_xs = caches.get("cross_slots",
                          tuple(None for _ in pattern)) if has_cross else \
        tuple(jnp.zeros((_n_periods(cfg, False), 0)) for _ in pattern)
    if cfg.scan_periods:
        x, new_slots = jax.lax.scan(
            period_fn, x, (params["slots"], caches["slots"], cross_xs))
    else:
        n = jax.tree.leaves(params["slots"])[0].shape[0]
        ys = []
        for i in range(n):
            x, y = period_fn(x, jax.tree.map(
                lambda p: p[i], (params["slots"], caches["slots"], cross_xs)))
            ys.append(y)
        new_slots = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    out = {"prefix": new_prefix, "slots": new_slots}
    if has_cross:
        out["cross_prefix"] = caches["cross_prefix"]
        out["cross_slots"] = caches["cross_slots"]
    return x, out
