"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) layer.

Chunked SSD algorithm: within-chunk terms are attention-like einsums
(parallel over chunks), the cross-chunk recurrence is a short ``lax.scan``
over chunk states — giving O(S * Q) work with Q = chunk length instead of
O(S^2), and an O(1)-state decode step.

Layout: d_inner = expand * d_model channels split into H = d_inner/P heads of
dim P; B/C projections have G groups of state size N shared across heads.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import shard
from .layers import dense_init, rms_norm


class MambaCache(NamedTuple):
    conv: jax.Array    # (B, d_conv-1, conv_channels) rolling conv inputs
    state: jax.Array   # (B, H, N, P) SSD state


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_ch


def init_mamba(key, cfg: ModelConfig):
    s = cfg.ssm
    d_inner, n_heads, conv_ch = _dims(cfg)
    ks = jax.random.split(key, 4)
    # dt bias initialized so softplus(dt_bias) spans [dt_min, dt_max].
    u = jax.random.uniform(ks[2], (n_heads,))
    dt0 = jnp.exp(u * (jnp.log(s.dt_max) - jnp.log(s.dt_min)) + jnp.log(s.dt_min))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))  # inverse softplus
    return {
        "in_proj": dense_init(ks[0], (cfg.d_model,
                                      2 * d_inner + 2 * s.n_groups * s.d_state
                                      + n_heads), cfg.pdtype),
        "conv_w": dense_init(ks[1], (s.d_conv, conv_ch), cfg.pdtype),
        "conv_b": jnp.zeros((conv_ch,), cfg.pdtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(jnp.arange(1, n_heads + 1, dtype=jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "norm": {"scale": jnp.zeros((d_inner,), cfg.pdtype)},
        "out_proj": dense_init(ks[3], (d_inner, cfg.d_model), cfg.pdtype),
    }


def _split_proj(params, x, cfg: ModelConfig):
    s = cfg.ssm
    d_inner, n_heads, conv_ch = _dims(cfg)
    dt_ = cfg.cdtype
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt_))
    # split points: z | xBC | dt
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:d_inner + conv_ch]
    dt = proj[..., d_inner + conv_ch:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, prev=None):
    """Depthwise causal conv along seq. xbc: (B, S, C); prev: (B, K-1, C)."""
    k = conv_w.shape[0]
    if prev is None:
        prev = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    padded = jnp.concatenate([prev, xbc], axis=1)
    out = sum(padded[:, i:i + xbc.shape[1], :] * conv_w[i][None, None, :]
              for i in range(k))
    new_prev = padded[:, -(k - 1):, :] if k > 1 else prev
    return jax.nn.silu(out + conv_b[None, None, :]), new_prev


def mamba_layer(params, x: jax.Array, cfg: ModelConfig, *,
                return_cache: bool = False):
    """Full-sequence SSD pass. x: (B, S, d_model)."""
    s = cfg.ssm
    d_inner, n_heads, conv_ch = _dims(cfg)
    g, n, p = s.n_groups, s.d_state, s.head_dim
    b, seqlen, _ = x.shape
    q = min(s.chunk, seqlen)
    assert seqlen % q == 0, f"seq {seqlen} not divisible by chunk {q}"
    nc = seqlen // q
    dt_c = cfg.cdtype

    z, xbc, dt_raw = _split_proj(params, x, cfg)
    xbc, conv_tail = _causal_conv(xbc, params["conv_w"].astype(dt_c),
                                  params["conv_b"].astype(dt_c))
    xc = xbc[..., :d_inner].reshape(b, nc, q, n_heads, p).astype(jnp.float32)
    Bm = xbc[..., d_inner:d_inner + g * n].reshape(b, nc, q, g, n).astype(jnp.float32)
    Cm = xbc[..., d_inner + g * n:].reshape(b, nc, q, g, n).astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])     # (B,S,H)
    dt = dt.reshape(b, nc, q, n_heads)
    A = -jnp.exp(params["A_log"])                                # (H,) negative
    da = dt * A[None, None, None, :]                             # (B,nc,Q,H)
    cum = jnp.cumsum(da, axis=2)

    # Heads per group mapping (G groups broadcast over H heads).
    hpg = n_heads // g
    Bh = jnp.repeat(Bm, hpg, axis=3)                             # (B,nc,Q,H,N)
    Ch = jnp.repeat(Cm, hpg, axis=3)

    # --- intra-chunk (attention-like) ---
    idx = jnp.arange(q)
    causal = idx[:, None] >= idx[None, :]
    ld = cum[:, :, :, None, :] - cum[:, :, None, :, :]           # (B,nc,Qi,Qj,H)
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(ld), 0.0)
    cb = jnp.einsum("bcihn,bcjhn->bcijh", Ch, Bh)
    m = cb * L * dt[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", m, xc)

    # --- chunk states + cross-chunk recurrence ---
    w = jnp.exp(cum[:, :, -1:, :] - cum) * dt                    # (B,nc,Q,H)
    s_chunk = jnp.einsum("bcqhn,bcqh,bcqhp->bchnp", Bh, w, xc)   # (B,nc,H,N,P)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                      # (B,nc,H)

    def scan_fn(h, inp):
        s_c, dec = inp
        h_new = h * dec[..., None, None] + s_c
        return h_new, h

    h0 = jnp.zeros((b, n_heads, n, p), jnp.float32)
    h_final, h_states = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_states = jnp.moveaxis(h_states, 0, 1)                      # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcqhn,bchnp->bcqhp", Ch, h_states) \
        * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter + params["D"][None, None, None, :, None]
         * xc).reshape(b, seqlen, d_inner)

    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(dt_c), params["norm"]["scale"], cfg.norm_eps)
    y = shard(y, "batch", "seq", "d_ff")
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt_c))
    if return_cache:
        return out, MambaCache(conv=conv_tail, state=h_final.astype(jnp.float32))
    return out


def mamba_decode(params, x: jax.Array, cache: MambaCache,
                 cfg: ModelConfig) -> Tuple[jax.Array, MambaCache]:
    """One-token recurrent step. x: (B, 1, d_model)."""
    s = cfg.ssm
    d_inner, n_heads, conv_ch = _dims(cfg)
    g, n, p = s.n_groups, s.d_state, s.head_dim
    b = x.shape[0]
    dt_c = cfg.cdtype

    z, xbc, dt_raw = _split_proj(params, x, cfg)
    xbc, conv_tail = _causal_conv(xbc, params["conv_w"].astype(dt_c),
                                  params["conv_b"].astype(dt_c),
                                  prev=cache.conv.astype(dt_c))
    xc = xbc[:, 0, :d_inner].reshape(b, n_heads, p).astype(jnp.float32)
    Bm = xbc[:, 0, d_inner:d_inner + g * n].reshape(b, g, n).astype(jnp.float32)
    Cm = xbc[:, 0, d_inner + g * n:].reshape(b, g, n).astype(jnp.float32)
    hpg = n_heads // g
    Bh = jnp.repeat(Bm, hpg, axis=1)                             # (B,H,N)
    Ch = jnp.repeat(Cm, hpg, axis=1)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + params["dt_bias"][None, :])           # (B,H)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A[None, :])                             # (B,H)

    new_state = (cache.state * decay[..., None, None]
                 + jnp.einsum("bhn,bh,bhp->bhnp", Bh, dt, xc))
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state) \
        + params["D"][None, :, None] * xc
    y = y.reshape(b, 1, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(dt_c), params["norm"]["scale"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt_c))
    return out, MambaCache(conv=conv_tail, state=new_state)
