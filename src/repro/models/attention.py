"""Attention: GQA/MHA with q/k-norm, partial RoPE, sliding windows, and MLA.

Train/prefill use a blockwise (flash-style) O(block^2)-memory implementation
in pure jnp — the Pallas kernel in :mod:`repro.kernels.flash_attention` is
the TPU-target version of the same schedule. Decode uses a dense-view cache
(B, S, KV, D) with per-slot position tags so full, sliding-window and
ring-buffer caches share one masking rule; the paged pool + descriptor-chain
view lives in :mod:`repro.serve.kv_cache` and lowers to
:mod:`repro.kernels.paged_attention` on TPU.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import shard
from .layers import apply_rope, dense_init, init_rms_norm, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    dt = cfg.pdtype
    if cfg.mla is not None:
        m = cfg.mla
        ks = jax.random.split(key, 6)
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        p = {
            "q_down": dense_init(ks[0], (d, m.q_lora_rank), dt),
            "q_norm": init_rms_norm(m.q_lora_rank, dt),
            "q_up": dense_init(ks[1], (m.q_lora_rank, h, qk), dt),
            "kv_down": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dt),
            "kv_norm": init_rms_norm(m.kv_lora_rank, dt),
            "kv_up": dense_init(ks[3], (m.kv_lora_rank, h,
                                        m.qk_nope_head_dim + m.v_head_dim), dt),
            "wo": dense_init(ks[4], (h, m.v_head_dim, d), dt, in_axis=0),
        }
        return p
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), dt),
        "wk": dense_init(ks[1], (d, kv, hd), dt),
        "wv": dense_init(ks[2], (d, kv, hd), dt),
        "wo": dense_init(ks[3], (h, hd, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dt)
        p["bk"] = jnp.zeros((kv, hd), dt)
        p["bv"] = jnp.zeros((kv, hd), dt)
    if cfg.qk_norm:
        p["q_norm"] = init_rms_norm(hd, dt)
        p["k_norm"] = init_rms_norm(hd, dt)
    return p


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention — jnp reference schedule
# ---------------------------------------------------------------------------

def _mask(q_pos, kv_pos, causal: bool, window: Optional[int]):
    """q_pos: (..., Sq), kv_pos: (..., Sk) -> (..., Sq, Sk) additive mask."""
    ok = kv_pos[..., None, :] >= 0
    if causal:
        ok &= kv_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        ok &= q_pos[..., :, None] - kv_pos[..., None, :] < window
    return jnp.where(ok, 0.0, NEG_INF)


def blockwise_attention(
    q: jax.Array,              # (B, Sq, H, D)
    k: jax.Array,              # (B, Sk, KV, D)
    v: jax.Array,              # (B, Sk, KV, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_positions: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
    softcap: Optional[float] = None,
    q_block: int = 512,
    kv_block: int = 512,
) -> jax.Array:
    """Memory-efficient attention: outer scan over q blocks, inner over kv
    blocks with running (max, sum, acc) — the flash schedule in pure jnp."""
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    dv = v.shape[-1]            # value head dim may differ (MLA)
    g = h // kv
    scale = d ** -0.5
    q_block = min(q_block, sq)
    kv_block = min(kv_block, sk)
    assert sq % q_block == 0 and sk % kv_block == 0
    nq, nk = sq // q_block, sk // kv_block

    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(sq, dtype=jnp.int32), (b, sq))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(sk, dtype=jnp.int32), (b, sk))

    qb = q.reshape(b, nq, q_block, kv, g, d)
    kb = k.reshape(b, nk, kv_block, kv, d)
    vb = v.reshape(b, nk, kv_block, kv, dv)
    qpb = q_positions.reshape(b, nq, q_block)
    kpb = kv_positions.reshape(b, nk, kv_block)

    def q_step(qi):
        qi_q = qb[:, qi]          # (B, qb, KV, G, D)
        qi_pos = qpb[:, qi]       # (B, qb)

        def kv_step(carry, ki):
            m_run, l_run, acc = carry
            kk, vv, kpos = kb[:, ki], vb[:, ki], kpb[:, ki]
            s = jnp.einsum("bqkgd,bskd->bkgqs", qi_q, kk,
                           preferred_element_type=jnp.float32) * scale
            if softcap is not None:
                s = jnp.tanh(s / softcap) * softcap
            s = s + _mask(qi_pos, kpos, causal, window)[:, None, None, :, :]
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vv.dtype), vv,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, kv, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, kv, g, q_block, dv), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          jnp.arange(nk))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4).reshape(b, q_block, h, dv)

    out = jax.lax.map(q_step, jnp.arange(nq))       # (nq, B, qb, H, Dv)
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, h, dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Full-pass (train / prefill) attention layers
# ---------------------------------------------------------------------------

class KVCacheView(NamedTuple):
    """Dense-view cache for one layer: position-tagged slots."""
    k: jax.Array           # (B, S, KV, D) — MLA: (B, S, 1, lora+rope)
    v: jax.Array           # (B, S, KV, D) — MLA: unused placeholder (B,0,..)
    kv_pos: jax.Array      # (B, S) int32, -1 = empty


def _project_qkv(params, x, cfg: ModelConfig, positions):
    dt = cfg.cdtype
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dke->bske", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dke->bske", x, params["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"]["scale"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"]["scale"], cfg.norm_eps)
    q = apply_rope(q, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
    k = apply_rope(k, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def attention(params, x, positions, cfg: ModelConfig, *,
              kind: str = "attn", causal: bool = True,
              return_cache: bool = False):
    """Full-sequence attention. kind: 'attn' (full) or 'local' (windowed)."""
    if cfg.mla is not None:
        return _mla_attention(params, x, positions, cfg,
                              return_cache=return_cache)
    dt = cfg.cdtype
    q, k, v = _project_qkv(params, x, cfg, positions)
    window = cfg.sliding_window if kind == "local" else None
    if cfg.attention_impl == "proj_only":
        # Dry-run accounting mode: projections kept, core replaced by a
        # shape-correct passthrough (its cost is added analytically).
        g = cfg.num_heads // cfg.num_kv_heads
        out = jnp.repeat(v, g, axis=2)
    else:
        out = blockwise_attention(q, k, v, causal=causal, window=window,
                                  q_positions=positions,
                                  kv_positions=positions,
                                  softcap=cfg.attn_logit_softcap)
    y = jnp.einsum("bshe,hed->bsd", out, params["wo"].astype(dt))
    y = shard(y, "batch", "seq", None)
    if return_cache:
        return y, KVCacheView(k, v, positions.astype(jnp.int32))
    return y


def _mla_attention(params, x, positions, cfg: ModelConfig, *,
                   return_cache: bool = False):
    """DeepSeek-V2 multi-head latent attention (training: expanded form)."""
    m = cfg.mla
    dt = cfg.cdtype
    b, s, _ = x.shape
    cq = jnp.einsum("bsd,dr->bsr", x, params["q_down"].astype(dt))
    cq = rms_norm(cq, params["q_norm"]["scale"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhe->bshe", cq, params["q_up"].astype(dt))
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, theta=cfg.rope_theta)

    ckv = jnp.einsum("bsd,dr->bsr", x, params["kv_down"].astype(dt))
    c_kv, k_rope = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, params["kv_norm"]["scale"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        theta=cfg.rope_theta)  # (B,S,1,rope)

    kv = jnp.einsum("bsr,rhe->bshe", c_kv, params["kv_up"].astype(dt))
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, cfg.num_heads,
                                           m.qk_rope_head_dim))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    if cfg.attention_impl == "proj_only":
        out = v  # dry-run accounting mode (core added analytically)
    else:
        out = blockwise_attention(q_full, k, v, causal=True,
                                  q_positions=positions,
                                  kv_positions=positions)
    y = jnp.einsum("bshe,hed->bsd", out, params["wo"].astype(dt))
    y = shard(y, "batch", "seq", None)
    if return_cache:
        # MLA caches the *compressed* latents: (c_kv | k_rope) per position.
        lat = jnp.concatenate([c_kv, k_rope[:, :, 0, :]], axis=-1)[:, :, None, :]
        empty_v = jnp.zeros((b, s, 1, 0), dt)
        return y, KVCacheView(lat, empty_v, positions.astype(jnp.int32))
    return y


# ---------------------------------------------------------------------------
# Decode (single-token) attention against a dense-view cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, kind: str,
               dtype=None) -> KVCacheView:
    dtype = dtype or cfg.cdtype
    if cfg.mla is not None:
        m = cfg.mla
        lat = m.kv_lora_rank + m.qk_rope_head_dim
        return KVCacheView(
            k=jnp.zeros((batch, max_len, 1, lat), dtype),
            v=jnp.zeros((batch, max_len, 1, 0), dtype),
            kv_pos=jnp.full((batch, max_len), -1, jnp.int32))
    size = min(max_len, cfg.sliding_window) if (
        kind == "local" and cfg.sliding_window) else max_len
    return KVCacheView(
        k=jnp.zeros((batch, size, cfg.num_kv_heads, cfg.head_dim_), dtype),
        v=jnp.zeros((batch, size, cfg.num_kv_heads, cfg.head_dim_), dtype),
        kv_pos=jnp.full((batch, size), -1, jnp.int32))


def decode_attention(params, x, cache: KVCacheView, cur_pos, cfg: ModelConfig,
                     *, kind: str = "attn") -> Tuple[jax.Array, KVCacheView]:
    """One decode step. x: (B, 1, d_model); cur_pos: (B,) current position.

    The cache is a position-tagged ring: slot = pos % cache_len, masking by
    tag, so full caches, sliding windows and ring buffers share this code.
    """
    if cfg.mla is not None:
        return _mla_decode(params, x, cache, cur_pos, cfg)
    dt = cfg.cdtype
    b = x.shape[0]
    positions = cur_pos[:, None]
    q, k_new, v_new = _project_qkv(params, x, cfg, positions)

    cache_len = cache.k.shape[1]
    slot = (cur_pos % cache_len).astype(jnp.int32)
    bidx = jnp.arange(b)
    k = cache.k.at[bidx, slot].set(k_new[:, 0])
    v = cache.v.at[bidx, slot].set(v_new[:, 0])
    kv_pos = cache.kv_pos.at[bidx, slot].set(cur_pos.astype(jnp.int32))

    window = cfg.sliding_window if kind == "local" else None
    s = jnp.einsum("bqkgd,bskd->bkgqs",
                   q.reshape(b, 1, cfg.num_kv_heads,
                             cfg.num_heads // cfg.num_kv_heads, cfg.head_dim_),
                   k, preferred_element_type=jnp.float32) * cfg.head_dim_ ** -0.5
    if cfg.attn_logit_softcap:
        s = jnp.tanh(s / cfg.attn_logit_softcap) * cfg.attn_logit_softcap
    s = s + _mask(positions, kv_pos, True, window)[:, None, None, :, :]
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(dt), v)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, 1, cfg.num_heads, cfg.head_dim_)
    y = jnp.einsum("bshe,hed->bsd", out, params["wo"].astype(dt))
    return y, KVCacheView(k, v, kv_pos)


def _mla_decode(params, x, cache: KVCacheView, cur_pos, cfg: ModelConfig):
    """Absorbed MLA decode: attend in the compressed latent space.

    Cache holds (c_kv | k_rope) of size kv_lora+rope per position — the MLA
    memory win (DeepSeek-V2 §2.1): scores are computed by absorbing kv_up
    into the query, values by attending over c_kv then projecting.
    """
    m = cfg.mla
    dt = cfg.cdtype
    b = x.shape[0]
    positions = cur_pos[:, None]

    cq = jnp.einsum("bsd,dr->bsr", x, params["q_down"].astype(dt))
    cq = rms_norm(cq, params["q_norm"]["scale"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhe->bshe", cq, params["q_up"].astype(dt))
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, theta=cfg.rope_theta)

    ckv = jnp.einsum("bsd,dr->bsr", x, params["kv_down"].astype(dt))
    c_kv_new, k_rope_new = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    c_kv_new = rms_norm(c_kv_new, params["kv_norm"]["scale"], cfg.norm_eps)
    k_rope_new = apply_rope(k_rope_new[:, :, None, :], positions,
                            theta=cfg.rope_theta)[:, :, 0, :]
    lat_new = jnp.concatenate([c_kv_new, k_rope_new], axis=-1)

    cache_len = cache.k.shape[1]
    slot = (cur_pos % cache_len).astype(jnp.int32)
    bidx = jnp.arange(b)
    lat = cache.k.at[bidx, slot, 0].set(lat_new[:, 0])
    kv_pos = cache.kv_pos.at[bidx, slot].set(cur_pos.astype(jnp.int32))
    c_kv, k_rope = lat[:, :, 0, :m.kv_lora_rank], lat[:, :, 0, m.kv_lora_rank:]

    # Absorb kv_up's key half into q: q_abs (B,1,H,r).
    w_up_k = params["kv_up"].astype(dt)[:, :, :m.qk_nope_head_dim]
    q_abs = jnp.einsum("bshe,rhe->bshr", q_nope, w_up_k)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s = (jnp.einsum("bqhr,bsr->bhqs", q_abs, c_kv,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bqhe,bse->bhqs", q_rope, k_rope,
                      preferred_element_type=jnp.float32)) * scale
    s = s + _mask(positions, kv_pos, True, None)[:, None, :, :]
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    # Attend over latents, then expand with kv_up's value half.
    lat_out = jnp.einsum("bhqs,bsr->bqhr", p.astype(dt), c_kv)
    w_up_v = params["kv_up"].astype(dt)[:, :, m.qk_nope_head_dim:]
    out = jnp.einsum("bqhr,rhe->bqhe", lat_out, w_up_v)
    y = jnp.einsum("bshe,hed->bsd", out, params["wo"].astype(dt))
    return y, KVCacheView(lat, cache.v, kv_pos)
