"""Mixture-of-experts with sort-based capacity dispatch.

The dispatch plan (which token row goes to which expert slot) is exactly a
descriptor stream in the paper's sense: src = token index, dst = (expert,
slot), weight in `config`. `moe_dispatch_plan` emits that plan; the dense
jnp path executes it with gather/scatter (the Pallas kernel
`repro.kernels.moe_dispatch` consumes the same plan on TPU).

Routing: softmax router, top-k (optionally renormalized), capacity-bounded
with token dropping (GShard-style), shared experts added densely
(DeepSeek-V2), plus load-balance and router-z auxiliary losses.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.distributed import shard
from .layers import dense_init, mlp, init_mlp


class DispatchPlan(NamedTuple):
    """Descriptor streams for token<->expert movement (static shapes).

    Forward stream (dispatch): slot s <- token_idx[s]  (length E*C).
    Inverse stream (combine):  token t <- sum_j inv_weight[t,j] *
                               expert_out[inv_slot[t,j]]  (shape T x k).
    """
    token_idx: jax.Array    # (E*C,) source token row, -1 = empty slot
    weight: jax.Array       # (E*C,) combine weight for the slot
    inv_slot: jax.Array     # (T, k) expert-slot id per token copy, -1 dropped
    inv_weight: jax.Array   # (T, k) combine weight (0 where dropped)
    num_dropped: jax.Array  # () tokens dropped by capacity


def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, m.num_experts), jnp.float32),
        "w_gate": dense_init(ks[1], (m.num_experts, d, m.expert_d_ff), cfg.pdtype),
        "w_up": dense_init(ks[2], (m.num_experts, d, m.expert_d_ff), cfg.pdtype),
        "w_down": dense_init(ks[3], (m.num_experts, m.expert_d_ff, d), cfg.pdtype),
    }
    if m.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d,
                               (m.shared_d_ff or m.expert_d_ff) * m.num_shared_experts,
                               cfg.pdtype)
    return p


def capacity(num_tokens: int, m: MoEConfig) -> int:
    c = int(num_tokens * m.experts_per_token * m.capacity_factor
            // m.num_experts)
    return max(8, (c + 7) // 8 * 8)  # pad to 8 for tiling friendliness


def moe_dispatch_plan(router_probs: jax.Array, m: MoEConfig,
                      cap: int) -> DispatchPlan:
    """Build the dispatch descriptor stream from router probabilities.

    router_probs: (T, E) fp32. Returns slots for each of E experts x cap.
    """
    t, e = router_probs.shape
    k = m.experts_per_token
    topv, topi = jax.lax.top_k(router_probs, k)             # (T, k)
    if m.router_norm_topk:
        topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    flat_expert = topi.reshape(-1)                          # (T*k,)
    flat_weight = topv.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    # Stable sort by expert id; rank within expert = position - group start.
    order = jnp.argsort(flat_expert, stable=True)
    se, stok, sw = flat_expert[order], flat_token[order], flat_weight[order]
    group_start = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype))
    rank = jnp.arange(t * k, dtype=jnp.int32) - group_start[se].astype(jnp.int32)
    keep = rank < cap
    slot = se.astype(jnp.int32) * cap + rank                # (T*k,)
    slot = jnp.where(keep, slot, e * cap)                   # drop -> overflow

    token_idx = jnp.full((e * cap + 1,), -1, jnp.int32).at[slot].set(
        stok, mode="drop")[:-1]
    weight = jnp.zeros((e * cap + 1,), jnp.float32).at[slot].set(
        sw, mode="drop")[:-1]

    # Inverse plan: scatter each sorted entry's slot back to its (t, j) copy.
    inv_flat = jnp.full((t * k,), -1, jnp.int32).at[order].set(
        jnp.where(keep, slot, -1))
    inv_slot = inv_flat.reshape(t, k)
    inv_weight = jnp.where(inv_slot >= 0, topv, 0.0)
    return DispatchPlan(token_idx, weight, inv_slot, inv_weight,
                        jnp.sum(~keep))


def aux_losses(router_probs: jax.Array, topi: jax.Array, m: MoEConfig,
               router_logits: jax.Array):
    """Switch/GShard load-balance loss + router z-loss."""
    t, e = router_probs.shape
    me = router_probs.mean(axis=0)                               # (E,)
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.float32).sum(1)   # (T, E)
    ce = onehot.mean(axis=0) * e / m.experts_per_token
    lb = (me * ce).sum() * e * m.aux_loss_weight
    z = jnp.square(jax.nn.logsumexp(router_logits, axis=-1)).mean()
    return lb + m.router_z_weight * z, {"moe_lb": lb, "moe_z": z}


def moe_ffn(params, x: jax.Array, cfg: ModelConfig,
            act_fn: str = "silu") -> Tuple[jax.Array, jax.Array, dict]:
    """x: (B, S, d) -> (y, aux_loss, metrics).

    Under an active mesh with a model axis, dispatch runs expert-parallel in
    shard_map (zero-communication local dispatch + one combine psum —
    EXPERIMENTS.md §Perf-1); otherwise the pure-GSPMD gather path below.
    """
    from repro.distributed import shardlib
    mesh = shardlib.current_mesh()
    m = cfg.moe
    if (mesh is not None and "model" in mesh.shape
            and m.num_experts % mesh.shape["model"] == 0):
        return _moe_ffn_ep(params, x, cfg, act_fn, mesh)
    return _moe_ffn_gspmd(params, x, cfg, act_fn)


def _moe_ffn_ep(params, x: jax.Array, cfg: ModelConfig, act_fn: str, mesh):
    """Expert-parallel MoE: tokens stay on their (pod, data) shard, every
    shard dispatches locally to all experts (per-shard capacity), each
    model-rank computes its E/TP experts, partial token outputs psum over
    the model axis. Dispatch itself moves zero bytes across chips — the
    descriptor plan stays local, exactly the paper's cheap-descriptor thesis.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed import shardlib

    m = cfg.moe
    dt = cfg.cdtype
    b, s, d = x.shape
    rules = shardlib.current_rules()
    batch_ax = rules.get("batch")
    if batch_ax is not None:
        axes = batch_ax if isinstance(batch_ax, tuple) else (batch_ax,)
        ax_size = 1
        for a in axes:
            ax_size *= mesh.shape.get(a, 1)
        if (b * s) % ax_size != 0:
            batch_ax = None     # e.g. single-sequence long-context decode
    n_model = mesh.shape["model"]
    e_loc = m.num_experts // n_model
    act = jax.nn.silu if act_fn == "silu" else jax.nn.gelu

    def local_fn(xt, router_w, w_gate, w_up, w_down):
        # xt: (T_loc, d); w_*: (E_loc, d, f) — this rank's experts.
        t_loc = xt.shape[0]
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router_w)
        probs = jax.nn.softmax(logits, axis=-1)
        cap = capacity(t_loc, m)
        plan = moe_dispatch_plan(probs, m, cap)
        topv, topi = jax.lax.top_k(probs, m.experts_per_token)
        aux, metrics = aux_losses(probs, topi, m, logits)

        # Local gather of THIS rank's expert slots only (no communication).
        ridx = jax.lax.axis_index("model")
        slot0 = ridx * e_loc * cap
        own_tokens = jax.lax.dynamic_slice_in_dim(
            plan.token_idx, slot0, e_loc * cap)
        xe = xt[jnp.maximum(own_tokens, 0)].astype(dt)
        xe = xe * (own_tokens >= 0)[:, None].astype(dt)
        xe = xe.reshape(e_loc, cap, d)

        gate = jnp.einsum("ecd,edf->ecf", xe, w_gate.astype(dt))
        up = jnp.einsum("ecd,edf->ecf", xe, w_up.astype(dt))
        ye = jnp.einsum("ecf,efd->ecd", act(gate) * up, w_down.astype(dt))
        ye_flat = ye.reshape(e_loc * cap, d)

        # Combine: this rank contributes only its own slots; psum finishes.
        rel = plan.inv_slot - slot0
        own = (rel >= 0) & (rel < e_loc * cap)
        rows = ye_flat[jnp.clip(rel, 0, e_loc * cap - 1)]
        w = jnp.where(own, plan.inv_weight, 0.0)
        y = jnp.einsum("tk,tkd->td", w.astype(jnp.float32),
                       rows.astype(jnp.float32)).astype(dt)
        y = jax.lax.psum(y, "model")
        # aux is identical within a data row; average across token shards.
        if batch_ax is not None:
            aux = jax.lax.pmean(aux, batch_ax)
            dropped = jax.lax.pmean(plan.num_dropped / jnp.maximum(t_loc, 1),
                                    batch_ax)
        else:
            dropped = plan.num_dropped / jnp.maximum(t_loc, 1)
        return y, aux, dropped

    t_spec = P(batch_ax, None)
    w_spec = P("model", None, None)
    other_axes = tuple(a for a in mesh.axis_names)
    y, aux, dropped = shard_map(
        local_fn, mesh=mesh,
        in_specs=(t_spec, P(None, None), w_spec, w_spec, w_spec),
        out_specs=(t_spec, P(), P()),
        check_rep=False,
    )(x.reshape(b * s, d), params["router"],
      params["w_gate"], params["w_up"], params["w_down"])

    if m.num_shared_experts:
        y = y + mlp(params["shared"], x.reshape(b * s, d), act_fn, dt)
    metrics = {"moe_dropped": dropped}
    return y.reshape(b, s, d), aux, metrics


def _moe_ffn_gspmd(params, x: jax.Array, cfg: ModelConfig,
                   act_fn: str = "silu") -> Tuple[jax.Array, jax.Array, dict]:
    m = cfg.moe
    dt = cfg.cdtype
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    cap = capacity(t, m)
    plan = moe_dispatch_plan(probs, m, cap)
    topv, topi = jax.lax.top_k(probs, m.experts_per_token)
    aux, metrics = aux_losses(probs, topi, m, logits)

    # Gather tokens into (E, C, d) — the descriptor-engine gather. Experts
    # shard over the TP axis (EP) and the capacity dim over the data axis,
    # so expert matmuls use the full chip grid (EXPERIMENTS.md §Perf-1).
    safe = jnp.maximum(plan.token_idx, 0)
    xe = xt[safe].reshape(m.num_experts, cap, d).astype(dt)
    xe = xe * (plan.token_idx >= 0).reshape(m.num_experts, cap, 1).astype(dt)
    xe = shard(xe, "experts", "expert_cap", None)

    act = jax.nn.silu if act_fn == "silu" else jax.nn.gelu
    gate = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(dt))
    up = jnp.einsum("ecd,edf->ecf", xe, params["w_up"].astype(dt))
    h = act(gate) * up
    h = shard(h, "experts", "expert_cap", None)
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dt))
    ye = shard(ye, "experts", "expert_cap", None)

    # Combine via the inverse descriptor stream: gather-and-weight per token
    # (gather keeps GSPMD happy and matches kernels.moe_dispatch on TPU).
    flat_y = ye.reshape(m.num_experts * cap, d)
    rows = flat_y[jnp.maximum(plan.inv_slot, 0)]          # (T, k, d)
    w = jnp.where(plan.inv_slot >= 0, plan.inv_weight, 0.0)
    y = jnp.einsum("tk,tkd->td", w.astype(jnp.float32),
                   rows.astype(jnp.float32)).astype(dt)

    if m.num_shared_experts:
        y = y + mlp(params["shared"], xt, act_fn, dt)

    metrics = dict(metrics, moe_dropped=plan.num_dropped / jnp.maximum(t, 1))
    return y.reshape(b, s, d), aux, metrics
