"""MMU-aware virtual page addressing (DESIGN.md §11).

Mirrors Kurth et al. (arXiv 1808.09751): a DMA engine that walks page
tables and prefetches IOTLB entries along descriptor chains makes
virtual addressing essentially free for irregular transfer shapes. The
subsystem has two halves:

* :class:`PageTable` — virtual page id -> (shard, physical slot) with
  per-page generation counters, the substrate for remap-based
  defragmentation and ownership-first migration;
* :class:`IOTLB` / :class:`IOTLBParams` — the cycle-simulator model of
  the engine-side translation cache: walk latency, miss stalls, and
  prefetch-along-chain lookahead whose depth comes from the
  :mod:`repro.core.speculation` policy layer.
"""
from .page_table import PageTable, TLB_SHOOTDOWN_CYCLES, remap_cycles
from .iotlb import IOTLB, IOTLBParams, DEFAULT_WALK_CYCLES

__all__ = [
    "PageTable",
    "IOTLB",
    "IOTLBParams",
    "DEFAULT_WALK_CYCLES",
    "TLB_SHOOTDOWN_CYCLES",
    "remap_cycles",
]
