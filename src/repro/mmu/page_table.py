"""Virtual page table: vpage -> (shard, physical slot) + generations.

The table is the single source of truth for where a virtual page's
contents live. Two invariants every mutator preserves (the hypothesis
suite in ``tests/test_mmu.py`` checks them):

* a remap never changes *which* contents a live vpage names — only the
  physical slot they occupy;
* every remap bumps both the per-page generation and the global
  generation, monotonically. A cached translation keyed on the global
  generation is therefore invalidated by *any* remap, and one keyed on a
  page generation by remaps of *that* page.

Cost model: a remap is a table write plus an IOTLB shootdown for the
stale entry — :func:`remap_cycles` is what the remap-vs-copy defrag
cell charges per page, against a full descriptor-chain copy.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

#: Cycles to invalidate one stale IOTLB entry after a remap (the engine
#: re-walks on next touch; the walk itself is charged by the IOTLB
#: model). Small by construction — the whole point of remap-defrag.
TLB_SHOOTDOWN_CYCLES = 2


def remap_cycles(n_pages: int, walk_cycles: int) -> int:
    """Modeled cost of remapping ``n_pages``: one table write + shootdown
    per page, plus one refill walk on the first post-remap touch."""
    if n_pages <= 0:
        return 0
    return n_pages * (1 + TLB_SHOOTDOWN_CYCLES) + walk_cycles


class PageTable:
    """Dense vpage -> (shard, slot) map with generation counters.

    Identity-initialized: vpage ``v`` starts mapped to slot ``v`` on the
    shard that physically owns slot ``v`` (``slot // pages_per_shard``
    for the sharded pool, shard 0 for single-node pools). ``slot == -1``
    marks a *pending* page: ownership has been flipped but contents not
    yet pulled (the lazy-migration state; ``home_of`` remembers where
    the bits still live).
    """

    def __init__(self, num_pages: int, num_shards: int = 1):
        if num_pages < 1 or num_shards < 1:
            raise ValueError("need >= 1 page and >= 1 shard")
        if num_pages % num_shards:
            raise ValueError("num_pages must divide evenly across shards")
        self.num_pages = int(num_pages)
        self.num_shards = int(num_shards)
        self.pages_per_shard = self.num_pages // self.num_shards
        self._slot = np.arange(self.num_pages, dtype=np.int64)
        self._shard = self._slot // self.pages_per_shard
        self._gen = np.zeros(self.num_pages, np.int64)
        # Pending (ownership-flipped, not yet pulled) pages: vpage ->
        # (home_shard, home_slot) where the contents still live.
        self._home: Dict[int, Tuple[int, int]] = {}
        self.generation = 0          # global: bumped by every mutation
        self.remaps = 0              # lifetime remap count (cost model)

    # -- lookups -------------------------------------------------------------
    def _check(self, vpage: int) -> int:
        v = int(vpage)
        if not 0 <= v < self.num_pages:
            raise IndexError(f"vpage {v} out of range [0, {self.num_pages})")
        return v

    def map(self, vpage: int) -> Tuple[int, int]:
        """(shard, physical slot); slot is -1 for a pending page."""
        v = self._check(vpage)
        return int(self._shard[v]), int(self._slot[v])

    def shard_of(self, vpage: int) -> int:
        return int(self._shard[self._check(vpage)])

    def slot_of(self, vpage: int) -> int:
        return int(self._slot[self._check(vpage)])

    def page_generation(self, vpage: int) -> int:
        return int(self._gen[self._check(vpage)])

    def is_pending(self, vpage: int) -> bool:
        return int(self._slot[self._check(vpage)]) < 0

    def home_of(self, vpage: int) -> Tuple[int, int]:
        """Where a pending page's contents still live (the pull source)."""
        v = self._check(vpage)
        if not self.is_pending(v):
            return self.map(v)
        return self._home[v]

    def slots_of(self, vpages: Sequence[int]) -> np.ndarray:
        """Vectorized translation (kernel-facing block tables). Entries
        < 0 pass through (the block tables' empty-slot sentinel)."""
        vp = np.asarray(vpages, np.int64)
        out = np.where(vp >= 0, self._slot[np.clip(vp, 0, None)], vp)
        return out.astype(np.int64)

    # -- mutations -----------------------------------------------------------
    def _bump(self, vpage: int) -> None:
        self._gen[vpage] += 1
        self.generation += 1

    def remap(self, vpage: int, shard: int, slot: int) -> None:
        """Point ``vpage`` at a (shard, slot); bumps generations."""
        v = self._check(vpage)
        if not 0 <= int(shard) < self.num_shards:
            raise IndexError(f"shard {shard} out of range")
        if int(slot) >= self.num_pages:
            raise IndexError(f"slot {slot} out of range")
        self._shard[v] = int(shard)
        self._slot[v] = int(slot)
        self._home.pop(v, None)
        self._bump(v)
        self.remaps += 1

    def remap_many(self, mapping: Dict[int, Tuple[int, int]]) -> None:
        """Atomic batch remap (sorted order, so replays are deterministic)."""
        for v in sorted(mapping):
            shard, slot = mapping[v]
            self.remap(v, shard, slot)

    def rehome_slots(self, slot_map: Dict[int, Tuple[int, int]]) -> None:
        """Physical relocation (evacuation/resize): every vpage whose
        slot appears in ``slot_map`` is remapped to its new (shard,
        slot) — so refs survive the move — and pending pages whose
        *pull home* moved follow too. Ascending-vpage order keeps
        replays deterministic."""
        if not slot_map:
            return
        keys = np.asarray(sorted(slot_map), np.int64)
        for v in np.flatnonzero(np.isin(self._slot, keys)):
            shard, slot = slot_map[int(self._slot[v])]
            self.remap(int(v), shard, slot)
        for v, (hs, hslot) in list(self._home.items()):
            if hslot in slot_map:
                self._home[v] = slot_map[hslot]

    def flip_owner(self, vpage: int, shard: int) -> None:
        """Ownership-first migration step 1: move the page's *owner* now,
        leave its contents where they are (pending state). The pull
        source is remembered so a first touch can fetch lazily."""
        v = self._check(vpage)
        if not 0 <= int(shard) < self.num_shards:
            raise IndexError(f"shard {shard} out of range")
        if not self.is_pending(v):
            self._home[v] = (int(self._shard[v]), int(self._slot[v]))
        self._shard[v] = int(shard)
        self._slot[v] = -1
        self._bump(v)

    def complete_pull(self, vpage: int, slot: int) -> Tuple[int, int]:
        """Ownership-first step 2 (first touch): contents have landed in
        ``slot`` on the owning shard. Returns the vacated home (shard,
        slot) for the caller to free."""
        v = self._check(vpage)
        if not self.is_pending(v):
            raise RuntimeError(f"vpage {v} is not pending a pull")
        home = self._home.pop(v)
        self._slot[v] = int(slot)
        self._bump(v)
        self.remaps += 1
        return home

    # -- oracle --------------------------------------------------------------
    def snapshot(self) -> Dict[str, np.ndarray]:
        """Copies of the raw arrays (the tests' numpy oracle)."""
        return {"shard": self._shard.copy(), "slot": self._slot.copy(),
                "gen": self._gen.copy()}

    def pending_pages(self) -> List[int]:
        return sorted(self._home)
