"""IOTLB model: the DMA engine's translation cache (cycle simulator side).

Kurth et al. (arXiv 1808.09751) put an IOTLB in front of the DMA engine
and prefetch translations *along the descriptor chain* — the same
sequential-lookahead idea as the §II-C descriptor speculator, applied to
page walks. The model here mirrors that coupling: translation prefetches
ride the speculative descriptor fetch stream, and the lookahead depth is
a :mod:`repro.core.speculation` policy (``FixedDepth`` /
``AdaptiveDepth``), so the TLB prefetcher and the descriptor speculator
share one policy vocabulary.

Timing model:

* a **walk** costs ``walk_cycles`` (default: one memory round trip,
  ``2L + PIPE``) on a dedicated walker port — walks overlap payload
  traffic, only *waiting* for one stalls the launch;
* an **access** to a cached, ready entry is free; to an in-flight
  prefetched entry it stalls until the walk lands (counted a hit — the
  prefetch already hid most of the walk); to an absent entry it stalls
  the full walk (a miss);
* capacity is LRU over ``entries`` translations.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

from repro.core.speculation import DEFAULT_DEPTH, FixedDepth, PolicyLike

#: Fallback walk latency when the memory round trip is unknown (the
#: simulator derives ``2L + PIPE`` from its memory config instead).
DEFAULT_WALK_CYCLES = 20

#: Hardware-typical first-level IOTLB capacity (entries).
DEFAULT_ENTRIES = 32


@dataclasses.dataclass(frozen=True)
class IOTLBParams:
    """Engine-side IOTLB configuration (frozen: embeddable in SimConfig).

    ``walk_cycles = 0`` means "derive from the memory system": one
    request round trip, ``2 * mem_latency + PIPE``. ``prefetch`` is the
    chain-lookahead policy — ``FixedDepth(0)`` disables translation
    prefetching (every new page is a demand walk), the A/B leg the
    ``--no-iotlb``-adjacent cells measure against.
    """

    entries: int = DEFAULT_ENTRIES
    walk_cycles: int = 0
    prefetch: PolicyLike = FixedDepth(DEFAULT_DEPTH)

    def __post_init__(self):
        if self.entries < 1:
            raise ValueError("IOTLB needs >= 1 entry")
        if self.walk_cycles < 0:
            raise ValueError("walk_cycles must be >= 0")

    def resolved_walk_cycles(self, mem_latency: int) -> int:
        from repro.core.simulator import PIPE
        return self.walk_cycles or (2 * int(mem_latency) + PIPE)


class IOTLB:
    """LRU translation cache with in-flight prefetch tracking."""

    def __init__(self, params: IOTLBParams, *, mem_latency: int = 13):
        self.params = params
        self.walk_cycles = params.resolved_walk_cycles(mem_latency)
        # vpage -> cycle the translation becomes usable (walk completion).
        self._entries: "OrderedDict[int, float]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.prefetches = 0
        self.walk_stall_cycles = 0.0

    def _insert(self, vpage: int, ready: float) -> None:
        self._entries[vpage] = ready
        self._entries.move_to_end(vpage)
        while len(self._entries) > self.params.entries:
            self._entries.popitem(last=False)

    def prefetch(self, vpage: int, now: float) -> None:
        """Start a walk for ``vpage`` if untranslated (walker port: free
        of bus contention; only *waiting* on it costs cycles)."""
        v = int(vpage)
        if v in self._entries:
            return
        self.prefetches += 1
        self._insert(v, now + self.walk_cycles)

    def access(self, vpage: int, now: float) -> float:
        """Translate at cycle ``now``; returns the stall in cycles."""
        v = int(vpage)
        ready = self._entries.get(v)
        if ready is not None:
            self._entries.move_to_end(v)
            self.hits += 1
            stall = max(0.0, ready - now)       # in-flight prefetch
        else:
            self.misses += 1
            stall = float(self.walk_cycles)     # demand walk
            self._insert(v, now + stall)
        self.walk_stall_cycles += stall
        return stall

    def invalidate(self, vpage: int) -> None:
        """Shootdown after a remap (cost modeled by
        :func:`repro.mmu.page_table.remap_cycles`)."""
        self._entries.pop(int(vpage), None)

    @property
    def accesses(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        n = self.accesses
        return self.hits / n if n else 0.0

    def stats(self) -> dict:
        return {
            "entries": self.params.entries,
            "walk_cycles": self.walk_cycles,
            "hits": self.hits,
            "misses": self.misses,
            "prefetches": self.prefetches,
            "hit_rate": self.hit_rate,
            "walk_stall_cycles": float(self.walk_stall_cycles),
        }
