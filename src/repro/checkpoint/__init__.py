"""Checkpoint substrate: atomic, manifest-driven, elastic-restore capable."""
from .checkpointer import Checkpointer  # noqa: F401
