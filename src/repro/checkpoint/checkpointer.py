"""Fault-tolerant checkpointing: atomic commits, manifests, elastic restore.

Layout per step::

    <dir>/step_000123/
        shard_<host>.npz      flat {path -> array} for host-local data
        manifest.json         descriptor-style records per array:
                              (name, shape, dtype, shard, offset=0, length)
        COMMIT                completion flag written last (the paper's
                              all-ones writeback, §II-D, as a filesystem rite)

Restores ignore step dirs without COMMIT (torn writes from preempted hosts).
`restore` reshards to whatever mesh/sharding the caller passes — elastic
scaling = restoring yesterday's 2-pod checkpoint onto today's 1-pod mesh.
Saves run on a background thread (training continues) but are serialized.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, host_id: int = 0):
        self.dir = directory
        self.keep = keep
        self.host_id = host_id
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self._pending: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool = False,
             extra: Optional[Dict] = None) -> None:
        # Materialize on host *now* (cheap vs training step), write async.
        flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        t = threading.Thread(target=self._write, args=(step, flat, extra),
                             daemon=True)
        self.wait()
        self._pending = t
        t.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, flat: Dict[str, np.ndarray],
               extra: Optional[Dict]):
        with self._lock:
            final = os.path.join(self.dir, f"step_{step:09d}")
            tmp = final + f".tmp{self.host_id}"
            os.makedirs(tmp, exist_ok=True)
            shard_file = os.path.join(tmp, f"shard_{self.host_id}.npz")
            np.savez(shard_file, **flat)
            manifest = {
                "step": step,
                "time": time.time(),
                "extra": extra or {},
                "arrays": [
                    {"name": k, "shape": list(v.shape), "dtype": str(v.dtype),
                     "shard": self.host_id, "offset": 0,
                     "length": int(v.size)}
                    for k, v in flat.items()
                ],
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            os.replace(tmp, final) if not os.path.exists(final) else None
            # Completion writeback: the COMMIT flag is written last.
            with open(os.path.join(final, "COMMIT"), "w") as f:
                f.write("1")
            self._gc()

    def _gc(self):
        steps = self.committed_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # -- discovery / restore -------------------------------------------------
    def committed_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            if not name.startswith("step_"):
                continue
            if os.path.exists(os.path.join(self.dir, name, "COMMIT")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, *,
                shardings: Any = None) -> Tuple[Any, Dict]:
        """Restore into the structure of `like`; optionally (re)shard each
        array with the given shardings tree (elastic re-mesh)."""
        d = os.path.join(self.dir, f"step_{step:09d}")
        if not os.path.exists(os.path.join(d, "COMMIT")):
            raise FileNotFoundError(f"no committed checkpoint at step {step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = {}
        for name in os.listdir(d):
            if name.startswith("shard_") and name.endswith(".npz"):
                with np.load(os.path.join(d, name)) as z:
                    data.update({k: z[k] for k in z.files})

        flat_like = _flatten(like)
        missing = set(flat_like) - set(data)
        if missing:
            raise KeyError(f"checkpoint missing arrays: {sorted(missing)[:5]}")
        flat_sh = _flatten(shardings) if shardings is not None else {}

        def rebuild(path_key, leaf):
            arr = data[path_key]
            want_dtype = getattr(leaf, "dtype", arr.dtype)
            if arr.dtype.kind == "V":
                # bf16 & friends round-trip through npz as raw void bytes.
                arr = arr.view(want_dtype)
            else:
                arr = arr.astype(want_dtype)
            sh = flat_sh.get(path_key)
            if sh is not None:
                return jax.device_put(arr, sh)
            return jax.numpy.asarray(arr)

        leaves_with_path = jax.tree_util.tree_flatten_with_path(like)
        paths = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                          for p in path)
                 for path, _ in leaves_with_path[0]]
        new_leaves = [rebuild(k, leaf)
                      for k, (_, leaf) in zip(paths, leaves_with_path[0])]
        tree = jax.tree_util.tree_unflatten(leaves_with_path[1], new_leaves)
        return tree, manifest.get("extra", {})
