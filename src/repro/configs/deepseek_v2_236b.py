"""DeepSeek-V2-236B [moe]: 60L d5120 128H, MLA kv_lora 512, vocab 102400.

MoE: 160 routed experts top-6 (expert d_ff 1536) + 2 shared experts; first
layer dense (d_ff 12288). MLA: q_lora 1536, kv_lora 512, nope 128, rope 64,
v 128. [arXiv:2405.04434; hf]
"""
import dataclasses

from .base import MLAConfig, ModelConfig, MoEConfig
from .registry import register


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
        head_dim=192,  # qk_nope(128) + qk_rope(64)
        d_ff=12288,    # dense (first-layer) FFN width
        vocab_size=102400,
        rope_theta=10000.0,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(num_experts=160, experts_per_token=6, expert_d_ff=1536,
                      num_shared_experts=2, shared_d_ff=3072,
                      capacity_factor=1.25, router_norm_topk=True),
        block_pattern=(("attn", "moe"),),
        first_k_dense=1,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), name="deepseek-v2-236b-reduced",
        num_layers=3, d_model=64, num_heads=4, head_dim=24,
        d_ff=128, vocab_size=512, vocab_pad_multiple=8, num_kv_heads=4,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16),
        moe=MoEConfig(num_experts=8, experts_per_token=2, expert_d_ff=32,
                      num_shared_experts=1, shared_d_ff=64,
                      capacity_factor=1.5),
        first_k_dense=1,
    )


register("deepseek-v2-236b", config, reduced)
