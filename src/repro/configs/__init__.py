"""Configs: 10 assigned architectures + shapes (see DESIGN.md §6)."""
from .base import (  # noqa: F401
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    shape_applicable,
)
from .registry import get_config, list_archs  # noqa: F401
