"""Phi-3-vision-4.2B [vlm]: 32L d3072 32H (MHA kv=32) d_ff 8192 vocab 32064.

phi3-mini backbone + CLIP vision frontend — the frontend is a STUB per the
assignment: ``input_specs()`` provides precomputed patch embeddings as a
576-token prefix. [hf:microsoft/Phi-3-vision-128k-instruct; hf]
"""
import dataclasses

from .base import ModelConfig
from .registry import register


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", family="vlm",
        num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
        head_dim=96, d_ff=8192, vocab_size=32064,
        rope_theta=10000.0, norm_eps=1e-5,
        prefix_len=576,           # CLIP ViT-L/14 @336px -> 24x24 patches
        block_pattern=(("attn", "dense"),),
        vocab_pad_multiple=64,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), name="phi-3-vision-4.2b-reduced",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=512, vocab_pad_multiple=8,
        prefix_len=8,
    )


register("phi-3-vision-4.2b", config, reduced)
