"""Jamba-v0.1-52B [hybrid]: 32L d4096 32H (GQA kv=8) d_ff 14336 vocab 65536.

Mamba:attention 7:1 interleave (attn at period offset 4), MoE 16 experts
top-2 on every other layer (odd offsets). One period = 8 layers; 4 periods.
Jamba's mixer is Mamba-1; we realize it in SSD (Mamba-2 dual) form with the
published d_state 16 — see DESIGN.md "assumptions". [arXiv:2403.19887; hf]
"""
import dataclasses

from .base import ModelConfig, MoEConfig, SSMConfig
from .registry import register


def _pattern():
    blocks = []
    for idx in range(8):
        mixer = "attn" if idx == 4 else "mamba"
        ffn = "moe" if idx % 2 == 1 else "dense"
        blocks.append((mixer, ffn))
    return tuple(blocks)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=65536,
        rope_theta=10000.0,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk=256),
        moe=MoEConfig(num_experts=16, experts_per_token=2, expert_d_ff=14336,
                      capacity_factor=1.25, router_norm_topk=True),
        block_pattern=_pattern(),
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), name="jamba-v0.1-52b-reduced",
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, vocab_pad_multiple=8,
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=16,
                      n_groups=1, chunk=32),
        moe=MoEConfig(num_experts=4, experts_per_token=2, expert_d_ff=64,
                      capacity_factor=1.5),
    )


register("jamba-v0.1-52b", config, reduced)
