"""Mamba2-780M [ssm]: 48L d1536, attention-free, vocab 50280, ssm_state 128.

SSD (state-space duality), no FFN blocks, tied embeddings.
[arXiv:2405.21060; unverified]
"""
import dataclasses

from .base import ModelConfig, SSMConfig
from .registry import register


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
        head_dim=1,  # unused (attention-free)
        d_ff=0, vocab_size=50280,
        tie_embeddings=True, norm_eps=1e-5,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk=256),
        block_pattern=(("mamba", "none"),),
        vocab_pad_multiple=16,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), name="mamba2-780m-reduced",
        num_layers=2, d_model=64, vocab_size=512, vocab_pad_multiple=8,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      n_groups=1, chunk=32),
    )


register("mamba2-780m", config, reduced)
