"""Gemma3-12B [dense]: 48L d3840 16H (GQA kv=8) d_ff 15360 vocab 262144.

5:1 local(window 1024):global interleave, qk-norm, head_dim 256, 128k ctx.
[hf:google/gemma-3 family; unverified]
"""
import dataclasses

from .base import ModelConfig
from .registry import register

# One period = 5 sliding-window locals + 1 global; 8 periods = 48 layers.
_PATTERN = (("local", "dense"),) * 5 + (("attn", "dense"),)


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b", family="dense",
        num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8,
        head_dim=256, d_ff=15360, vocab_size=262144,
        qk_norm=True, rope_theta=1_000_000.0, sliding_window=1024,
        tie_embeddings=True, act_fn="gelu",
        block_pattern=_PATTERN,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), name="gemma3-12b-reduced",
        num_layers=6, d_model=96, num_heads=4, num_kv_heads=2,
        head_dim=24, d_ff=192, vocab_size=512, vocab_pad_multiple=8,
        sliding_window=16,
    )


register("gemma3-12b", config, reduced)
