"""DBRX-132B [moe]: 40L d6144 48H (GQA kv=8), 16 experts top-4, vocab 100352.

Fine-grained MoE (expert d_ff 10752), head_dim 128, RoPE theta 5e5.
[hf:databricks/dbrx-base; unverified]
"""
import dataclasses

from .base import ModelConfig, MoEConfig
from .registry import register


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
        head_dim=128, d_ff=10752, vocab_size=100352,
        rope_theta=500_000.0,
        moe=MoEConfig(num_experts=16, experts_per_token=4, expert_d_ff=10752,
                      capacity_factor=1.25, router_norm_topk=True),
        block_pattern=(("attn", "moe"),),
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), name="dbrx-132b-reduced",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, vocab_pad_multiple=8,
        moe=MoEConfig(num_experts=4, experts_per_token=2, expert_d_ff=64,
                      capacity_factor=1.5),
    )


register("dbrx-132b", config, reduced)
