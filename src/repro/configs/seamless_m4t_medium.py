"""SeamlessM4T-medium [audio]: enc-dec 12L+12L d1024 16H (MHA) d_ff 4096,
vocab 256206. The audio frontend is a STUB per the assignment: the encoder
consumes precomputed frame embeddings from ``input_specs()``.
[arXiv:2308.11596; hf]
"""
import dataclasses

from .base import ModelConfig
from .registry import register


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="audio",
        num_layers=12, encoder_layers=12,
        d_model=1024, num_heads=16, num_kv_heads=16,
        head_dim=64, d_ff=4096, vocab_size=256206,
        rope_theta=10000.0, act_fn="gelu", norm_eps=1e-5,
        block_pattern=(("attn", "dense"),),
        vocab_pad_multiple=2,   # 256206 -> 256206 (even); keep exact-ish
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), name="seamless-m4t-medium-reduced",
        num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        vocab_pad_multiple=8,
    )


register("seamless-m4t-medium", config, reduced)
