"""Model/shape configuration system.

Every assigned architecture is a :class:`ModelConfig`; the per-arch modules
in this package hold the exact published hyperparameters plus a ``reduced()``
variant for CPU smoke tests. Layer stacks are described as a *block pattern*
(one period of heterogeneous blocks, repeated), which keeps the lowered HLO
small via ``lax.scan`` over periods.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp

# Block descriptors: (mixer, ffn)
#   mixer: "attn" | "local" (sliding window) | "mamba"
#   ffn:   "dense" | "moe"
Block = Tuple[str, str]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    expert_d_ff: int
    num_shared_experts: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    router_norm_topk: bool = True     # renormalize top-k probs (DeepSeek-style)
    aux_loss_weight: float = 0.01
    router_z_weight: float = 1e-3


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // num_heads
    # Layer pattern: one period, repeated num_layers/len(pattern) times.
    # first_k_dense_replace: the first k layers use dense FFN even if the
    # pattern says MoE (DeepSeek layer 0).
    block_pattern: Tuple[Block, ...] = (("attn", "dense"),)
    first_k_dense: int = 0
    # Attention flavour
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0              # fraction of head_dim rotated
    sliding_window: Optional[int] = None    # for "local" blocks
    attn_logit_softcap: Optional[float] = None
    mla: Optional[MLAConfig] = None
    # Mixture of experts
    moe: Optional[MoEConfig] = None
    # State space
    ssm: Optional[SSMConfig] = None
    # Encoder-decoder
    encoder_layers: int = 0                 # >0 -> enc-dec model
    # Multimodal prefix stub (precomputed patch/frame embeddings)
    prefix_len: int = 0
    # Numerics / misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    mlp_gated: bool = True                  # SwiGLU/GeGLU vs plain 2-matmul MLP
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    act_fn: str = "silu"                    # silu | gelu
    remat_policy: str = "minimal"           # none | minimal | full
    # blockwise: flash-style jnp schedule (production); proj_only: skip the
    # attention core (dry-run loop-accounting — see EXPERIMENTS.md §Roofline)
    attention_impl: str = "blockwise"
    # lax.scan over periods (small HLO, production) vs python unroll (flat
    # HLO for exact cost_analysis in the dry-run measurement lowers).
    scan_periods: bool = True
    vocab_pad_multiple: int = 128

    # -- derived -----------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return int(math.ceil(self.vocab_size / m) * m)

    @property
    def num_periods(self) -> int:
        assert self.num_layers % len(self.block_pattern) == 0, (
            f"{self.name}: {self.num_layers} layers not divisible by pattern "
            f"of {len(self.block_pattern)}")
        return self.num_layers // len(self.block_pattern)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def has_mixer(self, kind: str) -> bool:
        return any(m == kind for m, _ in self.block_pattern)

    @property
    def attention_free(self) -> bool:
        return not (self.has_mixer("attn") or self.has_mixer("local"))

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM / hybrid / mostly-sliding-window."""
        n_full = sum(1 for m, _ in self.block_pattern if m == "attn")
        return self.family in ("ssm", "hybrid") or (
            self.sliding_window is not None
            and n_full <= len(self.block_pattern) // 2)

    # -- parameter counting (for roofline MODEL_FLOPS) ----------------------
    def param_counts(self) -> dict:
        """Approximate parameter counts: total and active-per-token."""
        d, hd = self.d_model, self.head_dim_
        counts = {"embed": self.padded_vocab * d *
                  (1 if self.tie_embeddings else 2)}
        per_layer_total = per_layer_active = 0.0
        for mixer, ffn in self.block_pattern:
            if mixer in ("attn", "local"):
                if self.mla is not None:
                    m = self.mla
                    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                    p = (d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk
                         + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                         + m.kv_lora_rank * self.num_heads *
                         (m.qk_nope_head_dim + m.v_head_dim)
                         + self.num_heads * m.v_head_dim * d)
                else:
                    p = (d * self.num_heads * hd
                         + 2 * d * self.num_kv_heads * hd
                         + self.num_heads * hd * d)
            else:  # mamba
                s = self.ssm
                d_in = s.expand * d
                n_h = d_in // s.head_dim
                p = (d * (2 * d_in + 2 * s.n_groups * s.d_state + n_h)
                     + d_in * d + s.d_conv * (d_in + 2 * s.n_groups * s.d_state))
            mix_p = p
            if ffn == "moe":
                m = self.moe
                expert_p = 3 * d * m.expert_d_ff
                ffn_total = m.num_experts * expert_p + d * m.num_experts
                ffn_active = m.experts_per_token * expert_p
                if m.num_shared_experts:
                    sh = 3 * d * (m.shared_d_ff or m.expert_d_ff) * m.num_shared_experts
                    ffn_total += sh
                    ffn_active += sh
            elif ffn == "none":
                ffn_total = ffn_active = 0
            else:
                ffn_total = ffn_active = (3 if self.mlp_gated else 2) * d * self.d_ff
            per_layer_total += mix_p + ffn_total
            per_layer_active += mix_p + ffn_active
        n_periods = self.num_periods
        counts["layers_total"] = per_layer_total * n_periods
        counts["layers_active"] = per_layer_active * n_periods
        if self.is_encdec:  # encoder stack mirrors decoder block cost, dense
            enc = (4 * d * self.num_heads * hd + 3 * d * self.d_ff) * self.encoder_layers
            counts["layers_total"] += enc
            counts["layers_active"] += enc
        total = counts["embed"] + counts["layers_total"]
        active = counts["embed"] + counts["layers_active"]
        return {"total": total, "active": active, **counts}


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode
    page_size: int = 256


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Which (arch x shape) cells run; skips are recorded in EXPERIMENTS.md."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("long_500k requires sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention (DESIGN.md §6)")
    return True, ""
