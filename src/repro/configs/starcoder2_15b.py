"""StarCoder2-15B [dense]: 40L d6144 48H (GQA kv=4) d_ff 24576 vocab 49152.

GQA + RoPE (theta 1e5), attention/MLP bias, non-gated GELU MLP (2-matmul,
matching the published d_ff and ~15B param count). [arXiv:2402.19173; hf]
"""
import dataclasses

from .base import ModelConfig
from .registry import register


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b", family="dense",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4,
        head_dim=128, d_ff=24576, vocab_size=49152,
        qkv_bias=True, rope_theta=100_000.0, act_fn="gelu",
        mlp_gated=False, norm_eps=1e-5,
        block_pattern=(("attn", "dense"),),
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), name="starcoder2-15b-reduced",
        num_layers=2, d_model=96, num_heads=6, num_kv_heads=2,
        head_dim=16, d_ff=192, vocab_size=512, vocab_pad_multiple=8,
    )


register("starcoder2-15b", config, reduced)
