"""Qwen3-14B [dense]: 40L d5120 40H (GQA kv=8) d_ff 17408 vocab 151936.

qk-norm + GQA, head_dim 128, RoPE theta 1e6. [hf:Qwen/Qwen3-8B family; hf]
"""
import dataclasses

from .base import ModelConfig
from .registry import register


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b", family="dense",
        num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8,
        head_dim=128, d_ff=17408, vocab_size=151936,
        qk_norm=True, rope_theta=1_000_000.0,
        block_pattern=(("attn", "dense"),),
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), name="qwen3-14b-reduced",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512, vocab_pad_multiple=8,
    )


register("qwen3-14b", config, reduced)
