"""Architecture registry: the 10 assigned archs as selectable configs."""
from __future__ import annotations

from typing import Callable, Dict

from .base import ModelConfig

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
_REDUCED: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str, full: Callable[[], ModelConfig],
             reduced: Callable[[], ModelConfig]) -> None:
    _REGISTRY[name] = full
    _REDUCED[name] = reduced


def get_config(name: str, *, reduced: bool = False) -> ModelConfig:
    _ensure_loaded()
    table = _REDUCED if reduced else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]()


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    from . import (  # noqa: F401
        dbrx_132b,
        deepseek_v2_236b,
        gemma3_12b,
        jamba_v0_1_52b,
        mamba2_780m,
        phi3_vision_4_2b,
        qwen2_5_3b,
        qwen3_14b,
        seamless_m4t_medium,
        starcoder2_15b,
    )
