"""Qwen2.5-3B [dense]: 36L d2048 16H (GQA kv=2) d_ff 11008 vocab 151936.

GQA with QKV bias, head_dim 128, tied embeddings. [hf:Qwen/Qwen2.5 family; hf]
"""
import dataclasses

from .base import ModelConfig
from .registry import register


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b", family="dense",
        num_layers=36, d_model=2048, num_heads=16, num_kv_heads=2,
        head_dim=128, d_ff=11008, vocab_size=151936,
        qkv_bias=True, rope_theta=1_000_000.0, tie_embeddings=True,
        block_pattern=(("attn", "dense"),),
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(), name="qwen2.5-3b-reduced",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, vocab_pad_multiple=8,
    )


register("qwen2.5-3b", config, reduced)
