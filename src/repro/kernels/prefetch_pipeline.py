"""The speculative descriptor prefetch engine as a manual Pallas pipeline.

This is the paper's §II-C mechanism transliterated to TPU DMA primitives:
while descriptor i's payload streams HBM->VMEM, the copy for descriptor i+1
is already in flight ("the proper request is issued over the AXI manager
interface in the same cycle"), using two VMEM bounce buffers and DMA
semaphores — the classic double-buffered pipeline. `descriptor_copy.py` gets
the same effect implicitly from the Pallas grid pipeliner; this kernel makes
the mechanism explicit and controllable (bounce-buffer depth = the paper's
`prefetch` parameter, clamped to 2..N here).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pipeline_kernel(src_idx_ref, dst_idx_ref, src_hbm, dst_in, dst_hbm,
                     scratch, in_sems, out_sems, *, depth: int):
    del dst_in
    n = src_idx_ref.shape[0]

    def start_in(i):
        slot = jax.lax.rem(i, depth)
        pltpu.make_async_copy(
            src_hbm.at[src_idx_ref[i]], scratch.at[slot], in_sems.at[slot]
        ).start()

    # Warmup: issue the first `depth` speculative fetches back to back.
    for j in range(depth):
        @pl.when(j < n)
        def _(j=j):
            start_in(jnp.int32(j))

    def body(i, carry):
        slot = jax.lax.rem(i, depth)
        # Wait for descriptor i's payload...
        pltpu.make_async_copy(
            src_hbm.at[src_idx_ref[i]], scratch.at[slot], in_sems.at[slot]
        ).wait()
        # ...drain it to its destination...
        out_copy = pltpu.make_async_copy(
            scratch.at[slot], dst_hbm.at[dst_idx_ref[i]], out_sems.at[slot])
        out_copy.start()
        out_copy.wait()
        # ...and immediately refill the slot with descriptor i+depth
        # (the speculative next request).
        @pl.when(i + depth < n)
        def _():
            start_in(i + depth)
        return carry

    jax.lax.fori_loop(0, n, body, 0)


@functools.partial(jax.jit, static_argnames=("depth", "interpret"))
def prefetched_chain_copy(src_idx: jax.Array, dst_idx: jax.Array,
                          src: jax.Array, dst: jax.Array, *,
                          depth: int = 2, interpret: bool = False):
    """Row-pool copy with an explicit `depth`-deep descriptor prefetch
    pipeline. Semantics match `descriptor_copy` for non-negative indices."""
    n = src_idx.shape[0]
    rows, unit = src.shape
    depth = max(2, min(depth, max(n, 2)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        scratch_shapes=[
            pltpu.VMEM((depth, unit), src.dtype),
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SemaphoreType.DMA((depth,)),
        ],
    )
    kernel = functools.partial(_pipeline_kernel, depth=depth)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst.shape, dst.dtype),
        input_output_aliases={3: 0},
        interpret=interpret,
    )(jnp.maximum(src_idx.astype(jnp.int32), 0),
      jnp.maximum(dst_idx.astype(jnp.int32), 0), src, dst)
