"""Paged decode attention — descriptor-chain block tables, scalar-prefetched.

Each sequence's KV cache is a chain of fixed-size pages (one page = one
descriptor, §II-B); the flattened chain (block table) and sequence lengths
are scalar-prefetch operands, so page addresses are resolved in SMEM ahead
of the grid step that streams the page HBM->VMEM — descriptor prefetching as
a first-class Pallas construct (DESIGN.md §2/§3).

Grid (batch, max_pages): running-softmax state persists in VMEM scratch
across the page axis; pages past ceil(len/page) are skipped via pl.when
(fetch suppressed by clamping the index map to the last valid page).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, page: int, kvh: int, g: int,
                  d: int, max_pages: int, scale: float):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]
    num_pages = (length + page - 1) // page
    active = (p < num_pages) & (tables_ref[b, p] >= 0)

    @pl.when(active)
    def _compute():
        q = q_ref[0].astype(jnp.float32).reshape(kvh, g, d)
        k = k_ref[0].astype(jnp.float32)          # (page, KV, D)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.einsum("kgd,skd->kgs", q, k,
                       preferred_element_type=jnp.float32) * scale
        pos = p * page + jax.lax.broadcasted_iota(
            jnp.int32, (kvh, g, page), 2)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=2))
        pr = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + pr.sum(axis=2)
        acc_ref[...] = (acc_ref[...] * corr[..., None]
                        + jnp.einsum("kgs,skd->kgd", pr, v,
                                     preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(p == max_pages - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = out.reshape(kvh * g, d).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    interpret: bool = False):
    """q: (B, H, D); {k,v}_pages: (P, page, KV, D);
    block_tables: (B, max_pages) int32 (-1 pads); lengths: (B,)."""
    b, h, d = q.shape
    _, page, kvh, _ = k_pages.shape
    g = h // kvh
    max_pages = block_tables.shape[1]

    def page_map(bb, p, tables, lengths_):
        return (jnp.maximum(tables[bb, p], 0), 0, 0, 0)

    kernel = functools.partial(
        _paged_kernel, page=page, kvh=kvh, g=g, d=d, max_pages=max_pages,
        scale=d ** -0.5)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, max_pages),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda bb, p, t, L: (bb, 0, 0)),
            pl.BlockSpec((1, page, kvh, d), page_map),
            pl.BlockSpec((1, page, kvh, d), page_map),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda bb, p, t, L: (bb, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kvh, g), jnp.float32),
            pltpu.VMEM((kvh, g), jnp.float32),
            pltpu.VMEM((kvh, g, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pages, v_pages)
