"""Descriptor-driven row copy — the paper's DMAC as a Pallas TPU kernel.

The descriptor stream (src row, dst row) is passed as *scalar-prefetch*
operands (``pltpu.PrefetchScalarGridSpec``): Pallas materializes them in SMEM
*before* the grid runs and feeds them to the ``BlockSpec.index_map``s, so the
address of step i+1's block is known while step i's payload streams — exactly
the paper's speculative descriptor prefetching, realized with the TPU's
native double-buffered grid pipeline (§II-C; DESIGN.md §2).

Rows are the transfer unit (the fixed "burst"): irregularity lives entirely
in the descriptor index pattern, as in the paged-KV / MoE consumers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(src_idx_ref, dst_idx_ref, src_ref, dst_in_ref, dst_ref):
    """Body: move one row-block. Inactive descriptors (-1) write nothing.

    dst_in_ref is the aliased destination pool (untouched rows keep their
    contents through the input/output alias); it is not read here.
    """
    del dst_in_ref
    i = pl.program_id(0)
    active = (src_idx_ref[i] >= 0) & (dst_idx_ref[i] >= 0)

    @pl.when(active)
    def _():
        dst_ref[...] = src_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def descriptor_copy(src_idx: jax.Array, dst_idx: jax.Array, src: jax.Array,
                    dst: jax.Array, *, interpret: bool = False) -> jax.Array:
    """dst[dst_idx[i]] = src[src_idx[i]] for each descriptor i.

    src/dst: (rows, unit) row pools — `unit` should be a multiple of 128
    lanes for full VREG utilization on TPU (asserted softly).
    """
    n = src_idx.shape[0]
    unit = src.shape[1]

    dst_map = lambda i, sidx, didx: (jnp.maximum(didx[i], 0), 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, unit),
                         lambda i, sidx, didx: (jnp.maximum(sidx[i], 0), 0)),
            pl.BlockSpec((1, unit), dst_map),
        ],
        out_specs=pl.BlockSpec((1, unit), dst_map),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst.shape, dst.dtype),
        input_output_aliases={3: 0},   # dst pool (after 2 scalars + src)
        interpret=interpret,
    )(src_idx.astype(jnp.int32), dst_idx.astype(jnp.int32), src, dst)


# ---------------------------------------------------------------------------
# Bucketed variant: one compiled kernel per pow2 descriptor-count bucket.
# ---------------------------------------------------------------------------

def descriptor_copy_bucketed(src_idx: jax.Array, dst_idx: jax.Array,
                             src: jax.Array, dst: jax.Array, *,
                             n_bucket: int,
                             interpret: bool = False) -> jax.Array:
    """:func:`descriptor_copy` padded to a fixed grid of ``n_bucket`` steps.

    The translation cache (:mod:`repro.runtime.lowering`) keys compiled
    artifacts on pow2 segment-count buckets; padding the index operands
    with ``-1`` (inactive — the kernel's ``pl.when`` gate skips them)
    makes every chain in a bucket re-enter one compiled kernel instead of
    recompiling per exact descriptor count.
    """
    n = src_idx.shape[0]
    if n > n_bucket:
        raise ValueError(f"{n} descriptors exceed bucket {n_bucket}")
    if n < n_bucket:
        pad = jnp.full((n_bucket - n,), -1, jnp.int32)
        src_idx = jnp.concatenate([src_idx.astype(jnp.int32), pad])
        dst_idx = jnp.concatenate([dst_idx.astype(jnp.int32), pad])
    return descriptor_copy(src_idx, dst_idx, src, dst, interpret=interpret)


# ---------------------------------------------------------------------------
# Chained variant: executes a linked list without pre-flattening, using the
# pointer-doubled permutation from repro.core.chain.flatten_chain.
# ---------------------------------------------------------------------------

def chain_copy(descs, src, dst, *, head: int = 0,
               interpret: bool = False) -> jax.Array:
    """Execute a DescriptorArray chain of row moves on the row pools."""
    from repro.core.chain import flatten_chain

    perm, _ = flatten_chain(descs.nxt, head)
    order = jnp.where(perm >= 0, perm, 0)
    gathered_src = jnp.where(perm >= 0, descs.src[order], -1)
    gathered_dst = jnp.where(perm >= 0, descs.dst[order], -1)
    return descriptor_copy(gathered_src, gathered_dst, src, dst,
                           interpret=interpret)
