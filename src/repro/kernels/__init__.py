"""Pallas TPU kernels (validated in interpret mode on CPU) + jnp oracles."""
from .ops import (  # noqa: F401
    chain_copy_op,
    descriptor_copy_op,
    flash_attention_op,
    moe_combine_op,
    moe_gather_op,
    paged_attention_op,
    prefetched_chain_copy_op,
)
from .quantize_copy import quantize_copy, quantize_copy_bucketed  # noqa: F401
