"""Public jit'd entry points for the Pallas kernels.

On CPU (this container) kernels run in interpret mode — the Pallas body
executes in Python for correctness validation; on TPU they compile to Mosaic.
Model code selects these via config, defaulting to the jnp reference path
for AOT dry-run lowering (kernel FLOPs == reference FLOPs at the HLO level).
"""
from __future__ import annotations

import jax

from repro.core.speculation import DEFAULT_POLICY, PolicyLike, static_depth

from . import ref  # noqa: F401  (re-exported oracles)
from .descriptor_copy import chain_copy, descriptor_copy
from .flash_attention import flash_attention
from .moe_dispatch import moe_combine, moe_gather
from .paged_attention import paged_attention
from .prefetch_pipeline import prefetched_chain_copy


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def descriptor_copy_op(src_idx, dst_idx, src, dst):
    return descriptor_copy(src_idx, dst_idx, src, dst, interpret=_interpret())


def chain_copy_op(descs, src, dst, head: int = 0):
    return chain_copy(descs, src, dst, head=head, interpret=_interpret())


def flash_attention_op(q, k, v, *, causal=True, window=None,
                       q_block=128, kv_block=128):
    return flash_attention(q, k, v, causal=causal, window=window,
                           q_block=q_block, kv_block=kv_block,
                           interpret=_interpret())


def paged_attention_op(q, k_pages, v_pages, block_tables, lengths):
    return paged_attention(q, k_pages, v_pages, block_tables, lengths,
                           interpret=_interpret())


def moe_gather_op(token_idx, tokens):
    return moe_gather(token_idx, tokens, interpret=_interpret())


def moe_combine_op(inv_slot, inv_weight, expert_out):
    return moe_combine(inv_slot, inv_weight, expert_out,
                       interpret=_interpret())


def prefetched_chain_copy_op(src_idx, dst_idx, src, dst,
                             depth: "PolicyLike | None" = None):
    """Chain copy through the explicit prefetch pipeline (§II-C).

    ``depth`` accepts the legacy int, any
    :class:`repro.core.speculation.SpeculationPolicy`, or ``None`` for the
    shared :data:`repro.core.speculation.DEFAULT_POLICY` — the same source
    of truth the cycle simulator's speculation config uses, so the kernel
    and the simulator cannot silently diverge.
    """
    resolved = static_depth(DEFAULT_POLICY if depth is None else depth)
    return prefetched_chain_copy(src_idx, dst_idx, src, dst, depth=resolved,
                                 interpret=_interpret())
