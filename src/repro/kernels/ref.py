"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def descriptor_copy_ref(src_idx, dst_idx, src, dst):
    """Row gather/scatter: dst[dst_idx[i]] = src[src_idx[i]]; -1 skips."""
    active = src_idx >= 0
    rows = src[jnp.maximum(src_idx, 0)]
    tgt = jnp.where(active & (dst_idx >= 0), dst_idx, dst.shape[0])
    return dst.at[tgt].set(rows, mode="drop")


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """Naive softmax attention. q: (B,S,H,D); k,v: (B,S,KV,D)."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * d ** -0.5
    qi = jnp.arange(sq)[:, None]
    ki = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= qi - ki < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths):
    """Decode attention over a paged KV pool.

    q: (B, H, D); {k,v}_pages: (P, page, KV, D);
    block_tables: (B, max_pages) int32 page ids (-1 pads);
    lengths: (B,) tokens in cache. Returns (B, H, D).
    """
    b, h, d = q.shape
    _, page, kvh, _ = k_pages.shape
    g = h // kvh
    max_pages = block_tables.shape[1]

    safe = jnp.maximum(block_tables, 0)
    k = k_pages[safe]          # (B, max_pages, page, KV, D)
    v = v_pages[safe]
    k = k.reshape(b, max_pages * page, kvh, d)
    v = v.reshape(b, max_pages * page, kvh, d)
    pos = jnp.arange(max_pages * page)[None, :]
    valid = (pos < lengths[:, None]) & jnp.repeat(
        block_tables >= 0, page, axis=1)
    qg = q.reshape(b, kvh, g, d)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * d ** -0.5
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def moe_gather_ref(token_idx, tokens):
    """Dispatch gather: (E*C,) slots from (T, d) tokens; -1 -> zeros."""
    rows = tokens[jnp.maximum(token_idx, 0)]
    return jnp.where((token_idx >= 0)[:, None], rows, 0).astype(tokens.dtype)


def moe_combine_ref(inv_slot, inv_weight, expert_out):
    """Combine: out[t] = sum_j w[t,j] * expert_out[inv_slot[t,j]]; -1 skips."""
    rows = expert_out[jnp.maximum(inv_slot, 0)]          # (T, k, d)
    w = jnp.where(inv_slot >= 0, inv_weight, 0.0)
    return jnp.einsum("tk,tkd->td", w.astype(jnp.float32),
                      rows.astype(jnp.float32)).astype(expert_out.dtype)
