"""Flash attention forward — Pallas TPU kernel with explicit VMEM tiling.

Grid (batch*kv_heads*groups, q_blocks, kv_blocks): the innermost axis streams
KV blocks HBM->VMEM while running-softmax state (m, l, acc) persists in VMEM
scratch across that axis. Block shapes are MXU-aligned (q_block x head_dim
and kv_block x head_dim tiles, head_dim expected 128-multiple-friendly).

This is the TPU-target version of models.attention.blockwise_attention (the
jnp oracle is kernels.ref.flash_attention_ref).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, window: Optional[int], q_block: int,
                  kv_block: int, num_kv_blocks: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * q_block
    k_start = ki * kv_block
    # Static-shape causal/window skip: only compute blocks that intersect.
    run = True
    if causal:
        run = k_start <= q_start + q_block - 1

    @pl.when(jnp.asarray(run))
    def _compute():
        q = q_ref[0].astype(jnp.float32)         # (q_block, d)
        k = k_ref[0].astype(jnp.float32)         # (kv_block, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_block", "kv_block", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, q_block: int = 128,
                    kv_block: int = 128, interpret: bool = False):
    """q: (B, S, H, D); k/v: (B, S, KV, D) -> (B, S, H, D).

    GQA is handled by folding groups into the leading grid axis so each
    (kv_head, group) pair re-reads its kv head's blocks.
    """
    b, sq, h, d = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    q_block = min(q_block, sq)
    kv_block = min(kv_block, sk)
    assert sq % q_block == 0 and sk % kv_block == 0
    nq, nk = sq // q_block, sk // kv_block

    # Layout: fold (b, kv_head, group) into one axis; q -> (BKG, S, D).
    qf = q.reshape(b, sq, kvh, g, d).transpose(0, 2, 3, 1, 4) \
        .reshape(b * kvh * g, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3).reshape(b * kvh, sk, d), g, axis=0)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3).reshape(b * kvh, sk, d), g, axis=0)

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, q_block=q_block,
        kv_block=kv_block, num_kv_blocks=nk, scale=d ** -0.5)

    out = pl.pallas_call(
        kernel,
        grid=(b * kvh * g, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, kv_block, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, kv_block, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh * g, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, kvh, g, sq, d).transpose(0, 3, 1, 2, 4) \
        .reshape(b, sq, h, d)
