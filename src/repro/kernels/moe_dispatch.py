"""MoE dispatch/combine kernels driven by the DispatchPlan descriptor streams.

Dispatch is the paper's gather: slot s pulls token row token_idx[s]
(scalar-prefetched, one row-block per grid step). Combine is the inverse
stream: token t pulls its k expert-output rows — realized by passing the
expert-output pool k times, each copy with its own descriptor-driven
index_map, so all k fetches pipeline like speculative descriptor reads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, tok_ref, out_ref):
    i = pl.program_id(0)
    active = idx_ref[i] >= 0
    out_ref[...] = jnp.where(active, tok_ref[...], 0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def moe_gather(token_idx: jax.Array, tokens: jax.Array, *,
               interpret: bool = False) -> jax.Array:
    """Dispatch: (E*C,) descriptor stream gathering (T, d) token rows."""
    n = token_idx.shape[0]
    d = tokens.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, d), lambda i, idx: (jnp.maximum(idx[i], 0), 0))],
        out_specs=pl.BlockSpec((1, d), lambda i, idx: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), tokens.dtype),
        interpret=interpret,
    )(token_idx.astype(jnp.int32), tokens)


def _combine_kernel(slot_ref, w_ref, *refs):
    (*expert_refs, out_ref) = refs
    t = pl.program_id(0)
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for j, eref in enumerate(expert_refs):
        active = slot_ref[t, j] >= 0
        w = jnp.where(active, w_ref[t, j], 0.0)
        acc = acc + w * eref[...].astype(jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def moe_combine(inv_slot: jax.Array, inv_weight: jax.Array,
                expert_out: jax.Array, *, interpret: bool = False):
    """Combine: out[t] = sum_j w[t,j] * expert_out[inv_slot[t,j]].

    inv_slot/inv_weight: (T, k); expert_out: (E*C, d) -> (T, d).
    The pool is passed k times, each with a descriptor-driven index_map —
    the k fetches for one token pipeline like the paper's speculative
    descriptor requests.
    """
    t, k = inv_slot.shape
    d = expert_out.shape[1]

    def make_map(j):
        return lambda i, slot, w: (jnp.maximum(slot[i, j], 0), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(t,),
        in_specs=[pl.BlockSpec((1, d), make_map(j)) for j in range(k)],
        out_specs=pl.BlockSpec((1, d), lambda i, slot, w: (i, 0)),
    )
    return pl.pallas_call(
        _combine_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, d), expert_out.dtype),
        interpret=interpret,
    )(inv_slot.astype(jnp.int32), inv_weight.astype(jnp.float32),
      *([expert_out] * k))
