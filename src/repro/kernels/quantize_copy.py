"""Descriptor-driven quantize-dequantize row copy (DMAC + in-flight kv_int8).

The XDMA-style transform stage (DESIGN.md §9) fused into the Pallas
descriptor-copy idiom: the same scalar-prefetched descriptor stream and
double-buffered grid as :mod:`repro.kernels.descriptor_copy`, but each
row passes through the EF-int8 per-256-block symmetric round trip of
:mod:`repro.optim.compress` between the read and the write — the wire
carries int8 payload + one fp32 scale per block, the destination pool
receives dequantized values.

Bit-compatibility contract: for row width a multiple of ``BLOCK`` and
unit-aligned pools, a row's local 256-blocks coincide with the
pool-absolute blocks of :func:`repro.core.transform.kv8_roundtrip`, so
this kernel is value-identical to copying from the round-tripped pool
(the lowered fallback path and the numpy oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.optim.compress import BLOCK


def _quantize_copy_kernel(src_idx_ref, dst_idx_ref, src_ref, dst_in_ref,
                          dst_ref):
    """Body: round-trip one row through per-BLOCK int8 scales, then write.

    Inactive descriptors (-1) write nothing; dst_in_ref is the aliased
    destination pool (untouched rows keep their contents).
    """
    del dst_in_ref
    i = pl.program_id(0)
    active = (src_idx_ref[i] >= 0) & (dst_idx_ref[i] >= 0)

    @pl.when(active)
    def _():
        row = src_ref[...].astype(jnp.float32)
        blocks = row.reshape(-1, BLOCK)
        scale = jnp.maximum(
            jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0, 1e-12)
        q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
        deq = (q.astype(jnp.float32) * scale).reshape(row.shape)
        dst_ref[...] = deq.reshape(src_ref.shape).astype(dst_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_copy(src_idx: jax.Array, dst_idx: jax.Array, src: jax.Array,
                  dst: jax.Array, *, interpret: bool = False) -> jax.Array:
    """dst[dst_idx[i]] = kv8_roundtrip(src[src_idx[i]]) per descriptor i.

    src/dst: (rows, unit) row pools with ``unit % BLOCK == 0`` (each row
    is a whole number of quantization blocks).
    """
    n = src_idx.shape[0]
    unit = src.shape[1]
    if unit % BLOCK:
        raise ValueError(f"row width {unit} is not a multiple of {BLOCK}")

    dst_map = lambda i, sidx, didx: (jnp.maximum(didx[i], 0), 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, unit),
                         lambda i, sidx, didx: (jnp.maximum(sidx[i], 0), 0)),
            pl.BlockSpec((1, unit), dst_map),
        ],
        out_specs=pl.BlockSpec((1, unit), dst_map),
    )
    return pl.pallas_call(
        _quantize_copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst.shape, dst.dtype),
        input_output_aliases={3: 0},   # dst pool (after 2 scalars + src)
        interpret=interpret,
    )(src_idx.astype(jnp.int32), dst_idx.astype(jnp.int32), src, dst)


def quantize_copy_bucketed(src_idx: jax.Array, dst_idx: jax.Array,
                           src: jax.Array, dst: jax.Array, *,
                           n_bucket: int,
                           interpret: bool = False) -> jax.Array:
    """:func:`quantize_copy` padded to a fixed grid of ``n_bucket`` steps.

    Same pow2-bucket contract as ``descriptor_copy_bucketed``: ``-1``
    padding marks inactive grid steps, so every chain in a signature
    bucket re-enters one compiled kernel.
    """
    n = src_idx.shape[0]
    if n > n_bucket:
        raise ValueError(f"{n} descriptors exceed bucket {n_bucket}")
    if n < n_bucket:
        pad = jnp.full((n_bucket - n,), -1, jnp.int32)
        src_idx = jnp.concatenate([src_idx.astype(jnp.int32), pad])
        dst_idx = jnp.concatenate([dst_idx.astype(jnp.int32), pad])
    return quantize_copy(src_idx, dst_idx, src, dst, interpret=interpret)
