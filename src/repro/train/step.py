"""Train step: microbatched grad accumulation, AdamW, optional cross-pod
gradient compression. Pure function of (TrainState, batch) -> (TrainState,
metrics); sharding is applied by the caller via in/out shardings + the
logical-axis constraints inside the model.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import loss_fn
from repro import optim


class TrainState(NamedTuple):
    params: Any
    opt: optim.AdamWState
    residuals: Optional[Any]      # EF-compression residuals (or None)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: optim.AdamWConfig = optim.AdamWConfig()
    microbatches: int = 1          # grad accumulation steps
    compress_pod_axis: Optional[str] = None   # e.g. "pod" on multi-pod mesh
    # Cast >=2-D fp32 params to compute dtype *before* they are consumed, so
    # FSDP all-gathers move bf16 instead of fp32 (EXPERIMENTS.md §Perf-2).
    cast_params_bf16: bool = False


def init_state(params, tcfg: TrainConfig) -> TrainState:
    residuals = None
    if tcfg.compress_pod_axis:
        residuals = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return TrainState(params=params, opt=optim.init(params),
                      residuals=residuals)


def _split_microbatches(batch, n: int):
    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by microbatches {n}"
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(split, batch)


def _cast_params(params, dtype):
    import jax.numpy as jnp_

    def cast(p):
        if hasattr(p, "dtype") and p.dtype == jnp_.float32 and p.ndim >= 2:
            return p.astype(dtype)
        return p
    return jax.tree.map(cast, params)


def grads_and_metrics(params, batch, cfg: ModelConfig, microbatches: int,
                      cast_bf16: bool = False):
    """Value-and-grad with lax.scan grad accumulation over microbatches."""
    def fwd(p, b):
        if cast_bf16:
            p = _cast_params(p, cfg.cdtype)
        return loss_fn(p, b, cfg)

    grad_fn = jax.value_and_grad(fwd, has_aux=True)

    if microbatches == 1:
        (loss, metrics), grads = grad_fn(params, batch)
        return grads, dict(metrics, loss=loss)

    mb = _split_microbatches(batch, microbatches)

    def body(carry, mb_batch):
        acc, loss_acc = carry
        (loss, _), grads = grad_fn(params, mb_batch)
        acc = jax.tree.map(jnp.add, acc, grads)
        return (acc, loss_acc + loss), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gsum, loss_sum), _ = jax.lax.scan(body, (zeros, jnp.zeros(())), mb)
    grads = jax.tree.map(lambda g: g / microbatches, gsum)
    return grads, {"loss": loss_sum / microbatches}


def train_step(state: TrainState, batch, cfg: ModelConfig,
               tcfg: TrainConfig) -> Tuple[TrainState, dict]:
    grads, metrics = grads_and_metrics(state.params, batch, cfg,
                                       tcfg.microbatches,
                                       cast_bf16=tcfg.cast_params_bf16)
    residuals = state.residuals
    if tcfg.compress_pod_axis and residuals is not None:
        # Cross-pod error-feedback int8 allreduce. Inside pjit the psum over
        # a mesh axis requires shard_map; the launcher wraps this step in one
        # when compression is on. Here we expose the pure-tree transform.
        grads, residuals = optim.compressed_psum_tree(
            grads, residuals, tcfg.compress_pod_axis)
    new_params, new_opt, opt_metrics = optim.apply(
        tcfg.optimizer, state.params, grads, state.opt)
    metrics = {**metrics, **opt_metrics}
    return TrainState(new_params, new_opt, residuals), metrics


def jit_train_step(cfg: ModelConfig, tcfg: TrainConfig, *, donate=True):
    fn = functools.partial(train_step, cfg=cfg, tcfg=tcfg)
    return jax.jit(fn, donate_argnums=(0,) if donate else ())
