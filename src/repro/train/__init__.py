"""Training substrate."""
from .step import (  # noqa: F401
    TrainConfig,
    TrainState,
    grads_and_metrics,
    init_state,
    jit_train_step,
    train_step,
)
from .trainer import StragglerMonitor, Trainer, TrainerConfig  # noqa: F401
