"""Training loop with production posture: auto-resume from the latest
committed checkpoint, periodic async saves (data-iterator state included),
straggler detection via per-step EWMA timing, and preemption-safe shutdown.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Dict, Optional

import jax

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig
from repro.data import DataConfig, DataIterator, IteratorState
from repro.models import init_params

from .step import TrainConfig, init_state, jit_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    seed: int = 0
    straggler_threshold: float = 3.0    # x EWMA step time -> flag


class StragglerMonitor:
    """Flags steps whose wall time exceeds `threshold` x EWMA — on real
    fleets this feeds the controller that re-schedules slow hosts."""

    def __init__(self, threshold: float, alpha: float = 0.1):
        self.ewma: Optional[float] = None
        self.threshold = threshold
        self.alpha = alpha
        self.flagged: list[int] = []

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = (self.ewma is not None
                        and dt > self.threshold * self.ewma)
        if is_straggler:
            self.flagged.append(step)
        self.ewma = dt if self.ewma is None else \
            (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig,
                 run: TrainerConfig, data_cfg: DataConfig,
                 log_fn: Callable[[int, Dict], None] = None):
        self.cfg, self.tcfg, self.run = cfg, tcfg, run
        self.data_cfg = data_cfg
        self.ckpt = Checkpointer(run.checkpoint_dir,
                                 keep=run.keep_checkpoints)
        self.monitor = StragglerMonitor(run.straggler_threshold)
        self.log_fn = log_fn or (lambda s, m: None)
        self.step_fn = jit_train_step(cfg, tcfg)
        self._preempted = False

    def _install_signal_handler(self):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # non-main thread (tests)

    # -- lifecycle -----------------------------------------------------------
    def init_or_resume(self):
        key = jax.random.PRNGKey(self.run.seed)
        params = init_params(key, self.cfg)
        state = init_state(params, self.tcfg)
        start_step = 0
        it_state = IteratorState()
        latest = self.ckpt.latest_step()
        if latest is not None:
            state, extra = self.ckpt.restore(latest, state)
            start_step = latest
            it_state = IteratorState.from_dict(
                extra.get("iterator", {"step": latest}))
        return state, start_step, it_state

    def train(self) -> Dict:
        self._install_signal_handler()
        state, start_step, it_state = self.init_or_resume()
        data = DataIterator(self.data_cfg, it_state)
        losses = []
        step = start_step
        try:
            for step in range(start_step, self.run.total_steps):
                t0 = time.perf_counter()
                batch = next(data)
                batch = {k: v for k, v in batch.items()
                         if k in ("tokens", "labels", "loss_mask")}
                state, metrics = self.step_fn(state, batch)
                loss = float(metrics["loss"])
                losses.append(loss)
                dt = time.perf_counter() - t0
                if self.monitor.observe(step, dt):
                    self.log_fn(step, {"straggler_step_time": dt})
                if (step + 1) % self.run.log_every == 0:
                    self.log_fn(step, {"loss": loss, "step_time": dt})
                if (step + 1) % self.run.checkpoint_every == 0 \
                        or self._preempted:
                    self.ckpt.save(step + 1, state,
                                   extra={"iterator": data.state.to_dict()})
                if self._preempted:
                    break
        finally:
            self.ckpt.save(step + 1, state, blocking=True,
                           extra={"iterator": data.state.to_dict()})
            data.close()
        return {"final_step": step + 1, "losses": losses,
                "stragglers": self.monitor.flagged}
