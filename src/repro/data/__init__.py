"""Data pipeline substrate."""
from .pipeline import (  # noqa: F401
    DataConfig,
    DataIterator,
    IteratorState,
    make_batch,
    pack_documents,
)
