"""Synthetic data pipeline: deterministic corpus, descriptor-chain packing,
prefetching, and checkpointable iterator state.

The sequence-packing map (which document spans land where in each fixed-size
training sequence) is emitted as a descriptor chain and executed by the core
engine — the data path is a consumer of the paper's mechanism (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core.chain import from_segments
from repro.core.descriptor import DescriptorArray


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    num_hosts: int = 1
    host_id: int = 0
    prefetch_depth: int = 2


@dataclasses.dataclass
class IteratorState:
    """Checkpointable position: (step, rng counter). Restoring reproduces the
    exact upcoming batch stream."""
    step: int = 0

    def to_dict(self) -> Dict:
        return {"step": self.step}

    @staticmethod
    def from_dict(d: Dict) -> "IteratorState":
        return IteratorState(step=int(d["step"]))


def _doc_stream(cfg: DataConfig, step: int) -> np.random.Generator:
    # Counter-based: host and step fully determine the stream (restartable,
    # disjoint across hosts).
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, cfg.host_id, step]))


def pack_documents(cfg: DataConfig, rng: np.random.Generator,
                   batch_rows: int) -> Tuple[np.ndarray, np.ndarray,
                                             DescriptorArray]:
    """Draw documents and pack them into (rows, seq_len) via descriptors.

    Returns (tokens, segment_ids, packing_chain). Document boundaries insert
    an EOS-like separator (token 0); segment_ids let attention variants mask
    across documents if desired.
    """
    rows, s = batch_rows, cfg.seq_len
    tokens = np.zeros((rows, s), np.int32)
    seg = np.zeros((rows, s), np.int32)
    srcs, dsts, lens = [], [], []
    flat_docs = []
    cursor = 0
    for r in range(rows):
        filled = 0
        seg_id = 1
        while filled < s:
            doc_len = int(rng.integers(cfg.mean_doc_len // 4,
                                       cfg.mean_doc_len * 2))
            doc_len = min(doc_len, s - filled)
            # Learnable synthetic text: a noisy affine recurrence, so models
            # have real structure to fit (pure uniform tokens would pin the
            # loss at ln(V) and make convergence tests meaningless).
            v = cfg.vocab_size - 1
            doc = np.empty(doc_len, np.int32)
            doc[0] = rng.integers(1, cfg.vocab_size)
            noise = rng.random(doc_len) < 0.15
            rand = rng.integers(1, cfg.vocab_size, doc_len, dtype=np.int32)
            for i in range(1, doc_len):
                doc[i] = rand[i] if noise[i] else \
                    (doc[i - 1] * 31 + 17) % v + 1
            flat_docs.append(doc)
            srcs.append(cursor)
            dsts.append(r * s + filled)
            lens.append(doc_len)
            tokens[r, filled:filled + doc_len] = doc
            seg[r, filled:filled + doc_len] = seg_id
            cursor += doc_len
            filled += doc_len
            seg_id += 1
    chain = from_segments(np.asarray(srcs), np.asarray(dsts),
                          np.asarray(lens))
    return tokens, seg, chain


def make_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    rng = _doc_stream(cfg, step)
    rows = cfg.global_batch // cfg.num_hosts
    tokens, seg, chain = pack_documents(cfg, rng, rows)
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = 0
    mask = (labels != 0).astype(np.float32)
    return {"tokens": tokens, "labels": labels, "loss_mask": mask,
            "segment_ids": seg}


class DataIterator:
    """Prefetching, restartable iterator over synthetic packed batches."""

    def __init__(self, cfg: DataConfig, state: Optional[IteratorState] = None):
        self.cfg = cfg
        self.state = state or IteratorState()
        self._q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch_depth)
        self._stop = threading.Event()
        self._next_to_produce = self.state.step
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        while not self._stop.is_set():
            step = self._next_to_produce
            batch = make_batch(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            self._next_to_produce += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        step, batch = self._q.get()
        assert step == self.state.step, "prefetch stream out of sync"
        self.state.step += 1
        return batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
