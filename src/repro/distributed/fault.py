"""Elastic scaling: restore a checkpoint onto a *different* mesh topology.

On node failure the controller rebuilds a smaller mesh (e.g. 2 pods -> 1),
calls :func:`reshard_checkpoint` to land the last committed state on the new
topology, and training resumes — the checkpoint manifest (descriptor-style
array records, DESIGN.md §3) carries everything needed.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

from jax.sharding import Mesh

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig

from .sharding import to_named, train_state_specs


def reshard_checkpoint(
    ckpt: Checkpointer,
    step: int,
    cfg: ModelConfig,
    new_mesh: Mesh,
    state_shapes: Any,
) -> Tuple[Any, dict]:
    """Restore `step` with shardings computed for `new_mesh`.

    `state_shapes` is the TrainState shape tree for the *same model* (the
    mesh doesn't change parameter shapes, only their placement), typically
    from `launch.inputs.train_state_specs_shapes`.
    """
    specs = train_state_specs(cfg, new_mesh, state_shapes)
    shardings = to_named(specs, new_mesh)
    return ckpt.restore(step, state_shapes, shardings=shardings)


def survive_shrink(
    ckpt: Checkpointer,
    cfg: ModelConfig,
    state_shapes: Any,
    make_mesh,
    *,
    max_attempts: int = 3,
) -> Optional[Tuple[Any, dict, Mesh]]:
    """Controller-side recovery loop: try progressively smaller meshes until
    the latest committed checkpoint restores (capacity permitting)."""
    step = ckpt.latest_step()
    if step is None:
        return None
    last_err = None
    for attempt in range(max_attempts):
        try:
            mesh = make_mesh(attempt)
            state, extra = reshard_checkpoint(ckpt, step, cfg, mesh,
                                              state_shapes)
            return state, extra, mesh
        except Exception as e:  # noqa: BLE001 — controller retries smaller
            last_err = e
    raise RuntimeError(
        f"elastic recovery failed after {max_attempts} topologies: {last_err}")
