"""Elastic scaling: restore a checkpoint onto a *different* mesh topology.

On node failure the controller rebuilds a smaller mesh (e.g. 2 pods -> 1),
calls :func:`reshard_checkpoint` to land the last committed state on the new
topology, and training resumes — the checkpoint manifest (descriptor-style
array records, DESIGN.md §3) carries everything needed.

The serving-side counterpart is :func:`ungraceful_resize`: losing a shard
while fabric tickets are in flight is treated as an unplanned mesh resize
(DESIGN.md §10) — outstanding hops are re-routed, the lost shard's live
pages are handed off to survivors, and the mesh quiesces on N-1 shards.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import numpy as np
from jax.sharding import Mesh

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig

from .fabric import IN_FLIGHT, INGRESS
from .sharding import to_named, train_state_specs


def reshard_checkpoint(
    ckpt: Checkpointer,
    step: int,
    cfg: ModelConfig,
    new_mesh: Mesh,
    state_shapes: Any,
) -> Tuple[Any, dict]:
    """Restore `step` with shardings computed for `new_mesh`.

    `state_shapes` is the TrainState shape tree for the *same model* (the
    mesh doesn't change parameter shapes, only their placement), typically
    from `launch.inputs.train_state_specs_shapes`.
    """
    specs = train_state_specs(cfg, new_mesh, state_shapes)
    shardings = to_named(specs, new_mesh)
    return ckpt.restore(step, state_shapes, shardings=shardings)


def survive_shrink(
    ckpt: Checkpointer,
    cfg: ModelConfig,
    state_shapes: Any,
    make_mesh,
    *,
    max_attempts: int = 3,
) -> Optional[Tuple[Any, dict, Mesh]]:
    """Controller-side recovery loop: try progressively smaller meshes until
    the latest committed checkpoint restores (capacity permitting)."""
    step = ckpt.latest_step()
    if step is None:
        return None
    last_err = None
    for attempt in range(max_attempts):
        try:
            mesh = make_mesh(attempt)
            state, extra = reshard_checkpoint(ckpt, step, cfg, mesh,
                                              state_shapes)
            return state, extra, mesh
        except Exception as e:  # noqa: BLE001 — controller retries smaller
            last_err = e
    raise RuntimeError(
        f"elastic recovery failed after {max_attempts} topologies: {last_err}")


def ungraceful_resize(kv, lost_shard: int, *,
                      priority: int = 0) -> Dict[int, int]:
    """Treat a lost shard as an unplanned mesh resize (DESIGN.md §10).

    Must be called while the async fabric may still hold tickets touching
    ``lost_shard``. Recovery follows the :func:`reshard_checkpoint`
    contract — the lost node's host-visible state (last committed image)
    stays readable even though the device is gone — so every page the
    shard held, including pages mid-migration, lands exactly once on a
    survivor:

    1. outstanding egress gathers on the lost shard complete from the
       recovered image (one recovery drain);
    2. in-flight tickets *destined to* the lost shard are re-routed: new
       pages on the survivor with the most free capacity, staged payloads
       re-placed, and a fresh §II-D control descriptor on the new
       destination (the old writeback slot died with the shard);
    3. the shard's remaining live pages — minus pages already leaving on
       outstanding hops, which arrive via (1)+(2) — are evacuated through
       the planner placement (``ShardedKVPool.evacuate``);
    4. the fabric pumps to quiescence on the surviving mesh.

    Returns the combined ``{old_page: new_page}`` remap (re-routed hop
    destinations plus evacuated pages); callers rewrite references.
    """
    srt = kv.rt
    if srt.fabric_mode != "async":
        raise RuntimeError("ungraceful_resize requires fabric='async'")
    if not srt.active[lost_shard]:
        raise ValueError(f"shard {lost_shard} already left the mesh")
    survivors = [s for s in srt.active_shards() if s != lost_shard]
    if not survivors:
        raise RuntimeError("no surviving shards to resize onto")
    pps = kv.owner.pages_per_shard
    remap: Dict[int, int] = {}

    # (1) recovery drain: outstanding egress gathers source their bytes
    # from the checkpointed image of the lost shard.
    srt.shards[lost_shard].drain_until_idle()

    # (2) re-route tickets destined to the lost shard.
    rerouted: set = set()
    for t in srt._pending_hops:
        if t.dst_shard != lost_shard:
            continue
        old_pages = [lost_shard * pps + int(r) for r in t.rows_d]
        rerouted.update(old_pages)
        target = max(survivors,
                     key=lambda s: (kv.free_pages_on(s), -s))
        new_pages = kv.alloc_on(target, len(old_pages))
        old_dst = srt.shards[t.dst_shard]
        if t.state == INGRESS:
            # Scatter chains already queued on the dead shard are
            # abandoned; the staged payload is still addressable there
            # (recovered image) — recapture it for the new destination.
            for name in t.pool_names:
                stage = srt._stage_name(t.hop_id, name)
                t.staged[name] = old_dst.pool(stage)
                old_dst.pools.pop(stage, None)
            t.ingress = []
        if t.state in (IN_FLIGHT, INGRESS):
            t.staged = {name: srt._place(target, arr)
                        for name, arr in t.staged.items()}
        t.dst_shard = target
        t.rows_d = np.asarray(
            [kv.owner.local_row(kv.table.slot_of(int(p)))
             for p in new_pages], np.int64)
        ctrl = srt.shards[target].submit_control(payload=t.src_shard,
                                                 channel="completion")
        t.ctrl_ticket = ctrl.tickets[-1]
        if t.state == INGRESS:
            srt._submit_ingress(t)
        remap.update(zip(old_pages, new_pages))

    # (3) hand off the shard's remaining live pages; pages leaving on an
    # outstanding hop arrive at their hop destination instead.
    # Re-routed hop destinations were allocated slots that never held
    # content — their remap entry already points at the new destination,
    # so evacuation must not remap them a second time.
    leaving = set(rerouted)
    for t in srt._pending_hops:
        # Includes IN_FLIGHT/INGRESS sources: already staged off the
        # shard, but their page ids stay allocated until the caller
        # releases them — evacuating them too would duplicate content.
        if t.src_shard == lost_shard:
            leaving.update(lost_shard * pps + int(r) for r in t.rows_s)
    remap.update(kv.evacuate(lost_shard, priority=priority,
                             exclude=sorted(leaving)))

    # (4) quiesce on the surviving mesh.
    srt.pump_until_idle()
    srt.drain_until_idle()
    return remap
