"""Sharded DMA serving across a device mesh (DESIGN.md §6).

The paper's win is decoupling transfer *launch* from the processing units;
at production scale that decoupling must survive sharding. Following the
multi-frontend direction of iDMA (arXiv 2305.05240) and XDMA's
distributed layout-flexible data movement (arXiv 2508.08396), this module
instantiates one full :class:`repro.runtime.DMARuntime` — submission
rings, serial data channels, coalescer, completion queue, control channel
— per mesh shard, and lowers every cross-shard page movement into §II-B
descriptor chains:

* **Page ownership** (:class:`PageOwnerMap`) — the global page space is
  statically partitioned across shards; a page's owner never changes, the
  page *contents* move.
* **Migration planner** (:meth:`ShardedDMARuntime.migrate_rows`) — page
  moves are split into shard-local chains (submitted straight to the
  owner's serial channel, where the runtime coalescer merges contiguous
  page runs) and cross-shard *hops*: an egress gather chain on the source
  shard into a staging buffer, the fabric transfer (``jax.device_put``
  when the shard has a real mesh device), and an ingress scatter chain on
  the destination shard. Every hop carries a per-hop completion control
  descriptor on the destination's control channel: the §II-D writeback is
  the only signal the planner trusts that a hop's bytes landed.
* **Sharded serve path** (:class:`ShardedServeEngine`) — requests are
  admitted to the shard that owns (the majority of) their KV pages;
  pages a request needs from other shards become migration chains into
  the owning shard before admission ("remote reads become migrations").

Shards are *logical*: with a `jax.sharding.Mesh` the per-shard pools are
placed on the mesh's devices (1×N and N×1 meshes are equivalent — the
shard count is the device count), and without one everything runs on the
default device with identical semantics, so the perf sweep's gated
numbers are placement-independent and regenerate bit-for-bit anywhere.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chain import from_segments
from repro.core.pageref import PageRef, as_pagerefs
from repro.core.prefetch import estimate_hit_rate
from repro.mmu import PageTable
from repro.obs.counters import PerfCounters, namespaced
from repro.obs.metrics import Histogram
from repro.obs.trace import Tracer, monotonic
from repro.runtime import ChannelConfig, DMARuntime, PerfProbe
from repro.runtime.submit import SubmitRequest, Ticket, reject_legacy_submit

from . import shardlib
from .fabric import (
    COMPLETED,
    EGRESS,
    IN_FLIGHT,
    INGRESS,
    AsyncFabric,
    FabricTicket,
    RebalancePlanner,
)


def resolve_num_shards(mesh=None) -> int:
    """Shard count of a mesh: its total device count (shape-agnostic, so
    1×N and N×1 meshes shard identically)."""
    mesh = mesh if mesh is not None else shardlib.current_mesh()
    if mesh is None:
        return 1
    return int(np.prod(list(mesh.shape.values()), dtype=np.int64))


@dataclasses.dataclass(frozen=True)
class PageOwnerMap:
    """Static partition of a global page space across shards.

    Shard ``s`` owns the contiguous block of global pages
    ``[s * pages_per_shard, (s + 1) * pages_per_shard)``; a page's local
    row on its owner is its offset inside that block.
    """

    num_pages: int
    num_shards: int

    def __post_init__(self):
        if self.num_shards < 1:
            raise ValueError("need >= 1 shard")
        if self.num_pages % self.num_shards:
            raise ValueError(
                f"{self.num_pages} pages do not partition evenly over "
                f"{self.num_shards} shards")

    @property
    def pages_per_shard(self) -> int:
        return self.num_pages // self.num_shards

    def owner(self, page: int) -> int:
        if not 0 <= page < self.num_pages:
            raise IndexError(f"page {page} outside [0, {self.num_pages})")
        return page // self.pages_per_shard

    def local_row(self, page: int) -> int:
        return page % self.pages_per_shard

    def shard_pages(self, shard: int) -> range:
        lo = shard * self.pages_per_shard
        return range(lo, lo + self.pages_per_shard)


@dataclasses.dataclass
class MigrationStats:
    """What one ``migrate_rows`` plan did, summed over pools and hops."""

    pages: int = 0              # page moves requested
    local_pages: int = 0        # moves with src and dst on one shard
    cross_pages: int = 0        # moves that crossed the fabric
    hops: int = 0               # (src_shard, dst_shard) fabric transfers
    chain_in: int = 0           # descriptors before the coalescer
    chain_out: int = 0          # descriptors after merge (real submissions)
    hop_completions: int = 0    # per-hop §II-D writebacks observed
    fabric_inflight_rounds: int = 0  # pump rounds with a hop on the wire
    fabric_hidden_rounds: int = 0    # ... during which a shard drained

    @property
    def merge_ratio(self) -> float:
        """chain_in / chain_out — the §II-C payoff of run-preserving
        migration plans (>1 means contiguous page runs were fused)."""
        return self.chain_in / max(self.chain_out, 1)

    @property
    def overlap_ratio(self) -> float:
        """Fraction of fabric in-flight rounds hidden behind local drain
        progress (async fabric only; 0.0 when nothing crossed the wire).

        Accounted globally by the pump loop — only the mesh-wide
        ``ShardedDMARuntime.migration`` aggregate carries these rounds;
        per-plan stats report their own hops/chains but leave the fabric
        round fields at zero (a round is not attributable to one plan)."""
        return self.fabric_hidden_rounds / max(self.fabric_inflight_rounds, 1)

    def merge(self, other: "MigrationStats") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))


class ShardedDMARuntime:
    """One DMA runtime per mesh shard plus the cross-shard migration planner.

    Each shard owns ``data_channels`` serial-tier channels (the §II-B
    chain path, coalescer on) and one control-tier ``completion`` channel
    (serve-request markers and per-hop migration writebacks). Pools are
    registered *sharded*: a flat global row space split into per-shard
    slices, placed on the shard's mesh device when a mesh is present.
    """

    STAGE_POOL = "migrate.stage"

    def __init__(
        self,
        num_shards: Optional[int] = None,
        mesh=None,
        *,
        data_channels: int = 2,
        ring_capacity: int = 256,
        max_len: int = 1024,
        completion_ring: int = 256,
        arbitration: str = "round_robin",
        backpressure: str = "block",
        speculation=None,
        translation: bool = True,
        fabric: str = "async",
        fabric_latency: int = 1,
        fabric_page_beats: int = 1,
    ):
        if fabric not in ("async", "sync"):
            raise ValueError(f"fabric must be 'async' or 'sync', "
                             f"got {fabric!r}")
        explicit_mesh = mesh is not None
        mesh = mesh if explicit_mesh else shardlib.current_mesh()
        mesh_shards = resolve_num_shards(mesh)
        if num_shards is None:
            num_shards = mesh_shards
        if mesh is not None and num_shards != mesh_shards:
            if explicit_mesh:
                raise ValueError(
                    f"num_shards={num_shards} but the mesh has "
                    f"{mesh_shards} devices; drop one or make them agree")
            # An *ambient* mesh of the wrong size must not veto an
            # explicit shard count (e.g. the mesh-1 perf cell running
            # inside someone else's 8-device context): shards are
            # logical, so just run unplaced — no metric depends on it.
            mesh = None
        if num_shards < 1:
            raise ValueError("need >= 1 shard")
        self.num_shards = num_shards
        self.mesh = mesh
        self._devices = (list(mesh.devices.flat)
                         if mesh is not None else None)
        self.data_channels = data_channels
        self.shards: List[DMARuntime] = []
        for _ in range(num_shards):
            cfgs = [ChannelConfig(name=f"dma{i}", tier="serial",
                                  ring_capacity=ring_capacity,
                                  max_len=max_len)
                    for i in range(data_channels)]
            cfgs.append(ChannelConfig(name="completion", tier="control",
                                      ring_capacity=completion_ring))
            # Per-shard translation caches: each shard lowers its own
            # migration-hop and data chains (counters aggregate in stats()).
            self.shards.append(DMARuntime(
                cfgs, arbitration=arbitration, backpressure=backpressure,
                speculation=speculation, translation=translation))
        self.max_len = max_len
        self._sharded_pools: Dict[str, PageOwnerMap] = {}
        self._row_elems: Dict[str, int] = {}
        self._pool_elems: Dict[str, int] = {}   # logical per-shard elements
        self.migration = MigrationStats()
        self.tracer: Optional[Tracer] = None
        self._trace_args: Dict[str, object] = {}
        self._hop_seq = 0    # sampling key for hop spans (deterministic)
        # -- async fabric state (DESIGN.md §10) --
        self.fabric_mode = fabric
        self.fabric = (AsyncFabric(latency=fabric_latency,
                                   page_beats=fabric_page_beats)
                       if fabric == "async" else None)
        self._pending_hops: List[FabricTicket] = []
        # Elastic mesh membership: resize flips these, ownership does not
        # move — an inactive shard's pages are evacuated, not re-owned.
        self.active: List[bool] = [True] * num_shards

    # -- instrumentation -----------------------------------------------------
    def attach_probe(self, probe: Optional[PerfProbe]) -> None:
        """One probe observes every shard (channel names collide by design:
        the probe's per-channel counters aggregate the mesh)."""
        for rt in self.shards:
            rt.attach_probe(probe)

    def attach_tracer(self, tracer: Optional[Tracer]) -> None:
        """Attach (or with None, detach) a lifecycle span tracer.

        Every shard's runtime gets the same tracer under a ``shard{i}/``
        track prefix, so an exported timeline shows one track group per
        shard; migration hops additionally emit egress/fabric/ingress
        spans linked by Perfetto flow arrows (DESIGN.md §8).
        """
        self.tracer = tracer
        for s, rt in enumerate(self.shards):
            rt.attach_tracer(tracer, track_prefix=f"shard{s}/")

    @contextlib.contextmanager
    def trace_context(self, **args):
        """Parent subsequent hop spans to a logical originator.

        The serve router wraps remote-page pull-ins in
        ``trace_context(uid=...)`` so every egress/fabric/ingress span of
        the resulting hops carries the originating request id.
        """
        prev = self._trace_args
        self._trace_args = {**prev, **args}
        try:
            yield
        finally:
            self._trace_args = prev

    # -- pools ---------------------------------------------------------------
    def _place(self, shard: int, array: jax.Array) -> jax.Array:
        if self._devices is None:
            return array
        return jax.device_put(array, self._devices[shard])

    def _pad(self, array: jax.Array) -> jax.Array:
        """Append ``max_len`` of tail padding to a flat pool.

        ``execute_serial`` copies through static ``max_len``-sized masked
        windows whose start offsets XLA *clamps* into bounds — a window
        starting within ``max_len`` of the pool end would silently land at
        the clamped offset. Tail padding guarantees every in-bounds
        descriptor's window fits, so no start is ever clamped.
        """
        return jnp.concatenate(
            [array, jnp.zeros(self.max_len, array.dtype)])

    def register_sharded_pool(self, name: str, array: jax.Array,
                              owner: PageOwnerMap, row_elems: int) -> None:
        """Split a flat global row pool into per-shard slices.

        ``array`` has ``owner.num_pages * row_elems`` elements; shard ``s``
        receives the slice covering its pages, device-placed when meshed.
        """
        if name == self.STAGE_POOL or \
                name.startswith(self.STAGE_POOL + "."):
            raise ValueError(
                f"pool name {name!r} is reserved for the migration "
                "planner's staging buffers")
        array = jnp.asarray(array)
        if array.ndim != 1 or array.shape[0] != owner.num_pages * row_elems:
            raise ValueError(
                f"pool {name!r}: expected flat "
                f"({owner.num_pages * row_elems},) array, "
                f"got shape {array.shape}")
        if owner.num_shards != self.num_shards:
            raise ValueError("owner map shard count mismatch")
        per = owner.pages_per_shard * row_elems
        for s, rt in enumerate(self.shards):
            rt.register_pool(name, self._place(
                s, self._pad(array[s * per:(s + 1) * per])))
        self._sharded_pools[name] = owner
        self._row_elems[name] = row_elems
        self._pool_elems[name] = per

    def pool_shard(self, name: str, shard: int) -> jax.Array:
        """A shard's logical pool slice (padding stripped)."""
        return self.shards[shard].pool(name)[:self._pool_elems[name]]

    def gather_pool(self, name: str) -> np.ndarray:
        """The global flat pool, reassembled host-side in page order."""
        return np.concatenate([np.asarray(self.pool_shard(name, s))
                               for s in range(self.num_shards)])

    # -- migration planner ---------------------------------------------------
    def migrate_rows(
        self,
        pool_names: Sequence[str],
        src_pages: Sequence[int],
        dst_pages: Sequence[int],
        *,
        drain: bool = True,
        priority: int = 0,
    ) -> MigrationStats:
        """Lower page moves into descriptor chains across the mesh.

        All named pools move in lockstep under one plan (the paged-KV K/V
        pair). Local moves go straight onto the owner shard's serial
        channels; cross-shard moves become per-(src, dst)-shard hops:
        egress gather chain -> fabric -> ingress scatter chain, with the
        hop's completion control descriptor written back (§II-D) on the
        destination shard only after the ingress chain drained.

        Under the async fabric (the default), hops are non-blocking
        :class:`repro.distributed.fabric.FabricTicket` objects: the
        local-gather half issues immediately and the remote-scatter half
        completes when the fabric delivers, with shard drains overlapping
        in-flight hops via :meth:`pump`. ``drain=True`` pumps the plan to
        completion before returning; ``drain=False`` leaves the tickets
        outstanding for the caller to :meth:`pump` (hop_completions then
        lands on both the returned stats and the mesh aggregate as hops
        retire). ``priority`` rides the channels' weighted arbitration —
        the rebalancer submits at 0 so it never preempts serve traffic.
        The synchronous fabric (``fabric="sync"``) ignores ``priority``
        and executes hops exactly as PR 8 did.
        """
        if len(src_pages) != len(dst_pages):
            raise ValueError("src/dst page lists must pair up")
        stats = MigrationStats()
        if not src_pages:
            return stats
        if not pool_names:
            raise ValueError("need at least one pool to migrate")
        owner = self._sharded_pools[pool_names[0]]
        for name in pool_names:
            if self._sharded_pools.get(name) != owner:
                raise ValueError(
                    f"pool {name!r} is not sharded under the same owner map")

        src = np.asarray(src_pages, np.int64)
        dst = np.asarray(dst_pages, np.int64)
        # Hops execute grouped by shard pair, not in plan order, and even
        # one in-order chain clobbers serially — a destination that is
        # also a source (or a doubly-written destination) is ambiguous.
        # Every real caller (defrag, remote-read pull-in) moves onto free
        # pages, so reject overlap loudly instead of corrupting quietly.
        if len(set(dst.tolist())) != len(dst):
            raise ValueError("duplicate destination pages in migration plan")
        overlap = set(src.tolist()) & set(dst.tolist())
        if overlap:
            raise ValueError(
                f"migration plan reads and writes pages {sorted(overlap)}; "
                "stage through free pages instead")
        stats.pages = len(src)
        s_owner = src // owner.pages_per_shard
        d_owner = dst // owner.pages_per_shard
        src_local = src % owner.pages_per_shard
        dst_local = dst % owner.pages_per_shard

        # Group moves by (src_shard, dst_shard), preserving plan order so
        # contiguous page runs survive into the chains the coalescer sees.
        groups: Dict[Tuple[int, int], List[int]] = {}
        for k in range(len(src)):
            groups.setdefault((int(s_owner[k]), int(d_owner[k])),
                              []).append(k)

        sync = self.fabric_mode == "sync"
        for (ss, ds), idx in sorted(groups.items()):
            rows_s = src_local[idx]
            rows_d = dst_local[idx]
            if ss == ds:
                stats.local_pages += len(idx)
                if sync:
                    self._submit_local(pool_names, ss, rows_s, rows_d,
                                       stats)
                else:
                    self._submit_local_async(pool_names, ss, rows_s,
                                             rows_d, stats, priority)
            else:
                stats.cross_pages += len(idx)
                stats.hops += 1
                if sync:
                    self._submit_hop(pool_names, ss, ds, rows_s, rows_d,
                                     stats)
                else:
                    self._begin_hop(pool_names, ss, ds, rows_s, rows_d,
                                    stats, priority)
        if not sync and drain:
            self.pump_until_idle()
        if drain:
            self.drain_until_idle()
        self.migration.merge(stats)
        if not sync:
            # Hops left outstanding (drain=False) retire later inside
            # pump(); their writeback counts must land on the mesh
            # aggregate too, so mark this plan's stats as already merged.
            for t in self._pending_hops:
                if t.stats is stats:
                    t.merged = True
        return stats

    def _chain(self, rows_s: np.ndarray, rows_d: np.ndarray,
               row_elems: int):
        return from_segments(rows_s * row_elems, rows_d * row_elems,
                             np.full(len(rows_s), row_elems, np.int64))

    def _submit_local(self, pool_names, shard, rows_s, rows_d, stats):
        rt = self.shards[shard]
        for name in pool_names:
            d = self._chain(rows_s, rows_d, self._row_elems[name])
            res = rt.submit(SubmitRequest(
                chain=d, src_pool=name, dst_pool=name, tier="serial"))
            if res.coalesce is not None:
                stats.chain_in += res.coalesce.n_in
                stats.chain_out += res.coalesce.n_out
        rt.drain_until_idle()

    def _submit_hop(self, pool_names, src_shard, dst_shard,
                    rows_s, rows_d, stats):
        src_rt = self.shards[src_shard]
        dst_rt = self.shards[dst_shard]
        n = len(rows_s)
        ctrl = dst_rt.submit_control(payload=src_shard,
                                     channel="completion")
        # One flow arrow per hop (egress -> fabric -> ingress), sampled on
        # the process-deterministic hop ordinal; the spans carry whatever
        # the active trace_context says originated this hop (request uid).
        tr = self.tracer
        self._hop_seq += 1
        rec = tr is not None and tr.sampled(("hop", self._hop_seq))
        fid = tr.next_flow_id() if rec else 0
        hop_args = dict(self._trace_args, src_shard=src_shard,
                        dst_shard=dst_shard, pages=n) if rec else {}
        first_pool = pool_names[0]
        for name in pool_names:
            row_elems = self._row_elems[name]
            stage_rows = np.arange(n, dtype=np.int64)
            # Egress: gather the moving pages into a dense staging buffer
            # on the source shard (the fabric's send window).
            t0 = monotonic() if rec else 0.0
            src_rt.register_pool(
                self.STAGE_POOL,
                self._place(src_shard, self._pad(jnp.zeros(
                    n * row_elems, src_rt.pool(name).dtype))))
            d_out = self._chain(rows_s, stage_rows, row_elems)
            res = src_rt.submit(SubmitRequest(
                chain=d_out, src_pool=name, dst_pool=self.STAGE_POOL,
                tier="serial"))
            if res.coalesce is not None:
                stats.chain_in += res.coalesce.n_in
                stats.chain_out += res.coalesce.n_out
            src_rt.drain_until_idle()
            t1 = monotonic() if rec else 0.0
            if rec:
                track = f"shard{src_shard}/migrate"
                tr.complete("migrate.egress", track, t0 * 1e6,
                            (t1 - t0) * 1e6, pool=name, **hop_args)
                if name == first_pool:
                    # Flow start binds to the egress slice just emitted.
                    tr.flow_start("hop", track, fid, ts=t1 * 1e6 - 1e-3)
            # Fabric transfer: the staging buffer crosses to the
            # destination shard's device.
            stage = self._place(dst_shard, src_rt.pool(self.STAGE_POOL))
            dst_rt.register_pool(self.STAGE_POOL, stage)
            t2 = monotonic() if rec else 0.0
            if rec:
                tr.complete("migrate.fabric", "fabric", t1 * 1e6,
                            (t2 - t1) * 1e6, pool=name, **hop_args)
                if name == first_pool:
                    tr.flow_step("hop", "fabric", fid, ts=t2 * 1e6 - 1e-3)
            # Ingress: scatter staging rows onto the destination pages.
            d_in = self._chain(stage_rows, rows_d, row_elems)
            res = dst_rt.submit(SubmitRequest(
                chain=d_in, src_pool=self.STAGE_POOL, dst_pool=name,
                tier="serial"))
            if res.coalesce is not None:
                stats.chain_in += res.coalesce.n_in
                stats.chain_out += res.coalesce.n_out
            dst_rt.drain_until_idle()
            if rec:
                t3 = monotonic()
                track = f"shard{dst_shard}/migrate"
                tr.complete("migrate.ingress", track, t2 * 1e6,
                            (t3 - t2) * 1e6, pool=name, **hop_args)
                if name == first_pool:
                    tr.flow_end("hop", track, fid, ts=t3 * 1e6 - 1e-3)
        # Per-hop completion: only after every pool's ingress chain
        # drained does the hop's control descriptor get its §II-D
        # writeback. It is observed via the non-destructive ring table
        # scan (the serve scheduler's poll): draining the shared
        # completion queue here would steal other owners' events — a
        # ServeEngine on this shard polls the same queue.
        dst_rt.complete(ctrl.tickets[-1])
        ring = dst_rt.channels["completion"].ring
        stats.hop_completions += int(
            ctrl.tickets[-1] in ring.live_done_tickets())
        # The staging buffer is planner-internal scratch: drop it so pool
        # enumerations (stats, gather, serialization) never see hop state.
        src_rt.pools.pop(self.STAGE_POOL, None)
        dst_rt.pools.pop(self.STAGE_POOL, None)

    # -- async fabric (DESIGN.md §10) ----------------------------------------
    def _stage_name(self, hop_id: int, pool: str) -> str:
        """Per-(hop, pool) staging buffer name: concurrent in-flight hops
        on one shard must not clobber each other's send windows."""
        return f"{self.STAGE_POOL}.{hop_id}.{pool}"

    def _submit_local_async(self, pool_names, shard, rows_s, rows_d,
                            stats, priority):
        # Same chains as the sync path, but no drain here: local batches
        # drain inside pump() rounds, overlapping with in-flight hops.
        rt = self.shards[shard]
        for name in pool_names:
            d = self._chain(rows_s, rows_d, self._row_elems[name])
            res = rt.submit(SubmitRequest(
                chain=d, src_pool=name, dst_pool=name, tier="serial",
                priority=priority))
            if res.coalesce is not None:
                stats.chain_in += res.coalesce.n_in
                stats.chain_out += res.coalesce.n_out

    def _begin_hop(self, pool_names, src_shard, dst_shard, rows_s, rows_d,
                   stats, priority) -> FabricTicket:
        """Issue the local-gather half of a hop and ticket the rest.

        The egress gather chains go onto the source shard's serial
        channels *without* draining; the control descriptor is posted on
        the destination up front (its §II-D writeback still only fires
        at :meth:`_finish_hop`, after every ingress chain drained)."""
        src_rt = self.shards[src_shard]
        dst_rt = self.shards[dst_shard]
        n = len(rows_s)
        ctrl = dst_rt.submit_control(payload=src_shard,
                                     channel="completion")
        tr = self.tracer
        self._hop_seq += 1
        rec = tr is not None and tr.sampled(("hop", self._hop_seq))
        t = FabricTicket(
            hop_id=self._hop_seq, src_shard=src_shard, dst_shard=dst_shard,
            pages=n, pool_names=tuple(pool_names),
            rows_s=np.asarray(rows_s, np.int64),
            rows_d=np.asarray(rows_d, np.int64),
            ctrl_ticket=ctrl.tickets[-1], stats=stats, priority=priority,
            issued_round=self.fabric.now, rec=rec,
            flow_id=tr.next_flow_id() if rec else 0,
            trace_args=(dict(self._trace_args, src_shard=src_shard,
                             dst_shard=dst_shard, pages=n) if rec else {}),
            t0=monotonic() if rec else 0.0)
        stage_rows = np.arange(n, dtype=np.int64)
        for name in pool_names:
            row_elems = self._row_elems[name]
            stage = self._stage_name(t.hop_id, name)
            src_rt.register_pool(stage, self._place(
                src_shard, self._pad(jnp.zeros(
                    n * row_elems, src_rt.pool(name).dtype))))
            d_out = self._chain(rows_s, stage_rows, row_elems)
            res = src_rt.submit(SubmitRequest(
                chain=d_out, src_pool=name, dst_pool=stage, tier="serial",
                priority=priority))
            if res.coalesce is not None:
                stats.chain_in += res.coalesce.n_in
                stats.chain_out += res.coalesce.n_out
            t.egress.append((name, res.channel, frozenset(res.tickets)))
        self._pending_hops.append(t)
        return t

    @staticmethod
    def _chains_pending(rt: DMARuntime, entries) -> bool:
        """Whether any of a hop's submitted chains still await drain.

        A data chain is done exactly when none of its tickets sit in a
        pending ring batch (or the spill queue) any more — ``drain_one``
        marks the slots done and retires them in the same step, so batch
        membership is the drain-state signal. The completion queue is
        deliberately *not* polled: its events belong to the serve
        scheduler (see the sync hop's writeback comment)."""
        for _, channel, tset in entries:
            for b in rt.channels[channel].pending:
                if tset.intersection(b.tickets):
                    return True
        for sp in rt._spill:
            for _, _, tset in entries:
                if tset.intersection(sp.tickets):
                    return True
        return False

    def _hop_stat(self, t: FabricTicket, **deltas) -> None:
        """Bump a hop's plan stats; mirror onto the mesh aggregate when
        the plan was already merged (drain=False plans retire late)."""
        for k, v in deltas.items():
            setattr(t.stats, k, getattr(t.stats, k) + v)
            if t.merged:
                setattr(self.migration, k, getattr(self.migration, k) + v)

    def _send_hop(self, t: FabricTicket) -> None:
        """Egress drained: capture the staging buffers onto the
        destination device and put the payload on the fabric link."""
        src_rt = self.shards[t.src_shard]
        tr = self.tracer
        if t.rec:
            t.t1 = monotonic()
            track = f"shard{t.src_shard}/migrate"
            tr.complete("migrate.egress", track, t.t0 * 1e6,
                        (t.t1 - t.t0) * 1e6, **t.trace_args)
            tr.flow_start("hop", track, t.flow_id, ts=t.t1 * 1e6 - 1e-3)
        for name in t.pool_names:
            stage = self._stage_name(t.hop_id, name)
            t.staged[name] = self._place(t.dst_shard, src_rt.pool(stage))
            src_rt.pools.pop(stage, None)
        self.fabric.send(t)
        if t.rec:
            ln = self.fabric.link(t.src_shard, t.dst_shard)
            tr.counter(f"fabric.link{t.src_shard}-{t.dst_shard}", "fabric",
                       occupancy_rounds=max(0, ln.busy_until -
                                            self.fabric.now),
                       pages_in_flight=t.pages)

    def _submit_ingress(self, t: FabricTicket) -> None:
        """Fabric delivered: issue the remote-scatter half on the
        destination shard (completes via the §II-D writeback)."""
        dst_rt = self.shards[t.dst_shard]
        tr = self.tracer
        if t.rec:
            t.t2 = monotonic()
            tr.complete("migrate.fabric", "fabric", t.t1 * 1e6,
                        (t.t2 - t.t1) * 1e6, sent_round=t.sent_round,
                        deliver_round=t.deliver_round, **t.trace_args)
            tr.flow_step("hop", "fabric", t.flow_id, ts=t.t2 * 1e6 - 1e-3)
            ln = self.fabric.link(t.src_shard, t.dst_shard)
            tr.counter(f"fabric.link{t.src_shard}-{t.dst_shard}", "fabric",
                       occupancy_rounds=max(0, ln.busy_until -
                                            self.fabric.now),
                       pages_in_flight=0)
        stage_rows = np.arange(t.pages, dtype=np.int64)
        for name in t.pool_names:
            stage = self._stage_name(t.hop_id, name)
            dst_rt.register_pool(stage, t.staged.pop(name))
            d_in = self._chain(stage_rows, t.rows_d,
                               self._row_elems[name])
            res = dst_rt.submit(SubmitRequest(
                chain=d_in, src_pool=stage, dst_pool=name, tier="serial",
                priority=t.priority))
            if res.coalesce is not None:
                self._hop_stat(t, chain_in=res.coalesce.n_in,
                               chain_out=res.coalesce.n_out)
            t.ingress.append((name, res.channel, frozenset(res.tickets)))

    def _finish_hop(self, t: FabricTicket) -> None:
        """Ingress drained: observe the hop's §II-D writeback and drop
        the staging pools (non-destructive ring scan, never a queue
        poll — the completion queue belongs to the serve scheduler)."""
        dst_rt = self.shards[t.dst_shard]
        dst_rt.complete(t.ctrl_ticket)
        ring = dst_rt.channels["completion"].ring
        self._hop_stat(t, hop_completions=int(
            t.ctrl_ticket in ring.live_done_tickets()))
        for name in t.pool_names:
            dst_rt.pools.pop(self._stage_name(t.hop_id, name), None)
        t.state = COMPLETED
        t.completed_round = self.fabric.now
        if t.rec:
            t3 = monotonic()
            track = f"shard{t.dst_shard}/migrate"
            self.tracer.complete("migrate.ingress", track, t.t2 * 1e6,
                                 (t3 - t.t2) * 1e6, **t.trace_args)
            self.tracer.flow_end("hop", track, t.flow_id,
                                 ts=t3 * 1e6 - 1e-3)

    def _pump_round(self) -> int:
        """One fabric round: drain every active shard once, tick the
        clock, then move tickets through their lifecycle edges."""
        fab = self.fabric
        progress = 0
        for s, rt in enumerate(self.shards):
            if self.active[s]:
                progress += rt.drain_all()
        fab.advance()
        # Higher-priority tickets claim link slots first each round, so a
        # background handoff (priority 0) queued behind foreground serve
        # migration (priority 1) cannot capture a link ahead of it.
        ready = [t for t in self._pending_hops
                 if t.state == EGRESS and not self._chains_pending(
                     self.shards[t.src_shard], t.egress)]
        for t in sorted(ready, key=lambda t: (-t.priority, t.hop_id)):
            self._send_hop(t)
        for t in fab.deliveries():
            self._submit_ingress(t)
        finished = False
        for t in self._pending_hops:
            if t.state == INGRESS and not self._chains_pending(
                    self.shards[t.dst_shard], t.ingress):
                self._finish_hop(t)
                finished = True
        if finished:
            self._pending_hops = [t for t in self._pending_hops
                                  if t.state != COMPLETED]
        # Overlap accounting: a round counts as in-flight when a payload
        # is on the wire, and as hidden when local drains made progress
        # under it. Global only — rounds are mesh-wide, not per-plan.
        if fab.in_flight:
            self.migration.fabric_inflight_rounds += 1
            if progress:
                self.migration.fabric_hidden_rounds += 1
            for t in fab.in_flight:
                t.inflight_rounds += 1
                if progress:
                    t.hidden_rounds += 1
        return progress

    def fabric_outstanding(self) -> int:
        """Hops ticketed but not yet completed (async fabric)."""
        return len(self._pending_hops)

    def plan_outstanding(self, stats: MigrationStats) -> int:
        """Hops of one ``migrate_rows`` plan still on the fabric — lets a
        caller pump a foreground plan to completion while background
        traffic (rebalance, resize handoff) keeps flowing."""
        return sum(1 for t in self._pending_hops if t.stats is stats)

    def pump(self, rounds: int = 1) -> int:
        """Advance the async fabric by up to ``rounds`` rounds; returns
        batches drained. Stops early once no hop is outstanding."""
        if self.fabric_mode != "async":
            raise RuntimeError("pump() requires fabric='async'")
        drained = 0
        for _ in range(rounds):
            if not self._pending_hops:
                break
            drained += self._pump_round()
        return drained

    def pump_until_idle(self, max_rounds: int = 65536) -> None:
        """Run the pump until every outstanding hop completed."""
        if self.fabric_mode != "async":
            return
        for _ in range(max_rounds):
            if not self._pending_hops:
                return
            self._pump_round()
        raise RuntimeError(
            f"async fabric did not quiesce in {max_rounds} rounds "
            f"({len(self._pending_hops)} hops outstanding)")

    # -- elastic mesh membership ---------------------------------------------
    def set_active(self, shard: int, active: bool = True) -> None:
        """Flip a shard's mesh membership (resize). Ownership is static;
        an inactive shard's pages must have been evacuated first
        (``ShardedKVPool.evacuate`` / ``fault.ungraceful_resize``)."""
        self.active[shard] = bool(active)

    def active_shards(self) -> List[int]:
        return [s for s in range(self.num_shards) if self.active[s]]

    # -- drain / stats -------------------------------------------------------
    def drain_all(self) -> int:
        return sum(rt.drain_all()
                   for s, rt in enumerate(self.shards) if self.active[s])

    def drain_until_idle(self, max_rounds: int = 1024) -> None:
        if self._pending_hops:
            self.pump_until_idle()
        for s, rt in enumerate(self.shards):
            if self.active[s]:
                rt.drain_until_idle(max_rounds)

    def _translation_stats_raw(self) -> Dict[str, object]:
        """Bare-key mesh aggregate (summed over shards' raw blocks)."""
        from repro.runtime.lowering import aggregate_stats
        return aggregate_stats(
            [rt._translation_stats_raw() for rt in self.shards])

    def translation_stats(self) -> PerfCounters:
        """Mesh-wide translation-cache counters (``translation.*`` keys)."""
        return namespaced(self._translation_stats_raw(), "translation")

    def stats(self) -> Dict[str, object]:
        out = {
            "num_shards": self.num_shards,
            "active_shards": self.active_shards(),
            "migration": dataclasses.asdict(self.migration),
            "migration_chain_merge_ratio": self.migration.merge_ratio,
            "migration_overlap_ratio": self.migration.overlap_ratio,
            "translation_cache": self.translation_stats(),
            "shards": [rt.stats() for rt in self.shards],
        }
        if self.fabric is not None:
            out["fabric"] = {
                "rounds": self.fabric.now,
                "outstanding_hops": len(self._pending_hops),
                "links": self.fabric.link_stats(),
            }
        return out


class ShardedKVPool:
    """Paged K/V pool partitioned across a sharded runtime's shards.

    Flat element-space pools (one K, one V) so migration chains run on the
    serial tier and the runtime coalescer genuinely merges contiguous page
    runs — the source of ``migration_chain_merge_ratio``. Page allocation
    is shard-aware: :meth:`alloc_on` hands out pages *owned by* a given
    shard, which is how the serve router keeps a request's pages local.

    Virtual addressing (DESIGN.md §11): callers hold :class:`PageRef`
    handles naming *virtual* pages; a :class:`repro.mmu.PageTable` maps
    them to (shard, physical slot). Two consequences:

    * ``defragment(mode="remap")`` renumbers live pages onto dense
      virtual ids without moving a byte (the §II-C speculator sees a
      sequential virtual chain);
    * :meth:`flip_ownership` moves a page's *owner* immediately and
      leaves the contents behind — the first touch (:meth:`ensure_resident`,
      called by every contents accessor) pulls them lazily through the
      normal migration path. Static ``owner`` still partitions *slots*;
      the table partitions *pages*.
    """

    POOL_K = "kv.k"
    POOL_V = "kv.v"

    def __init__(self, runtime: ShardedDMARuntime, *, num_pages: int,
                 page: int, kv_heads: int, head_dim: int,
                 dtype=jnp.float32):
        self.rt = runtime
        self.page, self.kv_heads, self.head_dim = page, kv_heads, head_dim
        self.row_elems = page * kv_heads * head_dim
        self.owner = PageOwnerMap(num_pages, runtime.num_shards)
        flat = jnp.zeros(num_pages * self.row_elems, dtype)
        runtime.register_sharded_pool(self.POOL_K, flat, self.owner,
                                      self.row_elems)
        runtime.register_sharded_pool(self.POOL_V, flat, self.owner,
                                      self.row_elems)
        self._free: List[List[int]] = [
            sorted(self.owner.shard_pages(s))
            for s in range(runtime.num_shards)]
        # Virtual layer: vpage -> (shard, slot), plus which vids are
        # handed out. Identity until the first remap/flip, so legacy
        # int-addressed flows are bit-for-bit unchanged.
        self.table = PageTable(num_pages, runtime.num_shards)
        self._vused = np.zeros(num_pages, bool)
        self.first_touch_pulls = 0

    # -- allocation ----------------------------------------------------------
    def free_pages_on(self, shard: int) -> int:
        return len(self._free[shard])

    def refs(self, pages: Sequence[int]) -> List[PageRef]:
        """Mint :class:`PageRef` handles for virtual ids (the blessed
        conversion for internal code that computes ids numerically —
        bare ints through the public APIs are deprecated)."""
        return [PageRef(int(p), self.table.page_generation(int(p)))
                for p in pages]

    def owner_of(self, page) -> int:
        """Current owning shard of a virtual page (page-table truth —
        unlike ``owner.owner``, this follows :meth:`flip_ownership`)."""
        return self.table.shard_of(int(page))

    def _claim_vid(self, phys: int) -> PageRef:
        """Claim a virtual id for physical slot ``phys``: identity when
        the identity vid is free, else the lowest unused vid (remapped)."""
        shard = self.owner.owner(phys)
        vid = phys if not self._vused[phys] else int(
            np.flatnonzero(~self._vused)[0])
        self._vused[vid] = True
        if self.table.map(vid) != (shard, phys):
            self.table.remap(vid, shard, phys)
        return PageRef(vid, self.table.page_generation(vid))

    def alloc_on(self, shard: int, n: int) -> List[PageRef]:
        """Lowest-id free pages owned by ``shard`` (sequential preference:
        consecutive ids keep the §II-C speculator hitting)."""
        if not self.rt.active[shard]:
            raise RuntimeError(
                f"shard {shard} left the mesh; its pages are evacuated")
        free = self._free[shard]
        if n > len(free):
            raise RuntimeError(
                f"shard {shard}: need {n} pages, have {len(free)}")
        phys, self._free[shard] = free[:n], free[n:]
        return [self._claim_vid(p) for p in phys]

    def release(self, pages: Sequence[int]) -> None:
        refs = as_pagerefs(pages, api="ShardedKVPool.release")
        touched = set()
        for r in refs:
            v = int(r)
            s, slot = self.table.home_of(v)
            if self.table.is_pending(v):
                # Freeing an unpulled page drops the flip: the contents'
                # home slot is what actually returns to a free list.
                self.table.remap(v, s, slot)
            self._free[s].append(int(slot))
            self._vused[v] = False
            touched.add(s)
        for s in touched:
            self._free[s].sort()

    # -- translation / residency ---------------------------------------------
    def _locate(self, vpage: int) -> Tuple[int, int]:
        """(shard, slot) for a *resident* virtual page."""
        self.ensure_resident([vpage])
        return self.table.map(int(vpage))

    def ensure_resident(self, pages: Sequence[int], *,
                        priority: int = 0) -> int:
        """First-touch pull: materialize any ownership-flipped pages on
        their (new) owner through the normal migration path, then free
        the vacated home slots. Returns the number of pages pulled.

        This is the lazy half of ownership-first migration: a flip is a
        table write; the bytes only move when someone touches the page.
        Each pull is a single-page migration, so the first-touch cost is
        bounded by one page's hop latency — not the full batch.
        """
        pending = list(dict.fromkeys(
            int(p) for p in pages if self.table.is_pending(int(p))))
        if not pending:
            return 0
        moves = []
        for v in pending:
            hs, hslot = self.table.home_of(v)
            dshard = self.table.shard_of(v)
            free = self._free[dshard]
            if not free:
                raise RuntimeError(
                    f"shard {dshard}: no free slot to pull vpage {v} into")
            moves.append((v, hs, hslot, free.pop(0)))
        self.rt.migrate_rows(
            (self.POOL_K, self.POOL_V),
            [m[2] for m in moves], [m[3] for m in moves],
            priority=priority)
        for v, hs, hslot, slot in moves:
            self.table.complete_pull(v, slot)
            self._free[hs].append(hslot)
        for hs in {m[1] for m in moves}:
            self._free[hs].sort()
        self.first_touch_pulls += len(moves)
        return len(moves)

    def flip_ownership(self, pages: Sequence[int],
                       shard: int) -> List[PageRef]:
        """Ownership-first migration: the pages belong to ``shard`` *now*
        (routing, admission, and ``owner_of`` all see the flip
        immediately); their contents stay put until first touch. Returns
        refreshed refs (the flip bumps each page's generation)."""
        if not self.rt.active[shard]:
            raise RuntimeError(f"shard {shard} is not in the mesh")
        refs = as_pagerefs(pages, api="ShardedKVPool.flip_ownership")
        for r in refs:
            v = int(r)
            if self.table.shard_of(v) != int(shard):
                self.table.flip_owner(v, int(shard))
        return self.refs(refs)

    # -- contents (host-side oracle / writers) -------------------------------
    def write_page(self, page: int, k_row: np.ndarray,
                   v_row: np.ndarray) -> None:
        (ref,) = as_pagerefs([page], api="ShardedKVPool.write_page")
        s, slot = self._locate(int(ref))
        lo = self.owner.local_row(slot) * self.row_elems
        rt = self.rt.shards[s]
        for name, row in ((self.POOL_K, k_row), (self.POOL_V, v_row)):
            arr = rt.pool(name)
            rt.register_pool(name, arr.at[lo:lo + self.row_elems].set(
                jnp.asarray(row, arr.dtype).reshape(-1)))

    def page_rows(self, pages: Sequence[int]) -> Tuple[np.ndarray, np.ndarray]:
        """(K, V) rows for ``pages``, gathered host-side (test oracle)."""
        refs = as_pagerefs(pages, api="ShardedKVPool.page_rows")
        self.ensure_resident(refs)
        ks, vs = [], []
        for p in refs:
            s, slot = self.table.map(int(p))
            lo = self.owner.local_row(slot) * self.row_elems
            ks.append(np.asarray(
                self.rt.pool_shard(self.POOL_K, s)[lo:lo + self.row_elems]))
            vs.append(np.asarray(
                self.rt.pool_shard(self.POOL_V, s)[lo:lo + self.row_elems]))
        return (np.stack(ks) if ks else np.zeros((0, self.row_elems)),
                np.stack(vs) if vs else np.zeros((0, self.row_elems)))

    # -- runtime-mediated movement (DESIGN.md §6) ----------------------------
    def move_pages(self, src_pages: Sequence[int],
                   dst_pages: Sequence[int], *,
                   priority: int = 0,
                   drain: bool = True) -> MigrationStats:
        """Relocate page *contents* between virtual pages through the
        sharded runtime: local moves stay on the owner's channels,
        cross-owner moves become hops. Pages are addressed physically
        via the page table (pending pages are pulled resident first)."""
        src = as_pagerefs(src_pages, api="ShardedKVPool.move_pages")
        dst = as_pagerefs(dst_pages, api="ShardedKVPool.move_pages")
        self.ensure_resident(list(src) + list(dst), priority=priority)
        return self.rt.migrate_rows(
            (self.POOL_K, self.POOL_V),
            [self.table.slot_of(int(p)) for p in src],
            [self.table.slot_of(int(p)) for p in dst],
            priority=priority, drain=drain)

    # -- elastic mesh resize (DESIGN.md §10) ---------------------------------
    def evacuate(self, shard: int, *, planner=None, priority: int = 0,
                 exclude: Sequence[int] = ()) -> Dict[int, int]:
        """Graceful leave: hand the shard's live pages to survivors.

        The handoff lowers through :meth:`RebalancePlanner.placement`
        (free-capacity-weighted spread over the surviving shards) and
        rides the normal migration path at the given priority; the shard
        then goes inactive and its free list empties. Returns the
        ``{old_page: new_page}`` remap — the caller owns rewriting any
        references (serve request page lists) to the vacated pages.
        """
        srt = self.rt
        survivors = [s for s in srt.active_shards() if s != shard]
        if not survivors:
            raise RuntimeError("cannot evacuate the last active shard")
        banned = set(int(p) for p in exclude)
        live = sorted(set(self.owner.shard_pages(shard))
                      - set(self._free[shard]) - banned)
        if planner is None:
            planner = RebalancePlanner(srt.num_shards)
        new = planner.placement(self, live, survivors)
        if live:
            srt.migrate_rows((self.POOL_K, self.POOL_V), live, new,
                             priority=priority)
            # The page table follows the physical relocation, so every
            # PageRef naming an evacuated slot stays valid across the
            # resize (pending pages' pull homes follow too).
            self.table.rehome_slots(
                {o: (self.owner.owner(nw), nw)
                 for o, nw in zip(live, new)})
        self._free[shard] = []
        srt.set_active(shard, False)
        return dict(zip(live, new))

    def readmit(self, shard: int) -> None:
        """Rejoin after a leave: the shard comes back empty — evacuation
        moved every live page off, so its whole owned block is free."""
        self.rt.set_active(shard, True)
        self._free[shard] = sorted(self.owner.shard_pages(shard))

    def defragment(self, pages: Sequence[int], *,
                   mode: str = "remap") -> Tuple[List[PageRef],
                                                 MigrationStats,
                                                 float]:
        """Compact a page list onto the lowest free ids (possibly on other
        shards) and return ``(new_pages, stats, new_hit_rate)``.

        ``mode="remap"`` (default): the live pages keep their physical
        slots and are *renumbered* onto dense virtual ids — page-table
        writes only, no descriptor chain, empty ``MigrationStats``.
        ``mode="copy"`` is the legacy physical compaction (descriptor
        work through the runtime; the freed source slots return to their
        owners' free lists). Both modes leave identical logical contents
        under the returned refs — the ``tests/test_mmu.py`` oracle.
        """
        if mode not in ("remap", "copy"):
            raise ValueError(f"mode must be 'remap' or 'copy', got {mode!r}")
        refs = as_pagerefs(pages, api="ShardedKVPool.defragment")
        n = len(refs)
        if n == 0:
            return [], MigrationStats(), 1.0
        self.ensure_resident(refs)
        free_all = sorted(p for free in self._free for p in free)
        if mode == "remap":
            # Dense virtual ids: lowest free-slot ids whose vids are
            # unclaimed (identical to the copy-mode ids while the table
            # is identity), topped up from the unclaimed-vid pool.
            cand = [p for p in free_all if not self._vused[p]]
            if len(cand) < n:
                have = set(cand)
                cand += [int(v) for v in np.flatnonzero(~self._vused)
                         if int(v) not in have]
            if len(cand) < n:
                raise RuntimeError(f"defragment: need {n} free virtual "
                                   f"ids, have {len(cand)}")
            new = cand[:n]
            for nv, ov in zip(new, refs):
                s, slot = self.table.map(int(ov))
                self.table.remap(nv, s, slot)
                self._vused[nv] = True
                self._vused[int(ov)] = False
            rate = estimate_hit_rate(np.asarray(new, np.int64) * 32)
            return self.refs(new), MigrationStats(), rate
        if len(free_all) < n:
            raise RuntimeError(f"defragment: need {n} free pages, "
                               f"have {len(free_all)}")
        new_phys = free_all[:n]
        for p in new_phys:
            self._free[self.owner.owner(p)].remove(p)
        stats = self.rt.migrate_rows(
            (self.POOL_K, self.POOL_V),
            [self.table.slot_of(int(ov)) for ov in refs], new_phys)
        self.release(refs)
        out = [self._claim_vid(p) for p in new_phys]
        rate = estimate_hit_rate(np.asarray([int(p) for p in out],
                                            np.int64) * 32)
        return out, stats, rate


class ShardedServeEngine:
    """Continuous-batching serving over a sharded runtime.

    One :class:`repro.serve.ServeEngine` per shard, each riding its
    shard's control channel for §II-D request completions. Admission is
    *ownership routing*: a request goes to the shard owning the majority
    of its KV pages (ties to the lowest shard; page-less requests
    round-robin by uid). Pages the winning shard does not own are
    migrated in first — the remote read becomes a migration chain — so by
    the time the request decodes, all of its pages are shard-local.
    """

    def __init__(self, params, cfg, *, runtime: ShardedDMARuntime,
                 kv_pool: Optional[ShardedKVPool] = None,
                 capacity: int = 2, max_len: int = 64, greedy: bool = True):
        from repro.serve import ServeEngine
        if kv_pool is not None and kv_pool.rt is not runtime:
            raise ValueError("kv_pool must live on the same sharded runtime")
        self.rt = runtime
        self.kv = kv_pool
        self.engines = [
            ServeEngine(params, cfg, capacity=capacity, max_len=max_len,
                        greedy=greedy, runtime=rt)
            for rt in runtime.shards]
        self.shard_of: Dict[int, int] = {}       # uid -> shard
        self.request_pages: Dict[int, List[int]] = {}
        self.requests_per_shard = [0] * runtime.num_shards
        self.remote_page_reads = 0
        self.migration = MigrationStats()
        # Pages may be shared across requests; a migrated-away source is
        # only freed once no admitted-but-undelivered request still reads
        # it (the migration copies contents, so earlier readers keep
        # valid data on the original page).
        self._page_refs: Dict[int, int] = {}
        self._deferred_free: set = set()
        self._unreffed: set = set()              # uids already decreffed

    # -- routing -------------------------------------------------------------
    def _route(self, uid: int, kv_pages: Optional[Sequence[int]]) -> int:
        if not kv_pages or self.kv is None:
            # No pages (or no pool to own them): deterministic round-robin.
            return uid % self.rt.num_shards
        counts = np.zeros(self.rt.num_shards, np.int64)
        for p in kv_pages:
            # Page-table truth: an ownership flip re-routes immediately,
            # before any byte of the page has moved.
            counts[self.kv.owner_of(p)] += 1
        return int(np.argmax(counts))   # argmax ties -> lowest shard

    def submit(self, req):
        """Admit a request to the shard owning its KV pages.

        Unified form: a :class:`~repro.runtime.SubmitRequest` whose
        ``request`` field is the serve ``Request``; returns a
        :class:`~repro.runtime.Ticket` with ``shard`` and ``uid`` set.
        The legacy positional-``Request`` form was removed one release
        after 0.4 and raises ``TypeError``. Remote pages are migrated
        into the owner first.
        """
        if not isinstance(req, SubmitRequest):
            reject_legacy_submit("ShardedServeEngine.submit", req)
        if req.request is None:
            raise ValueError(
                "ShardedServeEngine.submit needs SubmitRequest.request "
                "set to a serve Request")
        return self._admit(req.request, on_complete=req.on_complete)

    def _admit(self, req, on_complete=None) -> Ticket:
        kv_pages = list(getattr(req, "kv_pages", None) or [])
        if kv_pages and self.kv is not None:
            # Request.kv_pages is a PageRef surface; the shim coerces
            # bare ints (one DeprecationWarning per request).
            kv_pages = list(as_pagerefs(kv_pages, api="Request.kv_pages"))
        shard = self._route(req.uid, kv_pages)
        if kv_pages and self.kv is not None:
            # Dedupe: a page listed twice still migrates (and frees) once.
            remote = list(dict.fromkeys(
                p for p in kv_pages
                if self.kv.owner_of(p) != shard))
            if remote:
                new_local = self.kv.alloc_on(shard, len(remote))
                # Hop spans of this pull-in carry the originating request.
                with self.rt.trace_context(uid=req.uid):
                    stats = self.kv.move_pages(remote, new_local)
                # Counted only once the pull-in actually happened, so the
                # counter always matches the merged migration stats.
                self.remote_page_reads += len(remote)
                self.migration.merge(stats)
                # Free a migrated source only when no earlier live
                # request still references it; shared pages wait on the
                # deferred list until their last reader is delivered.
                shared = {p for p in remote
                          if self._page_refs.get(p, 0) > 0}
                self.kv.release([p for p in remote if p not in shared])
                self._deferred_free.update(shared)
                remap = dict(zip(remote, new_local))
                kv_pages = [remap.get(p, p) for p in kv_pages]
                if hasattr(req, "kv_pages"):
                    req.kv_pages = list(kv_pages)
        for p in set(kv_pages):
            self._page_refs[p] = self._page_refs.get(p, 0) + 1
        self.request_pages[req.uid] = kv_pages
        self.shard_of[req.uid] = shard
        self.requests_per_shard[shard] += 1
        t = self.engines[shard].submit(
            SubmitRequest(request=req, on_complete=on_complete))
        return dataclasses.replace(t, shard=shard)

    # -- stepping ------------------------------------------------------------
    def step(self) -> None:
        for eng in self.engines:
            eng.step()

    def run(self, max_steps: int = 1000) -> Dict[int, object]:
        for _ in range(max_steps):
            if not any(eng.queue or any(s.busy for s in eng.slots)
                       for eng in self.engines):
                break
            self.step()
        # Deliver through the poll path so page refcounts (and deferred
        # frees of migrated-away shared pages) always settle, whichever
        # API the caller drives.
        self.poll_completed()
        out: Dict[int, object] = {}
        for eng in self.engines:
            out.update(eng.completed)
        return out

    def poll_completed(self) -> List[object]:
        done: List[object] = []
        for eng in self.engines:
            done.extend(eng.poll_completed())
        for req in done:
            uid = req.uid
            if uid in self._unreffed:
                continue
            self._unreffed.add(uid)
            for p in set(self.request_pages.get(uid, [])):
                self._page_refs[p] = self._page_refs.get(p, 1) - 1
                if self._page_refs[p] <= 0 and p in self._deferred_free:
                    self._deferred_free.discard(p)
                    self.kv.release([p])
        return done

    # -- counters ------------------------------------------------------------
    def attach_probe(self, probe: Optional[PerfProbe]) -> None:
        for eng in self.engines:
            eng.attach_probe(probe)

    def attach_tracer(self, tracer: Optional[Tracer]) -> None:
        """One tracer observes the whole mesh: per-shard serve loops on
        ``shard{i}/serve`` tracks, runtimes under ``shard{i}/`` prefixes,
        migration hops via the sharded runtime's flow spans."""
        self.rt.attach_tracer(tracer)
        for s, eng in enumerate(self.engines):
            # The runtime tracks were already prefixed by rt.attach_tracer;
            # re-prefixing here is idempotent (same prefix, same names).
            eng.attach_tracer(tracer, track=f"shard{s}/serve",
                              track_prefix=f"shard{s}/")

    def request_latency_histogram(self) -> Histogram:
        """Mesh-wide request latency: per-shard histograms merged.

        The fixed bucket layout makes the merge plain element-wise count
        addition — associative, so shard order never matters (DESIGN.md §8).
        """
        merged = Histogram()
        for eng in self.engines:
            merged.merge(eng.request_latency)
        return merged

    def perf_counters(self) -> PerfCounters:
        """Mesh counters under the unified ``sharded.*`` namespace.

        Canonical keys are ``sharded.<field>`` plus a nested
        ``translation`` block; the old bare-key aliases were removed one
        release after 0.4 (DESIGN.md §9). Per-shard blocks under
        ``sharded.per_shard`` are ``serve.*``-namespaced.
        """
        per = [eng.perf_counters() for eng in self.engines]
        latency = self.request_latency_histogram()
        raw = {
            "num_shards": self.rt.num_shards,
            "requests_per_shard": list(self.requests_per_shard),
            "remote_page_reads": self.remote_page_reads,
            "migration": dataclasses.asdict(self.migration),
            # Virtual paging (DESIGN.md §11): lazy pulls landed after
            # ownership flips, plus the page table's mutation clock (any
            # remap/flip/pull bumps it — forensics for stale handles).
            "first_touch_pulls": self.kv.first_touch_pulls,
            "page_table_generation": self.kv.table.generation,
            "page_table_remaps": self.kv.table.remaps,
            "pending_pages": len(self.kv.table.pending_pages()),
            "steps": max(p["serve.steps"] for p in per),
            "completed": sum(p["serve.completed"] for p in per),
            "admission_stalls": sum(p["serve.admission_stalls"]
                                    for p in per),
            # Mesh-wide tail latency: per-shard histograms merged (steps
            # are scheduling outcomes, so these are seed-deterministic).
            "request_latency_steps_p50": latency.percentile(50),
            "request_latency_steps_p99": latency.percentile(99),
            "request_latency_steps": latency.snapshot(),
            "per_shard": per,
        }
        # Mesh-wide translation-cache counters: per-engine blocks are
        # in per_shard; this is their sum (DESIGN.md §7).
        return namespaced(
            raw, "sharded",
            extra={"translation": self.rt.translation_stats()})
