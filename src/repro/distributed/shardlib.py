"""Logical-axis sharding: models annotate tensors with *logical* axis names;
a per-launch rule table maps them to mesh axes (MaxText-style).

Models call ``shard(x, "batch", "seq", "heads", None)``; outside a mesh
context this is the identity, so smoke tests and CPU examples never touch
device state.

Lifecycle contract: the mesh and the rule table live and die together.
``set_mesh(None)`` (== ``clear_mesh()``) drops the rules too — rules are
*interpretations of a mesh*, and letting them outlive it silently
re-applies a stale mapping to the next mesh. State is thread-local, so
concurrent launchers (e.g. a serving thread next to a background defrag
thread) never observe each other's mesh; ``use_mesh`` is the scoped form.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Iterator, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Union[str, Tuple[str, ...], None]]

_state = threading.local()


def set_mesh(mesh: Optional[Mesh]) -> None:
    """Install (or with None, tear down) the thread's mesh.

    Tearing down the mesh also clears the rules: the mesh/rules lifecycle
    is symmetric, so ``set_mesh(None)`` and ``clear_mesh()`` leave the
    thread in the identical pristine state.
    """
    _state.mesh = mesh
    if mesh is None:
        _state.rules = {}


def clear_mesh() -> None:
    set_mesh(None)


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def set_rules(rules: Rules) -> None:
    _state.rules = dict(rules)


def current_rules() -> Rules:
    return getattr(_state, "rules", {})


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh],
             rules: Optional[Rules] = None) -> Iterator[Optional[Mesh]]:
    """Scoped mesh+rules install; restores the previous pair on exit.

    The exception-safe form of the set/clear pair: state never leaks out
    of the ``with`` block — even when the *install itself* throws (a bad
    rule table must not leave the new mesh half-installed), and even when
    the body resizes or tears down the mesh before raising (elastic
    resize: the body may legitimately ``set_mesh`` a grown/shrunk mesh;
    on error the pre-``with`` pair still comes back).
    """
    prev_mesh = current_mesh()
    prev_rules = dict(current_rules())
    try:
        set_mesh(mesh)
        if rules is not None:
            set_rules(rules)
        yield mesh
    finally:
        set_mesh(prev_mesh)
        set_rules(prev_rules)


def axis_size(mesh_axis: str) -> int:
    mesh = current_mesh()
    if mesh is None or mesh_axis not in mesh.shape:
        return 1
    return mesh.shape[mesh_axis]


def logical_spec(*logical_axes: Optional[str]) -> P:
    """Resolve logical axis names to a PartitionSpec under current rules."""
    rules = current_rules()
    entries = []
    for ax in logical_axes:
        if ax is None:
            entries.append(None)
        else:
            entries.append(rules.get(ax))
    return P(*entries)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Apply a sharding constraint by logical axis names (no-op w/o mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(
            f"shard(): got {len(logical_axes)} axes for rank-{x.ndim} tensor")
    spec = logical_spec(*logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
