"""Logical-axis sharding: models annotate tensors with *logical* axis names;
a per-launch rule table maps them to mesh axes (MaxText-style).

Models call ``shard(x, "batch", "seq", "heads", None)``; outside a mesh
context this is the identity, so smoke tests and CPU examples never touch
device state.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Union[str, Tuple[str, ...], None]]

_state = threading.local()


def set_mesh(mesh: Optional[Mesh]) -> None:
    _state.mesh = mesh


def clear_mesh() -> None:
    _state.mesh = None
    _state.rules = {}


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def set_rules(rules: Rules) -> None:
    _state.rules = dict(rules)


def current_rules() -> Rules:
    return getattr(_state, "rules", {})


def axis_size(mesh_axis: str) -> int:
    mesh = current_mesh()
    if mesh is None or mesh_axis not in mesh.shape:
        return 1
    return mesh.shape[mesh_axis]


def logical_spec(*logical_axes: Optional[str]) -> P:
    """Resolve logical axis names to a PartitionSpec under current rules."""
    rules = current_rules()
    entries = []
    for ax in logical_axes:
        if ax is None:
            entries.append(None)
        else:
            entries.append(rules.get(ax))
    return P(*entries)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Apply a sharding constraint by logical axis names (no-op w/o mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(
            f"shard(): got {len(logical_axes)} axes for rank-{x.ndim} tensor")
    spec = logical_spec(*logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
