"""Async fabric model for cross-shard migration (DESIGN.md §10).

PR 8's migration planner executed every cross-shard hop synchronously:
egress gather, ``drain_until_idle``, device transfer, ingress scatter,
``drain_until_idle`` — the mesh idled while each hop crossed the fabric.
This module models the interconnect explicitly so hops become
*non-blocking*: a :class:`FabricTicket` tracks each hop through
``egress -> in_flight -> ingress -> completed`` while shard-local channel
drains keep running, and per-link occupancy/latency (:class:`FabricLink`)
makes fabric contention observable instead of free.

Time is a logical *round* counter advanced by the planner's pump loop
(one round == one ``drain_all`` sweep across the mesh), so every number
here is deterministic: no wall clock, no randomness.  The overlap the
async fabric buys is measured directly — rounds where a hop was in
flight *and* some shard drained a batch are "hidden" rounds, and
``migration_overlap_ratio = hidden / in_flight`` is the gated metric.

On top of the fabric sit two policies:

* :class:`RebalancePlanner` — watches per-shard load (per-shard
  ``PerfProbe`` submitted-descriptor deltas) over a sliding window and,
  under hysteresis, emits ownership-migration plans that *spread* the
  hottest pages of the hottest shard across the other shards' free
  pages (greedy least-projected-load, with an overshoot guard so a
  single heavy page never ping-pongs between two shards).  Page heat
  decays exponentially per sample, so plans chase recent traffic, not
  all history.  Plans execute at background priority (0) so PR 8's
  weighted arbitration keeps latency-critical traffic ahead of
  rebalancing.
* Elastic resize placement (:meth:`RebalancePlanner.placement`) — when a
  shard joins or leaves, page handoff is lowered through the same
  planner: evacuated pages spread across survivors by free capacity.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

# FabricTicket lifecycle states (DESIGN.md §10).
EGRESS = "egress"          # gather chains submitted, not yet drained
IN_FLIGHT = "in_flight"    # staged payload crossing the link
INGRESS = "ingress"        # scatter chains submitted on the destination
COMPLETED = "completed"    # §II-D writeback observed


@dataclasses.dataclass
class FabricLink:
    """One directed interconnect link with occupancy-based serialization.

    A send entering a busy link queues behind the in-flight payload:
    ``deliver = max(now, busy_until) + latency + pages * page_beats``.
    The counters make per-link contention exportable (Perfetto counter
    track) and feed the cycle simulator's contended mode cross-check.
    """

    src: int
    dst: int
    latency: int = 1           # rounds of pure wire latency
    page_beats: int = 1        # link-occupancy rounds per page
    busy_until: int = 0
    sends: int = 0
    pages_sent: int = 0
    busy_rounds: int = 0       # rounds the link was occupied
    queued_rounds: int = 0     # rounds sends waited behind earlier traffic

    def send(self, now: int, pages: int) -> int:
        start = max(now, self.busy_until)
        occupancy = self.latency + max(1, pages) * self.page_beats
        deliver = start + occupancy
        self.queued_rounds += start - now
        self.busy_rounds += occupancy
        self.busy_until = deliver
        self.sends += 1
        self.pages_sent += pages
        return deliver


@dataclasses.dataclass
class FabricTicket:
    """One cross-shard hop in flight through the async fabric.

    The local-gather half (egress chains) issues immediately at submit;
    the remote-scatter half (ingress chains) is submitted when the link
    delivers, and the hop completes through the destination shard's
    §II-D control-channel writeback — exactly the synchronous hop's
    completion contract, just decoupled from the caller's timeline.
    """

    hop_id: int
    src_shard: int
    dst_shard: int
    pages: int
    pool_names: Tuple[str, ...]
    rows_s: np.ndarray
    rows_d: np.ndarray
    ctrl_ticket: int
    stats: Any                       # the owning plan's MigrationStats
    priority: int = 0
    state: str = EGRESS
    # (pool, channel, tickets) per pool: how the pump detects chain drain.
    egress: List[Tuple[str, str, frozenset]] = \
        dataclasses.field(default_factory=list)
    ingress: List[Tuple[str, str, frozenset]] = \
        dataclasses.field(default_factory=list)
    staged: Dict[str, Any] = dataclasses.field(default_factory=dict)
    issued_round: int = 0
    sent_round: int = 0
    deliver_round: int = 0
    completed_round: int = 0
    inflight_rounds: int = 0         # rounds spent in IN_FLIGHT
    hidden_rounds: int = 0           # ... during which some shard drained
    merged: bool = False             # plan stats already merged globally
    # tracing (sampled per hop, deterministic)
    rec: bool = False
    flow_id: int = 0
    trace_args: Dict[str, object] = dataclasses.field(default_factory=dict)
    t0: float = 0.0
    t1: float = 0.0
    t2: float = 0.0


class AsyncFabric:
    """The mesh interconnect: directed links plus a logical round clock.

    ``advance()`` ticks the clock (the pump calls it once per drain
    sweep); ``send`` places a staged payload on its link;
    ``deliveries()`` returns tickets whose payloads have arrived and
    moves them to ``ingress``.
    """

    def __init__(self, *, latency: int = 1, page_beats: int = 1):
        if latency < 0 or page_beats < 1:
            raise ValueError("need latency >= 0 and page_beats >= 1")
        self.latency = latency
        self.page_beats = page_beats
        self.now = 0
        self.links: Dict[Tuple[int, int], FabricLink] = {}
        self.in_flight: List[FabricTicket] = []

    def link(self, src: int, dst: int) -> FabricLink:
        key = (src, dst)
        ln = self.links.get(key)
        if ln is None:
            ln = self.links[key] = FabricLink(
                src, dst, latency=self.latency, page_beats=self.page_beats)
        return ln

    def advance(self) -> int:
        self.now += 1
        return self.now

    def send(self, ticket: FabricTicket) -> int:
        ln = self.link(ticket.src_shard, ticket.dst_shard)
        ticket.sent_round = self.now
        ticket.deliver_round = ln.send(self.now, ticket.pages)
        ticket.state = IN_FLIGHT
        self.in_flight.append(ticket)
        return ticket.deliver_round

    def deliveries(self) -> List[FabricTicket]:
        out = [t for t in self.in_flight if t.deliver_round <= self.now]
        if out:
            self.in_flight = [t for t in self.in_flight
                              if t.deliver_round > self.now]
            for t in out:
                t.state = INGRESS
        return out

    def occupied_links(self) -> int:
        return sum(1 for ln in self.links.values()
                   if ln.busy_until > self.now)

    def link_stats(self) -> List[Dict[str, int]]:
        """Per-link counters, sorted by (src, dst) for stable export."""
        return [dataclasses.asdict(self.links[k])
                for k in sorted(self.links)]


class RebalancePlanner:
    """Load-driven hot-page rebalancing and resize placement.

    Feeds on per-shard load samples (``observe`` / ``observe_probes``)
    kept in a sliding window.  Hysteresis: a rebalance *episode* opens
    when the windowed max/mean load imbalance crosses ``high_water`` and
    closes when it falls back under ``low_water`` — between the two
    thresholds the planner holds its last decision, so load noise near
    one threshold cannot make it thrash.
    """

    def __init__(self, num_shards: int, *, window: int = 8,
                 high_water: float = 1.5, low_water: float = 1.1,
                 max_pages_per_plan: int = 8, heat_decay: float = 0.5):
        if num_shards < 1:
            raise ValueError("need >= 1 shard")
        if not low_water <= high_water:
            raise ValueError("need low_water <= high_water")
        if window < 1 or max_pages_per_plan < 1:
            raise ValueError("window and max_pages_per_plan must be >= 1")
        if not 0.0 <= heat_decay < 1.0:
            raise ValueError("heat_decay must be in [0, 1)")
        self.num_shards = num_shards
        self.window = window
        self.high_water = high_water
        self.low_water = low_water
        self.max_pages_per_plan = max_pages_per_plan
        self.heat_decay = heat_decay
        self._loads: List[List[float]] = [[] for _ in range(num_shards)]
        self._probe_totals: Optional[List[int]] = None
        self.page_heat: Dict[int, float] = {}
        self._episode = False
        self.plans_emitted = 0
        self.pages_planned = 0

    # -- load intake ---------------------------------------------------------
    def observe(self, per_shard_load: Sequence[float],
                hot_pages: Sequence[int] = ()) -> None:
        """One load sample per shard plus the pages touched this step."""
        if len(per_shard_load) != self.num_shards:
            raise ValueError("need one load sample per shard")
        for s, v in enumerate(per_shard_load):
            w = self._loads[s]
            w.append(float(v))
            if len(w) > self.window:
                del w[0]
        # Exponential heat decay: plans chase recent traffic, not the
        # all-time total (stale heat re-plans pages that already cooled).
        self.page_heat = {p: h * self.heat_decay
                          for p, h in self.page_heat.items()
                          if h * self.heat_decay > 0.05}
        for p in hot_pages:
            self.page_heat[int(p)] = self.page_heat.get(int(p), 0.0) + 1.0

    def observe_probes(self, probes: Sequence[Any],
                       hot_pages: Sequence[int] = ()) -> None:
        """Sample per-shard load from per-shard ``PerfProbe`` objects.

        Load is the *delta* of submitted descriptors across the shard's
        channels since the previous sample — the probe-side view of bus
        utilization (Eq. 1's numerator) without resetting the probes.
        """
        totals = [sum(c.submitted_descriptors
                      for c in probe.channels.values())
                  for probe in probes]
        prev = self._probe_totals or [0] * len(totals)
        self._probe_totals = totals
        self.observe([t - p for t, p in zip(totals, prev)], hot_pages)

    # -- imbalance / hysteresis ----------------------------------------------
    def windowed_load(self) -> List[float]:
        return [sum(w) / len(w) if w else 0.0 for w in self._loads]

    def imbalance(self) -> float:
        """Windowed max/mean load ratio (1.0 == perfectly balanced)."""
        loads = self.windowed_load()
        mean = sum(loads) / len(loads)
        if mean <= 0.0:
            return 1.0
        return max(loads) / mean

    def should_rebalance(self) -> bool:
        r = self.imbalance()
        if self._episode:
            if r <= self.low_water:
                self._episode = False
        elif r >= self.high_water:
            self._episode = True
        return self._episode

    # -- planning ------------------------------------------------------------
    def plan(self, kv, active: Optional[Sequence[bool]] = None,
             exclude: Sequence[int] = ()) -> Optional[
                 Tuple[List[int], List[int]]]:
        """One ownership-migration step: spread the hottest pages of the
        hottest shard across the other active shards' free pages.

        Greedy least-projected-load placement: each candidate page goes
        to the receiver whose projected load (windowed load plus the
        heat already routed to it this plan) is lowest, and is skipped
        entirely when moving it would leave the receiver hotter than the
        source — the overshoot guard that keeps a single Zipf-head page
        from ping-ponging between two shards forever.

        Returns ``(src_pages, dst_pages)`` for ``kv.move_pages`` at
        background priority, or None when balanced (hysteresis closed),
        when the hot shard has no movable heat, or when no receiver has
        a free page.  The caller owns reference rewriting and releasing
        the vacated source pages.
        """
        picked = self._select(kv, active, exclude)
        if picked is None:
            return None
        src, shard_of = picked
        dst: List[int] = []
        for p, s in zip(src, shard_of):
            dst.extend(kv.alloc_on(s, 1))
            # The heat moves with the content: future samples re-heat the
            # destination pages, so one hot set is never re-planned.
            self.page_heat.pop(p, None)
        self.plans_emitted += 1
        self.pages_planned += len(src)
        return src, dst

    def plan_ownership(self, kv, active: Optional[Sequence[bool]] = None,
                       exclude: Sequence[int] = ()) -> Optional[
                           Tuple[List[int], List[int]]]:
        """Ownership-first variant of :meth:`plan` (DESIGN.md §11): same
        candidate selection, but returns ``(pages, dst_shards)`` with
        *no destination allocation* — the caller flips the ownership
        table (``kv.flip_ownership``) and page contents pull lazily on
        first touch, so the rebalance decision takes effect in O(table
        write) instead of O(synchronous batch migration)."""
        picked = self._select(kv, active, exclude)
        if picked is None:
            return None
        src, shard_of = picked
        for p in src:
            self.page_heat.pop(p, None)
        self.plans_emitted += 1
        self.pages_planned += len(src)
        return src, shard_of

    def _select(self, kv, active, exclude) -> Optional[
            Tuple[List[int], List[int]]]:
        """Greedy hot-page pick shared by both plan flavors: returns
        ``(pages, receiver_shards)`` before any allocation/heat pop."""
        if not self.should_rebalance():
            return None
        loads = self.windowed_load()
        alive = [s for s in range(self.num_shards)
                 if active is None or active[s]]
        if len(alive) < 2:
            return None
        hot = max(alive, key=lambda s: (loads[s], -s))
        banned = set(int(p) for p in exclude)
        candidates = sorted(
            (p for p, h in self.page_heat.items()
             if h > 0.0 and p not in banned
             and kv.owner_of(p) == hot),
            key=lambda p: (-self.page_heat[p], p))
        receivers = [s for s in alive if s != hot]
        proj = {s: loads[s] for s in receivers}
        free = {s: kv.free_pages_on(s) for s in receivers}
        hot_proj = loads[hot]
        src: List[int] = []
        shard_of: List[int] = []
        for p in candidates:
            if len(src) >= self.max_pages_per_plan:
                break
            open_ = [s for s in receivers if free[s] > 0]
            if not open_:
                break
            h = self.page_heat[p]
            s = min(open_, key=lambda sh: (proj[sh], sh))
            if proj[s] + h > hot_proj - h:
                # Overshoot: the receiver would end hotter than the
                # source. A lighter candidate may still fit.
                continue
            src.append(p)
            shard_of.append(s)
            proj[s] += h
            hot_proj -= h
            free[s] -= 1
        if not src:
            return None
        return src, shard_of

    def placement(self, kv, pages: Sequence[int],
                  survivors: Sequence[int]) -> List[int]:
        """Resize handoff: destination pages for ``pages`` spread across
        ``survivors``, round-robin weighted by free capacity (the shard
        with the most free pages takes the next page)."""
        if not survivors:
            raise ValueError("resize placement needs at least one survivor")
        free = {s: kv.free_pages_on(s) for s in survivors}
        out: List[int] = []
        for _ in pages:
            s = max(survivors, key=lambda sh: (free[sh], -sh))
            if free[s] == 0:
                raise RuntimeError(
                    f"resize placement: survivors out of free pages "
                    f"({len(out)}/{len(pages)} placed)")
            out.extend(kv.alloc_on(s, 1))
            free[s] -= 1
        return out
