"""Sharding policies: FSDP x TP x EP (x SP for long-context decode).

Parameters and optimizer state shard (fsdp_axes, "model") MaxText-style
(ZeRO-3 equivalent; GSPMD inserts the all-gathers). Activations shard batch
over (pod, data); logical axes inside the model map via shardlib rules.
KV/SSM caches shard batch over data — or the *sequence/page* axis when
global_batch < data-axis size (long-context SP with distributed partial
softmax).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def activation_rules(mesh: Mesh) -> dict:
    """Logical-axis -> mesh-axis map consumed by shardlib."""
    fs = fsdp_axes(mesh)
    return {
        "batch": fs if len(fs) > 1 else (fs[0] if fs else None),
        "seq": None,
        "heads": "model" if "model" in mesh.shape else None,
        "kv_heads": None,          # GQA kv heads replicated across TP
        "d_ff": "model" if "model" in mesh.shape else None,
        "experts": "model" if "model" in mesh.shape else None,
        "expert_cap": fs if len(fs) > 1 else (fs[0] if fs else None),
        "vocab": "model" if "model" in mesh.shape else None,
    }


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def _param_spec(path: Tuple, shape: Tuple[int, ...], mesh: Mesh) -> P:
    names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    fs = fsdp_axes(mesh)
    FSDP = fs if len(fs) > 1 else (fs[0] if fs else None)
    M = "model" if "model" in mesh.shape else None
    leaf = names[-1]
    under_slots = "slots" in names
    under_moe = "ffn" in names and any(n == "router" or leaf in
                                       ("router",) for n in names)

    def spec(*entries):
        # Stacked period params carry a leading (periods,) axis.
        if under_slots:
            entries = (None,) + entries
        # Guard divisibility: drop axes that don't divide the dim.
        fixed = []
        base = 1 if under_slots else 0
        for i, e in enumerate(entries):
            if e is None:
                fixed.append(None)
                continue
            axes = e if isinstance(e, tuple) else (e,)
            size = 1
            for a in axes:
                size *= mesh.shape.get(a, 1)
            dim = shape[i] if i < len(shape) else 1
            fixed.append(e if dim % size == 0 else None)
        return P(*fixed)

    ndim_eff = len(shape) - (1 if under_slots else 0)

    if leaf == "embedding":
        return spec(M, FSDP)
    if leaf == "unembed":
        return spec(FSDP, M)
    if leaf == "wq":
        return spec(FSDP, M, None)
    if leaf in ("wk", "wv"):
        return spec(FSDP, None, None)
    if leaf == "wo":
        return spec(M, None, FSDP)
    if leaf == "bq":
        return spec(M, None)
    if leaf in ("bk", "bv"):
        return spec(None, None)
    if leaf in ("q_down", "kv_down"):
        return spec(FSDP, None)
    if leaf in ("q_up", "kv_up"):
        return spec(None, M, None)
    if leaf == "router":
        return spec(None, None)
    if leaf in ("w_gate", "w_up"):
        if ndim_eff == 3:           # MoE experts (E, d, f)
            return spec(M, FSDP, None)
        return spec(FSDP, M)
    if leaf == "w_down":
        if ndim_eff == 3:
            return spec(M, None, FSDP)
        return spec(M, FSDP)
    if leaf == "in_proj":
        return spec(FSDP, None)
    if leaf == "out_proj":
        return spec(None, FSDP)
    if leaf in ("conv_w", "conv_b", "dt_bias", "A_log", "D", "scale"):
        return spec(*(None,) * ndim_eff)
    # Fallback: replicate.
    return spec(*(None,) * ndim_eff)


def param_specs(cfg: ModelConfig, mesh: Mesh, shapes_tree: Any) -> Any:
    """PartitionSpec pytree congruent with `shapes_tree` (from param_shapes)."""
    def assign(path, leaf):
        return _param_spec(path, leaf.shape, mesh)
    return jax.tree_util.tree_map_with_path(assign, shapes_tree)


def serving_param_specs(cfg: ModelConfig, mesh: Mesh, shapes_tree: Any) -> Any:
    """Serving layout: TP over `model` only; replicated over (pod, data).

    Training's FSDP layout would re-all-gather every parameter on every
    decode step; serving replicas keep full TP shards resident instead
    (EXPERIMENTS.md §Perf-3).
    """
    fs = fsdp_axes(mesh)

    def strip(spec: P) -> P:
        entries = []
        for e in spec:
            if e is None:
                entries.append(None)
            elif isinstance(e, tuple):
                kept = tuple(a for a in e if a not in fs)
                entries.append(kept if len(kept) > 1 else
                               (kept[0] if kept else None))
            else:
                entries.append(None if e in fs else e)
        return P(*entries)

    return jax.tree.map(strip, param_specs(cfg, mesh, shapes_tree),
                        is_leaf=lambda x: isinstance(x, P))


def to_named(tree_of_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Batch / state specs
# ---------------------------------------------------------------------------

def batch_axis(mesh: Mesh, global_batch: int):
    """Largest prefix of (pod, data) that divides global_batch."""
    axes = []
    size = 1
    for a in ("pod", "data"):
        if a in mesh.shape and global_batch % (size * mesh.shape[a]) == 0:
            axes.append(a)
            size *= mesh.shape[a]
    if not axes:
        return None
    return tuple(axes) if len(axes) > 1 else axes[0]


def train_batch_specs(mesh: Mesh, global_batch: int, batch: Any) -> Any:
    BA = batch_axis(mesh, global_batch)

    def assign(path, leaf):
        return P(*((BA,) + (None,) * (leaf.ndim - 1)))
    return jax.tree_util.tree_map_with_path(assign, batch)


def train_state_specs(cfg: ModelConfig, mesh: Mesh, state_shapes: Any) -> Any:
    """TrainState(params, opt(step,m,v), residuals) -> spec tree."""
    from repro.train.step import TrainState
    from repro.optim import AdamWState
    p_specs = param_specs(cfg, mesh, state_shapes.params)
    return TrainState(
        params=p_specs,
        opt=AdamWState(step=P(),
                       m=param_specs(cfg, mesh, state_shapes.opt.m),
                       v=param_specs(cfg, mesh, state_shapes.opt.v)),
        residuals=None if state_shapes.residuals is None
        else param_specs(cfg, mesh, state_shapes.residuals),
    )


def decode_state_specs(cfg: ModelConfig, mesh: Mesh, state_shapes: Any,
                       global_batch: int, *,
                       kv_seq_axis: Optional[str] = None) -> Any:
    """DecodeState spec tree. Batch shards over data when divisible;
    otherwise the cache *sequence* axis shards over data (long-context SP).
    kv_seq_axis="model" additionally shards cache positions over TP ranks
    (GQA kv-heads < TP degree make head-sharding impossible; sequence
    sharding is the lever — EXPERIMENTS.md §Perf-3)."""
    from repro.models.attention import KVCacheView
    from repro.models.mamba import MambaCache
    from repro.models.transformer import CrossCache

    BA = batch_axis(mesh, global_batch)
    seq_shard = "data" if BA is None and "data" in mesh.shape else None
    if kv_seq_axis and kv_seq_axis in mesh.shape and seq_shard is None:
        seq_shard = kv_seq_axis
    M = "model" if "model" in mesh.shape else None

    def walk(node, stacked: bool):
        lead = (None,) if stacked else ()
        if isinstance(node, KVCacheView):
            return KVCacheView(
                k=P(*lead, BA, seq_shard, None, None),
                v=P(*lead, BA, seq_shard, None, None),
                kv_pos=P(*lead, BA, seq_shard))
        if isinstance(node, MambaCache):
            hdim = node.state.shape[len(lead) + 1]
            h_ax = M if (M and hdim % mesh.shape["model"] == 0) else None
            return MambaCache(
                conv=P(*lead, BA, None, None),
                state=P(*lead, BA, h_ax, None, None))
        if isinstance(node, CrossCache):
            return CrossCache(k=P(*lead, BA, None, None, None),
                              v=P(*lead, BA, None, None, None))
        if isinstance(node, dict):
            return {k: walk(v, stacked or k in ("slots", "cross_slots"))
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)) and not hasattr(node, "_fields"):
            return type(node)(walk(v, stacked) for v in node)
        # Leaves outside caches (cur_pos etc.): batch-sharded on axis 0.
        nd = getattr(node, "ndim", 0)
        return P(*((BA,) + (None,) * max(nd - 1, 0)))

    from repro.models.model import DecodeState
    assert isinstance(state_shapes, DecodeState)
    return DecodeState(caches=walk(state_shapes.caches, False),
                       cur_pos=P(BA))
