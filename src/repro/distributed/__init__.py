"""Distribution substrate: logical-axis sharding, policies, fault tolerance,
and the sharded DMA serving layer (DESIGN.md §6)."""
from .shardlib import (  # noqa: F401
    axis_size,
    clear_mesh,
    current_mesh,
    current_rules,
    logical_spec,
    set_mesh,
    set_rules,
    shard,
    use_mesh,
)
from .fabric import (  # noqa: F401
    AsyncFabric,
    FabricLink,
    FabricTicket,
    RebalancePlanner,
)
from .sharded_runtime import (  # noqa: F401
    MigrationStats,
    PageOwnerMap,
    ShardedDMARuntime,
    ShardedKVPool,
    ShardedServeEngine,
    resolve_num_shards,
)
