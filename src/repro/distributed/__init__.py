"""Distribution substrate: logical-axis sharding, policies, fault tolerance."""
from .shardlib import (  # noqa: F401
    axis_size,
    clear_mesh,
    current_mesh,
    current_rules,
    logical_spec,
    set_mesh,
    set_rules,
    shard,
)
