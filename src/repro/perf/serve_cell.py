"""The end-to-end serve-path cell of the perf sweep (ROADMAP item).

The rest of the sweep drives the DMA runtime directly; this cell runs a
real :class:`repro.serve.ServeEngine` — reduced model config, real jitted
decode steps, §II-D writeback completions through the control ring — and
gates the *continuous-batching* regressions the runtime cells cannot see:
admission stalls (requests queued behind full slots) and completion-poll
latency (decode steps between a request's writeback and the scheduler
observing it).

Determinism contract: every gated metric is a pure scheduling quantity —
admission and completion depend only on prompt lengths, ``max_new_tokens``
and the poll cadence, never on logits — so the cell regenerates
bit-for-bit from the sweep seed even though the decode math runs for real.
Wall-clock (``step_seconds``) is measured but never stored, exactly like
the runtime cells.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Tuple

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.runtime import SubmitRequest
from repro.runtime.instrumentation import PerfProbe


@dataclasses.dataclass(frozen=True)
class ServeCellSpec:
    """Fully determines the serve cell (and hence its baseline entry)."""

    arch: str = "qwen2.5-3b"   # reduced clone: the smallest decode path
    capacity: int = 2          # slots — kept below n_requests so admission
    n_requests: int = 6        # pressure (stalls) is actually exercised
    min_prompt: int = 2
    max_prompt: int = 6
    max_new_tokens: int = 4
    max_len: int = 32
    poll_every: int = 3        # decode steps between scheduler polls
    max_steps: int = 400       # safety valve; the cell drains far earlier

    @property
    def cell_key(self) -> str:
        return f"serve/{self.arch}/cap{self.capacity}"


DEFAULT_SERVE_SPEC = ServeCellSpec()

#: Gated serve-path metrics (all scheduling-deterministic; lower is better).
#: ``request_latency_steps`` is histogram-valued (schema v5): the gate
#: compares it at named percentiles with per-percentile tolerance, while
#: the p50/p99 scalars gate directly (DESIGN.md §8).
SERVE_GATED_METRICS = (
    "admission_stall_rate",
    "completion_poll_latency_steps",
    "serve_steps_per_request",
    "request_latency_steps_p50",
    "request_latency_steps_p99",
    "request_latency_steps",
)

_WALL_CLOCK_SERVE_COUNTERS = ("step_seconds",)


def run_serve_cell(
    seed: int,
    spec: ServeCellSpec = DEFAULT_SERVE_SPEC,
) -> Tuple[Dict[str, float], Dict[str, object]]:
    """Run the cell; returns ``(gated_metrics, stored_counters)``."""
    from repro.models import init_params
    from repro.serve import Request, ServeEngine

    cfg = get_config(spec.arch, reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    probe = PerfProbe()
    eng = ServeEngine(params, cfg, capacity=spec.capacity,
                      max_len=spec.max_len)
    eng.attach_probe(probe)

    rng = np.random.default_rng(
        [seed, zlib.crc32(spec.cell_key.encode())])
    for uid in range(spec.n_requests):
        n_prompt = int(rng.integers(spec.min_prompt, spec.max_prompt + 1))
        prompt = [int(t) for t in rng.integers(1, cfg.vocab_size, n_prompt)]
        eng.submit(SubmitRequest(request=Request(
            uid=uid, prompt=prompt, max_new_tokens=spec.max_new_tokens)))

    while ((eng.queue or any(s.busy for s in eng.slots))
           and eng.steps < spec.max_steps):
        eng.step()
        if eng.steps % spec.poll_every == 0:
            eng.poll_completed()
    delivered = eng.poll_completed()

    if len(delivered) != spec.n_requests:
        raise RuntimeError(
            f"serve cell did not drain: {len(delivered)}/{spec.n_requests} "
            f"requests delivered in {eng.steps} steps — the cell would "
            "gate garbage")

    pc = eng.perf_counters()
    metrics = {
        "admission_stall_rate": float(pc["serve.admission_stall_rate"]),
        "completion_poll_latency_steps":
            float(pc["serve.completion_poll_latency_steps"]),
        "serve_steps_per_request":
            float(pc["serve.steps"] / spec.n_requests),
        # Tail latency (schema v5): end-to-end submit -> §II-D writeback in
        # decode steps. Steps are pure scheduling outcomes, so the whole
        # histogram (and hence its percentiles) regenerates bit-for-bit;
        # small-integer samples land in the width-1 linear buckets, making
        # p50/p99 *exact*, not bucket-floor approximations.
        "request_latency_steps_p50":
            float(pc["serve.request_latency_steps_p50"]),
        "request_latency_steps_p99":
            float(pc["serve.request_latency_steps_p99"]),
        "request_latency_steps": dict(pc["serve.request_latency_steps"]),
    }
    serve_counters = {
        k: v for k, v in dataclasses.asdict(probe.serve).items()
        if k not in _WALL_CLOCK_SERVE_COUNTERS
    }
    counters = {
        "serve": serve_counters,
        "speculation_depth": float(pc["serve.speculation_depth"]),
        # Deterministic translation-cache traffic of the engine's runtime
        # (event counts only — no wall clock). Stored raw (bare keys): the
        # document layout is schema-versioned, not deprecation-aliased.
        "translation_cache": eng.runtime._translation_stats_raw(),
    }
    return metrics, counters
