"""Transform-engine cells of the perf sweep (schema v6, DESIGN.md §9).

One cell per (transfer size, memory latency) point of the in-flight
transform surface: the cycle model runs the cached-artifact frontend
twice at the same *logical* payload — once charging full fp32 payload
beats, once charging the EF-int8 compressed beat count
(``payload_ratio = compression_ratio()``) — and the cell gates the
effective bandwidth of each plus their ratio. A quantized KV transfer
must move fewer bus beats for the *same* logical bytes, so the gain
gates strictly above 1.0 against the committed baseline.

The fidelity leg runs the seeded quantize→dequantize roundtrip through
the numpy oracle (:func:`repro.core.transform.kv8_roundtrip_np`) and
gates the worst-case error — "equal fidelity tolerance" in the v6
contract: bandwidth wins never get to trade away roundtrip accuracy
silently. The fusion leg drives a real :class:`repro.runtime.DMARuntime`
with ``kv_int8`` submissions and gates the transform-fusion hit rate of
the chain-lowering JIT (transform token in the
:class:`~repro.core.signature.ChainSignature` — every plan should be
served by a transform-fused compiled executor).

Determinism contract: identical to the DMA cells — metrics are pure
functions of ``(seed, cell_key)``; no wall-clock value is stored.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Tuple

import numpy as np

#: Gated transform-cell metrics (gate.py carries polarity + bands).
TRANSFORM_GATED_METRICS = (
    "effective_bandwidth_fp32",
    "effective_bandwidth_int8",
    "effective_bandwidth_gain",
    "fidelity_max_rel_err",
    "transform_fusion_hit_rate",
)


@dataclasses.dataclass(frozen=True)
class TransformCellSpec:
    """Fully determines the transform cells (and hence their baselines)."""

    transfer_bytes: Tuple[int, ...] = (1024, 4096)
    full_transfer_bytes: Tuple[int, ...] = (512, 1024, 4096, 16384)
    mem_latencies: Tuple[int, ...] = (13, 100)
    full_mem_latencies: Tuple[int, ...] = (1, 13, 100)
    num_transfers: int = 512
    fidelity_elems: int = 4096     # multiple of the EF-int8 block (256)
    fusion_chains: int = 8
    fusion_segments: int = 4
    fusion_unit: int = 64          # elements per fused-loop segment

    def cell_key(self, nbytes: int, mem_latency: int) -> str:
        return f"transform/kv{nbytes}B/L{mem_latency}"


DEFAULT_TRANSFORM_SPEC = TransformCellSpec()


def _effective_bandwidth(mem_latency: int, nbytes: int,
                         num_transfers: int, payload_ratio: float) -> float:
    """Logical bytes per bus cycle through the cached-artifact frontend.

    The numerator is always the *uncompressed* payload — the transform
    changes what crosses the bus, not what the workload asked to move —
    so a payload_ratio < 1 shows up directly as higher effective
    bandwidth at equal logical traffic.
    """
    from repro.core.simulator import SimConfig, simulate
    r = simulate(SimConfig.translated_frontend(), mem_latency, nbytes,
                 num_transfers=num_transfers, payload_ratio=payload_ratio)
    return float(num_transfers * nbytes / max(r.cycles, 1))


def _fidelity_pass(seed: int, key: str, elems: int) -> float:
    """Worst-case EF-int8 roundtrip error of a seeded KV-shaped pool.

    Mixed magnitudes per block (unit-scale values next to large
    outliers) make this the adversarial case for per-block scales; the
    error is normalized by the pool's max magnitude, matching the
    per-block symmetric-scale error model (bounded near 1/254).
    """
    from repro.core.transform import kv8_roundtrip_np
    rng = np.random.default_rng([seed, zlib.crc32(key.encode())])
    x = rng.standard_normal(elems).astype(np.float32)
    outliers = rng.random(elems) < 0.05
    x = np.where(outliers, x * 64.0, x).astype(np.float32)
    y = kv8_roundtrip_np(x)
    return float(np.max(np.abs(y - x)) / max(float(np.max(np.abs(x))), 1e-12))


def _fusion_pass(seed: int, spec: TransformCellSpec) -> float:
    """Transform-fusion hit rate of a real runtime under kv_int8 traffic."""
    import jax.numpy as jnp

    from repro.core.chain import from_segments
    from repro.runtime import ChannelConfig, DMARuntime, SubmitRequest

    rng = np.random.default_rng([seed, 0x7F5])
    unit = spec.fusion_unit
    pool = 64 * unit
    rt = DMARuntime([ChannelConfig(name="ch0", tier="serial",
                                   ring_capacity=256, max_len=512)])
    rt.register_pool("src", jnp.zeros(pool, jnp.float32))
    rt.register_pool("dst", jnp.zeros(pool, jnp.float32))
    n_slots = pool // unit
    for _ in range(spec.fusion_chains):
        src = rng.choice(n_slots, spec.fusion_segments, replace=False)
        dst = rng.choice(n_slots, spec.fusion_segments, replace=False)
        d = from_segments(src * unit, dst * unit,
                          np.full(spec.fusion_segments, unit, np.int64))
        rt.submit(SubmitRequest(chain=d, src_pool="src", dst_pool="dst",
                                tier="serial", transform="kv_int8"))
    rt.drain_until_idle()
    st = rt._translation_stats_raw()
    return float(st["transform_fusion_hit_rate"])


def transform_cell_entries(
    seed: int,
    spec: TransformCellSpec = DEFAULT_TRANSFORM_SPEC,
    *,
    quick: bool = True,
) -> List[Tuple[str, Dict[str, object]]]:
    """All (key, cell dict) transform entries for the sweep document."""
    from repro.optim.compress import compression_ratio

    ratio = compression_ratio()
    fusion = _fusion_pass(seed, spec)
    sizes = spec.transfer_bytes if quick else spec.full_transfer_bytes
    lats = spec.mem_latencies if quick else spec.full_mem_latencies
    entries: List[Tuple[str, Dict[str, object]]] = []
    for nbytes in sizes:
        for mem_latency in lats:
            key = spec.cell_key(nbytes, mem_latency)
            fidelity = _fidelity_pass(seed, key, spec.fidelity_elems)
            bw_fp32 = _effective_bandwidth(mem_latency, nbytes,
                                           spec.num_transfers, 1.0)
            bw_int8 = _effective_bandwidth(mem_latency, nbytes,
                                           spec.num_transfers, ratio)
            entries.append((key, {
                "kind": "transform",
                "workload": "kv_int8",
                "transfer_bytes": nbytes,
                "mem_latency": mem_latency,
                "metrics": {
                    "effective_bandwidth_fp32": bw_fp32,
                    "effective_bandwidth_int8": bw_int8,
                    "effective_bandwidth_gain":
                        bw_int8 / max(bw_fp32, 1e-12),
                    "fidelity_max_rel_err": fidelity,
                    "transform_fusion_hit_rate": fusion,
                },
                "counters": {
                    "payload_ratio": ratio,
                    "num_transfers": spec.num_transfers,
                },
            }))
    return entries
