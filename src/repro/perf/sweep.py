"""Scenario sweep: every (config x workload x channels x mem-latency) cell.

Each cell runs twice, once in each substrate:

1. **Runtime pass** — the cell's workload chains are submitted to a real
   :class:`repro.runtime.DMARuntime` with ``channels`` serial-tier virtual
   channels and drained to idle. A :class:`repro.runtime.PerfProbe` is
   attached, so coalescer merge ratio, §II-C speculation hit rate, and the
   per-channel counters come from the runtime's own instrumentation hooks,
   not from sweep-side re-derivation.
2. **Cycle-model pass** — :func:`repro.core.simulator.simulate_multichannel`
   reproduces the cell's bus behaviour (N frontends, fair arbiter, the
   cell's memory latency) at the workload's representative transfer size,
   yielding steady-state bus utilization and launch cycles per transfer.
3. **Speculation-policy pass** — the single-frontend cycle model runs the
   cell's traffic (its measured §II-C hit rate) under both a
   ``FixedDepth(4)`` and an ``AdaptiveDepth`` frontend, gating the
   contention-discounted utilizations ``spec_bus_utilization_fixed4`` /
   ``spec_bus_utilization_adaptive`` (DESIGN.md §5): steady-state
   utilization scaled by useful-payload share of *all* descriptor traffic
   including discarded speculative fetches, normalized so a zero-waste run
   reports plain utilization. This is the adaptive-vs-fixed contract: the
   adaptive policy must match fixed depth on sequential streams and beat
   it on MoE dispatch storms, where backing off converts wasted
   speculative beats back into payload bandwidth.

4. **Translation pass** (schema v4) — the runtime pass replays each
   workload's chains over warm rounds and gates the steady-state
   chain-lowering cache hit rate (DESIGN.md §7), while the cycle model
   compares the §II-A next-field-serialized baseline frontend against a
   cached-artifact frontend to gate ``translation_launch_speedup``.
   ``--no-translation-cache`` regenerates the uncached legacy document.

One additional **serve cell** (``kind: "serve"``) runs a reduced-config
end-to-end :class:`repro.serve.ServeEngine` and gates continuous-batching
scheduling metrics; see :mod:`repro.perf.serve_cell`.

The output document (``BENCH_perf.json``) is *bit-for-bit reproducible*
from ``(mode, seed)``: gated metrics are medians over ``repeats`` seeded
re-generations, wall-clock numbers never enter the document, and stored
counters are the deterministic subset of the probe snapshot.

CLI: ``python -m repro.perf.sweep --out BENCH_perf.json [--full] [--seed N]``
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Dict, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, list_archs
from repro.core.simulator import SimConfig, simulate, simulate_multichannel
from repro.core.speculation import DEFAULT_DEPTH, FixedDepth
from repro.runtime import ChannelConfig, DMARuntime, PerfProbe, SubmitRequest

from .serve_cell import (
    DEFAULT_SERVE_SPEC,
    SERVE_GATED_METRICS,
    run_serve_cell,
)
from .sharded_cell import (
    DEFAULT_SHARDED_SPEC,
    MESH_SIZES,
    SHARDED_GATED_METRICS,
    cell_entry as sharded_cell_entry,
)
from .mmu_cell import (
    DEFAULT_MMU_SPEC,
    MMU_GATED_METRICS,
    mmu_cell_entries,
)
from .transform_cell import (
    DEFAULT_TRANSFORM_SPEC,
    TRANSFORM_GATED_METRICS,
    transform_cell_entries,
)
from .workloads import SCALES, WORKLOAD_NAMES, Scale, generate

#: v8: MMU-aware virtual paging (DESIGN.md §11) — new "mmu" cells gate
#: the engine-side IOTLB (``tlb_hit_rate`` >= 0.9 on the sequential
#: paged-KV stream with chain-lookahead prefetch, ``walk_stall_cycles``)
#: and remap-vs-copy defragmentation (``defrag_remap_cycles`` strictly
#: below ``defrag_copy_cycles``); the sharded cells gain
#: ``first_touch_latency_rounds`` (ownership-first migration: pull-one-
#: page-on-touch rounds, strictly below the full synchronous batch
#: migration at mesh >= 4); the document records ``iotlb_enabled``.
#: v7: async-fabric sharded cells (DESIGN.md §10) — the sharded cells
#: regenerate on Zipf-skewed page traffic through the async fabric and
#: gain four gated metrics: ``migration_overlap_ratio`` (in-flight
#: rounds hidden behind local drains, >= 0.6 at mesh 4),
#: ``p99_migration_stall_cycles`` (contended per-link interconnect mode,
#: strictly below the shared-bus synchronous baseline stored in the
#: counters), ``rebalance_convergence_steps`` (hot-shard planner
#: hysteresis), and ``throughput_retained_during_resize`` (>= 0.8 at
#: mesh 4); the cell records its fabric mode.
#: v6: in-flight transform cells (kind: "transform", DESIGN.md §9) —
#: effective-bandwidth A/B of the EF-int8 quantize transform vs the fp32
#: baseline at equal logical payload, roundtrip fidelity, and the
#: chain-lowering JIT's transform-fusion hit rate.
#: v5: serve-cell tail-latency histograms (DESIGN.md §8) — the serve cell
#: gains ``request_latency_steps_p50``/``_p99`` scalars plus the
#: histogram-valued ``request_latency_steps`` (fixed log2-bucket layout,
#: gated at named percentiles with per-percentile tolerance). v4 added
#: chain-lowering translation-cache cells (DESIGN.md §7): every DMA cell
#: gains ``translation_cache_hit_rate`` (steady-state artifact-cache hit
#: rate over warm replay rounds) and ``translation_launch_speedup``
#: (cycle-model launch speedup of a cached lowered chain vs the §II-A
#: next-field-serialized baseline frontend), and the document records
#: ``translation_cache_enabled``. v3 added the sharded mesh cells
#: (kind: "sharded", mesh in {1,2,4,8}) gating the cross-shard migration
#: surface (DESIGN.md §6). v2 added the speculation-policy metrics
#: (spec_bus_utilization_*) on every DMA cell plus the end-to-end serve
#: cell. Older baselines must be regenerated.
SCHEMA_VERSION = 8

#: The gated perf surface of DMA cells. gate.py refuses documents missing
#: any of these (serve cells gate SERVE_GATED_METRICS instead).
GATED_METRICS = (
    "bus_utilization",
    "launch_cycles_per_transfer",
    "coalesce_merge_ratio",
    "speculation_hit_rate",
    "spec_bus_utilization_fixed4",
    "spec_bus_utilization_adaptive",
    "translation_cache_hit_rate",
    "translation_launch_speedup",
)

#: Warm replay rounds of the runtime pass: the workload's chains are
#: resubmitted unchanged after the cold round, and the steady-state
#: translation-cache hit rate is the artifact-cache hit fraction over the
#: warm rounds alone (counter deltas, so cold-round compiles never dilute
#: it). Ratio metrics (merge ratio, §II-C hit rate) are invariant under
#: the replays — identical chains scale numerator and denominator alike.
_WARM_ROUNDS = 3

#: Frontends of the speculation-policy pass. The fixed config is the
#: paper's Table-I speculation point through the policy layer; the
#: adaptive config deepens toward the scaled config's 24 slots on
#: sequential streams and backs off toward one probing slot on storms.
_SPEC_FRONTENDS = (
    ("fixed4", SimConfig("spec-fixed4", in_flight=DEFAULT_DEPTH,
                         prefetch=FixedDepth(DEFAULT_DEPTH))),
    ("adaptive", SimConfig.adaptive()),
)


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Fully determines one sweep (and hence one baseline document)."""

    mode: str
    seed: int
    repeats: int
    archs: Sequence[str]
    workloads: Sequence[str]
    channel_counts: Sequence[int]
    mem_latencies: Sequence[int]
    include_serve: bool = True
    mesh_sizes: Sequence[int] = MESH_SIZES
    include_sharded: bool = True
    #: In-flight transform cells (schema v6, DESIGN.md §9).
    include_transforms: bool = True
    #: Chain-lowering JIT (DESIGN.md §7). False reproduces the uncached
    #: legacy dispatch path: hit rate reports 0.0 and launch speedup 1.0,
    #: so a disabled baseline is self-describing rather than vacuously
    #: green.
    translation: bool = True
    #: MMU/IOTLB cells (schema v8, DESIGN.md §11). False (--no-iotlb) is
    #: the escape hatch: the mmu cells are skipped entirely and the
    #: document records ``iotlb_enabled: false``, so a disabled baseline
    #: is self-describing.
    iotlb: bool = True

    @property
    def scale(self) -> Scale:
        return SCALES[self.mode]


def default_spec(
    mode: str = "quick",
    seed: int = 0,
    *,
    archs: Optional[Sequence[str]] = None,
    workloads: Optional[Sequence[str]] = None,
    channel_counts: Optional[Sequence[int]] = None,
    mem_latencies: Optional[Sequence[int]] = None,
    repeats: Optional[int] = None,
    include_serve: bool = True,
    mesh_sizes: Optional[Sequence[int]] = None,
    include_sharded: bool = True,
    include_transforms: bool = True,
    translation: bool = True,
    iotlb: bool = True,
) -> SweepSpec:
    if mode not in SCALES:
        raise ValueError(f"unknown mode {mode!r}; have {sorted(SCALES)}")
    quick = mode == "quick"
    return SweepSpec(
        mode=mode,
        seed=seed,
        repeats=repeats if repeats is not None else (3 if quick else 5),
        archs=tuple(archs if archs is not None else list_archs()),
        workloads=tuple(workloads if workloads is not None else WORKLOAD_NAMES),
        channel_counts=tuple(channel_counts if channel_counts is not None
                             else ((4,) if quick else (1, 2, 4))),
        mem_latencies=tuple(mem_latencies if mem_latencies is not None
                            else ((13, 100) if quick else (1, 13, 100))),
        include_serve=include_serve,
        mesh_sizes=tuple(mesh_sizes if mesh_sizes is not None
                         else MESH_SIZES),
        include_sharded=include_sharded,
        include_transforms=include_transforms,
        translation=translation,
        iotlb=iotlb,
    )


def cell_key(arch: str, workload: str, channels: int, mem_latency: int) -> str:
    return f"{arch}/{workload}/ch{channels}/L{mem_latency}"


_NONDETERMINISTIC_COUNTERS = ("drain_seconds", "launch_seconds")


def _deterministic_counters(snapshot: Dict[str, object]) -> Dict[str, object]:
    """Strip wall-clock fields so the stored document is seed-pure."""
    out: Dict[str, object] = {}
    for name, c in snapshot["channels"].items():
        out[name] = {k: v for k, v in c.items()
                     if k not in _NONDETERMINISTIC_COUNTERS}
    return out


def _run_runtime_pass(arch: str, workload: str, channels: int,
                      scale: Scale, seed: int, *,
                      translation: bool = True) -> Dict[str, object]:
    cfg = get_config(arch)
    wl = generate(workload, cfg, scale, seed)
    probe = PerfProbe()
    rt = DMARuntime(
        [ChannelConfig(name=f"ch{i}", tier="serial",
                       ring_capacity=scale.ring_capacity,
                       max_len=scale.max_len)
         for i in range(channels)],
        arbitration="round_robin", backpressure="block",
        translation=translation)
    rt.attach_probe(probe)
    rt.register_pool("src", jnp.zeros(wl.pool_elems, jnp.float32))
    rt.register_pool("dst", jnp.zeros(wl.pool_elems, jnp.float32))

    def submit_all():
        for d in wl.chains:
            rt.submit(SubmitRequest(chain=d, src_pool="src",
                                    dst_pool="dst", tier="serial"))
        rt.drain_until_idle()

    submit_all()                       # cold round: plans + artifacts compile
    cold = rt._translation_stats_raw()
    warm_rounds = _WARM_ROUNDS if translation else 0
    for _ in range(warm_rounds):       # serve-shaped replays: same chains
        submit_all()
    warm = rt._translation_stats_raw()
    d_lookups = int(warm["lookups"]) - int(cold["lookups"])
    d_hits = int(warm["hits"]) - int(cold["hits"])
    steady_hit_rate = d_hits / d_lookups if d_lookups else 0.0

    st = rt.stats()
    return {
        "merge_ratio": float(st["coalesce_merge_ratio"]),
        "hit_rate": float(st["mean_input_hit_rate"]),
        "launch_us_per_descriptor": float(st["launch_us_per_descriptor"]),
        "translation_hit_rate": float(steady_hit_rate),
        "transfer_bytes": wl.transfer_bytes,
        "counters": {
            **_deterministic_counters(probe.snapshot()),
            # Deterministic event counts of the chain-lowering JIT
            # (DESIGN.md §7): artifact hit/miss/evict + plan-memo traffic
            # over the cold round plus all warm replays.
            "translation_cache": warm,
        },
    }


def _speculation_pass(mem_latency: int, transfer_bytes: int,
                      hit_rate: float, num_transfers: int):
    """Adaptive-vs-fixed cycle-model cells (DESIGN.md §5).

    The gated metric is *contention-discounted* utilization: steady-state
    utilization times the useful share of all descriptor traffic
    (``payload / (payload + desc_beats)``, where ``desc_beats`` includes
    discarded speculative fetches), normalized by the Eq.-1 ideal so a
    zero-waste frontend reports its plain utilization. On a saturated
    serving bus every wasted beat displaces a payload beat, which is
    exactly what this discount charges for.
    """
    metrics: Dict[str, float] = {}
    trajectory: Dict[str, Dict[str, float]] = {}
    for label, cfg in _SPEC_FRONTENDS:
        r = simulate(cfg, mem_latency, transfer_bytes,
                     num_transfers=num_transfers, hit_rate=hit_rate)
        useful = r.payload_beats / max(r.payload_beats + r.desc_beats, 1)
        metrics[f"spec_bus_utilization_{label}"] = float(
            r.utilization * useful / r.ideal)
        trajectory[label] = {
            "final_depth": int(r.final_depth),
            "mean_depth": float(r.mean_depth),
            "wasted_beats": int(r.wasted_beats),
        }
    return metrics, trajectory


def _translation_pass(mem_latency: int, transfer_bytes: int,
                      num_transfers: int) -> float:
    """Launch speedup of a cached lowered chain, from the cycle model.

    ``SimConfig.base()`` pays §II-A's next-field serialization on every
    descriptor fetch; ``SimConfig.translated_frontend()`` is the same bus
    driven by a compiled artifact that already knows every address, so
    fetches issue back-to-back. The ratio of total cycles is the gated
    ``translation_launch_speedup`` — ≥1.66x at 64-byte-class units, the
    paper's launch-latency claim carried over to the software cache.
    """
    base = simulate(SimConfig.base(), mem_latency, transfer_bytes,
                    num_transfers=num_transfers)
    translated = simulate(SimConfig.translated_frontend(), mem_latency,
                          transfer_bytes, num_transfers=num_transfers)
    return float(base.cycles / max(translated.cycles, 1))


def run_sweep(spec: Optional[SweepSpec] = None, *,
              progress: bool = False) -> Dict[str, object]:
    """Execute the sweep; returns the BENCH_perf document (JSON-ready)."""
    spec = spec or default_spec()
    scale = spec.scale
    cells: Dict[str, Dict[str, object]] = {}
    # The speculation pass depends only on (L, transfer size, hit rate) —
    # all channel-independent — so memoize it across the channel axis, the
    # same hoist the runtime pass gets across the latency axis. The
    # translation pass depends only on (L, transfer size), so it collapses
    # even further.
    spec_cache: Dict[tuple, tuple] = {}
    translation_cache_pass: Dict[tuple, float] = {}

    for arch in spec.archs:
        for workload in spec.workloads:
            for channels in spec.channel_counts:
                # The runtime pass is independent of memory latency; run it
                # once per repeat and fan metrics out over the L axis.
                passes = [
                    _run_runtime_pass(arch, workload, channels, scale,
                                      spec.seed + r,
                                      translation=spec.translation)
                    for r in range(spec.repeats)
                ]
                merge = float(np.median([p["merge_ratio"] for p in passes]))
                hit = float(np.median([p["hit_rate"] for p in passes]))
                cache_hit = float(np.median(
                    [p["translation_hit_rate"] for p in passes]))
                # transfer_bytes is a pure function of (arch, workload) —
                # the cycle model sees nothing seed-dependent, so it runs
                # once per cell, not once per repeat.
                transfer_bytes = passes[0]["transfer_bytes"]
                assert all(p["transfer_bytes"] == transfer_bytes
                           for p in passes), \
                    "transfer_bytes became seed-dependent; re-run the " \
                    "cycle model per repeat and median the results"
                if progress:
                    # Wall-clock launch cost is reported but NEVER stored:
                    # the document must regenerate bit-for-bit from the seed.
                    med = np.median([p["launch_us_per_descriptor"]
                                     for p in passes])
                    print(f"  {arch}/{workload}/ch{channels}: "
                          f"launch {med:.2f} us/desc (wall-clock, unstored)",
                          file=sys.stderr)
                for mem_latency in spec.mem_latencies:
                    sim = simulate_multichannel(
                        channels, mem_latency, transfer_bytes,
                        num_transfers=scale.sim_transfers)
                    spec_key = (mem_latency, transfer_bytes, hit,
                                scale.sim_transfers)
                    if spec_key not in spec_cache:
                        spec_cache[spec_key] = _speculation_pass(*spec_key)
                    spec_metrics, trajectory = spec_cache[spec_key]
                    if spec.translation:
                        tr_key = (mem_latency, transfer_bytes,
                                  scale.sim_transfers)
                        if tr_key not in translation_cache_pass:
                            translation_cache_pass[tr_key] = \
                                _translation_pass(*tr_key)
                        speedup = translation_cache_pass[tr_key]
                    else:
                        speedup = 1.0
                    total = channels * scale.sim_transfers
                    key = cell_key(arch, workload, channels, mem_latency)
                    cells[key] = {
                        "kind": "dma",
                        "arch": arch,
                        "workload": workload,
                        "channels": channels,
                        "mem_latency": mem_latency,
                        "metrics": {
                            "bus_utilization":
                                float(sim.aggregate_utilization),
                            "launch_cycles_per_transfer":
                                float(sim.cycles / total),
                            "coalesce_merge_ratio": merge,
                            "speculation_hit_rate": hit,
                            "translation_cache_hit_rate": cache_hit,
                            "translation_launch_speedup": speedup,
                            **spec_metrics,
                        },
                        "speculation": trajectory,
                        "counters": passes[0]["counters"],
                    }
                    if progress:
                        print(f"  {key}: "
                              f"util={cells[key]['metrics']['bus_utilization']:.3f} "
                              f"merge={merge:.2f} hit={hit:.2f} "
                              f"cache={cache_hit:.2f} "
                              f"speedup={speedup:.2f}x "
                              f"spec(fixed4="
                              f"{spec_metrics['spec_bus_utilization_fixed4']:.3f}, "
                              f"adaptive="
                              f"{spec_metrics['spec_bus_utilization_adaptive']:.3f})",
                              file=sys.stderr)

    serve_cells = []
    if spec.include_serve:
        serve_spec = DEFAULT_SERVE_SPEC
        serve_metrics, serve_counters = run_serve_cell(spec.seed, serve_spec)
        serve_cells = [serve_spec.cell_key]
        cells[serve_spec.cell_key] = {
            "kind": "serve",
            "arch": serve_spec.arch,
            "workload": "serve",
            "capacity": serve_spec.capacity,
            "n_requests": serve_spec.n_requests,
            "metrics": serve_metrics,
            "counters": serve_counters,
        }
        if progress:
            print(f"  {serve_spec.cell_key}: " + " ".join(
                f"{k}={v:.3f}" for k, v in serve_metrics.items()
                if isinstance(v, (int, float))),
                file=sys.stderr)

    sharded_cells = []
    if spec.include_sharded:
        for mesh in spec.mesh_sizes:
            key, cell = sharded_cell_entry(
                spec.seed, mesh, DEFAULT_SHARDED_SPEC,
                repeats=spec.repeats)
            cells[key] = cell
            sharded_cells.append(key)
            if progress:
                print(f"  {key}: " + " ".join(
                    f"{k}={v:.3f}" for k, v in cell["metrics"].items()),
                    file=sys.stderr)

    mmu_cells = []
    if spec.iotlb:
        for key, cell in mmu_cell_entries(spec.seed, spec.mem_latencies,
                                          DEFAULT_MMU_SPEC):
            cells[key] = cell
            mmu_cells.append(key)
            if progress:
                print(f"  {key}: " + " ".join(
                    f"{k}={v:.3f}" for k, v in cell["metrics"].items()),
                    file=sys.stderr)

    transform_cells = []
    if spec.include_transforms:
        for key, cell in transform_cell_entries(
                spec.seed, DEFAULT_TRANSFORM_SPEC,
                quick=spec.mode == "quick"):
            cells[key] = cell
            transform_cells.append(key)
            if progress:
                print(f"  {key}: " + " ".join(
                    f"{k}={v:.3f}" for k, v in cell["metrics"].items()),
                    file=sys.stderr)

    return {
        "schema_version": SCHEMA_VERSION,
        "mode": spec.mode,
        "seed": spec.seed,
        "repeats": spec.repeats,
        "translation_cache_enabled": spec.translation,
        "iotlb_enabled": spec.iotlb,
        "dimensions": {
            "archs": list(spec.archs),
            "workloads": list(spec.workloads),
            "channel_counts": list(spec.channel_counts),
            "mem_latencies": list(spec.mem_latencies),
            "serve_cells": serve_cells,
            "mesh_sizes": list(spec.mesh_sizes),
            "sharded_cells": sharded_cells,
            "transform_cells": transform_cells,
            "mmu_cells": mmu_cells,
        },
        "gated_metrics": list(GATED_METRICS),
        "serve_gated_metrics": list(SERVE_GATED_METRICS),
        "sharded_gated_metrics": list(SHARDED_GATED_METRICS),
        "transform_gated_metrics": list(TRANSFORM_GATED_METRICS),
        "mmu_gated_metrics": list(MMU_GATED_METRICS),
        "cells": cells,
    }


def spec_from_doc(doc: Dict[str, object]) -> SweepSpec:
    """Rebuild the exact spec a document was generated with."""
    dims = doc["dimensions"]
    return default_spec(
        doc["mode"], int(doc["seed"]),
        archs=dims["archs"], workloads=dims["workloads"],
        channel_counts=dims["channel_counts"],
        mem_latencies=dims["mem_latencies"],
        repeats=int(doc["repeats"]),
        include_serve=bool(dims.get("serve_cells")),
        mesh_sizes=dims.get("mesh_sizes", MESH_SIZES),
        include_sharded=bool(dims.get("sharded_cells")),
        include_transforms=bool(dims.get("transform_cells")),
        translation=bool(doc.get("translation_cache_enabled", True)),
        iotlb=bool(doc.get("iotlb_enabled", True)),
    )


def write_doc(doc: Dict[str, object], path: str) -> None:
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.perf.sweep",
        description="Run the scenario sweep and write BENCH_perf.json.")
    ap.add_argument("--out", default="BENCH_perf.json")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--quick", dest="mode", action="store_const",
                      const="quick", help="reduced CI sweep (default)")
    mode.add_argument("--full", dest="mode", action="store_const",
                      const="full", help="full baseline sweep")
    ap.set_defaults(mode="quick")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-translation-cache", action="store_true",
                    help="run the legacy uncached dispatch path (hit rate "
                         "0.0, speedup 1.0; recorded in the document)")
    ap.add_argument("--no-iotlb", action="store_true",
                    help="skip the MMU/IOTLB cells (schema v8); recorded "
                         "as iotlb_enabled=false in the document")
    ap.add_argument("--progress", action="store_true")
    args = ap.parse_args(argv)

    doc = run_sweep(default_spec(args.mode, args.seed,
                                 translation=not args.no_translation_cache,
                                 iotlb=not args.no_iotlb),
                    progress=args.progress)
    write_doc(doc, args.out)
    print(f"wrote {args.out}: {len(doc['cells'])} cells "
          f"(mode={args.mode}, seed={args.seed})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
