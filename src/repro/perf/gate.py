"""Perf-regression gate: compare a sweep against a committed baseline.

``python -m repro.perf.gate --baseline BENCH_perf.json`` re-runs the sweep
with the exact spec recorded inside the baseline document (mode, seed,
repeats, dimensions — so the comparison is seeded-median vs seeded-median)
and fails with a nonzero exit when any gated metric regresses past its
tolerance band. Every failure names the cell (arch/workload/channels/L)
and the metric, so a red CI run points at *what* eroded, not just *that*
something did.

Comparison semantics (DESIGN.md §4):

* metrics have a polarity — ``bus_utilization``, ``coalesce_merge_ratio``
  and ``speculation_hit_rate`` regress *downward*,
  ``launch_cycles_per_transfer`` regresses *upward*;
* a cell fails when the relative change in the bad direction exceeds the
  metric's tolerance band (improvements never fail, however large);
* a baseline cell or metric missing from the current run is an *error*
  (exit 2), not a pass — silence must never look green;
* schema-version or spec mismatches between the documents are errors too.

Exit codes: 0 pass, 1 regression, 2 malformed/incomparable documents.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import Dict, List, Optional, Sequence

from .mmu_cell import MMU_GATED_METRICS
from .serve_cell import SERVE_GATED_METRICS
from .sharded_cell import SHARDED_GATED_METRICS
from .transform_cell import TRANSFORM_GATED_METRICS
from .sweep import (
    GATED_METRICS,
    SCHEMA_VERSION,
    run_sweep,
    spec_from_doc,
    write_doc,
)


class GateError(Exception):
    """The documents cannot be compared (schema, spec, or coverage)."""


#: Relative tolerance bands per gated metric (fraction of baseline value).
DEFAULT_TOLERANCES: Dict[str, float] = {
    "bus_utilization": 0.03,
    "launch_cycles_per_transfer": 0.05,
    "coalesce_merge_ratio": 0.03,
    "speculation_hit_rate": 0.03,
    "spec_bus_utilization_fixed4": 0.03,
    "spec_bus_utilization_adaptive": 0.03,
    # Serve-path scheduling metrics are small-integer ratios: identical on
    # an unchanged tree, so the band only absorbs intentional re-scoping.
    "admission_stall_rate": 0.10,
    "completion_poll_latency_steps": 0.10,
    "serve_steps_per_request": 0.05,
    # Sharded mesh cells (DESIGN.md §6). Migration cycles sit on a
    # saturating interconnect, so queueing amplifies small plan changes —
    # the wider band absorbs that without letting real fabric regressions
    # (an extra hop per plan, a lost merge) through.
    "cross_shard_migration_cycles": 0.05,
    "per_shard_bus_utilization": 0.03,
    "migration_chain_merge_ratio": 0.03,
    # Async-fabric sharded metrics (schema v7, DESIGN.md §10). Overlap and
    # resize retention are logical-round ratios from the deterministic
    # fabric clock (exact on an unchanged tree); the stall p99 rides the
    # contended per-link interconnect model, so it gets the same queueing
    # band as the migration-cycle mean. Convergence steps are a small
    # integer, so the band only absorbs intentional planner re-tuning.
    "migration_overlap_ratio": 0.03,
    "p99_migration_stall_cycles": 0.05,
    "rebalance_convergence_steps": 0.10,
    "throughput_retained_during_resize": 0.03,
    # Chain-lowering translation cache (DESIGN.md §7). Steady-state hit
    # rate is a counter-delta ratio (deterministic on an unchanged tree);
    # launch speedup comes from the cycle model, also deterministic.
    "translation_cache_hit_rate": 0.03,
    "translation_launch_speedup": 0.05,
    # Serve tail latency (schema v5, DESIGN.md §8): medians move only when
    # scheduling changes; the p99 band is wider because a single request's
    # latency shift can move the tail of a small seeded cell.
    "request_latency_steps_p50": 0.05,
    "request_latency_steps_p99": 0.10,
    # Per-percentile bands of the histogram-valued metric; overridable as
    # --tolerance request_latency_steps.p95=0.2 etc.
    "request_latency_steps.p50": 0.05,
    "request_latency_steps.p95": 0.10,
    "request_latency_steps.p99": 0.10,
    # In-flight transform cells (schema v6, DESIGN.md §9). Bandwidths come
    # from the deterministic cycle model; fidelity is a seeded roundtrip
    # through the numpy oracle, so all of these are exact on an unchanged
    # tree and the bands only absorb intentional re-scoping.
    "effective_bandwidth_fp32": 0.03,
    "effective_bandwidth_int8": 0.03,
    "effective_bandwidth_gain": 0.03,
    "fidelity_max_rel_err": 0.10,
    "transform_fusion_hit_rate": 0.03,
    # MMU/IOTLB cells (schema v8, DESIGN.md §11). Every number comes from
    # the deterministic cycle model or the page-table cost model (exact
    # on an unchanged tree); the bands only absorb intentional re-tuning
    # of the walk/prefetch parameters.
    "tlb_hit_rate": 0.03,
    "walk_stall_cycles": 0.05,
    "defrag_remap_cycles": 0.05,
    "defrag_copy_cycles": 0.05,
    # Ownership-first migration (sharded cells, schema v8): first-touch
    # rounds ride the deterministic fabric clock; small integers, so the
    # band only absorbs intentional pull-path re-scoping.
    "first_touch_latency_rounds": 0.10,
}

#: Histogram-valued gated metrics (schema v5): the cell stores the full
#: snapshot dict; the gate compares it at these named percentiles, each
#: with its own tolerance band (keyed ``metric.percentile`` above).
HISTOGRAM_METRICS: Dict[str, Sequence[str]] = {
    "request_latency_steps": ("p50", "p95", "p99"),
}

#: +1 -> higher is better (regression = drop); -1 -> lower is better.
METRIC_POLARITY: Dict[str, int] = {
    "bus_utilization": +1,
    "launch_cycles_per_transfer": -1,
    "coalesce_merge_ratio": +1,
    "speculation_hit_rate": +1,
    "spec_bus_utilization_fixed4": +1,
    "spec_bus_utilization_adaptive": +1,
    "admission_stall_rate": -1,
    "completion_poll_latency_steps": -1,
    "serve_steps_per_request": -1,
    "cross_shard_migration_cycles": -1,
    "per_shard_bus_utilization": +1,
    "migration_chain_merge_ratio": +1,
    "migration_overlap_ratio": +1,
    "p99_migration_stall_cycles": -1,
    "rebalance_convergence_steps": -1,
    "throughput_retained_during_resize": +1,
    "translation_cache_hit_rate": +1,
    "translation_launch_speedup": +1,
    "request_latency_steps_p50": -1,
    "request_latency_steps_p99": -1,
    "request_latency_steps": -1,   # applied at each gated percentile
    "effective_bandwidth_fp32": +1,
    "effective_bandwidth_int8": +1,
    "effective_bandwidth_gain": +1,
    "fidelity_max_rel_err": -1,
    "transform_fusion_hit_rate": +1,
    "tlb_hit_rate": +1,
    "walk_stall_cycles": -1,
    "defrag_remap_cycles": -1,
    "defrag_copy_cycles": -1,
    "first_touch_latency_rounds": -1,
}

ALL_GATED_METRICS = (tuple(GATED_METRICS) + tuple(SERVE_GATED_METRICS)
                     + tuple(SHARDED_GATED_METRICS)
                     + tuple(TRANSFORM_GATED_METRICS)
                     + tuple(MMU_GATED_METRICS))

_KIND_METRICS = {
    "serve": SERVE_GATED_METRICS,
    "sharded": SHARDED_GATED_METRICS,
    "transform": TRANSFORM_GATED_METRICS,
    "mmu": MMU_GATED_METRICS,
}


def metrics_for_cell(cell: Dict[str, object]) -> Sequence[str]:
    """The gated metric set a cell must carry, by cell kind."""
    return _KIND_METRICS.get(cell.get("kind"), GATED_METRICS)


@dataclasses.dataclass(frozen=True)
class Regression:
    cell: str
    metric: str
    baseline: float
    current: float
    rel_change: float       # signed, in the metric's natural direction
    tolerance: float

    @property
    def message(self) -> str:
        return (f"REGRESSION cell={self.cell} metric={self.metric} "
                f"baseline={self.baseline:.6g} current={self.current:.6g} "
                f"({self.rel_change:+.2%} exceeds "
                f"{self.tolerance:.0%} tolerance)")


def load_doc(path: str) -> Dict[str, object]:
    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        raise GateError(f"baseline document not found: {path}")
    except json.JSONDecodeError as e:
        raise GateError(f"{path} is not valid JSON: {e}")
    check_schema(doc, path)
    return doc


_REQUIRED_DIMS = ("archs", "workloads", "channel_counts", "mem_latencies")


def check_schema(doc: Dict[str, object], label: str = "document") -> None:
    version = doc.get("schema_version")
    if version != SCHEMA_VERSION:
        raise GateError(
            f"{label}: schema_version {version!r} does not match this "
            f"tool's schema {SCHEMA_VERSION}; regenerate the baseline with "
            "`python -m repro.perf.sweep` (see DESIGN.md §4 re-baselining)")
    if not isinstance(doc.get("cells"), dict) or not doc["cells"]:
        raise GateError(f"{label}: no cells — not a sweep document")
    for key in ("mode", "seed", "repeats"):
        if key not in doc:
            raise GateError(
                f"{label}: missing {key!r} — malformed sweep document; "
                "regenerate it")
    dims = doc.get("dimensions")
    if not isinstance(dims, dict) or any(d not in dims
                                         for d in _REQUIRED_DIMS):
        raise GateError(
            f"{label}: missing or incomplete 'dimensions' (need "
            f"{_REQUIRED_DIMS}) — malformed sweep document; regenerate it")


def compare(
    baseline: Dict[str, object],
    current: Dict[str, object],
    tolerances: Optional[Dict[str, float]] = None,
) -> List[Regression]:
    """All tolerance-band violations of ``current`` vs ``baseline``.

    Raises :class:`GateError` when the documents are incomparable: schema
    mismatch, a baseline cell absent from the current run, or a gated
    metric absent from a present cell.
    """
    check_schema(baseline, "baseline")
    check_schema(current, "current")
    tol = dict(DEFAULT_TOLERANCES)
    if tolerances:
        tol.update(tolerances)

    regressions: List[Regression] = []
    cur_cells = current["cells"]
    for key, cell in sorted(baseline["cells"].items()):
        cur = cur_cells.get(key)
        if cur is None:
            raise GateError(
                f"cell {key} present in baseline but missing from current "
                "run — sweep coverage shrank (did the registry or workload "
                "set change without re-baselining?)")
        base_metrics = cell.get("metrics")
        if not isinstance(base_metrics, dict):
            raise GateError(
                f"cell {key}: baseline cell has no metrics dict — the "
                "baseline document is malformed; regenerate it")
        cur_metrics = cur.get("metrics", {})
        for metric in metrics_for_cell(cell):
            if metric not in base_metrics:
                raise GateError(
                    f"cell {key}: gated metric {metric!r} missing from "
                    "baseline — the baseline predates this metric; "
                    "re-baseline (DESIGN.md §4)")
            if metric not in cur_metrics:
                raise GateError(
                    f"cell {key}: gated metric {metric!r} missing from "
                    "current run — the sweep stopped measuring it")
            polarity = METRIC_POLARITY[metric]
            if metric in HISTOGRAM_METRICS:
                # Histogram-valued metric (schema v5): compare the stored
                # snapshot at each named percentile, each under its own
                # tolerance band. Absolute floor of one bucket absorbs
                # integer-step jitter around tiny baselines (a 2-step p50
                # moving to 3 is not a 50% regression worth failing on).
                base_snap, cur_snap = base_metrics[metric], cur_metrics[metric]
                if not isinstance(base_snap, dict) \
                        or not isinstance(cur_snap, dict):
                    raise GateError(
                        f"cell {key}: metric {metric!r} should be a "
                        "histogram snapshot dict in both documents; "
                        "re-baseline (DESIGN.md §8)")
                for pct in HISTOGRAM_METRICS[metric]:
                    if pct not in base_snap or pct not in cur_snap:
                        raise GateError(
                            f"cell {key}: histogram metric {metric!r} "
                            f"lacks percentile {pct!r}; re-baseline")
                    base_v = float(base_snap[pct])
                    cur_v = float(cur_snap[pct])
                    denom = max(abs(base_v), 1e-12)
                    rel = (cur_v - base_v) / denom
                    band = tol.get(f"{metric}.{pct}", 0.10)
                    if polarity * rel < -band and abs(cur_v - base_v) > 1.0:
                        regressions.append(Regression(
                            cell=key, metric=f"{metric}.{pct}",
                            baseline=base_v, current=cur_v,
                            rel_change=rel, tolerance=band))
                continue
            base_v = float(base_metrics[metric])
            cur_v = float(cur_metrics[metric])
            denom = max(abs(base_v), 1e-12)
            rel = (cur_v - base_v) / denom
            band = tol.get(metric, 0.05)
            if polarity * rel < -band:
                regressions.append(Regression(
                    cell=key, metric=metric, baseline=base_v,
                    current=cur_v, rel_change=rel, tolerance=band))
    return regressions


#: The dimensions a quick (CI) sweep covers; --quick gates this subset.
_QUICK_CHANNELS = (4,)
_QUICK_LATENCIES = (13, 100)


def quick_subset(doc: Dict[str, object]):
    """Restrict a baseline to the quick sweep dimensions (ch4, L13/L100).

    Lets CI gate a reduced sweep against a *full-mode* baseline: the
    returned document keeps the baseline's mode/scale (so re-run cells
    stay comparable) but drops cells outside the quick channel/latency
    axes. Returns ``(subset_doc, n_dropped)``; raises GateError when
    nothing remains (the baseline never covered the quick dimensions).
    """
    dims = doc["dimensions"]
    ch = [c for c in dims["channel_counts"] if c in _QUICK_CHANNELS]
    lat = [m for m in dims["mem_latencies"] if m in _QUICK_LATENCIES]
    # Serve and sharded cells are already reduced-config; the quick sweep
    # always runs them, so they always stay gated. Transform cells keep
    # only the quick (size, latency) grid a reduced sweep regenerates.
    from .transform_cell import DEFAULT_TRANSFORM_SPEC
    cells = {k: c for k, c in doc["cells"].items()
             if (c.get("kind") == "transform"
                 and c.get("mem_latency") in DEFAULT_TRANSFORM_SPEC
                 .mem_latencies
                 and c.get("transfer_bytes") in DEFAULT_TRANSFORM_SPEC
                 .transfer_bytes)
             or c.get("kind") in ("serve", "sharded")
             or (c.get("kind") == "mmu" and c.get("mem_latency") in lat)
             or (c.get("kind") == "dma" and c.get("channels") in ch
                 and c.get("mem_latency") in lat)}
    if not cells:
        raise GateError(
            "--quick: baseline has no cells in the quick dimensions "
            f"(channels {_QUICK_CHANNELS}, latencies {_QUICK_LATENCIES}); "
            "run without --quick or re-baseline")
    out = dict(doc)
    out["dimensions"] = dict(dims, channel_counts=ch, mem_latencies=lat)
    out["cells"] = cells
    return out, len(doc["cells"]) - len(cells)


def speculation_summary(doc: Dict[str, object]) -> str:
    """Adaptive-vs-fixed utilization delta, per workload and overall.

    Printed with every gate verdict (and into the CI job summary): the
    live evidence for the §II-C adaptive-policy claim — adaptive matches
    fixed-depth-4 on sequential streams and beats it on MoE dispatch
    storms (DESIGN.md §5).
    """
    per_workload: Dict[str, List[float]] = {}
    for cell in doc["cells"].values():
        m = cell.get("metrics", {})
        fixed = m.get("spec_bus_utilization_fixed4")
        adaptive = m.get("spec_bus_utilization_adaptive")
        if fixed is None or adaptive is None:
            continue
        delta = (adaptive - fixed) / max(abs(fixed), 1e-12)
        per_workload.setdefault(cell.get("workload", "?"), []).append(delta)
    if not per_workload:
        return "speculation: no adaptive-vs-fixed cells in this document"
    lines = ["speculation: adaptive vs fixed-depth-4 bus utilization"]
    all_deltas: List[float] = []
    for wl in sorted(per_workload):
        ds = per_workload[wl]
        all_deltas.extend(ds)
        lines.append(f"  {wl:<14} mean {sum(ds) / len(ds):+8.1%}  "
                     f"min {min(ds):+8.1%}  ({len(ds)} cells)")
    lines.append(f"  {'overall':<14} mean "
                 f"{sum(all_deltas) / len(all_deltas):+8.1%}")
    return "\n".join(lines)


def sharded_summary(doc: Dict[str, object]) -> str:
    """Per-mesh-size migration table (printed with every gate verdict and
    into the CI job summary, next to the adaptive-vs-fixed delta)."""
    rows = sorted(
        ((int(c.get("mesh", 0)), c.get("metrics", {}))
         for c in doc["cells"].values() if c.get("kind") == "sharded"),
        key=lambda r: r[0])
    if not rows:
        return "sharded: no mesh cells in this document"
    lines = ["sharded: cross-shard migration by mesh size",
             f"  {'mesh':>4}  {'migration_cycles':>16}  "
             f"{'per_shard_util':>14}  {'merge_ratio':>11}  "
             f"{'overlap':>7}  {'stall_p99':>9}  {'rebal':>5}  "
             f"{'retained':>8}  {'1st_touch':>9}"]
    for mesh, m in rows:
        lines.append(
            f"  {mesh:>4}  "
            f"{m.get('cross_shard_migration_cycles', float('nan')):>16.1f}  "
            f"{m.get('per_shard_bus_utilization', float('nan')):>14.3f}  "
            f"{m.get('migration_chain_merge_ratio', float('nan')):>11.2f}  "
            f"{m.get('migration_overlap_ratio', float('nan')):>7.2f}  "
            f"{m.get('p99_migration_stall_cycles', float('nan')):>9.1f}  "
            f"{m.get('rebalance_convergence_steps', float('nan')):>5.0f}  "
            f"{m.get('throughput_retained_during_resize', float('nan')):>8.2f}  "
            f"{m.get('first_touch_latency_rounds', float('nan')):>9.0f}")
    return "\n".join(lines)


def mmu_summary(doc: Dict[str, object]) -> str:
    """IOTLB + remap-vs-copy defrag table (schema v8, DESIGN.md §11).

    The live evidence for the MMU-aware paging claims: chain-lookahead
    translation prefetch keeps the sequential paged-KV stream >= 0.9
    IOTLB hit rate, and remap-defrag undercuts copy-defrag at every
    memory latency."""
    if not doc.get("iotlb_enabled", True):
        return "mmu: IOTLB cells disabled in this document (--no-iotlb)"
    rows = sorted(
        ((int(c.get("mem_latency", 0)), c.get("metrics", {}),
          c.get("counters", {}))
         for c in doc["cells"].values() if c.get("kind") == "mmu"))
    if not rows:
        return "mmu: no MMU cells in this document"
    lines = ["mmu: IOTLB hit rate and remap-vs-copy defrag by latency",
             f"  {'L':>3}  {'tlb_hit':>7}  {'walk_stall':>10}  "
             f"{'remap_cyc':>9}  {'copy_cyc':>8}  {'speedup':>7}"]
    for lat, m, c in rows:
        remap = m.get("defrag_remap_cycles", float("nan"))
        copy = m.get("defrag_copy_cycles", float("nan"))
        lines.append(
            f"  {lat:>3}  "
            f"{m.get('tlb_hit_rate', float('nan')):>7.3f}  "
            f"{m.get('walk_stall_cycles', float('nan')):>10.0f}  "
            f"{remap:>9.0f}  {copy:>8.0f}  "
            f"{copy / max(remap, 1.0):>6.1f}x")
    return "\n".join(lines)


def translation_summary(doc: Dict[str, object]) -> str:
    """Per-workload translation-cache table (DESIGN.md §7).

    Steady-state cache hit rate and cycle-model launch speedup, the live
    evidence for the chain-lowering claim: structurally-identical serve
    chains re-dispatch cached artifacts (hit rate -> 1.0) and the cached
    frontend beats the §II-A serialized baseline by ≥1.66x at
    64-byte-class units.
    """
    if not doc.get("translation_cache_enabled", True):
        return "translation: cache disabled in this document " \
               "(--no-translation-cache)"
    per_workload: Dict[str, List[tuple]] = {}
    for cell in doc["cells"].values():
        m = cell.get("metrics", {})
        hit = m.get("translation_cache_hit_rate")
        speedup = m.get("translation_launch_speedup")
        if hit is None or speedup is None:
            continue
        per_workload.setdefault(cell.get("workload", "?"), []).append(
            (hit, speedup))
    if not per_workload:
        return "translation: no translation-cache cells in this document"
    lines = ["translation: chain-lowering cache by workload",
             f"  {'workload':<14} {'hit_rate':>8}  {'min_hit':>7}  "
             f"{'speedup':>7}  {'max_speedup':>11}"]
    for wl in sorted(per_workload):
        rows = per_workload[wl]
        hits = [r[0] for r in rows]
        sps = [r[1] for r in rows]
        lines.append(f"  {wl:<14} {sum(hits) / len(hits):>8.3f}  "
                     f"{min(hits):>7.3f}  {sum(sps) / len(sps):>6.2f}x  "
                     f"{max(sps):>10.2f}x  ({len(rows)} cells)")
    return "\n".join(lines)


def transform_summary(doc: Dict[str, object]) -> str:
    """Per-size int8-vs-fp32 effective-bandwidth table (DESIGN.md §9).

    The live evidence for the in-flight transform claim: a quantized KV
    transfer moves fewer bus beats at equal logical payload (gain > 1)
    without trading away roundtrip fidelity, and every transform plan is
    served by a transform-fused compiled executor.
    """
    rows = sorted(
        ((int(c.get("transfer_bytes", 0)), int(c.get("mem_latency", 0)),
          c.get("metrics", {}))
         for c in doc["cells"].values() if c.get("kind") == "transform"))
    if not rows:
        return "transform: no transform cells in this document"
    lines = ["transform: EF-int8 KV quantize vs fp32 effective bandwidth",
             f"  {'bytes':>6}  {'L':>3}  {'bw_fp32':>8}  {'bw_int8':>8}  "
             f"{'gain':>6}  {'fidelity':>8}  {'fusion':>6}"]
    for nbytes, lat, m in rows:
        lines.append(
            f"  {nbytes:>6}  {lat:>3}  "
            f"{m.get('effective_bandwidth_fp32', float('nan')):>8.3f}  "
            f"{m.get('effective_bandwidth_int8', float('nan')):>8.3f}  "
            f"{m.get('effective_bandwidth_gain', float('nan')):>5.2f}x  "
            f"{m.get('fidelity_max_rel_err', float('nan')):>8.5f}  "
            f"{m.get('transform_fusion_hit_rate', float('nan')):>6.2f}")
    return "\n".join(lines)


def serve_latency_summary(doc: Dict[str, object]) -> str:
    """p50/p99 request-latency table over the serve cells (DESIGN.md §8).

    The tail-latency evidence the ROADMAP's continuous-batching work
    gates on — printed with every verdict and into the CI job summary.
    """
    rows = []
    for key, cell in sorted(doc["cells"].items()):
        if cell.get("kind") != "serve":
            continue
        m = cell.get("metrics", {})
        snap = m.get("request_latency_steps")
        if not isinstance(snap, dict):
            continue
        rows.append((key, m.get("request_latency_steps_p50", float("nan")),
                     snap.get("p95", float("nan")),
                     m.get("request_latency_steps_p99", float("nan")),
                     float(snap.get("sum", 0)) / max(int(snap.get("n", 0)), 1),
                     int(snap.get("n", 0))))
    if not rows:
        return "serve latency: no serve-cell histograms in this document"
    lines = ["serve latency: request p50/p99 (decode steps, exact buckets)",
             f"  {'cell':<28} {'p50':>6}  {'p95':>6}  {'p99':>6}  "
             f"{'mean':>7}  {'n':>4}"]
    for key, p50, p95, p99, mean, n in rows:
        lines.append(f"  {key:<28} {p50:>6.1f}  {p95:>6.1f}  {p99:>6.1f}  "
                     f"{mean:>7.2f}  {n:>4d}")
    return "\n".join(lines)


def _emit_summary(doc: Dict[str, object]) -> None:
    spec_text = speculation_summary(doc)
    sharded_text = sharded_summary(doc)
    translation_text = translation_summary(doc)
    transform_text = transform_summary(doc)
    serve_text = serve_latency_summary(doc)
    mmu_text = mmu_summary(doc)
    print(spec_text)
    print(sharded_text)
    print(translation_text)
    print(transform_text)
    print(serve_text)
    print(mmu_text)
    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if step_summary:
        with open(step_summary, "a") as f:
            f.write("### Perf gate — adaptive vs fixed speculation\n\n"
                    "```\n" + spec_text + "\n```\n")
            f.write("### Perf gate — sharded mesh cells\n\n"
                    "```\n" + sharded_text + "\n```\n")
            f.write("### Perf gate — translation cache\n\n"
                    "```\n" + translation_text + "\n```\n")
            f.write("### Perf gate — in-flight transforms (int8 vs fp32)\n\n"
                    "```\n" + transform_text + "\n```\n")
            f.write("### Perf gate — serve request latency (p50/p99)\n\n"
                    "```\n" + serve_text + "\n```\n")
            f.write("### Perf gate — MMU/IOTLB cells\n\n"
                    "```\n" + mmu_text + "\n```\n")


def _parse_tolerances(pairs: Sequence[str]) -> Dict[str, float]:
    hist_keys = tuple(f"{m}.{p}" for m, pcts in HISTOGRAM_METRICS.items()
                      for p in pcts)
    out: Dict[str, float] = {}
    for p in pairs:
        if "=" not in p:
            raise GateError(
                f"--tolerance expects metric=fraction, got {p!r}")
        k, v = p.split("=", 1)
        if k not in ALL_GATED_METRICS and k not in hist_keys:
            raise GateError(
                f"--tolerance: unknown metric {k!r}; "
                f"have {ALL_GATED_METRICS + hist_keys}")
        try:
            out[k] = float(v)
        except ValueError:
            raise GateError(f"--tolerance: {v!r} is not a number")
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.perf.gate",
        description="Compare a perf sweep against a committed baseline; "
                    "exit 1 on regression.")
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_perf.json to compare against")
    ap.add_argument("--current",
                    help="precomputed sweep document; omitted -> re-run the "
                         "sweep with the baseline's recorded spec")
    ap.add_argument("--quick", action="store_true",
                    help="gate only the quick-dimension subset of the "
                         "baseline (ch=4, L in {13,100}) — the reduced "
                         "sweep CI runs; errors if the baseline never "
                         "covered those dimensions")
    ap.add_argument("--out",
                    help="also write the current sweep document here "
                         "(e.g. for CI artifact upload)")
    ap.add_argument("--tolerance", action="append", default=[],
                    metavar="METRIC=FRACTION",
                    help="override a tolerance band, repeatable")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write the current sweep over --baseline instead "
                         "of comparing (re-baselining, DESIGN.md §4)")
    args = ap.parse_args(argv)

    try:
        if args.quick and args.update_baseline:
            raise GateError(
                "--update-baseline with --quick would shrink the baseline "
                "to the quick subset; re-baseline from a full sweep")
        baseline = load_doc(args.baseline)
        tolerances = _parse_tolerances(args.tolerance)
        if args.quick:
            baseline, dropped = quick_subset(baseline)
            if dropped:
                print(f"--quick: gating {len(baseline['cells'])} of "
                      f"{len(baseline['cells']) + dropped} baseline cells "
                      "(quick dimensions; the rest need a full run)")
        if args.current:
            current = load_doc(args.current)
        else:
            spec = spec_from_doc(baseline)
            print(f"re-running sweep: mode={spec.mode} seed={spec.seed} "
                  f"repeats={spec.repeats} "
                  f"({len(baseline['cells'])} baseline cells)")
            current = run_sweep(spec)
        if args.out:
            write_doc(current, args.out)
            print(f"wrote current sweep to {args.out}")
        if args.update_baseline:
            write_doc(current, args.baseline)
            print(f"re-baselined {args.baseline} "
                  f"({len(current['cells'])} cells)")
            return 0
        regressions = compare(baseline, current, tolerances)
    except GateError as e:
        print(f"GATE ERROR: {e}", file=sys.stderr)
        return 2

    _emit_summary(current)
    n = len(baseline["cells"])
    if regressions:
        for r in regressions:
            print(r.message, file=sys.stderr)
        print(f"perf gate: FAIL — {len(regressions)} regression(s) "
              f"across {n} cells", file=sys.stderr)
        return 1
    print(f"perf gate: PASS — {n} cells within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
