"""Deterministic, seeded workload generators for the perf sweep.

Every generator maps an architecture's :class:`repro.configs.base.ModelConfig`
to descriptor-chain traffic whose *shape* tracks that model: KV-page size
follows the head dimension, MoE dispatch fan-out follows the expert count
and top-k, token rows follow ``d_model``. The four families cover the
irregular-transfer space of the paper (§II-B) plus the serve-path patterns
the runtime was built for:

* ``paged_kv``     — serving bursts gathering mostly-sequential KV page runs
                     with fragmentation gaps (the allocator's sequential
                     preference; high coalesce + high §II-C hit rate);
* ``moe_dispatch`` — dispatch storms scattering token rows into per-expert
                     buffers in random arrival order (low coalesce, low hit
                     rate: the adversarial stream);
* ``chain_mix``    — one sequential, one strided, one random-permuted chain
                     per burst (the Fig-4 style microscopic patterns);
* ``defrag_churn`` — allocator churn: a partially-freed page map compacted
                     toward the front (mid coalesce, sequential layout).

Determinism contract: ``generate(name, cfg, scale, seed)`` is a pure
function of its arguments — the RNG is seeded from ``(seed, arch, name)``
only, so BENCH_perf.json baselines regenerate bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.descriptor import DescriptorArray

ELEM_BYTES = 4     # pools are float32
_BUS_BYTES = 8     # simulator bus width; transfer_bytes must be a multiple


@dataclasses.dataclass(frozen=True)
class Scale:
    """Sweep sizing knobs (quick = CI, full = local baselines)."""

    name: str
    n_bursts: int        # chains submitted per workload
    burst_len: int       # descriptors per burst, pre-coalesce
    pool_elems: int      # src/dst pool size in elements
    max_len: int         # serial-tier max burst (elements)
    ring_capacity: int   # per-channel submission-ring slots
    sim_transfers: int   # per-channel transfers in the cycle model


# max_len (the serial engine's static burst window) sits well above the
# page size so coalesced page runs survive the split pass — a 64-elem
# window would cut merged runs straight back into page-sized pieces and
# hide the merge ratio the gate watches.
QUICK = Scale("quick", n_bursts=2, burst_len=96, pool_elems=1 << 14,
              max_len=512, ring_capacity=256, sim_transfers=200)
FULL = Scale("full", n_bursts=4, burst_len=192, pool_elems=1 << 15,
             max_len=512, ring_capacity=512, sim_transfers=400)

SCALES: Dict[str, Scale] = {"quick": QUICK, "full": FULL}


@dataclasses.dataclass
class Workload:
    name: str
    arch: str
    chains: List[DescriptorArray]
    pool_elems: int
    transfer_bytes: int       # representative payload size for the cycle model
    meta: Dict[str, float]


def _rng(seed: int, arch: str, name: str) -> np.random.Generator:
    mix = zlib.crc32(f"{arch}/{name}".encode())
    return np.random.default_rng([seed, mix])


def _clamp(v: int, lo: int, hi: int) -> int:
    return max(lo, min(hi, int(v)))


@dataclasses.dataclass(frozen=True)
class ArchParams:
    """What each generator reads out of a ModelConfig."""

    page_elems: int    # KV page size (elements) ~ head dim
    kv_run: int        # typical sequential page-run length ~ kv heads
    experts: int       # MoE fan-out (surrogate for non-MoE archs)
    topk: int
    token_row: int     # dispatch row size (elements) ~ d_model


def arch_params(cfg: ModelConfig) -> ArchParams:
    return ArchParams(
        page_elems=_clamp(cfg.head_dim_, 8, 64),
        kv_run=_clamp(cfg.num_kv_heads, 2, 16),
        experts=_clamp(cfg.moe.num_experts if cfg.moe else cfg.num_heads,
                       4, 64),
        topk=_clamp(cfg.moe.experts_per_token if cfg.moe else 2, 1, 8),
        token_row=_clamp(cfg.d_model // 128, 4, 32),
    )


def _transfer_bytes(mean_elems: float) -> int:
    b = int(mean_elems * ELEM_BYTES)
    return max(_BUS_BYTES, (b // _BUS_BYTES) * _BUS_BYTES)


def _permuted_chain(src: np.ndarray, dst: np.ndarray, ln: np.ndarray,
                    perm: np.ndarray) -> DescriptorArray:
    """Store a logical (src, dst, ln) sequence at permuted table slots.

    ``perm[i]`` is the storage slot of visit step ``i`` (``perm[0]`` must be
    0: the runtime walks from head slot 0). A shuffled ``perm`` models a
    driver whose descriptor table was written out of walk order, which is
    exactly what defeats the §II-C sequential prefetcher.
    """
    n = len(perm)
    if n == 0 or perm[0] != 0:
        raise ValueError("perm[0] must be 0 (chain head is slot 0)")
    s = np.empty(n, np.int64)
    t = np.empty(n, np.int64)
    ell = np.empty(n, np.int64)
    nxt = np.empty(n, np.int64)
    s[perm] = src
    t[perm] = dst
    ell[perm] = ln
    nxt[perm[:-1]] = perm[1:]
    nxt[perm[-1]] = -1
    return DescriptorArray.create(s, t, ell, nxt=nxt)


def _shuffled_perm(rng: np.random.Generator, n: int) -> np.ndarray:
    perm = np.concatenate([[0], 1 + rng.permutation(n - 1)]) if n > 1 \
        else np.zeros(1, np.int64)
    return perm.astype(np.int64)


# ---------------------------------------------------------------------------
# Generators
# ---------------------------------------------------------------------------

def _paged_kv(cfg: ModelConfig, scale: Scale,
              rng: np.random.Generator) -> Tuple[List[DescriptorArray], int]:
    p = arch_params(cfg)
    n_pages_pool = scale.pool_elems // p.page_elems
    chains = []
    for _ in range(scale.n_bursts):
        page_ids: List[int] = []
        nxt_id = int(rng.integers(0, 8))
        while len(page_ids) < scale.burst_len:
            run = int(rng.integers(max(1, p.kv_run // 2), 2 * p.kv_run))
            page_ids.extend(range(nxt_id, nxt_id + run))
            nxt_id += run + int(rng.integers(1, 4))   # fragmentation gap
        ids = np.asarray(page_ids[:scale.burst_len], np.int64) % n_pages_pool
        src = ids * p.page_elems
        dst = np.arange(scale.burst_len, dtype=np.int64) * p.page_elems
        ln = np.full(scale.burst_len, p.page_elems, np.int64)
        chains.append(DescriptorArray.create(src, dst, ln))
    return chains, _transfer_bytes(p.page_elems)


def _moe_dispatch(cfg: ModelConfig, scale: Scale,
                  rng: np.random.Generator) -> Tuple[List[DescriptorArray], int]:
    p = arch_params(cfg)
    tokens = max(8, scale.burst_len // p.topk)
    expert_cap = scale.pool_elems // p.experts // p.token_row
    chains = []
    for _ in range(scale.n_bursts):
        fill = np.zeros(p.experts, np.int64)
        src = np.empty(tokens * p.topk, np.int64)
        dst = np.empty(tokens * p.topk, np.int64)
        for i in range(tokens):
            picks = rng.choice(p.experts, size=p.topk, replace=False)
            for j, e in enumerate(picks):
                k = i * p.topk + j
                src[k] = (i % (scale.pool_elems // p.token_row)) * p.token_row
                slot = fill[e] % max(expert_cap, 1)
                fill[e] += 1
                dst[k] = (e * expert_cap + slot) * p.token_row
        ln = np.full(len(src), p.token_row, np.int64)
        # Dispatch arrival order is routing order, not table order: the
        # descriptor table fills out of walk order (storm = low hit rate).
        perm = _shuffled_perm(rng, len(src))
        chains.append(_permuted_chain(src, dst, ln, perm))
    return chains, _transfer_bytes(p.token_row)


def _chain_mix(cfg: ModelConfig, scale: Scale,
               rng: np.random.Generator) -> Tuple[List[DescriptorArray], int]:
    p = arch_params(cfg)
    n = max(6, scale.burst_len // 3)
    seg = p.page_elems // 2 or 4
    limit = scale.pool_elems - 2 * n * seg
    chains = []
    for _ in range(scale.n_bursts):
        base_s = int(rng.integers(0, max(limit, 1)))
        base_d = int(rng.integers(0, max(limit, 1)))
        idx = np.arange(n, dtype=np.int64)
        # sequential: src and dst runs abut -> merges into max_len bursts
        chains.append(DescriptorArray.create(
            base_s + idx * seg, base_d + idx * seg,
            np.full(n, seg, np.int64)))
        # strided: 2-D row walk, no abutting ranges, sequential table
        chains.append(DescriptorArray.create(
            (idx * 2 * seg) % limit, (base_d + idx * 2 * seg) % limit,
            np.full(n, seg, np.int64)))
        # random: scattered ranges stored in shuffled table order
        src = rng.integers(0, scale.pool_elems - seg, n)
        dst = rng.integers(0, scale.pool_elems - seg, n)
        chains.append(_permuted_chain(
            src.astype(np.int64), dst.astype(np.int64),
            np.full(n, seg, np.int64), _shuffled_perm(rng, n)))
    return chains, _transfer_bytes(seg)


def _defrag_churn(cfg: ModelConfig, scale: Scale,
                  rng: np.random.Generator) -> Tuple[List[DescriptorArray], int]:
    p = arch_params(cfg)
    n_pages_pool = scale.pool_elems // p.page_elems
    n = min(scale.burst_len, n_pages_pool)
    chains = []
    for _ in range(scale.n_bursts):
        # Occupancy map after churn: ~30 % of pages freed, rest live.
        live = np.flatnonzero(rng.random(n_pages_pool) > 0.3)[:n]
        if len(live) == 0:
            live = np.asarray([0])
        src = live.astype(np.int64) * p.page_elems
        dst = np.arange(len(live), dtype=np.int64) * p.page_elems
        ln = np.full(len(live), p.page_elems, np.int64)
        chains.append(DescriptorArray.create(src, dst, ln))
    return chains, _transfer_bytes(p.page_elems)


def zipf_page_traffic(num_pages: int, n_touches: int, *,
                      alpha: float = 1.1,
                      rng: np.random.Generator,
                      hot_pages: np.ndarray = None) -> np.ndarray:
    """Bounded rank-based Zipf page-reference stream.

    Rank ``r`` (1-based) is touched with probability proportional to
    ``r ** -alpha``; ranks map onto page ids through ``hot_pages`` when
    given (rank 1 == ``hot_pages[0]``) or through a seeded permutation of
    the page space otherwise.  Unlike ``numpy``'s unbounded Zipf sampler
    every draw is a valid page id, so the stream can drive the sharded
    migration cells directly.  Pure function of ``(args, rng state)``.
    """
    if num_pages < 1:
        raise ValueError("num_pages must be >= 1")
    if alpha <= 0:
        raise ValueError("alpha must be > 0")
    weights = 1.0 / np.arange(1, num_pages + 1, dtype=np.float64) ** alpha
    weights /= weights.sum()
    page_of_rank = (np.asarray(hot_pages, np.int64) if hot_pages is not None
                    else rng.permutation(num_pages).astype(np.int64))
    if len(page_of_rank) != num_pages:
        raise ValueError("hot_pages must cover the whole page space")
    ranks = rng.choice(num_pages, size=n_touches, p=weights)
    return page_of_rank[ranks]


_GENERATORS = {
    "paged_kv": _paged_kv,
    "moe_dispatch": _moe_dispatch,
    "chain_mix": _chain_mix,
    "defrag_churn": _defrag_churn,
}

WORKLOAD_NAMES: Tuple[str, ...] = tuple(sorted(_GENERATORS))


def generate(name: str, cfg: ModelConfig, scale: Scale,
             seed: int) -> Workload:
    """Build one deterministic workload for (arch config, scale, seed)."""
    if name not in _GENERATORS:
        raise KeyError(f"unknown workload {name!r}; have {WORKLOAD_NAMES}")
    rng = _rng(seed, cfg.name, name)
    chains, transfer_bytes = _GENERATORS[name](cfg, scale, rng)
    n_desc = sum(c.num_descriptors for c in chains)
    mean_len = float(np.mean(np.concatenate(
        [np.asarray(c.length) for c in chains]))) if n_desc else 0.0
    return Workload(
        name=name, arch=cfg.name, chains=chains,
        pool_elems=scale.pool_elems, transfer_bytes=transfer_bytes,
        meta={"descriptors": n_desc, "mean_length_elems": mean_len},
    )
