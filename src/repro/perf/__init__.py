"""Scenario fleet + perf-regression gate (the repro's perf contract).

Three pieces, layered over the runtime and the cycle simulator:

* :mod:`repro.perf.workloads` — deterministic, seeded descriptor-workload
  generators (paged-KV serving bursts, MoE dispatch storms, mixed chain
  shapes, defragmentation churn) parameterized by every arch in
  :mod:`repro.configs.registry`;
* :mod:`repro.perf.sweep` — drives every (config x workload x channels x
  mem-latency) cell through :class:`repro.runtime.DMARuntime` and
  :func:`repro.core.simulator.simulate_multichannel`, writing the versioned
  ``BENCH_perf.json`` schema;
* :mod:`repro.perf.gate` — statistical baseline comparison (median-of-N,
  per-metric tolerance bands) that exits nonzero on regression:
  ``python -m repro.perf.gate --baseline BENCH_perf.json``.

DESIGN.md §4 documents the contract (metrics, bands, re-baselining).
"""
import importlib

# Lazy re-exports: sweep and gate are also `python -m` entrypoints, and an
# eager import here would shadow runpy's module execution (RuntimeWarning).
_EXPORTS = {
    "Scale": "workloads", "Workload": "workloads",
    "WORKLOAD_NAMES": "workloads", "generate": "workloads",
    "SCHEMA_VERSION": "sweep", "run_sweep": "sweep",
    "default_spec": "sweep", "SweepSpec": "sweep",
    "GateError": "gate", "Regression": "gate", "compare": "gate",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(f".{mod}", __name__), name)
