"""Sharded mesh cells of the perf sweep (DESIGN.md §6, §10).

One cell per mesh size in {1, 2, 4, 8}: Zipf-skewed page migration over a
page space partitioned across that many shards, lowered through the real
:class:`repro.distributed.ShardedKVPool` /
:class:`repro.distributed.ShardedDMARuntime` with the **async fabric**
(each cross-shard hop a non-blocking ticket over per-link occupancy,
DESIGN.md §10), plus the sharded cycle model
(:func:`repro.core.simulator.simulate_sharded`) in both interconnect
modes — ``contended`` (per-directed-link buses, matching the fabric) is
the gated number, ``shared`` (the PR-8 one-bus model, matching the
synchronous fabric) is stored as the synchronous baseline.

Gated metrics (schema v7):

* ``migration_chain_merge_ratio`` — descriptors in / out of the
  migration plan's chains (the runtime coalescer), median over repeats.
* ``per_shard_bus_utilization`` — mean shard-local steady-state bus
  utilization from the cycle model.
* ``cross_shard_migration_cycles`` — mean added interconnect cycles per
  migrated transfer, contended mode; 0.0 at mesh 1 by construction.
* ``migration_overlap_ratio`` — fraction of fabric in-flight rounds
  hidden behind shard-local drain progress, from the real async runtime
  (``MigrationStats.overlap_ratio``), median over repeats; 0.0 at mesh 1.
  Hard floor: **>= 0.6 at mesh >= 4** (in-cell RuntimeError).
* ``p99_migration_stall_cycles`` — p99 added interconnect cycles,
  contended mode.  Hard invariant at mesh >= 4: **strictly below** the
  shared-bus (synchronous-fabric) p99 stored in the counters.
* ``rebalance_convergence_steps`` — traffic steps until the
  :class:`repro.distributed.RebalancePlanner` hysteresis episode closes
  on an adversarial hot-shard Zipf workload (heat concentrated on shard
  0); 0 at mesh 1.
* ``throughput_retained_during_resize`` — pump rounds to complete a
  foreground migration workload alone / with a concurrent
  background-priority resize handoff off the last shard; 1.0 at mesh 1.
  Hard floor: **>= 0.8 at mesh >= 4** (the mesh-4 cell measures 4 -> 3).
* ``first_touch_latency_rounds`` (schema v8, DESIGN.md §11) — fabric
  rounds from the first touch of an ownership-flipped page to residency
  (the lazy pull through ``ensure_resident``); 0.0 at mesh 1.  Hard
  invariant at mesh >= 4: **strictly below** the rounds of a full
  synchronous migration of the same batch.

Determinism contract: identical to the DMA cells — every number is a
pure function of ``(seed, cell_key)``: the fabric runs on a logical
round clock, the planner and cycle model are seeded from the cell key,
device *placement* never enters any metric, and no wall-clock value is
stored.  ``ShardedCellSpec(fabric="sync")`` is the escape hatch: the
runtime passes lower through the PR-8 synchronous hop path and the
fabric-dependent metrics pin to their mesh-1 values.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.registry import get_config
from repro.core.simulator import simulate_sharded
from repro.perf.workloads import arch_params, zipf_page_traffic

#: Gated sharded-cell metrics (gate.py carries polarity + bands).
SHARDED_GATED_METRICS = (
    "cross_shard_migration_cycles",
    "per_shard_bus_utilization",
    "migration_chain_merge_ratio",
    "migration_overlap_ratio",
    "p99_migration_stall_cycles",
    "rebalance_convergence_steps",
    "throughput_retained_during_resize",
    "first_touch_latency_rounds",
)

#: The mesh axis of the sweep — matches the CI lane's 8 emulated devices.
MESH_SIZES = (1, 2, 4, 8)

#: In-cell hard floors at mesh >= 4 (enforced with RuntimeError so the
#: gate can never compare a cell that silently lost its async overlap).
MIN_OVERLAP_RATIO = 0.6
MIN_RETAINED_THROUGHPUT = 0.8


@dataclasses.dataclass(frozen=True)
class ShardedCellSpec:
    """Fully determines one mesh cell (and hence its baseline entry)."""

    arch: str = "qwen2.5-3b"
    pages_per_shard: int = 64
    n_moves: int = 96            # page moves per migration pass
    zipf_alpha: float = 1.1      # rank exponent of the page-traffic skew
    traffic_len: int = 256       # Zipf touches per traffic step
    channels_per_shard: int = 2
    mem_latency: int = 13
    sim_transfers: int = 200
    max_len: int = 512           # serial-channel burst window (elements)
    fabric: str = "async"        # "sync" = PR-8 escape hatch
    fabric_latency: int = 1
    fabric_page_beats: int = 1
    wave: int = 8                # moves per migrate_rows plan (pipelining)
    rebalance_window: int = 4
    rebalance_alpha: float = 0.9   # sustained-load skew (milder than moves)
    rebalance_traffic_len: int = 1024  # touches per load sample (noise floor)
    max_rebalance_steps: int = 64
    handoff_pages: int = 32      # resize handoff size (<= pages_per_shard/2)
    handoff_chunk: int = 4       # pages per background handoff plan
    handoff_period: int = 3      # pump rounds between handoff chunks

    def cell_key(self, mesh: int) -> str:
        return f"sharded/{self.arch}/mesh{mesh}"


DEFAULT_SHARDED_SPEC = ShardedCellSpec()


def _mesh_for(num_shards: int):
    """A real 1-D device mesh when the host has enough devices, else None
    (logical shards — metrics are placement-independent either way)."""
    import jax
    devices = jax.devices()
    if num_shards > 1 and len(devices) >= num_shards:
        return jax.sharding.Mesh(
            np.asarray(devices[:num_shards]), ("dma",))
    return None


def _make_runtime(mesh: int, spec: ShardedCellSpec):
    from repro.distributed.sharded_runtime import (
        ShardedDMARuntime, ShardedKVPool)
    cfg = get_config(spec.arch)
    p = arch_params(cfg)
    rt = ShardedDMARuntime(num_shards=mesh, mesh=_mesh_for(mesh),
                           data_channels=spec.channels_per_shard,
                           max_len=spec.max_len,
                           fabric=spec.fabric,
                           fabric_latency=spec.fabric_latency,
                           fabric_page_beats=spec.fabric_page_beats)
    kv = ShardedKVPool(rt, num_pages=spec.pages_per_shard * mesh,
                       page=p.page_elems, kv_heads=1, head_dim=1)
    return rt, kv, p


def _zipf_moves(rng: np.random.Generator, num_pages: int, n_moves: int,
                alpha: float, traffic_len: int
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Zipf-skewed migration plan: the hottest distinct pages of a seeded
    Zipf reference stream relocate onto untouched (cold) pages — hot
    content chases free space, the steady state of a paged KV cache
    under skewed request popularity."""
    traffic = zipf_page_traffic(num_pages, traffic_len, alpha=alpha,
                                rng=rng)
    pages, counts = np.unique(traffic, return_counts=True)
    hot = pages[np.argsort(-counts, kind="stable")]
    cold = np.setdiff1d(np.arange(num_pages, dtype=np.int64), hot)
    n = min(n_moves, len(hot), len(cold))
    if n == 0:
        raise RuntimeError("Zipf traffic covered the whole page space; "
                           "no cold destination pages left")
    src = hot[:n]
    dst = rng.permutation(cold)[:n]
    return src.astype(np.int64), dst.astype(np.int64)


def _cell_rng(seed: int, mesh: int, spec: ShardedCellSpec,
              salt: str = "") -> np.random.Generator:
    return np.random.default_rng(
        [seed, mesh, zlib.crc32((spec.cell_key(mesh) + salt).encode())])


def _submit_waves(kv, src: List[int], dst: List[int], wave: int,
                  priority: int) -> List[object]:
    """Submit a move set as ``wave``-sized plans with no intermediate
    drain: independent hops pipeline on the fabric instead of fusing
    into one monolithic transfer per shard pair, so delivered waves
    scatter locally while later waves are still on the wire — the
    overlap the async fabric exists to expose."""
    out = []
    for i in range(0, len(src), wave):
        out.append(kv.move_pages(kv.refs(src[i:i + wave]),
                                 kv.refs(dst[i:i + wave]),
                                 priority=priority, drain=False))
    return out


def _migration_pass(seed: int, mesh: int,
                    spec: ShardedCellSpec) -> Dict[str, float]:
    """One seeded Zipf migration through the real sharded runtime."""
    rng = _cell_rng(seed, mesh, spec)
    rt, kv, p = _make_runtime(mesh, spec)
    src, dst = _zipf_moves(rng, spec.pages_per_shard * mesh, spec.n_moves,
                           spec.zipf_alpha, spec.traffic_len)
    if spec.fabric == "async":
        _submit_waves(kv, src.tolist(), dst.tolist(), spec.wave,
                      priority=1)
        rt.pump_until_idle()
        rt.drain_until_idle()
    else:
        # Escape hatch: one monolithic plan through the PR-8 blocking
        # hop path, exactly as the v6 cell lowered it.
        kv.move_pages(src.tolist(), dst.tolist())
    # The waves all merged into the mesh aggregate at submit time; the
    # aggregate is the pass (fresh runtime per pass).
    agg = rt.migration
    if agg.hop_completions != agg.hops:
        # Not an assert: the gate must catch this even under python -O.
        raise RuntimeError(
            "a cross-shard hop finished without its §II-D writeback "
            f"({agg.hop_completions}/{agg.hops}) — the cell would "
            "gate garbage")
    return {
        "merge_ratio": agg.merge_ratio,
        "cross_fraction": agg.cross_pages / max(agg.pages, 1),
        "overlap_ratio": agg.overlap_ratio,
        "inflight_rounds": agg.fabric_inflight_rounds,
        "hidden_rounds": agg.fabric_hidden_rounds,
        "fabric_rounds": rt.fabric.now if rt.fabric is not None else 0,
        "pages": agg.pages,
        "cross_pages": agg.cross_pages,
        "hops": agg.hops,
        "chain_in": agg.chain_in,
        "chain_out": agg.chain_out,
        "transfer_bytes": p.page_elems * 4,   # float32 page rows
    }


def _rebalance_convergence(seed: int, mesh: int,
                           spec: ShardedCellSpec) -> Dict[str, float]:
    """Traffic steps until the planner's hysteresis episode closes.

    Adversarial placement: Zipf rank r maps to page r, so the whole hot
    head starts on shard 0.  Each step samples one traffic window,
    feeds the planner per-shard touch counts, and applies any emitted
    plan through the real migration path; references follow the content
    (``loc``), so spreading the hot head across the mesh is what brings
    the windowed imbalance back under ``low_water``.
    """
    from repro.distributed.fabric import RebalancePlanner

    if mesh == 1 or spec.fabric != "async":
        return {"steps": 0, "plans": 0, "pages_planned": 0,
                "final_imbalance": 1.0}
    rng = _cell_rng(seed, mesh, spec, salt="/rebalance")
    rt, kv, _ = _make_runtime(mesh, spec)
    num_pages = spec.pages_per_shard * mesh
    planner = RebalancePlanner(mesh, window=spec.rebalance_window)
    # Zipf rank r maps to page r (hot_pages=loc starts as the identity),
    # so the whole hot head begins on shard 0 — the adversarial start.
    loc = np.arange(num_pages, dtype=np.int64)   # logical -> physical
    steps = plans = 0
    for step in range(1, spec.max_rebalance_steps + 1):
        touches = zipf_page_traffic(num_pages, spec.rebalance_traffic_len,
                                    alpha=spec.rebalance_alpha, rng=rng,
                                    hot_pages=loc)
        load = np.bincount(touches // spec.pages_per_shard,
                           minlength=mesh).astype(float)
        planner.observe(load.tolist(), hot_pages=touches.tolist())
        plan = planner.plan(kv)
        if plan is not None:
            src, dst = plan
            kv.move_pages(kv.refs(src), kv.refs(dst), priority=0)
            remap = dict(zip(src, dst))
            loc = np.asarray([remap.get(int(p), int(p)) for p in loc],
                             np.int64)
            plans += 1
        elif plans and not planner.should_rebalance():
            steps = step
            break
    else:
        steps = spec.max_rebalance_steps
    return {"steps": steps, "plans": plans,
            "pages_planned": planner.pages_planned,
            "final_imbalance": planner.imbalance()}


def _pump_plans(srt, plans, max_rounds: int = 65536) -> int:
    """Pump rounds until every fabric hop of the given plans completed."""
    rounds = 0
    while any(srt.plan_outstanding(s) for s in plans):
        srt.pump()
        rounds += 1
        if rounds > max_rounds:
            raise RuntimeError("migration plan did not quiesce")
    return rounds


def _resize_retention(seed: int, mesh: int,
                      spec: ShardedCellSpec) -> Dict[str, float]:
    """Foreground rounds alone vs. during a background resize handoff.

    Two fresh same-seed runtimes run the identical Zipf foreground
    workload; the second also carries a background-priority handoff of
    the last shard's pages (mesh N -> N-1) through the same fabric.
    Retention is the round ratio — per-link occupancy is the only thing
    that can slow the foreground down, which is exactly what the metric
    watches.
    """
    if mesh == 1 or spec.fabric != "async":
        return {"retained": 1.0, "rounds_alone": 0, "rounds_during": 0,
                "handoff_pages": 0}
    num_pages = spec.pages_per_shard * mesh
    leaving = mesh - 1

    def _workload(rng: np.random.Generator):
        # Cap the foreground at a quarter of the page space so the
        # leaving shard still has pages left to hand off (the full Zipf
        # move set can touch nearly every page on small meshes).
        return _zipf_moves(rng, num_pages, min(spec.n_moves, num_pages // 4),
                           spec.zipf_alpha, spec.traffic_len)

    # Alone: foreground only.
    rng = _cell_rng(seed, mesh, spec, salt="/resize")
    rt_a, kv_a, _ = _make_runtime(mesh, spec)
    src, dst = _workload(rng)
    fg_a = _submit_waves(kv_a, src.tolist(), dst.tolist(), spec.wave,
                         priority=1)
    rounds_alone = _pump_plans(rt_a, fg_a)
    rt_a.drain_until_idle()

    # During: same foreground + background handoff off the leaving shard.
    # The handoff is *paced* — one background-priority chunk per pump
    # round, the way a real rebalancer trickles ownership migration —
    # so it contends for drain slots and link occupancy continuously
    # instead of capturing every channel FIFO up front.
    rng = _cell_rng(seed, mesh, spec, salt="/resize")
    rt_b, kv_b, _ = _make_runtime(mesh, spec)
    src2, dst2 = _workload(rng)
    used = set(src2.tolist()) | set(dst2.tolist())
    h_src = [p for p in kv_b.owner.shard_pages(leaving)
             if p not in used][:spec.handoff_pages]
    h_dst = [p for p in range(num_pages)
             if kv_b.owner.owner(p) != leaving
             and p not in used][:len(h_src)]
    if len(h_dst) < len(h_src):
        h_src = h_src[:len(h_dst)]
    chunks = [(h_src[i:i + spec.handoff_chunk],
               h_dst[i:i + spec.handoff_chunk])
              for i in range(0, len(h_src), spec.handoff_chunk)]
    fg_b = _submit_waves(kv_b, src2.tolist(), dst2.tolist(), spec.wave,
                         priority=1)
    handoff = []
    rounds_during = 0
    while any(rt_b.plan_outstanding(s) for s in fg_b):
        if chunks and rounds_during % spec.handoff_period == 0:
            s, d = chunks.pop(0)
            handoff.append(kv_b.move_pages(kv_b.refs(s), kv_b.refs(d),
                                           priority=0, drain=False))
        rt_b.pump()
        rounds_during += 1
        if rounds_during > 65536:
            raise RuntimeError("resize foreground did not quiesce")
    for s, d in chunks:   # tail of the handoff after the foreground
        handoff.append(kv_b.move_pages(kv_b.refs(s), kv_b.refs(d),
                                       priority=0, drain=False))
    rt_b.pump_until_idle()
    rt_b.drain_until_idle()
    lost = [(s.hop_completions, s.hops) for s in handoff
            if s.hop_completions != s.hops]
    if lost:
        raise RuntimeError(
            f"resize handoff lost a §II-D writeback ({lost})")
    retained = (min(1.0, rounds_alone / rounds_during)
                if rounds_during else 1.0)
    return {"retained": retained, "rounds_alone": rounds_alone,
            "rounds_during": rounds_during, "handoff_pages": len(h_src)}


def _first_touch_latency(seed: int, mesh: int,
                         spec: ShardedCellSpec) -> Dict[str, float]:
    """Ownership-first migration (DESIGN.md §11): fabric rounds from the
    first touch of a flipped page to residency, vs the rounds a full
    synchronous migration of the same batch costs.

    Two same-seed pools each hold one written batch on shard 0.  The
    synchronous leg migrates the whole batch eagerly and counts fabric
    rounds to quiescence.  The lazy leg flips the batch's *ownership* to
    shard 1 (a page-table write — zero rounds) and then touches one
    page: ``ensure_resident`` pulls exactly that page through the
    normal fabric path.  The gated number is the touch-to-resident
    rounds — bounded by one page's hop, not the batch.
    """
    if mesh == 1 or spec.fabric != "async":
        return {"first_touch_rounds": 0.0, "sync_rounds": 0.0,
                "batch_pages": 0, "pulled": 0}
    rng = _cell_rng(seed, mesh, spec, salt="/firsttouch")
    batch = min(spec.handoff_pages, spec.pages_per_shard // 2)
    rows = rng.standard_normal((batch,)).astype(np.float32)

    def _filled():
        rt, kv, p = _make_runtime(mesh, spec)
        pages = kv.alloc_on(0, batch)
        for i, pg in enumerate(pages):
            row = np.full(kv.row_elems, rows[i], np.float32)
            kv.write_page(pg, row, -row)
        return rt, kv, pages

    # Synchronous leg: eager batch migration, rounds to quiescence.
    rt_s, kv_s, pages_s = _filled()
    dst = kv_s.alloc_on(1, batch)
    base = rt_s.fabric.now
    kv_s.move_pages(pages_s, dst, priority=1)
    sync_rounds = rt_s.fabric.now - base

    # Lazy leg: flip ownership now, pull one page on first touch.
    rt_l, kv_l, pages_l = _filled()
    flipped = kv_l.flip_ownership(pages_l, 1)
    base = rt_l.fabric.now
    k_one, _ = kv_l.page_rows([flipped[0]])
    first_rounds = rt_l.fabric.now - base
    if not np.allclose(k_one[0], np.full(kv_l.row_elems, rows[0])):
        raise RuntimeError(
            "first-touch pull delivered wrong page contents — the lazy "
            "migration path is corrupting pages")
    pulled = kv_l.first_touch_pulls
    if pulled != 1:
        raise RuntimeError(
            f"touching one flipped page pulled {pulled} pages — "
            "first touch is not lazy")
    return {"first_touch_rounds": float(first_rounds),
            "sync_rounds": float(sync_rounds),
            "batch_pages": batch, "pulled": pulled}


def run_sharded_cell(
    seed: int,
    mesh: int,
    spec: ShardedCellSpec = DEFAULT_SHARDED_SPEC,
    *,
    repeats: int = 3,
) -> Tuple[Dict[str, float], Dict[str, object]]:
    """Run one mesh cell; returns ``(gated_metrics, stored_counters)``.

    Migration-pass numbers are medians over ``repeats`` seeded passes
    (the same convention as the DMA cells); the cycle model, the
    rebalance-convergence loop, and the resize pair each run once at the
    base seed.
    """
    passes = [_migration_pass(seed + r, mesh, spec) for r in range(repeats)]
    merge = float(np.median([p["merge_ratio"] for p in passes]))
    cross = float(np.median([p["cross_fraction"] for p in passes]))
    overlap = float(np.median([p["overlap_ratio"] for p in passes]))
    transfer_bytes = int(passes[0]["transfer_bytes"])
    sim_seed = zlib.crc32(spec.cell_key(mesh).encode()) & 0x7FFFFFFF

    def _sim(mode: str):
        return simulate_sharded(
            mesh, spec.channels_per_shard, spec.mem_latency, transfer_bytes,
            num_transfers=spec.sim_transfers, cross_fraction=cross,
            interconnect_mode=mode, seed=sim_seed).sharded

    contended = _sim("contended")
    shared = _sim("shared")     # the synchronous-fabric baseline

    rebalance = _rebalance_convergence(seed, mesh, spec)
    resize = _resize_retention(seed, mesh, spec)
    first_touch = _first_touch_latency(seed, mesh, spec)

    if mesh >= 4 and spec.fabric == "async":
        if not (first_touch["first_touch_rounds"]
                < first_touch["sync_rounds"]):
            ft, sr = (first_touch["first_touch_rounds"],
                      first_touch["sync_rounds"])
            raise RuntimeError(
                f"first-touch latency ({ft:.0f} rounds) is not below a "
                f"full synchronous migration ({sr:.0f} rounds) at mesh "
                f"{mesh} — ownership-first migration lost its point")
        if overlap < MIN_OVERLAP_RATIO:
            raise RuntimeError(
                f"async fabric hid only {overlap:.3f} of its in-flight "
                f"rounds at mesh {mesh} (floor {MIN_OVERLAP_RATIO}) — "
                "migration is not overlapping with local drains")
        if not (contended.migration_cycles_p99
                < shared.migration_cycles_p99):
            raise RuntimeError(
                "contended-interconnect p99 stall "
                f"({contended.migration_cycles_p99:.1f}) is not below the "
                f"synchronous shared-bus baseline "
                f"({shared.migration_cycles_p99:.1f}) at mesh {mesh}")
        if resize["retained"] < MIN_RETAINED_THROUGHPUT:
            raise RuntimeError(
                f"foreground throughput retained only "
                f"{resize['retained']:.3f} during resize at mesh {mesh} "
                f"(floor {MIN_RETAINED_THROUGHPUT})")

    metrics = {
        "cross_shard_migration_cycles":
            float(contended.migration_cycles_mean),
        "per_shard_bus_utilization":
            float(contended.mean_shard_utilization),
        "migration_chain_merge_ratio": merge,
        "migration_overlap_ratio": overlap,
        "p99_migration_stall_cycles":
            float(contended.migration_cycles_p99),
        "rebalance_convergence_steps": float(rebalance["steps"]),
        "throughput_retained_during_resize": float(resize["retained"]),
        "first_touch_latency_rounds":
            float(first_touch["first_touch_rounds"]),
    }
    counters = {
        "mesh": mesh,
        "cross_fraction": cross,
        "fabric": {
            "mode": spec.fabric,
            "latency": spec.fabric_latency,
            "page_beats": spec.fabric_page_beats,
            "inflight_rounds": int(passes[0]["inflight_rounds"]),
            "hidden_rounds": int(passes[0]["hidden_rounds"]),
            "rounds": int(passes[0]["fabric_rounds"]),
        },
        "migration": {k: int(passes[0][k]) for k in
                      ("pages", "cross_pages", "hops",
                       "chain_in", "chain_out")},
        "rebalance": {k: float(v) for k, v in rebalance.items()},
        "resize": {k: float(v) for k, v in resize.items()},
        "first_touch": {k: float(v) for k, v in first_touch.items()},
        "sync_baseline": {
            "migration_cycles_mean": float(shared.migration_cycles_mean),
            "migration_cycles_p99": float(shared.migration_cycles_p99),
            "interconnect_busy_beats": int(shared.interconnect_busy_beats),
        },
        "sim": {
            "per_shard_utilization":
                [float(u) for u in contended.per_shard_utilization],
            "cross_transfers": int(contended.cross_transfers),
            "interconnect_latency": int(contended.interconnect_latency),
            "interconnect_busy_beats":
                int(contended.interconnect_busy_beats),
            "num_links": int(contended.num_links),
            "link_busy_beats_max": int(contended.link_busy_beats_max),
        },
    }
    return metrics, counters


def cell_entry(seed: int, mesh: int,
               spec: Optional[ShardedCellSpec] = None,
               repeats: int = 3) -> Tuple[str, Dict[str, object]]:
    """(key, cell dict) for the sweep document."""
    spec = spec or DEFAULT_SHARDED_SPEC
    metrics, counters = run_sharded_cell(seed, mesh, spec, repeats=repeats)
    return spec.cell_key(mesh), {
        "kind": "sharded",
        "arch": spec.arch,
        "workload": "kv_migration",
        "mesh": mesh,
        "fabric": spec.fabric,
        "metrics": metrics,
        "counters": counters,
    }
