"""Sharded mesh cells of the perf sweep (DESIGN.md §6, ROADMAP item).

One cell per mesh size in {1, 2, 4, 8}: a seeded defrag-churn compaction
over a page space partitioned across that many shards, lowered through the
real :class:`repro.distributed.ShardedKVPool` /
:class:`repro.distributed.ShardedDMARuntime` migration planner (local
chains + cross-shard hops with per-hop §II-D writebacks), plus the
sharded cycle model (:func:`repro.core.simulator.simulate_sharded`:
per-shard local buses, one shared interconnect for migration hops).

Gated metrics:

* ``migration_chain_merge_ratio`` — descriptors in / descriptors out of
  the migration plan's chains (the runtime coalescer fusing contiguous
  page runs); measured on the real runtime, median over repeats.
* ``per_shard_bus_utilization`` — mean shard-local steady-state bus
  utilization from the sharded cycle model.
* ``cross_shard_migration_cycles`` — mean added cycles a migrated
  transfer spends on the interconnect (payload + writeback beat) after
  finishing locally; exactly 0.0 on the mesh-1 cell by construction.

Determinism contract: identical to the DMA cells — the workload is a pure
function of ``(seed, cell_key)``, the cycle model is seeded from the cell
key, device *placement* never enters any metric (the sharded runtime runs
identically with or without a real `jax.sharding.Mesh`), and no
wall-clock value is stored. When enough host devices exist (the CI lane's
``--xla_force_host_platform_device_count=8``) the cell places its shards
on a real CPU-device mesh; the document is bit-for-bit the same either
way.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Optional, Tuple

import numpy as np

from repro.configs.registry import get_config
from repro.core.simulator import simulate_sharded
from repro.perf.workloads import arch_params

#: Gated sharded-cell metrics (gate.py carries polarity + bands).
SHARDED_GATED_METRICS = (
    "cross_shard_migration_cycles",
    "per_shard_bus_utilization",
    "migration_chain_merge_ratio",
)

#: The mesh axis of the sweep — matches the CI lane's 8 emulated devices.
MESH_SIZES = (1, 2, 4, 8)


@dataclasses.dataclass(frozen=True)
class ShardedCellSpec:
    """Fully determines one mesh cell (and hence its baseline entry)."""

    arch: str = "qwen2.5-3b"
    pages_per_shard: int = 64
    n_moves: int = 96            # page moves per compaction pass
    churn: float = 0.35          # fraction of pages freed before compaction
    channels_per_shard: int = 2
    mem_latency: int = 13
    sim_transfers: int = 200
    max_len: int = 512           # serial-channel burst window (elements)

    def cell_key(self, mesh: int) -> str:
        return f"sharded/{self.arch}/mesh{mesh}"


DEFAULT_SHARDED_SPEC = ShardedCellSpec()


def _mesh_for(num_shards: int):
    """A real 1-D device mesh when the host has enough devices, else None
    (logical shards — metrics are placement-independent either way)."""
    import jax
    devices = jax.devices()
    if num_shards > 1 and len(devices) >= num_shards:
        return jax.sharding.Mesh(
            np.asarray(devices[:num_shards]), ("dma",))
    return None


def _churn_moves(rng: np.random.Generator, num_pages: int, n_moves: int,
                 churn: float) -> Tuple[np.ndarray, np.ndarray]:
    """Defrag-churn compaction: surviving pages (scattered by churn) move
    onto the freed low-id run — naturally cross-shard once the mesh >1."""
    freed = rng.random(num_pages) < churn
    live = np.flatnonzero(~freed)
    free = np.flatnonzero(freed)
    n = min(n_moves, len(live), len(free))
    # The highest-id survivors compact onto the lowest-id free pages —
    # mostly shard 0's, so a multi-shard mesh must hop the fabric.
    src = live[-n:]
    dst = free[:n]
    return src.astype(np.int64), dst.astype(np.int64)


def _migration_pass(seed: int, mesh: int,
                    spec: ShardedCellSpec) -> Dict[str, float]:
    """One seeded compaction through the real sharded runtime."""
    from repro.distributed.sharded_runtime import (
        ShardedDMARuntime, ShardedKVPool)

    cfg = get_config(spec.arch)
    p = arch_params(cfg)
    rng = np.random.default_rng(
        [seed, mesh, zlib.crc32(spec.cell_key(mesh).encode())])
    num_pages = spec.pages_per_shard * mesh
    rt = ShardedDMARuntime(num_shards=mesh, mesh=_mesh_for(mesh),
                           data_channels=spec.channels_per_shard,
                           max_len=spec.max_len)
    kv = ShardedKVPool(rt, num_pages=num_pages, page=p.page_elems,
                       kv_heads=1, head_dim=1)
    src, dst = _churn_moves(rng, num_pages, spec.n_moves, spec.churn)
    stats = kv.move_pages(src.tolist(), dst.tolist())
    if stats.hop_completions != stats.hops:
        # Not an assert: the gate must catch this even under python -O.
        raise RuntimeError(
            "a cross-shard hop finished without its §II-D writeback "
            f"({stats.hop_completions}/{stats.hops}) — the cell would "
            "gate garbage")
    return {
        "merge_ratio": stats.merge_ratio,
        "cross_fraction": stats.cross_pages / max(stats.pages, 1),
        "pages": stats.pages,
        "cross_pages": stats.cross_pages,
        "hops": stats.hops,
        "chain_in": stats.chain_in,
        "chain_out": stats.chain_out,
        "transfer_bytes": p.page_elems * 4,   # float32 page rows
    }


def run_sharded_cell(
    seed: int,
    mesh: int,
    spec: ShardedCellSpec = DEFAULT_SHARDED_SPEC,
    *,
    repeats: int = 3,
) -> Tuple[Dict[str, float], Dict[str, object]]:
    """Run one mesh cell; returns ``(gated_metrics, stored_counters)``.

    Runtime-side numbers are medians over ``repeats`` seeded compaction
    passes (the same convention as the DMA cells); the cycle model runs
    once at the median cross fraction.
    """
    passes = [_migration_pass(seed + r, mesh, spec) for r in range(repeats)]
    merge = float(np.median([p["merge_ratio"] for p in passes]))
    cross = float(np.median([p["cross_fraction"] for p in passes]))
    transfer_bytes = int(passes[0]["transfer_bytes"])

    sim = simulate_sharded(
        mesh, spec.channels_per_shard, spec.mem_latency, transfer_bytes,
        num_transfers=spec.sim_transfers, cross_fraction=cross,
        seed=zlib.crc32(spec.cell_key(mesh).encode()) & 0x7FFFFFFF)
    sh = sim.sharded
    metrics = {
        "cross_shard_migration_cycles": float(sh.migration_cycles_mean),
        "per_shard_bus_utilization": float(sh.mean_shard_utilization),
        "migration_chain_merge_ratio": merge,
    }
    counters = {
        "mesh": mesh,
        "cross_fraction": cross,
        "migration": {k: int(passes[0][k]) for k in
                      ("pages", "cross_pages", "hops",
                       "chain_in", "chain_out")},
        "sim": {
            "per_shard_utilization": [float(u)
                                      for u in sh.per_shard_utilization],
            "cross_transfers": int(sh.cross_transfers),
            "interconnect_latency": int(sh.interconnect_latency),
            "interconnect_busy_beats": int(sh.interconnect_busy_beats),
            "aggregate_utilization": float(sim.aggregate_utilization),
        },
    }
    return metrics, counters


def cell_entry(seed: int, mesh: int,
               spec: Optional[ShardedCellSpec] = None,
               repeats: int = 3) -> Tuple[str, Dict[str, object]]:
    """(key, cell dict) for the sweep document."""
    spec = spec or DEFAULT_SHARDED_SPEC
    metrics, counters = run_sharded_cell(seed, mesh, spec, repeats=repeats)
    return spec.cell_key(mesh), {
        "kind": "sharded",
        "arch": spec.arch,
        "workload": "kv_migration",
        "mesh": mesh,
        "metrics": metrics,
        "counters": counters,
    }
