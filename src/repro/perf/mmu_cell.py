"""MMU cells of the perf sweep (schema v8, DESIGN.md §11).

One cell per memory latency on the sweep's L axis: the §II-C sequential
paged-KV stream driven through the cycle model with the engine-side
IOTLB enabled (:class:`repro.mmu.IOTLBParams`), translation prefetches
riding the speculative descriptor fetch stream — the Kurth et al.
(arXiv 1808.09751) coupling of chain lookahead and page walks.

Gated metrics:

* ``tlb_hit_rate`` — IOTLB hit fraction over all payload translations.
  Hard floor: **>= 0.9** with chain-lookahead prefetch enabled (in-cell
  RuntimeError — a sequential stream whose walks are not hidden means
  the prefetcher detached from the speculator).
* ``walk_stall_cycles`` — total launch cycles spent waiting on page
  walks (prefetch-enabled leg; the demand-walk A/B is in the counters).
* ``defrag_remap_cycles`` vs ``defrag_copy_cycles`` — compacting the
  same fragmented page set by page-table remap
  (:func:`repro.mmu.remap_cycles`: table write + shootdown per page +
  one refill walk) vs the legacy descriptor-chain copy through the §II-B
  engine.  Hard invariant: **remap strictly below copy** on every
  defrag-churn cell (in-cell RuntimeError) — the reason remap-defrag is
  the serve path's default.

Determinism: every number is a pure function of ``(seed, mem_latency)``
through the cycle model — no wall clock, no device placement.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.core.simulator import SimConfig, simulate
from repro.core.speculation import DEFAULT_DEPTH, FixedDepth
from repro.mmu import IOTLBParams, remap_cycles

#: Gated MMU-cell metrics (gate.py carries polarity + bands).
MMU_GATED_METRICS = (
    "tlb_hit_rate",
    "walk_stall_cycles",
    "defrag_remap_cycles",
    "defrag_copy_cycles",
)

#: In-cell hard floor on the prefetch-enabled sequential stream.
MIN_TLB_HIT_RATE = 0.9


@dataclasses.dataclass(frozen=True)
class MMUCellSpec:
    """Fully determines one MMU cell (and hence its baseline entry)."""

    transfer_bytes: int = 256     # one KV page row per descriptor
    num_transfers: int = 200      # sequential paged-KV chain length
    hit_rate: float = 0.95        # §II-C stream: mostly-sequential pages
    defrag_pages: int = 24        # defrag-churn compaction size

    def cell_key(self, mem_latency: int) -> str:
        return f"mmu/paged_seq/L{mem_latency}"


DEFAULT_MMU_SPEC = MMUCellSpec()


def run_mmu_cell(seed: int, mem_latency: int,
                 spec: MMUCellSpec = DEFAULT_MMU_SPEC
                 ) -> Tuple[Dict[str, float], Dict[str, object]]:
    """Run one MMU cell; returns ``(gated_metrics, stored_counters)``."""
    params = IOTLBParams()                       # chain-lookahead prefetch
    base = SimConfig("ours-mmu", in_flight=DEFAULT_DEPTH,
                     prefetch=FixedDepth(DEFAULT_DEPTH), iotlb=params)
    r = simulate(base, mem_latency, spec.transfer_bytes,
                 num_transfers=spec.num_transfers, hit_rate=spec.hit_rate)
    if r.tlb_hit_rate < MIN_TLB_HIT_RATE:
        raise RuntimeError(
            f"IOTLB hit rate {r.tlb_hit_rate:.3f} under chain-lookahead "
            f"prefetch at L={mem_latency} (floor {MIN_TLB_HIT_RATE}) — "
            "translation prefetches are not riding the §II-C stream")

    # A/B: demand walks only (prefetch depth 0) — stored, not gated.
    demand_cfg = dataclasses.replace(
        base, name="ours-mmu-demand",
        iotlb=IOTLBParams(prefetch=FixedDepth(0)))
    demand = simulate(demand_cfg, mem_latency, spec.transfer_bytes,
                      num_transfers=spec.num_transfers,
                      hit_rate=spec.hit_rate)

    # Defrag churn: compact `defrag_pages` live pages. Remap charges the
    # page-table cost model; copy is a real §II-B chain of page moves
    # through the cycle model (sequential destinations, so the copy leg
    # gets its best case and the invariant is conservative).
    walk = params.resolved_walk_cycles(mem_latency)
    remap = float(remap_cycles(spec.defrag_pages, walk))
    copy_cfg = SimConfig("defrag-copy", in_flight=DEFAULT_DEPTH,
                         prefetch=FixedDepth(DEFAULT_DEPTH))
    copy = float(simulate(copy_cfg, mem_latency, spec.transfer_bytes,
                          num_transfers=spec.defrag_pages,
                          hit_rate=1.0).cycles)
    if not remap < copy:
        raise RuntimeError(
            f"remap-defrag ({remap:.0f} cycles) is not below copy-defrag "
            f"({copy:.0f} cycles) at L={mem_latency} — the remap path "
            "lost its reason to exist")

    metrics = {
        "tlb_hit_rate": float(r.tlb_hit_rate),
        "walk_stall_cycles": float(r.walk_stall_cycles),
        "defrag_remap_cycles": remap,
        "defrag_copy_cycles": copy,
    }
    counters = {
        "mem_latency": mem_latency,
        "iotlb": {
            "entries": params.entries,
            "walk_cycles": walk,
            "prefetch_depth": DEFAULT_DEPTH,
            "tlb_hits": int(r.tlb_hits),
            "tlb_misses": int(r.tlb_misses),
        },
        "demand_walk_baseline": {
            "tlb_hit_rate": float(demand.tlb_hit_rate),
            "walk_stall_cycles": float(demand.walk_stall_cycles),
            "cycles": int(demand.cycles),
        },
        "cycles": int(r.cycles),
        "defrag": {
            "pages": spec.defrag_pages,
            "remap_vs_copy_speedup": copy / max(remap, 1.0),
        },
    }
    return metrics, counters


def mmu_cell_entries(seed: int, mem_latencies,
                     spec: MMUCellSpec = DEFAULT_MMU_SPEC):
    """(key, cell dict) pairs for the sweep document, one per latency."""
    for mem_latency in mem_latencies:
        metrics, counters = run_mmu_cell(seed, mem_latency, spec)
        yield spec.cell_key(mem_latency), {
            "kind": "mmu",
            "workload": "paged_seq",
            "mem_latency": mem_latency,
            "transfer_bytes": spec.transfer_bytes,
            "metrics": metrics,
            "counters": counters,
        }
