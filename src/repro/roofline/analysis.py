"""Three-term roofline from compiled AOT artifacts (TPU v5e targets).

    compute    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
    memory     = HLO_bytes / (chips x 819 GB/s HBM)
    collective = wire_bytes_per_chip / (links x 50 GB/s)

cost_analysis() gives per-device FLOPs/bytes on the partitioned module;
collective bytes are parsed from the partitioned HLO text: each collective's
per-partition tensor bytes x a ring-algorithm wire factor (all-reduce 2x,
all-gather/reduce-scatter/all-to-all/permute 1x).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip (v5e)
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link
LINKS_PER_CHIP = 2           # conservative usable links for a 2D-mesh axis

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVE_FACTORS = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "ragged-all-to-all": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string like 'f32[16,128]' or a tuple thereof."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-chip wire bytes by collective kind, from partitioned HLO text."""
    out = {k: 0.0 for k in _COLLECTIVE_FACTORS}
    op_re = re.compile(
        r"^\s*(?:%\S+|\S+)\s*=\s*(\([^)]*\)|\S+)\s+"
        r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
        r"collective-permute|ragged-all-to-all)\(",
        re.M)
    for m in op_re.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_str) * _COLLECTIVE_FACTORS[kind]
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    wire_bytes_per_chip: float
    collectives: Dict[str, float]
    model_flops: float            # 6 * N(active) * tokens (global)
    bytes_per_chip_hbm: float     # memory_analysis: peak alloc

    @property
    def compute_s(self) -> float:
        return self.hlo_flops_per_chip / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes_per_chip / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes_per_chip / (LINKS_PER_CHIP * LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO FLOPs) — remat/padding/causal waste."""
        total = self.hlo_flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs / (chips x peak x roofline step time)."""
        t = self.step_time_s
        return (self.model_flops / (self.chips * PEAK_FLOPS * t)) if t else 0.0

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_chip": self.hlo_flops_per_chip,
            "hlo_bytes_per_chip": self.hlo_bytes_per_chip,
            "wire_bytes_per_chip": self.wire_bytes_per_chip,
            "collectives": self.collectives,
            "model_flops": self.model_flops,
            "bytes_per_chip_hbm": self.bytes_per_chip_hbm,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu": self.mfu,
        }


# ---------------------------------------------------------------------------
# Loop-aware accounting (EXPERIMENTS.md §Roofline methodology)
#
# XLA's cost_analysis counts while-loop bodies ONCE (verified empirically:
# scan(10x matmul) reports 1x matmul FLOPs). All per-depth cost is affine in
# the period count P, so two lowers at P=1 and P=2 give exact totals:
#     F(P) = F(1) + (P - 1) * (F(2) - F(1)).
# The attention core (scores/softmax/AV) contains its own inner loops, so the
# extrapolation lowers run with attention_impl="proj_only" and the core is
# added back analytically with the flash-streaming traffic model below.
# ---------------------------------------------------------------------------

# Train factors for the attention core under remat_policy="minimal"
# (batch-dim dots are not saveable -> recomputed in backward):
TRAIN_CORE_FLOPS_FACTOR = 4.0    # fwd 1x + recompute 1x + bwd 2x
TRAIN_CORE_BYTES_FACTOR = 3.5    # fwd 1x + recompute 1x + bwd ~1.5x
Q_BLOCK = 512                    # flash schedule q-block (K/V re-read factor)


def extrapolate(f1: float, f2: float, periods: int) -> float:
    return f1 + (periods - 1) * (f2 - f1)


def attention_core(cfg, shape, kind: str) -> Tuple[float, float]:
    """(flops, bytes) of ONE attention layer's core, global across chips.

    Flash-streaming traffic: Q read + O write once; K/V streamed once per
    q-block. Sliding-window layers only touch the (window + q_block) band.
    """
    b, s = shape.global_batch, shape.seq_len
    if cfg.mla is not None:
        h, kvh = cfg.num_heads, cfg.num_heads
        dqk = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
        dv = cfg.mla.v_head_dim
    else:
        h, kvh = cfg.num_heads, cfg.num_kv_heads
        dqk = dv = cfg.head_dim_
    kv_len = s if kind != "local" or not cfg.sliding_window \
        else min(s, cfg.sliding_window + Q_BLOCK)
    # FLOPs: QK^T + AV (the blockwise schedule computes all tiles, masked).
    flops = 2.0 * b * s * kv_len * h * (dqk + dv)
    nq = max(1, s // Q_BLOCK)
    dt = 2  # bf16
    q_o = b * s * h * (dqk + dv) * dt
    kv = b * kv_len * kvh * (dqk + dv) * dt * nq
    byts = q_o + kv
    if shape.kind == "train":
        flops *= TRAIN_CORE_FLOPS_FACTOR
        byts *= TRAIN_CORE_BYTES_FACTOR
    return flops, byts


def core_totals(cfg, shape) -> Tuple[float, float]:
    """Analytic attention-core (flops, bytes) for the whole stack, global."""
    flops = byts = 0.0
    per_period = list(cfg.block_pattern)
    periods = (cfg.num_layers - cfg.first_k_dense) // len(per_period)
    layers = [(per_period[0][0])] * cfg.first_k_dense
    for _ in range(periods):
        layers.extend(m for m, _ in per_period)
    if cfg.is_encdec:
        layers.extend(["attn"] * cfg.encoder_layers)  # enc self-attn
        layers.extend(["attn"] * cfg.num_layers)      # dec cross-attn
    for kind in layers:
        if kind in ("attn", "local"):
            f, by = attention_core(cfg, shape, kind)
            flops += f
            byts += by
    return flops, byts


def model_flops(cfg, shape) -> float:
    """6*N*D for training; 2*N*D for a forward-only step (prefill/decode)."""
    counts = cfg.param_counts()
    n_active = counts["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def build(arch: str, shape, mesh_name: str, chips: int, cfg,
          cost: Dict, hlo_text: str, peak_bytes: Optional[float]) -> Roofline:
    coll = collective_bytes(hlo_text)
    return Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops_per_chip=float(cost.get("flops", 0.0)),
        hlo_bytes_per_chip=float(cost.get("bytes accessed", 0.0)),
        wire_bytes_per_chip=float(sum(coll.values())),
        collectives=coll,
        model_flops=model_flops(cfg, shape),
        bytes_per_chip_hbm=float(peak_bytes or 0.0),
    )
