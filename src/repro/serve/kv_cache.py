"""Paged KV cache: a page pool + per-sequence descriptor chains (§II-B as a
block table). One page = one descriptor: `src` = page id in the pool,
`next` links the sequence's pages, end-of-chain = -1. The allocator owns
placement, so chains are laid out sequentially when possible — making the
hardware's sequential speculation hit by construction (DESIGN.md §2).

Page *moves* (defragmentation, migration) are descriptor work and go
through the multi-channel DMA runtime (DESIGN.md §3): the pool registers
its page arrays as runtime pools and submits row-move chains instead of
calling execution engines directly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chain import from_pages
from repro.core.descriptor import DescriptorArray
from repro.core.prefetch import estimate_hit_rate
from repro.runtime import DMARuntime, SubmitRequest


class OutOfPages(RuntimeError):
    pass


@dataclasses.dataclass
class PageAllocator:
    """Free-list page allocator with sequential-preference placement."""

    num_pages: int

    def __post_init__(self):
        self._free = list(range(self.num_pages))
        self._owned: Dict[int, List[int]] = {}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, seq_id: int, n: int = 1) -> List[int]:
        if n > len(self._free):
            raise OutOfPages(f"need {n} pages, have {len(self._free)}")
        # Sequential preference: take the longest run of consecutive ids so
        # a hardware speculator prefetching page k+1 after page k would hit.
        self._free.sort()
        pages = self._free[:n]
        self._free = self._free[n:]
        self._owned.setdefault(seq_id, []).extend(pages)
        return pages

    def free(self, seq_id: int) -> None:
        self._free.extend(self._owned.pop(seq_id, []))

    def chain(self, seq_id: int, page_elems: int) -> DescriptorArray:
        """The sequence's block table as a descriptor chain."""
        return from_pages(self._owned.get(seq_id, []), page_elems)

    def speculation_hit_rate(self, seq_id: int, page_bytes: int = 32) -> float:
        pages = self._owned.get(seq_id, [])
        addrs = np.asarray(pages, np.int64) * page_bytes
        return estimate_hit_rate(addrs) if len(pages) > 1 else 1.0


@dataclasses.dataclass
class PagedKVCache:
    """Single-layer paged pool, shared across sequences.

    k_pages/v_pages: (num_pages, page, KV, D). Block tables are dense
    (max_seqs, max_pages) int32 snapshots of the descriptor chains, i.e. the
    flattened form the Pallas kernel consumes.
    """

    page: int
    num_pages: int
    max_seqs: int
    max_pages_per_seq: int
    kv_heads: int
    head_dim: int
    dtype: jnp.dtype = jnp.float32

    def __post_init__(self):
        shape = (self.num_pages, self.page, self.kv_heads, self.head_dim)
        self.k_pages = jnp.zeros(shape, self.dtype)
        self.v_pages = jnp.zeros(shape, self.dtype)
        self.tables = np.full((self.max_seqs, self.max_pages_per_seq), -1,
                              np.int32)
        self.lengths = np.zeros((self.max_seqs,), np.int32)
        self.alloc = PageAllocator(self.num_pages)

    # -- sequence lifecycle ---------------------------------------------------
    def admit(self, slot: int) -> None:
        self.evict(slot)
        self.tables[slot] = -1
        self.lengths[slot] = 0

    def evict(self, slot: int) -> None:
        self.alloc.free(slot)
        self.tables[slot] = -1
        self.lengths[slot] = 0

    def append(self, slot: int, k: jax.Array, v: jax.Array) -> None:
        """Append one token's KV (KV, D) to `slot`'s chain."""
        pos = int(self.lengths[slot])
        page_idx, offset = divmod(pos, self.page)
        if page_idx >= self.max_pages_per_seq:
            raise OutOfPages(f"sequence exceeds {self.max_pages_per_seq} pages")
        if self.tables[slot, page_idx] < 0:
            (page_id,) = self.alloc.alloc(slot, 1)
            self.tables[slot, page_idx] = page_id
        pid = int(self.tables[slot, page_idx])
        self.k_pages = self.k_pages.at[pid, offset].set(k)
        self.v_pages = self.v_pages.at[pid, offset].set(v)
        self.lengths[slot] = pos + 1

    # -- kernel-facing views ---------------------------------------------------
    def kernel_args(self) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
        return (self.k_pages, self.v_pages,
                jnp.asarray(self.tables), jnp.asarray(self.lengths))

    def chain(self, slot: int) -> DescriptorArray:
        pages = [int(p) for p in self.tables[slot] if p >= 0]
        return from_pages(pages, self.page * self.kv_heads * self.head_dim)

    # -- runtime-mediated page moves (DESIGN.md §3) ---------------------------
    _POOL_K = "kv.k_pages"
    _POOL_V = "kv.v_pages"

    def register_with_runtime(self, rt: DMARuntime) -> None:
        """Expose the page arrays as runtime pools (idempotent refresh)."""
        rt.register_pool(self._POOL_K, self.k_pages)
        rt.register_pool(self._POOL_V, self.v_pages)

    def move_pages(self, rt: DMARuntime, src_pages: List[int],
                   dst_pages: List[int], *,
                   channel: Optional[str] = None) -> None:
        """Relocate whole pages through the runtime (no direct engine call).

        Submits one row-move chain per pool (K and V) on a ``blocked_2d``
        channel, drains the runtime, and refreshes the local arrays from
        the runtime pools.
        """
        if len(src_pages) != len(dst_pages):
            raise ValueError("src/dst page lists must pair up")
        if not src_pages:
            return
        self.register_with_runtime(rt)
        moves = DescriptorArray.create(
            np.asarray(src_pages, np.int64),
            np.asarray(dst_pages, np.int64),
            np.ones(len(src_pages), np.int64))
        tier = None if channel else "blocked_2d"
        rt.submit(SubmitRequest(chain=moves, src_pool=self._POOL_K,
                                dst_pool=self._POOL_K, channel=channel,
                                tier=tier))
        rt.submit(SubmitRequest(chain=moves, src_pool=self._POOL_V,
                                dst_pool=self._POOL_V, channel=channel,
                                tier=tier))
        rt.drain_until_idle()
        self.k_pages = rt.pool(self._POOL_K)
        self.v_pages = rt.pool(self._POOL_V)

    def defragment(self, slot: int, rt: DMARuntime, *,
                   channel: Optional[str] = None) -> float:
        """Compact `slot`'s pages onto the lowest-id free run and return the
        §II-C speculation hit rate of the new layout.

        The physical copy is descriptor work submitted through the runtime;
        the block table and allocator state are rewired afterwards. A slot
        already on its best layout is left untouched.
        """
        old = [int(p) for p in self.tables[slot] if p >= 0]
        n = len(old)
        if n == 0:
            return 1.0
        free = sorted(self.alloc._free)
        if len(free) < n:
            return self.alloc.speculation_hit_rate(slot)
        new = free[:n]
        new_rate = estimate_hit_rate(np.asarray(new, np.int64) * 32)
        cur_rate = self.alloc.speculation_hit_rate(slot)
        if new_rate <= cur_rate:
            return cur_rate
        self.move_pages(rt, old, new, channel=channel)
        # Rewire bookkeeping: slot now owns `new`; `old` returns to the pool.
        self.alloc._free = [p for p in free if p not in set(new)] + old
        self.alloc._owned[slot] = list(new)
        self.tables[slot, :n] = np.asarray(new, np.int32)
        return new_rate

    def dense_view(self, slot: int) -> Tuple[np.ndarray, np.ndarray]:
        """Materialize the logical (len, KV, D) cache (host-side oracle)."""
        ln = int(self.lengths[slot])
        ks, vs = [], []
        for i in range((ln + self.page - 1) // self.page):
            pid = int(self.tables[slot, i])
            ks.append(np.asarray(self.k_pages[pid]))
            vs.append(np.asarray(self.v_pages[pid]))
        if not ks:
            return (np.zeros((0, self.kv_heads, self.head_dim)),) * 2
        k = np.concatenate(ks)[:ln]
        v = np.concatenate(vs)[:ln]
        return k, v
