"""Paged KV cache: a page pool + per-sequence descriptor chains (§II-B as a
block table). One page = one descriptor: `src` = page id in the pool,
`next` links the sequence's pages, end-of-chain = -1. The allocator owns
placement, so chains are laid out sequentially when possible — making the
hardware's sequential speculation hit by construction (DESIGN.md §2).

Virtual addressing (DESIGN.md §11): sequence block tables hold *virtual*
page ids; a :class:`repro.mmu.PageTable` maps them to physical pool
slots. ``defragment`` is therefore a *remap* — live pages get fresh
dense virtual ids pointing at their existing slots, so the §II-C
speculator sees a sequential chain without a single payload byte
crossing the bus. The legacy copy-defrag survives as ``mode="copy"``
(the A/B leg the remap-vs-copy perf cell measures against).

Page *moves* (migration, copy-defrag) are descriptor work and go
through the multi-channel DMA runtime (DESIGN.md §3): the pool registers
its page arrays as runtime pools and submits row-move chains instead of
calling execution engines directly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chain import from_pages
from repro.core.descriptor import DescriptorArray
from repro.core.pageref import PageRef, as_pagerefs
from repro.core.prefetch import estimate_hit_rate
from repro.mmu import PageTable
from repro.runtime import DMARuntime, SubmitRequest


class OutOfPages(RuntimeError):
    pass


@dataclasses.dataclass
class PageAllocator:
    """Free-list page allocator with sequential-preference placement.

    Allocates *virtual* page ids: the ids sequences hold in their block
    tables and the ids whose contiguity the §II-C speculator exploits.
    """

    num_pages: int

    def __post_init__(self):
        self._free = list(range(self.num_pages))
        self._owned: Dict[int, List[int]] = {}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, seq_id: int, n: int = 1) -> List[int]:
        if n > len(self._free):
            raise OutOfPages(f"need {n} pages, have {len(self._free)}")
        # Sequential preference: take the longest run of consecutive ids so
        # a hardware speculator prefetching page k+1 after page k would hit.
        self._free.sort()
        pages = self._free[:n]
        self._free = self._free[n:]
        self._owned.setdefault(seq_id, []).extend(pages)
        return pages

    def free(self, seq_id: int) -> None:
        self._free.extend(self._owned.pop(seq_id, []))

    def chain(self, seq_id: int, page_elems: int) -> DescriptorArray:
        """The sequence's block table as a descriptor chain (virtual)."""
        return from_pages(self._owned.get(seq_id, []), page_elems)

    def speculation_hit_rate(self, seq_id: int, page_bytes: int = 32) -> float:
        pages = self._owned.get(seq_id, [])
        addrs = np.asarray(pages, np.int64) * page_bytes
        return estimate_hit_rate(addrs) if len(pages) > 1 else 1.0


@dataclasses.dataclass
class PagedKVCache:
    """Single-layer paged pool, shared across sequences.

    k_pages/v_pages: (num_pages, page, KV, D), indexed by *physical*
    slot. Block tables are dense (max_seqs, max_pages) int32 snapshots of
    the descriptor chains in *virtual* ids; :meth:`kernel_args`
    translates them through the page table into the flattened physical
    form the Pallas kernel consumes.
    """

    page: int
    num_pages: int
    max_seqs: int
    max_pages_per_seq: int
    kv_heads: int
    head_dim: int
    dtype: jnp.dtype = jnp.float32

    def __post_init__(self):
        shape = (self.num_pages, self.page, self.kv_heads, self.head_dim)
        self.k_pages = jnp.zeros(shape, self.dtype)
        self.v_pages = jnp.zeros(shape, self.dtype)
        self.tables = np.full((self.max_seqs, self.max_pages_per_seq), -1,
                              np.int32)
        self.lengths = np.zeros((self.max_seqs,), np.int32)
        self.alloc = PageAllocator(self.num_pages)
        self.page_table = PageTable(self.num_pages)
        self._phys_free = list(range(self.num_pages))

    # -- translation ----------------------------------------------------------
    def _slot(self, vid: int) -> int:
        return self.page_table.slot_of(int(vid))

    def pageref(self, vid: int) -> PageRef:
        return PageRef(int(vid), self.page_table.page_generation(int(vid)))

    # -- sequence lifecycle ---------------------------------------------------
    def admit(self, slot: int) -> None:
        self.evict(slot)
        self.tables[slot] = -1
        self.lengths[slot] = 0

    def evict(self, slot: int) -> None:
        # Physical slots go back with their virtual ids: look them up
        # before the allocator forgets the ownership list.
        for v in self.alloc._owned.get(slot, []):
            self._phys_free.append(self._slot(v))
        self._phys_free.sort()
        self.alloc.free(slot)
        self.tables[slot] = -1
        self.lengths[slot] = 0

    def append(self, slot: int, k: jax.Array, v: jax.Array) -> None:
        """Append one token's KV (KV, D) to `slot`'s chain."""
        pos = int(self.lengths[slot])
        page_idx, offset = divmod(pos, self.page)
        if page_idx >= self.max_pages_per_seq:
            raise OutOfPages(f"sequence exceeds {self.max_pages_per_seq} pages")
        if self.tables[slot, page_idx] < 0:
            (page_id,) = self.alloc.alloc(slot, 1)
            phys = self._phys_free.pop(0)
            if self._slot(page_id) != phys:
                self.page_table.remap(page_id, 0, phys)
            self.tables[slot, page_idx] = page_id
        pid = self._slot(int(self.tables[slot, page_idx]))
        self.k_pages = self.k_pages.at[pid, offset].set(k)
        self.v_pages = self.v_pages.at[pid, offset].set(v)
        self.lengths[slot] = pos + 1

    # -- kernel-facing views ---------------------------------------------------
    def kernel_args(self) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
        phys = self.page_table.slots_of(
            self.tables.reshape(-1)).reshape(self.tables.shape)
        return (self.k_pages, self.v_pages,
                jnp.asarray(phys, jnp.int32), jnp.asarray(self.lengths))

    def chain(self, slot: int) -> DescriptorArray:
        """`slot`'s block table as a *virtual* descriptor chain — the
        layout the speculator sees; lower through
        :func:`repro.runtime.lowering.translate_chain` to execute."""
        pages = [int(p) for p in self.tables[slot] if p >= 0]
        return from_pages(pages, self.page * self.kv_heads * self.head_dim)

    # -- runtime-mediated page moves (DESIGN.md §3) ---------------------------
    _POOL_K = "kv.k_pages"
    _POOL_V = "kv.v_pages"

    def register_with_runtime(self, rt: DMARuntime) -> None:
        """Expose the page arrays as runtime pools (idempotent refresh)."""
        rt.register_pool(self._POOL_K, self.k_pages)
        rt.register_pool(self._POOL_V, self.v_pages)

    def move_pages(self, rt: DMARuntime, src_pages: List[PageRef],
                   dst_pages: List[PageRef], *,
                   channel: Optional[str] = None) -> None:
        """Copy page *contents* between virtual pages through the runtime.

        Submits one row-move chain per pool (K and V) on a ``blocked_2d``
        channel — addressed physically via the page table — drains the
        runtime, and refreshes the local arrays from the runtime pools.
        """
        if len(src_pages) != len(dst_pages):
            raise ValueError("src/dst page lists must pair up")
        if not src_pages:
            return
        src_pages = as_pagerefs(src_pages, api="PagedKVCache.move_pages")
        dst_pages = as_pagerefs(dst_pages, api="PagedKVCache.move_pages")
        self._move_phys(rt, [self._slot(p) for p in src_pages],
                        [self._slot(p) for p in dst_pages], channel=channel)

    def _move_phys(self, rt: DMARuntime, src: List[int], dst: List[int],
                   *, channel: Optional[str] = None) -> None:
        self.register_with_runtime(rt)
        moves = DescriptorArray.create(
            np.asarray(src, np.int64),
            np.asarray(dst, np.int64),
            np.ones(len(src), np.int64))
        tier = None if channel else "blocked_2d"
        rt.submit(SubmitRequest(chain=moves, src_pool=self._POOL_K,
                                dst_pool=self._POOL_K, channel=channel,
                                tier=tier))
        rt.submit(SubmitRequest(chain=moves, src_pool=self._POOL_V,
                                dst_pool=self._POOL_V, channel=channel,
                                tier=tier))
        rt.drain_until_idle()
        self.k_pages = rt.pool(self._POOL_K)
        self.v_pages = rt.pool(self._POOL_V)

    def defragment(self, slot: int, rt: Optional[DMARuntime] = None, *,
                   channel: Optional[str] = None,
                   mode: str = "remap") -> float:
        """Compact `slot`'s pages onto the lowest-id free run and return the
        §II-C speculation hit rate of the new layout.

        ``mode="remap"`` (default): the live pages keep their physical
        slots; they are *renumbered* onto fresh dense virtual ids — a
        page-table update, no descriptor chain, no payload traffic.
        ``mode="copy"`` is the legacy physical compaction (descriptor
        work through the runtime, which it then requires). Both modes
        leave identical logical pool contents (the ``tests/test_mmu.py``
        oracle); a slot already on its best layout is left untouched.
        """
        if mode not in ("remap", "copy"):
            raise ValueError(f"mode must be 'remap' or 'copy', got {mode!r}")
        old = [int(p) for p in self.tables[slot] if p >= 0]
        n = len(old)
        if n == 0:
            return 1.0
        free = sorted(self.alloc._free)
        if len(free) < n:
            return self.alloc.speculation_hit_rate(slot)
        new = free[:n]
        new_rate = estimate_hit_rate(np.asarray(new, np.int64) * 32)
        cur_rate = self.alloc.speculation_hit_rate(slot)
        if new_rate <= cur_rate:
            return cur_rate
        if mode == "remap":
            # Renumber: new vid i -> old vid i's physical slot. Contents
            # never move; the old vids return to the virtual free pool.
            for nv, ov in zip(new, old):
                self.page_table.remap(nv, 0, self._slot(ov))
        else:
            if rt is None:
                raise ValueError("mode='copy' needs a runtime")
            # Legacy compaction: contents physically move onto the lowest
            # free slots, and the new vids map onto those slots.
            dst_phys = sorted(self._phys_free)[:n]
            self._move_phys(rt, [self._slot(ov) for ov in old], dst_phys,
                            channel=channel)
            for nv, ph in zip(new, dst_phys):
                if self._slot(nv) != ph:
                    self.page_table.remap(nv, 0, ph)
                self._phys_free.remove(ph)
            # The vacated source slots are free again.
            self._phys_free.extend(self._slot(ov) for ov in old)
            self._phys_free.sort()
        # Rewire bookkeeping: slot now owns `new`; `old` returns to the pool.
        self.alloc._free = [p for p in free if p not in set(new)] + old
        self.alloc._owned[slot] = list(new)
        self.tables[slot, :n] = np.asarray(new, np.int32)
        return new_rate

    def dense_view(self, slot: int) -> Tuple[np.ndarray, np.ndarray]:
        """Materialize the logical (len, KV, D) cache (host-side oracle)."""
        ln = int(self.lengths[slot])
        ks, vs = [], []
        for i in range((ln + self.page - 1) // self.page):
            pid = self._slot(int(self.tables[slot, i]))
            ks.append(np.asarray(self.k_pages[pid]))
            vs.append(np.asarray(self.v_pages[pid]))
        if not ks:
            return (np.zeros((0, self.kv_heads, self.head_dim)),) * 2
        k = np.concatenate(ks)[:ln]
        v = np.concatenate(vs)[:ln]
        return k, v
